#!/usr/bin/env bash
# Tier-1 gate: format, build, test. Run from the repo root.
# Artifact-backed tests skip themselves when rust/artifacts is absent,
# so this is meaningful on a fresh checkout.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q
