#!/usr/bin/env bash
# Tier-1 gate: format, build, test. Run from the repo root.
#
# Since the pure-Rust reference backend landed, the engine, coordinator
# and server integration tests run UNCONDITIONALLY (seeded toy model, no
# artifacts needed); only the XLA-specific variants still skip themselves
# when rust/artifacts is absent.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== ref-backend suite must stay un-gated =="
# the artifact-free suites may never regress to #[ignore]
# (attribute position only — doc comments may mention the attribute)
if grep -rn '^\s*#\[ignore' tests/ src/; then
  echo "error: #[ignore] found — the ref-backend suites must run unconditionally" >&2
  exit 1
fi
# the golden fixtures are committed (per-case checks live in
# tests/golden.rs, which hard-fails on any missing/unreadable fixture)
if ! ls tests/golden/*.cbt >/dev/null 2>&1; then
  echo "error: tests/golden/*.cbt missing — run 'python -m compile.export_golden' from python/" >&2
  exit 1
fi

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
# runs everything, including the artifact-free ref-backend integration
# suites (tests/{integration,paged,golden,ref_backend}.rs) — on a fresh
# checkout the full engine/coordinator/server stack executes here
cargo test -q

echo "== cargo test -q, CHAI_THREADS=3 (worker-pool race shake) =="
# the whole suite again with every engine's kernel pool forced to 3
# threads: the kernels partition only over independent output slices,
# so every test must pass bit-for-bit at any pool size — this run
# shakes out data races and partitioning mistakes the serial default
# cannot see
CHAI_THREADS=3 cargo test -q

echo "== parallel-kernel gate: decode burst, worker pool vs --threads 1 (ref backend) =="
# parallel contract: a same-instant burst of distinct prompts decodes
# with bit-identical token streams --threads 1 vs the auto-sized pool,
# the pool actually fires (pool_tasks > 0), and pool tok/s is strictly
# above serial on multi-core runners (>= 1.8x on >= 4 cores); merges a
# "parallel" section into bench_results/BENCH_serving.json
cargo bench --bench bench_serving -- --backend ref --parallel

echo "== serving smoke: batched block-native vs sequential bucket decode (ref backend) =="
# smoke (no absolute-perf thresholds): asserts identical token streams,
# zero decode-path bucket copies, and batched tok/s strictly above the
# sequential path; writes bench_results/BENCH_serving.json
cargo bench --bench bench_serving -- --backend ref --smoke

echo "== relay decode gate: shared-prefix burst, relay groups vs fused rows (ref backend) =="
# relay contract: a burst sharing a >= 4-block system prompt decodes
# with bit-identical token streams relay-on vs --no-relay, relay tok/s
# strictly above fused, and the relay counters firing (relay_groups,
# relay_prefix_tokens_saved > 0); merges a "relay" section into
# bench_results/BENCH_serving.json
cargo bench --bench bench_serving -- --backend ref --relay

echo "== serving overload smoke: preempt-and-requeue under an over-capacity burst (ref backend) =="
# overload contract: zero dropped requests, bounded p99 queue wait, and
# both preemption flavors exercised (swap-out with a roomy spill tier,
# recompute-on-resume with the tier disabled); merges an "overload"
# section into bench_results/BENCH_serving.json
cargo bench --bench bench_serving -- --backend ref --overload

echo "== router smoke: 4 replicas vs 1, placement transparency, prefix-affinity hit rate (ref backend) =="
# router contract: 4-replica aggregate tok/s strictly above 1-replica on
# the burst workload (skipped on single-core runners), token streams
# bit-identical across replica counts and all routing policies, and the
# prefix-affinity policy beating round-robin's prefix-cache hit rate on
# a shared-system-prompt workload; merges a "router" section into
# bench_results/BENCH_serving.json
cargo bench --bench bench_serving -- --backend ref --replicas

echo "== ring buffers vs Mutex<VecDeque>: SPSC/MPSC microbench =="
# shape-only (no absolute thresholds): throughput of the net
# subsystem's lock-free rings next to a locked deque on the same
# bounded workload; writes bench_results/BENCH_ringbuf.json
cargo bench --bench bench_ringbuf

echo "== front-end fan-out gate: 1k+ streams, thread-per-conn vs epoll reactor (ref backend) =="
# event-driven front-end contract (Linux; self-skips elsewhere): both
# transports serve the identical streaming workload off one
# coordinator — bit-identical per-connection token streams, zero error
# terminals, reactor p99 TTFT no worse at 8 connections and strictly
# better at 1k+ where thread-per-connection pays for stacks and poll
# wakeups; merges a "connections" section into
# bench_results/BENCH_serving.json
cargo bench --bench bench_serving -- --backend ref --connections

echo "== failover drill: SIGKILL one of 4 replica processes mid-decode (ref backend) =="
# replica-mesh contract (Linux; self-skips elsewhere): 4 `chai replica`
# child processes behind the router, a streaming burst, kill -9 the
# busiest replica — zero accepted requests lost, every stream
# exactly-once and bit-identical to a single-engine oracle on the
# survivors; merges a "failover" section into
# bench_results/BENCH_serving.json
cargo bench --bench bench_serving -- --backend ref --failover

echo "== observability gate: obs-on vs --no-obs decode burst, trace coverage (ref backend) =="
# observability contract: token streams bit-identical obs-on vs
# --no-obs, obs-on tok/s >= 0.98x obs-off (the <= 2% overhead budget),
# and the drained Chrome trace covers >= 99% of submitted requests
# (distinct queue-span trace ids); writes bench_results/obs_trace.json
# and merges an "obs" section into bench_results/BENCH_serving.json
cargo bench --bench bench_serving -- --backend ref --obs

echo "== streaming + cancellation example client (ref backend) =="
# examples/stream_cancel.rs: spins a 2-replica router + TCP server,
# streams a generation frame-by-frame, then cancels one mid-decode and
# checks the terminal cancelled line + clean pool
cargo run --release --example stream_cancel

echo "== golden fixtures match the python oracles (when jax is available) =="
if python3 -c "import jax" >/dev/null 2>&1; then
  (cd ../python && python3 -m pytest -q tests/test_golden_export.py)
else
  echo "jax not available — skipping python-side golden regeneration diff"
fi
