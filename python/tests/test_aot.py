"""AOT exporter smoke tests: lowering produces parseable HLO text, the
manifest records faithful shapes, and the offline clustering pipeline
yields a consistent clusters blob. Uses a 2-layer config for speed."""

import json
import os

import numpy as np
import jax
import pytest

from compile import model as M
from compile.aot import Exporter, offline_clusters, to_hlo_text, uniform_clusters
from compile.configs import ModelConfig, PROBE_BUCKET


CFG = ModelConfig(n_layers=2, init_head_groups=(4, 2))


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_to_hlo_text_is_parseable_hlo(params):
    import jax.numpy as jnp
    fn = lambda t, ln: M.logprob_mha_graph(params, CFG, t, ln)
    low = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((8,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32))
    text = to_hlo_text(low)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # no ops the 0.5.1 parser rejects
    assert "topk(" not in text


def test_exporter_writes_artifact_and_manifest(tmp_path, params):
    ex = Exporter(CFG, params, str(tmp_path), "jnp")
    entry = ex.lower(
        "probe_test",
        lambda wlist, tok, ln: (M.probe_graph(
            dict(zip(ex.weight_names, wlist)), CFG, tok, ln),),
        [("tokens", np.zeros(PROBE_BUCKET, np.int32)),
         ("length", np.int32(0))],
        ["probe_maps"], {"bucket": PROBE_BUCKET})
    assert (tmp_path / "probe_test.hlo.txt").exists()
    assert entry["outputs"][0]["shape"] == [CFG.n_layers, CFG.n_heads,
                                            PROBE_BUCKET, PROBE_BUCKET]
    assert entry["inputs"][0]["shape"] == [PROBE_BUCKET]
    assert ex.manifest["artifacts"][0]["name"] == "probe_test"
    assert ex.manifest["weight_order"] == sorted(
        ex.manifest["weight_order"])


def test_exporter_rejects_output_name_mismatch(tmp_path, params):
    ex = Exporter(CFG, params, str(tmp_path), "jnp")
    with pytest.raises(AssertionError):
        ex.lower(
            "bad",
            lambda wlist, tok, ln: (M.probe_graph(
                dict(zip(ex.weight_names, wlist)), CFG, tok, ln),),
            [("tokens", np.zeros(PROBE_BUCKET, np.int32)),
             ("length", np.int32(0))],
            ["a", "b"])  # 2 names for 1 output


def test_offline_clusters_blob(tmp_path, params):
    blob = offline_clusters(CFG, params, str(tmp_path), n_samples=4)
    assert len(blob["k_list"]) == CFG.n_layers
    for layer in blob["layers"]:
        assert len(layer["membership"]) == CFG.n_heads
        assert max(layer["membership"]) < layer["k"]
        assert len(layer["reps"]) == layer["k"]
        assert layer["errors"][0] >= layer["errors"][-1]
    # file written and reloadable
    on_disk = json.load(open(os.path.join(tmp_path, "clusters.json")))
    assert on_disk["k_list"] == blob["k_list"]


def test_uniform_clusters_shape():
    kl, mem, reps = uniform_clusters(CFG, 4)
    assert kl == [4] * CFG.n_layers
    assert len(mem) == CFG.n_heads
    assert max(mem) == 3
    assert len(reps) == 4


def test_built_manifest_consistent_with_files():
    """If the real artifacts exist, every manifest entry's file exists and
    the weight order covers weights.cbt exactly."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    m = json.load(open(mpath))
    for a in m["artifacts"]:
        assert os.path.exists(os.path.join(art, a["path"])), a["path"]
    from compile import tensorio
    weights = tensorio.load(os.path.join(art, "weights.cbt"))
    assert sorted(weights.keys()) == m["weight_order"]
    assert m["k_list"] == json.load(open(os.path.join(art, "clusters.json")))["k_list"]
