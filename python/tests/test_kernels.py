"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/offsets per the repro mandate: the kernel
is the paper's hot path, so this is the core numeric signal.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import chai, mha, ref

RTOL, ATOL = 1e-5, 1e-5


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def case(seed, h, k, tq, tk, dh, offset, length):
    rng = np.random.default_rng(seed)
    q = rand(rng, h, tq, dh)
    kk = rand(rng, h, tk, dh)
    v = rand(rng, h, tk, dh)
    mem = jnp.asarray(rng.integers(0, k, size=h), jnp.int32)
    return q, kk, v, mem


# ---------------------------------------------------------------------------
# Dense MHA kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    h=st.sampled_from([1, 2, 8, 16]),
    tq=st.sampled_from([1, 2, 8, 16, 32]),
    tk=st.sampled_from([8, 16, 32, 64]),
    dh=st.sampled_from([4, 8, 16]),
    data=st.data(),
)
def test_mha_matches_ref(seed, h, tq, tk, dh, data):
    if tq > tk:
        tq = tk
    offset = data.draw(st.integers(0, tk - tq))
    length = data.draw(st.integers(1, tk))
    q, k, v, _ = case(seed, h, 1, tq, tk, dh, offset, length)
    o_ref, p_ref = ref.mha_attention_ref(q, k, v, offset, length)
    o, p = mha.mha_attention(q, k, v, offset, length, with_probs=True)
    np.testing.assert_allclose(o, o_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(p, p_ref, rtol=RTOL, atol=ATOL)


def test_mha_no_probs_variant():
    q, k, v, _ = case(0, 4, 1, 16, 16, 8, 0, 16)
    o = mha.mha_attention(q, k, v, 0, 16)
    o_ref, _ = ref.mha_attention_ref(q, k, v, 0, 16)
    np.testing.assert_allclose(o, o_ref, rtol=RTOL, atol=ATOL)


def test_mha_block_q_tiling():
    """Result must be invariant to the query-block size."""
    q, k, v, _ = case(3, 2, 1, 64, 64, 8, 0, 64)
    base = mha.mha_attention(q, k, v, 0, 64, block_q=64)
    for bq in (8, 16, 32, 128):
        o = mha.mha_attention(q, k, v, 0, 64, block_q=bq)
        np.testing.assert_allclose(o, base, rtol=RTOL, atol=ATOL)


def test_mha_probs_are_row_stochastic_and_causal():
    q, k, v, _ = case(1, 4, 1, 16, 16, 8, 0, 12)
    _, p = mha.mha_attention(q, k, v, 0, 12, with_probs=True)
    p = np.array(p)
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)
    for i in range(15):
        lo = max(i + 1, 12)
        if lo < 16:
            assert p[:, i, lo:].max() <= 1e-6  # causal+length mask


# ---------------------------------------------------------------------------
# CHAI clustered kernels
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    h=st.sampled_from([2, 8, 16]),
    k=st.integers(1, 8),
    tq=st.sampled_from([1, 8, 16]),
    tk=st.sampled_from([16, 32, 64]),
    dh=st.sampled_from([4, 8]),
    data=st.data(),
)
def test_clustered_matches_ref(seed, h, k, tq, tk, dh, data):
    k = min(k, h)
    offset = data.draw(st.integers(0, tk - tq))
    length = data.draw(st.integers(1, tk))
    q, kk, v, mem = case(seed, h, k, tq, tk, dh, offset, length)
    q_rep, k_rep = q[:k], kk[:k]
    o_ref, p_ref = ref.clustered_attention_ref(q_rep, k_rep, v, mem,
                                               offset, length)
    o, p = chai.clustered_attention(q_rep, k_rep, v, mem, offset, length)
    np.testing.assert_allclose(o, o_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(p, p_ref, rtol=RTOL, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 8))
def test_clustered_qkv_matches_ref(seed, k):
    h, tq, tk, dh = 16, 8, 32, 8
    q, kk, v, mem = case(seed, h, k, tq, tk, dh, 0, tk)
    reps = jnp.arange(k, dtype=jnp.int32)
    q_rep, k_rep = q[:k], kk[:k]
    o_ref, p_ref = ref.clustered_attention_qkv_ref(q_rep, k_rep, v, mem,
                                                   reps, 0, tk)
    p = chai.clustered_scores(q_rep, k_rep, 0, tk)
    o = chai.broadcast_av_qkv(p, v[reps], mem)
    np.testing.assert_allclose(p, p_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(o, o_ref, rtol=RTOL, atol=ATOL)


def test_chai_identity_clustering_equals_mha():
    """k = H with identity membership must reproduce dense MHA exactly —
    the paper's claim that CHAI is a pure redundancy elimination."""
    h, tq, tk, dh = 8, 16, 16, 8
    rng = np.random.default_rng(0)
    q, k, v = (rand(rng, h, tq, dh) for _ in range(3))
    mem = jnp.arange(h, dtype=jnp.int32)
    o_mha = mha.mha_attention(q, k, v, 0, tk)
    o_chai, _ = chai.clustered_attention(q, k, v, mem, 0, tk)
    np.testing.assert_allclose(o_chai, o_mha, rtol=RTOL, atol=ATOL)


def test_chai_single_cluster_all_heads_share_scores():
    h, tq, tk, dh = 8, 4, 16, 8
    rng = np.random.default_rng(1)
    q, k, v = (rand(rng, h, tq, dh) for _ in range(3))
    mem = jnp.zeros(h, jnp.int32)
    out, probs = chai.clustered_attention(q[:1], k[:1], v, mem, 0, tk)
    # every head output = probs[0] @ v[h]
    for hh in range(h):
        np.testing.assert_allclose(
            out[hh], np.array(probs[0]) @ np.array(v[hh]),
            rtol=RTOL, atol=ATOL)


def test_clustered_scores_padded_region_masked():
    """Keys beyond `length` must receive zero probability."""
    q, k, _, _ = case(2, 4, 1, 8, 32, 8, 24, 20)
    p = np.array(chai.clustered_scores(q[:4], k[:4], 24, 20))
    assert p[:, :, 20:].max() <= 1e-6
