"""Training-loop tests: tied reparametrization, gradient masking, smoke
convergence. Uses tiny configs so each test runs in seconds."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import data
from compile.configs import ModelConfig, TrainConfig, model_config
from compile.model import head_group_of
from compile.train import (adamw_init, adamw_update, clip_grads, lr_at,
                           materialize, pack_corpus, tied_init, train_step,
                           loss_fn)
from compile import model as M


TINY = ModelConfig(n_layers=2, init_head_groups=(4, 2))


def test_tied_init_shapes():
    tr, st = tied_init(TINY, jax.random.PRNGKey(0))
    assert tr["l0.qbase"].shape == (4, TINY.d_model, TINY.head_dim)
    assert tr["l1.kbase"].shape == (2, TINY.d_model, TINY.head_dim)
    assert "l0.wq" not in tr  # replaced by bases
    assert "emb" in tr
    p = materialize(tr, st, TINY)
    assert p["l0.wq"].shape == (TINY.d_model, TINY.n_heads * TINY.head_dim)


def test_materialized_groups_are_near_identical():
    tr, st = tied_init(TINY, jax.random.PRNGKey(1))
    p = materialize(tr, st, TINY)
    wq = np.asarray(p["l1.wq"]).reshape(TINY.d_model, TINY.n_heads,
                                        TINY.head_dim)
    g = TINY.init_head_groups[1]
    for h in range(1, TINY.n_heads):
        c = np.corrcoef(wq[:, 0].ravel(), wq[:, h].ravel())[0, 1]
        if head_group_of(h, TINY.n_heads, g) == head_group_of(0, TINY.n_heads, g):
            assert c > 0.99, f"head {h} same group but corr {c}"
        else:
            assert c < 0.5, f"head {h} different group but corr {c}"


def test_opt_uniform_heads_frozen_through_updates():
    cfg = model_config("opt")
    cfg = ModelConfig(**{**cfg.__dict__, "n_layers": 2})
    tr, st = tied_init(cfg, jax.random.PRNGKey(0))
    p0 = materialize(tr, st, cfg)
    wv0 = np.asarray(p0["l0.wv"]).reshape(cfg.d_model, cfg.n_heads,
                                          cfg.head_dim)
    # uniform heads' V must start exactly zero
    assert np.abs(wv0[:, cfg.n_heads - cfg.uniform_heads:, :]).max() == 0.0


def test_adamw_moves_params_and_decays():
    tc = TrainConfig(steps=10, warmup=1)
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    grads = {"w": jnp.ones((4,))}
    new, opt = adamw_update(params, grads, opt, 0.1, tc)
    assert (np.asarray(new["w"]) < 1.0).all()
    assert int(opt["t"]) == 1


def test_clip_grads_bounds_norm():
    grads = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_grads(grads, 1.0)
    total = float(jnp.sqrt(sum(jnp.sum(jnp.square(g))
                               for g in jax.tree.leaves(clipped))))
    assert total <= 1.0 + 1e-5
    assert float(norm) > 100.0


def test_lr_schedule_warmup_and_decay():
    tc = TrainConfig(steps=100, warmup=10, lr=1e-3)
    assert float(lr_at(0, tc)) < float(lr_at(9, tc))
    assert float(lr_at(99, tc)) < float(lr_at(50, tc))
    assert float(lr_at(9, tc)) == pytest.approx(1e-3, rel=1e-5)


def test_smoke_training_reduces_loss():
    cfg = ModelConfig(n_layers=2, init_head_groups=(4, 2))
    tc = TrainConfig(steps=8, batch_size=4, seq_len=32, corpus_docs=80,
                     warmup=2)
    w = data.build_world()
    rng = np.random.default_rng(0)
    chunks = pack_corpus(data.corpus_docs(w, tc.corpus_docs), tc.seq_len, rng)
    tr, st = tied_init(cfg, jax.random.PRNGKey(0))
    mask = jax.tree.map(jnp.ones_like, tr)
    opt = adamw_init(tr)
    losses = []
    for step in range(tc.steps):
        idx = rng.integers(0, len(chunks), tc.batch_size)
        batch = jnp.asarray(chunks[idx])
        tr, opt, loss, _ = train_step(tr, st, opt, batch,
                                      jnp.asarray(step), mask, cfg, tc)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses).all()


def test_loss_fn_matches_manual_xent():
    cfg = ModelConfig(n_layers=1)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = jnp.asarray(np.arange(10)[None, :] % 250, jnp.int32)
    loss = float(loss_fn(p, cfg, batch))
    logits = M.forward_train(p, cfg, batch[:, :-1])
    logp = jax.nn.log_softmax(logits, -1)
    manual = -float(np.mean([logp[0, i, batch[0, i + 1]]
                             for i in range(9)]))
    assert loss == pytest.approx(manual, rel=1e-5)
