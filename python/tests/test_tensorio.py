"""`.cbt` format roundtrip + layout contract (mirrored by rust tests)."""

import struct

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import tensorio


def test_roundtrip_basic(tmp_path):
    p = str(tmp_path / "t.cbt")
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.array([1, -2, 3], dtype=np.int32),
        "scalarish": np.array(7.5, dtype=np.float32),
    }
    tensorio.save(p, tensors)
    out = tensorio.load(p)
    assert set(out) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 5),
    seed=st.integers(0, 999),
)
def test_roundtrip_random(tmp_path_factory, n, seed):
    rng = np.random.default_rng(seed)
    tensors = {}
    for i in range(n):
        shape = tuple(int(s) for s in rng.integers(1, 6, rng.integers(1, 4)))
        if rng.random() < 0.5:
            tensors[f"t{i}"] = rng.normal(size=shape).astype(np.float32)
        else:
            tensors[f"t{i}"] = rng.integers(-100, 100, shape).astype(np.int32)
    p = str(tmp_path_factory.mktemp("cbt") / "r.cbt")
    tensorio.save(p, tensors)
    out = tensorio.load(p)
    for k, v in tensors.items():
        np.testing.assert_array_equal(out[k], v)


def test_header_layout(tmp_path):
    p = str(tmp_path / "h.cbt")
    tensorio.save(p, {"x": np.zeros((2, 2), np.float32)})
    blob = open(p, "rb").read()
    assert blob[:4] == b"CBT1"
    (hlen,) = struct.unpack("<I", blob[4:8])
    import json
    hdr = json.loads(blob[8:8 + hlen])
    e = hdr["tensors"][0]
    assert e["name"] == "x" and e["dtype"] == "f32"
    assert e["shape"] == [2, 2] and e["nbytes"] == 16
    assert e["offset"] % 64 == 0


def test_f64_i64_coerced(tmp_path):
    p = str(tmp_path / "c.cbt")
    tensorio.save(p, {"a": np.ones(3, np.float64), "b": np.ones(3, np.int64)})
    out = tensorio.load(p)
    assert out["a"].dtype == np.float32
    assert out["b"].dtype == np.int32


def test_bad_magic_rejected(tmp_path):
    p = str(tmp_path / "bad.cbt")
    open(p, "wb").write(b"NOPE" + b"\0" * 16)
    with pytest.raises(ValueError):
        tensorio.load(p)


def test_unsupported_dtype_rejected(tmp_path):
    with pytest.raises(ValueError):
        tensorio.save(str(tmp_path / "x.cbt"),
                      {"c": np.ones(2, np.complex64)})
