"""Synthetic corpus / eval-suite generators + tokenizer tests."""

import numpy as np

from compile import data, tokenizer


def test_tokenizer_roundtrip():
    for t in ["hello world", "the color of tom is red .", ""]:
        ids = tokenizer.encode(t, bos=True, eos=True)
        assert ids[0] == tokenizer.BOS and ids[-1] == tokenizer.EOS
        assert tokenizer.decode(ids) == t
        assert all(0 <= i < tokenizer.VOCAB_SIZE for i in ids)


def test_world_deterministic_and_consistent():
    a, b = data.build_world(), data.build_world()
    assert a.color == b.color and a.friend == b.friend
    for n in data.NAMES:
        assert a.friend[n] != n
        assert a.friend[n] in data.NAMES


def test_corpus_docs_reproducible_and_nonempty():
    w = data.build_world()
    d1 = data.corpus_docs(w, 50, seed=7)
    d2 = data.corpus_docs(w, 50, seed=7)
    assert d1 == d2
    assert all(len(x) > 10 for x in d1)
    assert d1 != data.corpus_docs(w, 50, seed=8)


def test_eval_suites_structure():
    w = data.build_world()
    suites = data.eval_suites(w)
    assert set(suites) == {"piqa-syn", "hellaswag-syn", "arc-challenge-syn",
                           "arc-easy-syn", "boolq-syn"}
    for name, items in suites.items():
        assert len(items) >= 24
        n_choices = 2 if name in ("piqa-syn", "boolq-syn") else 4
        for it in items:
            assert len(it["choices"]) == n_choices
            assert 0 <= it["label"] < n_choices
            # correct choice actually appears at the label index
            assert isinstance(it["choices"][it["label"]], str)


def test_eval_answers_consistent_with_world():
    w = data.build_world()
    suites = data.eval_suites(w)
    for it in suites["hellaswag-syn"]:
        name = it["prompt"].split()[3]
        assert it["choices"][it["label"]].strip() == w.color[name]
    for it in suites["boolq-syn"]:
        assert it["choices"] == [" yes", " no"]


def test_boolq_balanced():
    w = data.build_world()
    items = data.eval_suites(w)["boolq-syn"]
    labels = [it["label"] for it in items]
    assert 0.4 < np.mean(labels) < 0.6


def test_train_packing():
    from compile.train import pack_corpus
    w = data.build_world()
    docs = data.corpus_docs(w, 20, seed=1)
    rng = np.random.default_rng(0)
    chunks = pack_corpus(docs, 32, rng)
    assert chunks.shape[1] == 33
    assert chunks.dtype == np.int32
    assert (chunks >= 0).all() and (chunks < tokenizer.VOCAB_SIZE).all()
