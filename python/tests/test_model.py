"""L2 model invariants: variant-equivalence limits, decode/prefill parity,
shape contracts. Uses a 2-layer config so everything runs in seconds."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.configs import ModelConfig

CFG = ModelConfig(n_layers=2)
T = 16
L, H, DH = CFG.n_layers, CFG.n_heads, CFG.head_dim


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def toks():
    return jnp.asarray(np.arange(T) % 250, jnp.int32)


def identity_clusters():
    mem = jnp.tile(jnp.arange(H, dtype=jnp.int32), (L, 1))
    return mem, mem, [H] * L


def random_clusters(seed=0, k_list=(3, 5)):
    rng = np.random.default_rng(seed)
    kmax = max(k_list)
    mem = np.stack([rng.integers(0, k_list[i], H) for i in range(L)])
    reps = np.zeros((L, kmax), np.int64)
    for i in range(L):
        reps[i, :k_list[i]] = rng.choice(H, k_list[i], replace=False)
    return (jnp.asarray(mem, jnp.int32), jnp.asarray(reps, jnp.int32),
            list(k_list))


def test_param_count_matches_config(params):
    n = sum(int(np.prod(v.shape)) for v in params.values())
    assert n == CFG.n_params


def test_chai_with_identity_clustering_equals_mha(params, toks):
    ln = jnp.asarray(T, jnp.int32)
    lm = M.logprob_mha_graph(params, CFG, toks, ln)
    mem, reps, kl = identity_clusters()
    lc = M.logprob_chai_graph(params, CFG, toks, ln, mem, reps, kl)
    np.testing.assert_allclose(lc, lm, rtol=2e-4, atol=2e-5)


def test_dejavu_all_heads_equals_mha(params, toks):
    ln = jnp.asarray(T, jnp.int32)
    lm = M.logprob_mha_graph(params, CFG, toks, ln)
    kept = jnp.tile(jnp.arange(H, dtype=jnp.int32), (L, 1))
    ld = M.logprob_dejavu_graph(params, CFG, toks, ln, kept)
    np.testing.assert_allclose(ld, lm, rtol=2e-4, atol=2e-5)


def test_spatten_no_pruning_equals_mha(params, toks):
    ln = jnp.asarray(T, jnp.int32)
    lm = M.logprob_mha_graph(params, CFG, toks, ln)
    ls = M.logprob_spatten_graph(params, CFG, toks, ln, [1.0] * L, 1.0)
    np.testing.assert_allclose(ls, lm, rtol=2e-4, atol=2e-5)


def test_spatten_pruning_changes_output(params, toks):
    ln = jnp.asarray(T, jnp.int32)
    lm = M.logprob_mha_graph(params, CFG, toks, ln)
    ls = M.logprob_spatten_graph(params, CFG, toks, ln, [1.0, 0.5], 0.5)
    assert np.abs(np.array(ls) - np.array(lm)).max() > 1e-4


def test_mha_decode_chain_matches_prefill(params, toks):
    ln = jnp.asarray(T, jnp.int32)
    lg, kc, vc = M.prefill_mha_graph(params, CFG, toks, ln)
    kc2 = jnp.zeros((L, H, T, DH))
    vc2 = jnp.zeros_like(kc2)
    for i in range(T):
        lgd, kc2, vc2 = M.decode_mha_graph(
            params, CFG, toks[i], jnp.asarray(i, jnp.int32), kc2, vc2)
    np.testing.assert_allclose(lgd, lg, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(kc2, kc, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(vc2, vc, rtol=2e-4, atol=2e-5)


def test_chai_decode_chain_matches_prefill(params, toks):
    ln = jnp.asarray(T, jnp.int32)
    mem, reps, kl = random_clusters()
    out = M.prefill_chai_graph(params, CFG, toks, ln, mem, reps, kl)
    lg, kreps, vc = out[0], list(out[1:1 + L]), out[-1]
    kreps2 = [jnp.zeros((kl[i], T, DH)) for i in range(L)]
    vc2 = jnp.zeros((L, H, T, DH))
    for i in range(T):
        res = M.decode_chai_graph(params, CFG, toks[i],
                                  jnp.asarray(i, jnp.int32), kreps2, vc2,
                                  mem, reps, kl)
        lgd, kreps2, vc2 = res[0], list(res[1:1 + L]), res[-1]
    np.testing.assert_allclose(lgd, lg, rtol=2e-4, atol=2e-5)
    for a, b in zip(kreps, kreps2):
        np.testing.assert_allclose(b, a, rtol=2e-4, atol=2e-5)


def test_chai_prefill_logits_match_logprob_last_row(params, toks):
    ln = jnp.asarray(T, jnp.int32)
    mem, reps, kl = random_clusters(seed=3)
    lcl = M.logprob_chai_graph(params, CFG, toks, ln, mem, reps, kl)
    out = M.prefill_chai_graph(params, CFG, toks, ln, mem, reps, kl)
    np.testing.assert_allclose(out[0], lcl[T - 1], rtol=2e-4, atol=2e-5)


def test_probe_graph_shapes_and_stochasticity(params, toks):
    from compile.configs import PROBE_TOKENS
    probe = M.probe_graph(params, CFG, toks[:8], jnp.asarray(8, jnp.int32))
    assert probe.shape == (L, H, 8, 8)
    np.testing.assert_allclose(np.array(probe).sum(-1), 1.0, rtol=1e-4)


def test_pallas_and_jnp_impl_agree(params, toks):
    ln = jnp.asarray(T, jnp.int32)
    a = M.logprob_mha_graph(params, CFG, toks, ln, impl="jnp")
    b = M.logprob_mha_graph(params, CFG, toks, ln, impl="pallas")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)
    mem, reps, kl = random_clusters(seed=5)
    a = M.logprob_chai_graph(params, CFG, toks, ln, mem, reps, kl, impl="jnp")
    b = M.logprob_chai_graph(params, CFG, toks, ln, mem, reps, kl,
                             impl="pallas")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_padded_tokens_do_not_affect_valid_logits(params):
    """Bucket padding invariant: logits at positions < length must not
    depend on pad content — the contract the rust coordinator relies on."""
    ln = 10
    base = jnp.asarray(list(range(ln)) + [258] * (T - ln), jnp.int32)
    alt = jnp.asarray(list(range(ln)) + [7] * (T - ln), jnp.int32)
    a = M.logprob_mha_graph(params, CFG, base, jnp.asarray(ln, jnp.int32))
    b = M.logprob_mha_graph(params, CFG, alt, jnp.asarray(ln, jnp.int32))
    np.testing.assert_allclose(a[:ln], b[:ln], rtol=1e-5, atol=1e-6)


def test_rope_positions_shift_invariance():
    """RoPE is relative: shifting absolute positions changes individual
    projections but attention of (q,k) at equal relative distance holds."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    a = M.rope(x, jnp.arange(4))
    b = M.rope(x, jnp.arange(4) + 7)
    # dot products between same relative offsets must match
    da = float(jnp.dot(a[0], a[2]))
    db = float(jnp.dot(b[0], b[2]))
    assert abs(da - db) < 1e-4
