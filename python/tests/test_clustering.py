"""Offline/online clustering pipeline tests (python side)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import clustering as C


def blobs(rng, k, per, f, spread=0.05):
    cents = rng.normal(size=(k, f)) * 3
    pts = np.concatenate([c + rng.normal(size=(per, f)) * spread
                          for c in cents])
    labels = np.repeat(np.arange(k), per)
    return pts.astype(np.float32), labels


def test_kmeans_recovers_separated_blobs():
    rng = np.random.default_rng(0)
    pts, true = blobs(rng, 3, 5, 8)
    labels, cents, sse = C.kmeans(pts, 3, seed=1)
    # same-blob points share a label; cross-blob points don't
    for b in range(3):
        blk = labels[true == b]
        assert (blk == blk[0]).all()
    assert len(set(labels[::5])) == 3
    assert sse < 1.0


def test_kmeans_deterministic():
    rng = np.random.default_rng(2)
    pts = rng.normal(size=(16, 10)).astype(np.float32)
    a = C.kmeans(pts, 4, seed=7)
    b = C.kmeans(pts, 4, seed=7)
    assert (a[0] == b[0]).all()
    np.testing.assert_array_equal(a[1], b[1])


@settings(max_examples=20, deadline=None)
@given(h=st.integers(2, 16), k=st.integers(1, 16), seed=st.integers(0, 999))
def test_kmeans_labels_in_range_and_sse_monotone_in_k(h, k, seed):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(h, 6)).astype(np.float32)
    labels, cents, sse = C.kmeans(pts, k, seed=seed)
    k_eff = min(k, h)
    assert labels.min() >= 0 and labels.max() < k_eff
    if k_eff > 1:
        _, _, sse1 = C.kmeans(pts, 1, seed=seed)
        assert sse <= sse1 + 1e-5


def test_elbow_pick_plateau():
    # sharp elbow at k=3 (residual < 8% of base)
    errors = [100.0, 40.0, 5.0, 4.5, 4.2, 4.0]
    assert C.elbow_pick(errors) == 3
    # no structure: linear decline -> keep all heads (no pruning)
    lin = [16.0 - i for i in range(16)]
    assert C.elbow_pick(lin) == 16
    # fully redundant: k=1 already explains everything
    assert C.elbow_pick([0.001, 0.0005, 0.0]) is not None


def test_normalize_features_correlation_semantics():
    rng = np.random.default_rng(1)
    a = rng.normal(size=(1, 20))
    scaled = 5 * a + 2  # perfectly correlated with a
    anti = -a
    f = C.normalize_features(np.concatenate([a, scaled, anti]))
    assert np.dot(f[0], f[1]) == pytest.approx(1.0, abs=1e-5)
    assert np.dot(f[0], f[2]) == pytest.approx(-1.0, abs=1e-5)


def test_representatives_are_members_of_their_cluster():
    rng = np.random.default_rng(3)
    pts, _ = blobs(rng, 4, 4, 6)
    labels, cents, _ = C.kmeans(pts, 4, seed=0)
    reps = C.representatives(pts, labels, cents)
    for j, r in enumerate(reps):
        assert labels[r] == j


def test_canonical_membership_sorted_reps():
    labels = np.array([1, 1, 0, 2])
    reps = np.array([9, 3, 5])
    mem, reps2 = C.canonical_membership(labels, reps)
    assert list(reps2) == [3, 5, 9]
    # head 2 was cluster 0 (rep 9) -> now cluster index of rep 9 = 2
    assert list(mem) == [0, 0, 2, 1]


def test_cluster_layer_redundant_heads_collapse():
    """Heads with (near-)identical attention rows must land in one cluster
    and the elbow must find fewer clusters than heads."""
    rng = np.random.default_rng(4)
    base = rng.dirichlet(np.ones(32), size=3)  # 3 distinct score patterns
    feats = np.concatenate([
        np.tile(base[0], (6, 1)), np.tile(base[1], (6, 1)),
        np.tile(base[2], (4, 1))]) + rng.normal(size=(16, 32)) * 1e-3
    res = C.cluster_layer(feats.astype(np.float32))
    assert res["k"] == 3
    m = np.array(res["membership"])
    assert len(set(m[:6])) == 1 and len(set(m[6:12])) == 1
    assert len(np.array(res["reps"])) == 3


def test_online_membership_shapes_and_reuse():
    rng = np.random.default_rng(5)
    h, p = 16, 5
    maps = rng.dirichlet(np.ones(p), size=(h, p)).astype(np.float32)
    # causal-ify
    for q in range(p):
        maps[:, q, q + 1:] = 0
        maps[:, q, :q + 1] /= maps[:, q, :q + 1].sum(-1, keepdims=True)
    mem, reps = C.online_membership(maps, 4, seed=0)
    assert mem.shape == (h,) and len(reps) == 4
    assert mem.max() < 4
    for j, r in enumerate(reps):
        assert mem[r] == j  # rep belongs to its own cluster
