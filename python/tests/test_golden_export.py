"""The committed golden fixtures must match a fresh regeneration.

``rust/tests/golden/*.cbt`` pin the Rust reference backend to the jnp
oracles; this test regenerates every case from its seed and diffs it
against the committed file, so neither side of the cross-language
contract can drift without the other noticing.
"""

import os

import numpy as np
import pytest

from compile import export_golden, tensorio

GOLDEN_DIR = export_golden.OUT_DIR


def test_golden_dir_is_committed():
    assert os.path.isdir(GOLDEN_DIR), (
        f"{GOLDEN_DIR} missing — run `python -m compile.export_golden`")


@pytest.mark.parametrize("name", [c[0] for c in export_golden.ATTENTION_CASES]
                         + ["primitives"])
def test_committed_fixture_matches_regeneration(name):
    path = os.path.join(GOLDEN_DIR, f"{name}.cbt")
    assert os.path.exists(path), (
        f"{path} missing — run `python -m compile.export_golden`")
    committed = tensorio.load(path)
    fresh = export_golden.all_cases()[name]
    assert set(committed) == set(fresh), (
        f"{name}: tensor set changed: {sorted(committed)} vs {sorted(fresh)}")
    for key, want in fresh.items():
        got = committed[key]
        assert got.shape == tuple(np.shape(want)), f"{name}/{key} shape"
        if got.dtype == np.int32:
            np.testing.assert_array_equal(got, want, err_msg=f"{name}/{key}")
        else:
            # float ops may differ in the last ulp across BLAS builds
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6,
                                       err_msg=f"{name}/{key}")


def test_attention_goldens_are_row_stochastic():
    # sanity on the committed artifacts themselves (independent of jax)
    for name, h, k, tq, tk, dh, q_offset, length, _ in \
            export_golden.ATTENTION_CASES:
        case = tensorio.load(os.path.join(GOLDEN_DIR, f"{name}.cbt"))
        probs = case["mha_probs"]
        assert probs.shape == (h, tq, tk)
        np.testing.assert_allclose(probs.sum(-1), 1.0, atol=1e-4)
        # causality: no mass beyond the query position or the length
        for qi in range(tq):
            limit = min(q_offset + qi + 1, length)
            assert probs[:, qi, limit:].sum() == pytest.approx(0.0, abs=1e-6)
