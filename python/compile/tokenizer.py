"""Byte-level tokenizer with special tokens.

Tokens 0..255 are raw bytes; 256..259 are specials. This is the entire
tokenizer the system needs: the synthetic corpus is ASCII, and byte-level
vocab keeps the from-scratch model small. The rust engine mirrors this
mapping in ``rust/src/model/tokenizer.rs`` (kept in sync via the manifest's
vocab_size and the pytest/cargo cross-tests on the shared fixture in
``artifacts/tokenizer_fixture.json``).
"""

BOS = 256
EOS = 257
PAD = 258
SEP = 259
VOCAB_SIZE = 260


def encode(text: str, bos: bool = True, eos: bool = False) -> list:
    ids = list(text.encode("utf-8"))
    if bos:
        ids = [BOS] + ids
    if eos:
        ids = ids + [EOS]
    return ids


def decode(ids) -> str:
    return bytes(i for i in ids if 0 <= i < 256).decode("utf-8", errors="replace")
