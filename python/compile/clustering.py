"""Offline cluster identification (paper Fig 10a) — python side.

Runs once per model inside ``make artifacts``: collect per-head attention
features on held-out samples, k-means++ for k = 1..H, elbow-pick the
per-layer cluster count, and emit ``artifacts/clusters.json`` with
  k_list            per-layer cluster count (static shapes for CHAI HLO)
  static_membership per-layer head→cluster map (the CHAI-static baseline)
  static_reps       per-layer representative head per cluster
  elbow_errors      per-layer SSE curve (Figure 8)

The rust side re-implements k-means/elbow (``rust/src/clustering``) for the
online membership step and the analysis benches; `clusters.json` doubles as
a cross-language fixture.
"""

import json
from typing import List, Tuple

import numpy as np


def normalize_features(feats: np.ndarray) -> np.ndarray:
    """Center + L2-normalize per head so euclidean k-means groups by
    correlation (the paper clusters on attention-score correlation)."""
    f = feats - feats.mean(axis=1, keepdims=True)
    n = np.linalg.norm(f, axis=1, keepdims=True)
    return f / np.maximum(n, 1e-8)


def kmeans(feats: np.ndarray, k: int, seed: int = 0, iters: int = 50
           ) -> Tuple[np.ndarray, np.ndarray, float]:
    """k-means++ over rows of ``feats`` [H, F]. Returns (labels [H],
    centroids [k, F], SSE). Deterministic given seed."""
    h, f = feats.shape
    rng = np.random.default_rng(seed)
    k = min(k, h)
    # k-means++ init
    centroids = [feats[rng.integers(h)]]
    for _ in range(1, k):
        d2 = np.min([np.sum((feats - c) ** 2, axis=1) for c in centroids],
                    axis=0)
        if d2.sum() <= 1e-12:
            centroids.append(feats[rng.integers(h)])
            continue
        centroids.append(feats[rng.choice(h, p=d2 / d2.sum())])
    cents = np.stack(centroids)
    labels = np.zeros(h, np.int64)
    for _ in range(iters):
        d = ((feats[:, None, :] - cents[None]) ** 2).sum(-1)  # [H, k]
        new_labels = d.argmin(1)
        if (new_labels == labels).all() and _ > 0:
            break
        labels = new_labels
        for j in range(k):
            m = labels == j
            if m.any():
                cents[j] = feats[m].mean(0)
    sse = float(((feats - cents[labels]) ** 2).sum())
    return labels, cents, sse


def representatives(feats: np.ndarray, labels: np.ndarray,
                    cents: np.ndarray) -> np.ndarray:
    """Head closest to each centroid (the head whose Q/K survive)."""
    k = cents.shape[0]
    reps = np.zeros(k, np.int64)
    for j in range(k):
        idx = np.where(labels == j)[0]
        if len(idx) == 0:
            reps[j] = j % feats.shape[0]
            continue
        d = ((feats[idx] - cents[j]) ** 2).sum(1)
        reps[j] = idx[d.argmin()]
    return reps


def elbow_pick(errors: List[float], rel_tol: float = 0.08) -> int:
    """Paper §3.2: choose k where the SSE curve plateaus — the automated
    form of the manual elbow read.

    Rule: the smallest k whose *residual* SSE falls below ``rel_tol`` of
    the k=1 SSE (i.e. clustering at k explains ≥ 92% of the head-score
    variance). Layers with no redundancy never plateau, so the rule
    returns H (no pruning there — matching the paper's observation that
    early layers keep many clusters)."""
    if errors[0] < 1e-6:  # all heads already identical
        return 1
    base = errors[0]
    for k in range(1, len(errors) + 1):
        if errors[k - 1] / base <= rel_tol:
            return k
    return len(errors)


def canonical_membership(labels: np.ndarray, reps: np.ndarray
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Re-index clusters so reps are sorted by head index (a canonical form
    shared with rust so memberships compare bit-exactly in tests)."""
    order = np.argsort(reps)
    remap = np.zeros(len(reps), np.int64)
    remap[order] = np.arange(len(reps))
    return remap[labels], reps[order]


def cluster_layer(feats_raw: np.ndarray, max_k: int = None, seed: int = 0):
    """Full per-layer offline pipeline. feats_raw: [H, F] attention
    features. Returns dict with k, membership, reps, errors."""
    h = feats_raw.shape[0]
    max_k = max_k or h
    feats = normalize_features(feats_raw)
    errors = []
    results = {}
    for k in range(1, max_k + 1):
        labels, cents, sse = kmeans(feats, k, seed=seed)
        errors.append(sse)
        results[k] = (labels, cents)
    k = elbow_pick(errors)
    labels, cents = results[k]
    reps = representatives(feats, labels, cents)
    membership, reps = canonical_membership(labels, reps)
    return {
        "k": int(k),
        "membership": membership.astype(int).tolist(),
        "reps": reps.astype(int).tolist(),
        "errors": [float(e) for e in errors],
    }


def online_membership(probe_maps: np.ndarray, k: int, seed: int = 0):
    """Online cluster-membership identification (paper §3.3): k-means on
    the probe attention maps of ONE request. probe_maps: [H, P, P] causal
    attention of the first P tokens for one layer. Feature = flattened
    strictly-causal rows (query rows 1..P-1). Returns (membership [H],
    reps [k]). Mirrored by rust `clustering::membership`."""
    h, pp, _ = probe_maps.shape
    rows = [probe_maps[:, q, : q + 1] for q in range(1, pp)]
    feats = np.concatenate(rows, axis=1)  # [H, 2+3+..+P]
    feats = normalize_features(feats)
    labels, cents, _ = kmeans(feats, k, seed=seed)
    reps = representatives(feats, labels, cents)
    membership, reps = canonical_membership(labels, reps)
    return membership, reps
