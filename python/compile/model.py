"""L2: LLaMA-style decoder-only transformer with MHA / CHAI / DejaVu /
SpAtten attention variants.

Everything here is build-time JAX. ``aot.py`` lowers the ``*_graph``
functions below to HLO text; the rust runtime executes them. Parameters are
a flat ``{name: array}`` dict (the ``.cbt`` file layout) so both sides agree
on naming without a pytree protocol.

Architecture (matching the LLaMA family the paper evaluates):
  token emb → L × [RMSNorm → attention(+RoPE) → residual
                   → RMSNorm → SwiGLU MLP → residual] → RMSNorm → lm head

Attention variants:
  mha       dense multi-head attention (baseline, Tables 1-3 "MHA")
  chai      clustered-head attention (paper §3.4): per-layer static cluster
            count k_l (offline elbow), runtime membership/representatives
  chai_qkv  Table-4 ablation: V reused from the representative too
  dejavu    runtime head pruning at sparsity p: only the given head subset
            is computed, pruned heads contribute zero (DEJAVU's head
            sparsity, Tables 1-3)
  spatten   cascade token+head pruning by accumulated attention/output
            magnitude (SpAtten row of Tables 2-3)

``attn_impl`` selects the Pallas kernels (``'pallas'``, the L1 hot path,
lowered interpret=True) or the pure-jnp oracle path (``'jnp'``); both are
numerically identical (pytest-enforced) — training and analysis use 'jnp'
for wallclock, exported serving artifacts default to 'pallas'.
"""

import functools
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref as kref
from .kernels import mha as kmha
from .kernels import chai as kchai

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def head_group_of(h_idx: int, n_heads: int, n_groups: int) -> int:
    """Contiguous-block group assignment (shared with tests/rust)."""
    return min(h_idx * n_groups // n_heads, n_groups - 1)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """He-style init; flat dict keyed by the `.cbt` tensor names.

    Redundancy induction (DESIGN.md §Substitutions): within each layer the
    Q/K projections of heads in the same group start from a shared base
    plus small noise, so the attention-score redundancy the paper measures
    on LLaMA-7B exists at toy scale. The last ``cfg.uniform_heads`` heads
    per layer (OPT variant) get near-zero Q/K (→ uniform attention) and
    zero V (→ no output) — they stay frozen during training.
    """
    d, h, dh, f, v = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.d_ff, cfg.vocab_size
    hd = h * dh
    keys = iter(jax.random.split(key, 4 + 12 * cfg.n_layers))

    def dense(k, fan_in, shape):
        return (jax.random.normal(k, shape, jnp.float32)
                / jnp.sqrt(jnp.float32(fan_in)))

    def grouped_qk(k1, k2, n_groups):
        """[d, H*dh] where same-group heads share a base matrix."""
        bases = dense(k1, d, (n_groups, d, dh))
        noise = dense(k2, d, (h, d, dh)) * cfg.init_group_noise
        cols = []
        for hh in range(h):
            g = head_group_of(hh, h, n_groups)
            w = bases[g] + noise[hh]
            if hh >= h - cfg.uniform_heads:
                w = w * 0.02  # near-uniform attention scores
            cols.append(w)
        return jnp.stack(cols, axis=1).reshape(d, hd)

    p: Params = {
        "emb": jax.random.normal(next(keys), (v, d), jnp.float32) * 0.02,
        "final_norm": jnp.ones((d,), jnp.float32),
        "lm_head": dense(next(keys), d, (d, v)),
    }
    for i in range(cfg.n_layers):
        g = cfg.init_head_groups[i % len(cfg.init_head_groups)]
        p[f"l{i}.attn_norm"] = jnp.ones((d,), jnp.float32)
        p[f"l{i}.wq"] = grouped_qk(next(keys), next(keys), g)
        p[f"l{i}.wk"] = grouped_qk(next(keys), next(keys), g)
        wv = dense(next(keys), d, (d, h, dh))
        if cfg.uniform_heads:
            wv = wv.at[:, h - cfg.uniform_heads:, :].set(0.0)
        p[f"l{i}.wv"] = wv.reshape(d, hd)
        p[f"l{i}.wo"] = dense(next(keys), hd, (hd, d))
        p[f"l{i}.mlp_norm"] = jnp.ones((d,), jnp.float32)
        p[f"l{i}.wg"] = dense(next(keys), d, (d, f))
        p[f"l{i}.wu"] = dense(next(keys), d, (d, f))
        p[f"l{i}.wd"] = dense(next(keys), f, (f, d))
    return p


def grad_mask(cfg: ModelConfig, params: Params) -> Params:
    """1/0 mask freezing the OPT variant's uniform no-op heads (their Q/K
    stay near-zero-scale and V stays exactly zero through training)."""
    h, dh = cfg.n_heads, cfg.head_dim
    mask = {k: jnp.ones_like(v) for k, v in params.items()}
    if cfg.uniform_heads:
        col = jnp.ones((h, dh), jnp.float32)
        col = col.at[h - cfg.uniform_heads:].set(0.0)
        flat = col.reshape(-1)
        for i in range(cfg.n_layers):
            for w in ("wq", "wk", "wv"):
                mask[f"l{i}.{w}"] = jnp.broadcast_to(
                    flat, params[f"l{i}.{w}"].shape)
    return mask


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions, theta=10000.0):
    """Rotary embedding. x: [..., T, dh] with T matching positions [T]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(x, wg, wu, wd):
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd


def _heads(x, h, dh):
    """[T, h*dh] -> [h, T, dh]"""
    t = x.shape[0]
    return x.reshape(t, h, dh).transpose(1, 0, 2)


def _unheads(x):
    """[h, T, dh] -> [T, h*dh]"""
    h, t, dh = x.shape
    return x.transpose(1, 0, 2).reshape(t, h * dh)


def _dense_attn(q, k, v, q_offset, length, impl, with_probs=False,
                key_mask=None):
    """Dispatch dense attention to the Pallas kernel or the jnp oracle.
    ``key_mask`` (additive, [Tk]) is only used by the SpAtten variant and
    only supported on the jnp path (SpAtten is accuracy-only, DESIGN.md)."""
    if key_mask is not None:
        assert impl == "jnp"
        tq = q.shape[1]
        scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(q.shape[-1]))
        qpos = q_offset + jnp.arange(tq)[:, None]
        kpos = jnp.arange(k.shape[1])[None, :]
        mask = (kpos <= qpos) & (kpos < length)
        scores = jnp.where(mask[None], scores, kref.NEG_INF) + key_mask[None, None, :]
        scores = scores - jnp.max(scores, axis=-1, keepdims=True)
        e = jnp.exp(scores)
        probs = e / jnp.sum(e, axis=-1, keepdims=True)
        out = jnp.einsum("hqk,hkd->hqd", probs, v)
        return (out, probs) if with_probs else out
    if impl == "pallas":
        return kmha.mha_attention(q, k, v, q_offset, length,
                                  with_probs=with_probs)
    res = kref.mha_attention_ref(q, k, v, q_offset, length)
    return res if with_probs else res[0]


def _clustered_attn(q_rep, k_rep, v, membership, q_offset, length, impl):
    if impl == "pallas":
        return kchai.clustered_attention(q_rep, k_rep, v, membership,
                                         q_offset, length)
    return kref.clustered_attention_ref(q_rep, k_rep, v, membership,
                                        q_offset, length)


# ---------------------------------------------------------------------------
# Transformer blocks
# ---------------------------------------------------------------------------

def _mha_block(p: Params, i: int, cfg: ModelConfig, x, positions, length,
               impl, with_probs=False, key_mask=None, head_scale=None):
    """One decoder layer with dense MHA over the sequence itself (prefill /
    scoring). x: [T, d]. Returns (x', k [H,T,dh], v [H,T,dh], probs|None)."""
    h, dh = cfg.n_heads, cfg.head_dim
    xn = rmsnorm(x, p[f"l{i}.attn_norm"], cfg.rms_eps)
    q = rope(_heads(xn @ p[f"l{i}.wq"], h, dh), positions, cfg.rope_theta)
    k = rope(_heads(xn @ p[f"l{i}.wk"], h, dh), positions, cfg.rope_theta)
    v = _heads(xn @ p[f"l{i}.wv"], h, dh)
    res = _dense_attn(q, k, v, 0, length, impl, with_probs=with_probs,
                      key_mask=key_mask)
    out, probs = res if with_probs else (res, None)
    if head_scale is not None:  # SpAtten / DejaVu head gating
        out = out * head_scale[:, None, None]
    x = x + _unheads(out) @ p[f"l{i}.wo"]
    xn2 = rmsnorm(x, p[f"l{i}.mlp_norm"], cfg.rms_eps)
    x = x + swiglu(xn2, p[f"l{i}.wg"], p[f"l{i}.wu"], p[f"l{i}.wd"])
    return x, k, v, probs


def _chai_block(p: Params, i: int, cfg: ModelConfig, x, positions, length,
                membership, reps, n_clusters: int, impl, qkv=False):
    """One decoder layer with clustered-head attention over the sequence.

    membership: [H] int32 in [0, n_clusters); reps: [k_max] int32 (first
    ``n_clusters`` entries valid — head index of each representative).
    Q/K projections are computed **only for representative heads** by
    gathering the corresponding weight columns (this is the FLOP saving),
    V for all heads (kept per the paper).
    """
    h, dh = cfg.n_heads, cfg.head_dim
    d = cfg.d_model
    rep = reps[:n_clusters]
    xn = rmsnorm(x, p[f"l{i}.attn_norm"], cfg.rms_eps)
    wq = p[f"l{i}.wq"].reshape(d, h, dh)
    wk = p[f"l{i}.wk"].reshape(d, h, dh)
    wq_rep = jnp.take(wq, rep, axis=1)  # [d, k_l, dh]
    wk_rep = jnp.take(wk, rep, axis=1)
    q_rep = rope(jnp.einsum("td,dkh->kth", xn, wq_rep), positions,
                 cfg.rope_theta)
    k_rep = rope(jnp.einsum("td,dkh->kth", xn, wk_rep), positions,
                 cfg.rope_theta)
    v = _heads(xn @ p[f"l{i}.wv"], h, dh)
    if qkv:
        probs = (kchai.clustered_scores(q_rep, k_rep, 0, length)
                 if impl == "pallas"
                 else kref.attention_scores_ref(q_rep, k_rep, 0, length))
        v_rep = jnp.take(v, rep, axis=0)
        if impl == "pallas":
            out = kchai.broadcast_av_qkv(probs, v_rep, membership)
        else:
            out = jnp.einsum("kqt,ktd->kqd", probs, v_rep)[membership]
    else:
        out, probs = _clustered_attn(q_rep, k_rep, v, membership, 0, length,
                                     impl)
    x = x + _unheads(out) @ p[f"l{i}.wo"]
    xn2 = rmsnorm(x, p[f"l{i}.mlp_norm"], cfg.rms_eps)
    x = x + swiglu(xn2, p[f"l{i}.wg"], p[f"l{i}.wu"], p[f"l{i}.wd"])
    return x, k_rep, v


def _dejavu_block(p: Params, i: int, cfg: ModelConfig, x, positions, length,
                  kept, impl):
    """DejaVu head sparsity: compute attention only for the ``kept`` head
    indices [n_keep]; pruned heads contribute zero to the output projection
    (equivalent to zeroing their output rows)."""
    h, dh = cfg.n_heads, cfg.head_dim
    d = cfg.d_model
    xn = rmsnorm(x, p[f"l{i}.attn_norm"], cfg.rms_eps)
    wq = jnp.take(p[f"l{i}.wq"].reshape(d, h, dh), kept, axis=1)
    wk = jnp.take(p[f"l{i}.wk"].reshape(d, h, dh), kept, axis=1)
    wv = jnp.take(p[f"l{i}.wv"].reshape(d, h, dh), kept, axis=1)
    q = rope(jnp.einsum("td,dkh->kth", xn, wq), positions, cfg.rope_theta)
    k = rope(jnp.einsum("td,dkh->kth", xn, wk), positions, cfg.rope_theta)
    v = jnp.einsum("td,dkh->kth", xn, wv)
    out = _dense_attn(q, k, v, 0, length, impl)          # [n_keep, T, dh]
    # scatter kept-head outputs back into the full head layout
    full = jnp.zeros((h,) + out.shape[1:], jnp.float32)
    full = full.at[kept].set(out)
    x = x + _unheads(full) @ p[f"l{i}.wo"]
    xn2 = rmsnorm(x, p[f"l{i}.mlp_norm"], cfg.rms_eps)
    x = x + swiglu(xn2, p[f"l{i}.wg"], p[f"l{i}.wu"], p[f"l{i}.wd"])
    return x


# ---------------------------------------------------------------------------
# Whole-model graphs (the AOT export surface)
# ---------------------------------------------------------------------------

def embed(p: Params, tokens):
    return jnp.take(p["emb"], tokens, axis=0)


def unembed(p: Params, x, cfg: ModelConfig):
    return rmsnorm(x, p["final_norm"], cfg.rms_eps) @ p["lm_head"]


def logprob_mha_graph(p: Params, cfg: ModelConfig, tokens, length,
                      impl="jnp"):
    """Full-sequence logits [T, V] — the eval scoring path (MHA baseline)."""
    t = tokens.shape[0]
    positions = jnp.arange(t)
    x = embed(p, tokens)
    for i in range(cfg.n_layers):
        x, _, _, _ = _mha_block(p, i, cfg, x, positions, length, impl)
    return unembed(p, x, cfg)


def logprob_chai_graph(p: Params, cfg: ModelConfig, tokens, length,
                       membership, reps, k_list: Sequence[int],
                       impl="jnp", qkv=False):
    """CHAI scoring path. membership [L,H], reps [L,k_max]; k_list is the
    static per-layer cluster count (baked at lowering from the offline
    elbow file)."""
    t = tokens.shape[0]
    positions = jnp.arange(t)
    x = embed(p, tokens)
    for i in range(cfg.n_layers):
        x, _, _ = _chai_block(p, i, cfg, x, positions, length,
                              membership[i], reps[i], k_list[i], impl,
                              qkv=qkv)
    return unembed(p, x, cfg)


def logprob_dejavu_graph(p: Params, cfg: ModelConfig, tokens, length, kept,
                         impl="jnp"):
    """DejaVu scoring path. kept: [L, n_keep] int32 head indices."""
    t = tokens.shape[0]
    positions = jnp.arange(t)
    x = embed(p, tokens)
    for i in range(cfg.n_layers):
        x = _dejavu_block(p, i, cfg, x, positions, length, kept[i], impl)
    return unembed(p, x, cfg)


def logprob_spatten_graph(p: Params, cfg: ModelConfig, tokens, length,
                          token_keep: Sequence[float], head_keep: float):
    """SpAtten-style cascade token + head pruning (accuracy-only baseline).

    Token pruning: per layer, tokens are ranked by attention mass received
    (column sums of the probability matrix, accumulated across layers —
    SpAtten's cumulative importance); entering layer i only the top
    ``token_keep[i]·T`` keys stay visible (additive -inf mask keeps shapes
    static). Head pruning: heads ranked by accumulated output magnitude
    ‖A·V‖; the bottom ``1-head_keep`` fraction is gated off from layer 2 on.

    Selection uses O(n²) pairwise rank counting instead of ``lax.top_k``:
    the image's xla_extension 0.5.1 HLO-text parser predates the ``topk``
    op's ``largest`` attribute, and n ≤ 96 makes rank counting free.
    """

    def _top_mask(scores, n_keep):
        """Boolean mask of the n_keep largest entries (rank counting)."""
        rank = jnp.sum(scores[None, :] > scores[:, None], axis=1)
        return rank < n_keep

    t = tokens.shape[0]
    h = cfg.n_heads
    positions = jnp.arange(t)
    x = embed(p, tokens)
    token_imp = jnp.zeros((t,), jnp.float32)
    head_imp = jnp.zeros((h,), jnp.float32)
    key_mask = jnp.zeros((t,), jnp.float32)
    head_scale = jnp.ones((h,), jnp.float32)
    for i in range(cfg.n_layers):
        n_keep_tok = max(1, int(token_keep[i] * t))
        if n_keep_tok < t:
            key_mask = jnp.where(_top_mask(token_imp, n_keep_tok), 0.0,
                                 kref.NEG_INF)
        if i >= 2 and head_keep < 1.0:
            n_keep_h = max(1, int(head_keep * h))
            head_scale = _top_mask(head_imp, n_keep_h).astype(jnp.float32)
        x, _, v, probs = _mha_block(p, i, cfg, x, positions, length, "jnp",
                                    with_probs=True, key_mask=key_mask,
                                    head_scale=head_scale)
        token_imp = token_imp + jnp.sum(probs, axis=(0, 1))
        head_imp = head_imp + jnp.sqrt(
            jnp.sum(jnp.square(jnp.einsum("hqk,hkd->hqd", probs, v)),
                    axis=(1, 2)))
    return unembed(p, x, cfg)


def probe_graph(p: Params, cfg: ModelConfig, tokens, length, impl="jnp"):
    """First-5-token probe (paper §3.3 / Fig 10b): dense MHA over the probe
    bucket, returning per-layer attention maps [L, H, P, P] from which the
    rust engine k-means the cluster membership."""
    t = tokens.shape[0]
    positions = jnp.arange(t)
    x = embed(p, tokens)
    maps = []
    for i in range(cfg.n_layers):
        x, _, _, probs = _mha_block(p, i, cfg, x, positions, length, impl,
                                    with_probs=True)
        maps.append(probs)
    return jnp.stack(maps)  # [L, H, P, P]


def analyze_graph(p: Params, cfg: ModelConfig, tokens, length):
    """Offline-analysis forward: full attention maps [L, H, T, T] (figures
    2, 6, 7, 8, 9, 13 are all computed from these by the rust analysis
    tooling / elbow.py)."""
    return probe_graph(p, cfg, tokens, length, impl="jnp")


def prefill_mha_graph(p: Params, cfg: ModelConfig, tokens, length,
                      impl="jnp"):
    """MHA prefill: returns (last-position logits [V], K cache [L,H,T,dh],
    V cache [L,H,T,dh]).

    Deliberately does NOT emit attention probabilities: materializing the
    [H,T,T] probs tensor just to slice a probe costs ~268 MB of traffic
    per layer at T=2048 (measured 2× prefill wallclock). The online
    membership probe is its own tiny artifact (`probe_graph`, T=8)."""
    t = tokens.shape[0]
    positions = jnp.arange(t)
    x = embed(p, tokens)
    ks, vs = [], []
    for i in range(cfg.n_layers):
        x, k, v, _ = _mha_block(p, i, cfg, x, positions, length, impl)
        ks.append(k)
        vs.append(v)
    logits = unembed(p, x[length - 1][None], cfg)[0]
    return logits, jnp.stack(ks), jnp.stack(vs)


def prefill_chai_graph(p: Params, cfg: ModelConfig, tokens, length,
                       membership, reps, k_list: Sequence[int], impl="jnp"):
    """CHAI prefill (post-membership): returns (last logits [V], per-layer
    clustered K caches [k_l,T,dh] (a list — ragged across layers), V cache
    [L,H,T,dh]).

    Deviation noted in DESIGN.md: the paper runs MHA for the first 5 tokens
    then switches; we apply CHAI from position 0 within this graph — the
    probe run (separate artifact) is still dense, and TTFT accounting sums
    probe + clustering + this prefill.
    """
    t = tokens.shape[0]
    positions = jnp.arange(t)
    x = embed(p, tokens)
    kreps, vs = [], []
    for i in range(cfg.n_layers):
        x, k_rep, v = _chai_block(p, i, cfg, x, positions, length,
                                  membership[i], reps[i], k_list[i], impl)
        kreps.append(k_rep)
        vs.append(v)
    logits = unembed(p, x[length - 1][None], cfg)[0]
    return (logits, *kreps, jnp.stack(vs))


def decode_mha_graph(p: Params, cfg: ModelConfig, token, pos, kcache, vcache,
                     impl="jnp"):
    """Single-token MHA decode. kcache/vcache: [L,H,T,dh] (functional
    update at ``pos``). Returns (logits [V], kcache', vcache')."""
    h, dh = cfg.n_heads, cfg.head_dim
    x = embed(p, token[None])  # [1, d]
    positions = pos[None]
    length = pos + 1
    for i in range(cfg.n_layers):
        xn = rmsnorm(x, p[f"l{i}.attn_norm"], cfg.rms_eps)
        q = rope(_heads(xn @ p[f"l{i}.wq"], h, dh), positions, cfg.rope_theta)
        k_new = rope(_heads(xn @ p[f"l{i}.wk"], h, dh), positions,
                     cfg.rope_theta)
        v_new = _heads(xn @ p[f"l{i}.wv"], h, dh)
        kcache = jax.lax.dynamic_update_slice(kcache, k_new[None],
                                              (i, 0, pos, 0))
        vcache = jax.lax.dynamic_update_slice(vcache, v_new[None],
                                              (i, 0, pos, 0))
        out = _dense_attn(q, kcache[i], vcache[i], pos, length, impl)
        x = x + _unheads(out) @ p[f"l{i}.wo"]
        xn2 = rmsnorm(x, p[f"l{i}.mlp_norm"], cfg.rms_eps)
        x = x + swiglu(xn2, p[f"l{i}.wg"], p[f"l{i}.wu"], p[f"l{i}.wd"])
    logits = unembed(p, x, cfg)[0]
    return logits, kcache, vcache


def decode_chai_graph(p: Params, cfg: ModelConfig, token, pos, kreps,
                      vcache, membership, reps, k_list: Sequence[int],
                      impl="jnp"):
    """Single-token CHAI decode. kreps: list of per-layer clustered K caches
    [k_l,T,dh]; vcache [L,H,T,dh]; membership [L,H]; reps [L,k_max].
    Returns (logits, kreps'..., vcache')."""
    h, dh = cfg.n_heads, cfg.head_dim
    d = cfg.d_model
    x = embed(p, token[None])
    positions = pos[None]
    length = pos + 1
    new_kreps = []
    for i in range(cfg.n_layers):
        kl = k_list[i]
        rep = reps[i][:kl]
        xn = rmsnorm(x, p[f"l{i}.attn_norm"], cfg.rms_eps)
        wq = jnp.take(p[f"l{i}.wq"].reshape(d, h, dh), rep, axis=1)
        wk = jnp.take(p[f"l{i}.wk"].reshape(d, h, dh), rep, axis=1)
        q_rep = rope(jnp.einsum("td,dkh->kth", xn, wq), positions,
                     cfg.rope_theta)
        k_new = rope(jnp.einsum("td,dkh->kth", xn, wk), positions,
                     cfg.rope_theta)
        v_new = _heads(xn @ p[f"l{i}.wv"], h, dh)
        krep = jax.lax.dynamic_update_slice(kreps[i], k_new, (0, pos, 0))
        vcache = jax.lax.dynamic_update_slice(vcache, v_new[None],
                                              (i, 0, pos, 0))
        out, _ = _clustered_attn(q_rep, krep, vcache[i], membership[i], pos,
                                 length, impl)
        x = x + _unheads(out) @ p[f"l{i}.wo"]
        xn2 = rmsnorm(x, p[f"l{i}.mlp_norm"], cfg.rms_eps)
        x = x + swiglu(xn2, p[f"l{i}.wg"], p[f"l{i}.wu"], p[f"l{i}.wd"])
        new_kreps.append(krep)
    logits = unembed(p, x, cfg)[0]
    return (logits, *new_kreps, vcache)


# ---------------------------------------------------------------------------
# Training forward (batched, jnp path)
# ---------------------------------------------------------------------------

def forward_train(p: Params, cfg: ModelConfig, tokens):
    """Batched next-token logits [B, T, V] (dense MHA, jnp impl)."""
    def single(tok):
        return logprob_mha_graph(p, cfg, tok, tok.shape[0], impl="jnp")
    return jax.vmap(single)(tokens)
