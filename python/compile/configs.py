"""Model / bucket / artifact configuration shared across the compile path.

Everything the AOT pipeline needs to agree on with the rust runtime is
declared here and exported into ``artifacts/manifest.json`` so the rust side
never hardcodes shapes.
"""

from dataclasses import dataclass, field, asdict
from typing import List, Dict


@dataclass(frozen=True)
class ModelConfig:
    """LLaMA-style decoder-only transformer configuration.

    The default is the ``tiny-llama-chai`` model trained from scratch at
    build time (see DESIGN.md §Substitutions): a 1.3M-parameter stand-in for
    LLaMA-7B that preserves the head-count structure CHAI exploits.
    """

    name: str = "tiny-llama-chai"
    vocab_size: int = 260  # 256 bytes + BOS/EOS/PAD/SEP
    n_layers: int = 6
    n_heads: int = 16
    d_model: int = 128
    head_dim: int = 8  # d_model / n_heads
    d_ff: int = 352  # SwiGLU inner dim (~8/3 * d, multiple of 16)
    max_seq: int = 2048
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    # --- redundancy induction (DESIGN.md §Substitutions) ---------------
    # Head redundancy is emergent at LLM scale; at 1.3M params we induce
    # the same structure the paper measures: per-layer Q/K head groups
    # initialized (and trained) from a shared base, with group count
    # decreasing with depth (paper Fig 6: later layers more redundant).
    init_head_groups: tuple = (16, 12, 8, 5, 3, 2)
    init_group_noise: float = 2e-3
    # OPT-like variant (paper Fig 4 / Table 1): this many heads per layer
    # are frozen as near-uniform no-op heads (tiny Q/K scale -> uniform
    # attention; zero V -> no output contribution) — the heads DejaVu's
    # uniformity criterion detects and safely prunes on OPT-66B.
    uniform_heads: int = 0

    @property
    def n_params(self) -> int:
        d, h, f, v, L = (
            self.d_model,
            self.n_heads * self.head_dim,
            self.d_ff,
            self.vocab_size,
            self.n_layers,
        )
        per_layer = 3 * d * h + h * d + 3 * d * f + 2 * d  # qkv, o, mlp, norms
        return v * d + L * per_layer + d + d * v  # emb, layers, final norm, head


# The OPT-66B stand-in: same skeleton, but half the heads per layer are
# frozen near-uniform no-ops (what DejaVu exploits on OPT, paper Fig 4).
OPT_CONFIG_KW = dict(name="tiny-opt-chai", uniform_heads=8,
                     init_head_groups=(8, 8, 6, 4, 3, 2))

# The LLaMA-33B stand-in (Table 3): deeper/wider, same head count, with the
# paper's depth-redundancy gradient stretched over 8 layers.
LLAMA33_CONFIG_KW = dict(name="tiny-llama-33b-chai", n_layers=8,
                         d_model=160, head_dim=10, d_ff=432,
                         init_head_groups=(16, 14, 12, 8, 6, 4, 3, 2))


def model_config(which: str = "llama") -> "ModelConfig":
    if which == "llama":
        return ModelConfig()
    if which == "opt":
        return ModelConfig(**OPT_CONFIG_KW)
    if which == "llama33":
        return ModelConfig(**LLAMA33_CONFIG_KW)
    raise ValueError(f"unknown model variant {which!r}")


@dataclass(frozen=True)
class TrainConfig:
    """From-scratch training of the tiny model on the synthetic corpus."""

    seq_len: int = 128
    batch_size: int = 8
    steps: int = 300
    lr: float = 1e-3
    warmup: int = 30
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    seed: int = 0
    corpus_docs: int = 4000


# Static shape buckets for AOT-compiled executables. Requests are padded up
# to the nearest bucket by the rust coordinator.
PREFILL_BUCKETS: List[int] = [32, 128, 512, 2048]
DECODE_BUCKETS: List[int] = [32, 128, 512, 2048]  # max cache length
LOGPROB_BUCKET: int = 96  # MCQ eval sequences are short
PROBE_BUCKET: int = 8  # first-5-token probe, padded to 8
PROBE_TOKENS: int = 5  # paper §3.3: cluster after five tokens
ANALYZE_BUCKET: int = 128  # offline analysis / figures 2,6,7,8,9,13

# DejaVu head-sparsity ratios reproduced from Tables 1-3.
DEJAVU_SPARSITIES: List[int] = [10, 30, 50]

# Figure-1 / Figure-14 sweep: uniform cluster counts (the paper sweeps
# 4/8/16/24 of 32 heads on LLaMA-7B; we sweep the same fractions of H=16).
UNIFORM_K_SWEEP: List[int] = [2, 4, 8, 12]

# SpAtten cascade token-pruning schedule: fraction of tokens kept entering
# each layer (cascade: monotone non-increasing), plus fraction of heads kept.
SPATTEN_TOKEN_KEEP: List[float] = [1.0, 1.0, 0.75, 0.625, 0.5, 0.375]
SPATTEN_HEAD_KEEP: float = 0.75


def manifest_dict(cfg: ModelConfig) -> Dict:
    """Base manifest (artifact entries get appended by aot.py)."""
    return {
        "model": asdict(cfg),
        "n_params": cfg.n_params,
        "probe_tokens": PROBE_TOKENS,
        "probe_bucket": PROBE_BUCKET,
        "analyze_bucket": ANALYZE_BUCKET,
        "logprob_bucket": LOGPROB_BUCKET,
        "prefill_buckets": PREFILL_BUCKETS,
        "decode_buckets": DECODE_BUCKETS,
        "dejavu_sparsities": DEJAVU_SPARSITIES,
        "uniform_k_sweep": UNIFORM_K_SWEEP,
        "spatten_token_keep": SPATTEN_TOKEN_KEEP,
        "spatten_head_keep": SPATTEN_HEAD_KEEP,
        "artifacts": [],
    }
