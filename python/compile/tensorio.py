"""`.cbt` ("CHAI binary tensors") file format — the weight/activation
interchange between the python compile path and the rust runtime.

Layout:
    magic  b"CBT1"
    u32 LE header length
    header: UTF-8 JSON  {"tensors": [{name, dtype, shape, offset, nbytes}]}
    data section: raw little-endian C-order buffers, each 64-byte aligned,
                  offsets relative to the start of the data section.

Mirrored by ``rust/src/tensor/io.rs``; roundtrip-tested from both sides.
"""

import json
import struct
from typing import Dict

import numpy as np

MAGIC = b"CBT1"
_DTYPES = {"float32": "f32", "int32": "i32"}
_NP = {"f32": np.float32, "i32": np.int32}
_ALIGN = 64


def save(path: str, tensors: Dict[str, np.ndarray]) -> None:
    entries = []
    offset = 0
    bufs = []
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        if arr.dtype == np.float64:
            arr = arr.astype(np.float32)
        if arr.dtype == np.int64:
            arr = arr.astype(np.int32)
        dt = _DTYPES.get(arr.dtype.name)
        if dt is None:
            raise ValueError(f"unsupported dtype {arr.dtype} for tensor {name!r}")
        raw = arr.tobytes()
        pad = (-offset) % _ALIGN
        offset += pad
        bufs.append((pad, raw))
        entries.append({
            "name": name, "dtype": dt, "shape": list(arr.shape),
            "offset": offset, "nbytes": len(raw),
        })
        offset += len(raw)
    header = json.dumps({"tensors": entries}).encode("utf-8")
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for pad, raw in bufs:
            f.write(b"\0" * pad)
            f.write(raw)


def load(path: str) -> Dict[str, np.ndarray]:
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:4] != MAGIC:
        raise ValueError(f"{path}: bad magic {blob[:4]!r}")
    (hlen,) = struct.unpack("<I", blob[4:8])
    header = json.loads(blob[8:8 + hlen].decode("utf-8"))
    data = blob[8 + hlen:]
    out = {}
    for e in header["tensors"]:
        buf = data[e["offset"]:e["offset"] + e["nbytes"]]
        arr = np.frombuffer(buf, dtype=_NP[e["dtype"]]).reshape(e["shape"])
        out[e["name"]] = arr.copy()
    return out
