"""Export golden fixtures pinning the Rust reference backend to the
pure-jnp oracles.

The Rust side (``rust/src/runtime/refkernels.rs``) re-implements the
attention kernels of ``kernels/ref.py`` and the model primitives of
``model.py``; these fixtures are the cross-language contract. Each case is
one ``.cbt`` file under ``rust/tests/golden/`` holding the seeded inputs
and the jnp outputs; ``rust/tests/golden.rs`` replays the inputs through
the Rust kernels and asserts agreement to 1e-5, and
``python/tests/test_golden_export.py`` regenerates the cases and diffs
them against the committed files so the contract cannot drift silently.

Regenerate (from ``python/``):  python -m compile.export_golden
"""

import os

import jax.numpy as jnp
import numpy as np

from . import model as M
from . import tensorio
from .kernels import ref

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "golden")

# (name, h, k, tq, tk, dh, q_offset, length, seed) — prefill-shaped,
# decode-shaped (tq=1 against a longer cache) and a ragged length.
ATTENTION_CASES = [
    ("attn_prefill", 4, 2, 6, 6, 4, 0, 5, 0),
    ("attn_decode", 4, 3, 1, 8, 4, 7, 8, 1),
    ("attn_ragged", 3, 2, 5, 5, 2, 0, 3, 2),
]


def attention_case(name, h, k, tq, tk, dh, q_offset, length, seed):
    rng = np.random.default_rng(seed)

    def rand(*shape):
        return rng.standard_normal(shape).astype(np.float32)

    q = rand(h, tq, dh)
    kk = rand(h, tk, dh)
    v = rand(h, tk, dh)
    # contiguous-block membership; representative = first head per cluster
    membership = np.array([min(i * k // h, k - 1) for i in range(h)], np.int32)
    rep_heads = np.array(
        [int(np.argmax(membership == j)) for j in range(k)], np.int32)
    q_rep = q[rep_heads]
    k_rep = kk[rep_heads]

    mha_out, mha_probs = ref.mha_attention_ref(
        jnp.asarray(q), jnp.asarray(kk), jnp.asarray(v), q_offset, length)
    rep_scores = ref.attention_scores_ref(
        jnp.asarray(q_rep), jnp.asarray(k_rep), q_offset, length)
    chai_out, chai_probs = ref.clustered_attention_ref(
        jnp.asarray(q_rep), jnp.asarray(k_rep), jnp.asarray(v),
        jnp.asarray(membership), q_offset, length)
    qkv_out, _ = ref.clustered_attention_qkv_ref(
        jnp.asarray(q_rep), jnp.asarray(k_rep), jnp.asarray(v),
        jnp.asarray(membership), jnp.asarray(rep_heads), q_offset, length)

    return {
        "q": q, "k": kk, "v": v,
        "membership": membership, "rep_heads": rep_heads,
        # shape [1] (tensorio's ascontiguousarray promotes 0-d anyway)
        "q_offset": np.array([q_offset], np.int32),
        "length": np.array([length], np.int32),
        "mha_out": np.asarray(mha_out),
        "mha_probs": np.asarray(mha_probs),
        "rep_scores": np.asarray(rep_scores),
        "chai_out": np.asarray(chai_out),
        "chai_probs": np.asarray(chai_probs),
        "qkv_out": np.asarray(qkv_out),
    }


def primitives_case(seed=7):
    """rmsnorm / rope / swiglu from model.py — the non-attention pieces
    the Rust interpreter re-implements."""
    rng = np.random.default_rng(seed)

    def rand(*shape):
        return rng.standard_normal(shape).astype(np.float32)

    t, d, f = 5, 8, 12
    x = rand(t, d)
    norm_w = (1.0 + 0.1 * rand(d)).astype(np.float32)
    g, tr, dh = 2, 4, 6
    rx = rand(g, tr, dh)
    positions = np.arange(3, 3 + tr, dtype=np.int32)
    wg, wu, wd = rand(d, f), rand(d, f), rand(f, d)
    return {
        "x": x,
        "norm_w": norm_w,
        "rmsnorm_out": np.asarray(M.rmsnorm(jnp.asarray(x), jnp.asarray(norm_w))),
        "rope_x": rx,
        "positions": positions,
        "rope_out": np.asarray(M.rope(jnp.asarray(rx), jnp.asarray(positions))),
        "wg": wg, "wu": wu, "wd": wd,
        "swiglu_out": np.asarray(M.swiglu(jnp.asarray(x), jnp.asarray(wg),
                                          jnp.asarray(wu), jnp.asarray(wd))),
    }


def all_cases():
    cases = {name: attention_case(name, *rest)
             for name, *rest in ATTENTION_CASES}
    cases["primitives"] = primitives_case()
    return cases


def main():
    os.makedirs(OUT_DIR, exist_ok=True)
    for name, tensors in all_cases().items():
        path = os.path.join(OUT_DIR, f"{name}.cbt")
        tensorio.save(path, tensors)
        print(f"wrote {path} ({len(tensors)} tensors)")


if __name__ == "__main__":
    main()
