"""From-scratch training of the tiny LLaMA-style model on the synthetic
corpus (build-time only; see DESIGN.md §Substitutions — this is the
LLaMA-7B stand-in).

Hand-rolled AdamW + cosine schedule (optax is not available in the image).
Saves weights to ``artifacts/weights.cbt`` and the loss curve to
``artifacts/train_log.json``; skipped by ``make artifacts`` when the
checkpoint already exists.

Run:  python -m compile.train [--steps N] [--out DIR] [--smoke]
"""

import argparse
import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import data, tensorio, tokenizer
from .configs import ModelConfig, TrainConfig
from .model import forward_train, grad_mask, init_params


def pack_corpus(docs, seq_len, rng):
    """Concatenate BOS+doc+EOS streams and chunk into [N, seq_len+1]."""
    stream = []
    for d in docs:
        stream.extend(tokenizer.encode(d, bos=True, eos=True))
    n = (len(stream) - 1) // seq_len
    arr = np.array(stream[: n * seq_len + 1], dtype=np.int32)
    chunks = np.stack([arr[i * seq_len:(i + 1) * seq_len + 1]
                       for i in range(n)])
    rng.shuffle(chunks)
    return chunks  # [N, seq_len+1]


def loss_fn(params, cfg, batch):
    """Mean next-token cross entropy."""
    inputs, targets = batch[:, :-1], batch[:, 1:]
    logits = forward_train(params, cfg, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Tied Q/K reparametrization (redundancy induction, DESIGN.md §Substitutions)
#
# Head-score redundancy is emergent at LLM scale; freely-trained tiny models
# decorrelate their heads (measured: mean pairwise corr < 0.25 after 300
# steps). To reproduce the *structure* the paper exploits we train with the
# redundancy built in: same-group heads share ONE trainable Q/K base plus a
# small fixed jitter, so clustered scores survive training by construction
# (a GQA-like tying, but with per-head jitter so clustering is non-trivial
# and CHAI's accuracy deltas stay non-zero). The exported weights are the
# materialized flat per-head matrices — the serving stack sees ordinary MHA.
# ---------------------------------------------------------------------------

def tied_init(cfg: ModelConfig, key):
    """Returns (trainable, static): trainable has per-group q/k bases plus
    all ordinary params; static has the fixed jitter (and frozen uniform-
    head matrices for the OPT variant)."""
    from .model import init_params  # ordinary init for non-attention params
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    flat = init_params(cfg, key)
    trainable, static = {}, {}
    keys = iter(jax.random.split(jax.random.fold_in(key, 1),
                                 6 * cfg.n_layers))
    n_act = h - cfg.uniform_heads
    for i in range(cfg.n_layers):
        g = cfg.init_head_groups[i % len(cfg.init_head_groups)]
        for w in ("q", "k"):
            trainable[f"l{i}.{w}base"] = (
                jax.random.normal(next(keys), (g, d, dh), jnp.float32)
                / jnp.sqrt(jnp.float32(d)))
            static[f"l{i}.{w}noise"] = (
                jax.random.normal(next(keys), (n_act, d, dh), jnp.float32)
                * cfg.init_group_noise)
            if cfg.uniform_heads:
                static[f"l{i}.{w}frozen"] = (
                    jax.random.normal(next(keys),
                                      (cfg.uniform_heads, d, dh),
                                      jnp.float32)
                    / jnp.sqrt(jnp.float32(d)) * 0.02)
    for name, val in flat.items():
        if ".wq" in name or ".wk" in name:
            continue  # replaced by bases
        trainable[name] = val
    return trainable, static


def materialize(trainable, static, cfg: ModelConfig):
    """Build the flat per-head param dict the model/export side uses."""
    from .model import head_group_of
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    n_act = h - cfg.uniform_heads
    params = {k: v for k, v in trainable.items() if "base" not in k}
    for i in range(cfg.n_layers):
        g = cfg.init_head_groups[i % len(cfg.init_head_groups)]
        for w in ("q", "k"):
            base = trainable[f"l{i}.{w}base"]
            noise = static[f"l{i}.{w}noise"]
            groups = jnp.asarray(
                [head_group_of(hh, h, g) for hh in range(n_act)], jnp.int32)
            heads = base[groups] + noise  # [n_act, d, dh]
            if cfg.uniform_heads:
                heads = jnp.concatenate(
                    [heads, static[f"l{i}.{w}frozen"]], axis=0)
            params[f"l{i}.w{w}"] = heads.transpose(1, 0, 2).reshape(d, h * dh)
    return params


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, tc: TrainConfig):
    t = state["t"] + 1
    b1, b2 = tc.beta1, tc.beta2
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree.map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + 1e-8)
                                    + tc.weight_decay * p),
        params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def clip_grads(grads, max_norm):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def lr_at(step, tc: TrainConfig):
    warm = jnp.minimum(1.0, (step + 1) / tc.warmup)
    prog = jnp.clip((step - tc.warmup) / max(1, tc.steps - tc.warmup), 0, 1)
    return tc.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


@partial(jax.jit, static_argnames=("cfg", "tc"))
def train_step(trainable, static, opt, batch, step, mask, cfg, tc):
    def tied_loss(tr):
        return loss_fn(materialize(tr, static, cfg), cfg, batch)

    loss, grads = jax.value_and_grad(tied_loss)(trainable)
    grads = jax.tree.map(lambda g, m: g * m, grads, mask)
    grads, gnorm = clip_grads(grads, tc.grad_clip)
    old = trainable
    trainable, opt = adamw_update(trainable, grads, opt, lr_at(step, tc), tc)
    # frozen entries must not move (weight decay would otherwise leak)
    trainable = jax.tree.map(lambda new, o, m: new * m + o * (1 - m),
                             trainable, old, mask)
    return trainable, opt, loss, gnorm


def train(cfg: ModelConfig, tc: TrainConfig, out_dir: str, log_every=10):
    os.makedirs(out_dir, exist_ok=True)
    w = data.build_world()
    rng = np.random.default_rng(tc.seed)
    chunks = pack_corpus(data.corpus_docs(w, tc.corpus_docs), tc.seq_len, rng)
    print(f"model={cfg.name} params={cfg.n_params:,} "
          f"corpus_chunks={len(chunks)} steps={tc.steps}")
    trainable, static = tied_init(cfg, jax.random.PRNGKey(tc.seed))
    # mask freezes the OPT variant's uniform no-op heads (V columns).
    mask = {k: v for k, v in grad_mask(cfg, materialize(trainable, static,
                                                        cfg)).items()
            if k in trainable}
    for k in trainable:
        if k not in mask:
            mask[k] = jnp.ones_like(trainable[k])
    opt = adamw_init(trainable)
    log = []
    t0 = time.time()
    for step in range(tc.steps):
        idx = rng.integers(0, len(chunks), tc.batch_size)
        batch = jnp.asarray(chunks[idx])
        trainable, opt, loss, gnorm = train_step(trainable, static, opt,
                                                 batch, jnp.asarray(step),
                                                 mask, cfg, tc)
        if step % log_every == 0 or step == tc.steps - 1:
            l = float(loss)
            log.append({"step": step, "loss": l,
                        "elapsed_s": round(time.time() - t0, 1)})
            print(f"step {step:4d}  loss {l:.4f}  "
                  f"({time.time() - t0:.0f}s)")
    params = materialize(trainable, static, cfg)
    tensorio.save(os.path.join(out_dir, "weights.cbt"),
                  {k: np.asarray(v) for k, v in params.items()})
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump({"model": cfg.name, "n_params": cfg.n_params,
                   "steps": tc.steps, "final_loss": log[-1]["loss"],
                   "curve": log}, f, indent=1)
    return params, log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="llama", choices=["llama", "opt", "llama33"])
    ap.add_argument("--smoke", action="store_true",
                    help="2-step smoke run for tests")
    args = ap.parse_args()
    from .configs import model_config
    cfg = model_config(args.model)
    tc = TrainConfig()
    if args.smoke:
        tc = TrainConfig(steps=2, batch_size=2, seq_len=32, corpus_docs=50)
    elif args.steps:
        tc = TrainConfig(steps=args.steps)
    train(cfg, tc, args.out)


if __name__ == "__main__":
    main()
