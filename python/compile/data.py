"""Synthetic corpus + evaluation suites (the C4 / PIQA / HellaSwag / ARC /
BoolQ stand-ins, see DESIGN.md §Substitutions).

A small entity-attribute world is rendered through varied sentence templates
into a training corpus. Five evaluation suites query the *same* facts in the
formats of the paper's five benchmarks:

  piqa-syn          2-choice tool-affordance completion       (PIQA)
  hellaswag-syn     4-choice sentence continuation            (HellaSwag)
  arc-challenge-syn 4-choice compositional (friend-of) query  (ARC-Challenge)
  arc-easy-syn      4-choice direct-fact query                (ARC-Easy)
  boolq-syn         yes/no fact verification                  (BoolQ)

Accuracy *deltas* between attention variants are the reproduction target;
absolute accuracy only needs to sit well above chance so degradation is
measurable.
"""

import json
import random
from dataclasses import dataclass, field
from typing import Dict, List

NAMES = [
    "tom", "ana", "raj", "mia", "leo", "zoe",
    "kai", "eva", "sam", "ida", "max", "joy",
]
COLORS = ["red", "blue", "green", "black", "white", "pink", "gray", "gold"]
OBJECTS = ["hat", "book", "lamp", "drum", "kite", "ring", "fork", "vase", "coin", "bell"]
PLACES = ["box", "shed", "attic", "drawer", "garden", "cellar", "closet", "barn"]
FOODS = ["rice", "corn", "plums", "bread", "beans", "dates", "kale", "figs"]
TOOLS = ["hammer", "wrench", "glue", "tape", "needle", "brush", "saw", "clamp"]


@dataclass
class World:
    """One consistent assignment of attributes/relations to entities."""

    color: Dict[str, str] = field(default_factory=dict)
    obj: Dict[str, str] = field(default_factory=dict)
    place: Dict[str, str] = field(default_factory=dict)
    food: Dict[str, str] = field(default_factory=dict)
    tool: Dict[str, str] = field(default_factory=dict)
    friend: Dict[str, str] = field(default_factory=dict)


def build_world(seed: int = 1234) -> World:
    rng = random.Random(seed)
    w = World()
    shuffled = NAMES[:]
    rng.shuffle(shuffled)
    for i, n in enumerate(NAMES):
        w.color[n] = rng.choice(COLORS)
        w.obj[n] = rng.choice(OBJECTS)
        w.place[n] = rng.choice(PLACES)
        w.food[n] = rng.choice(FOODS)
        w.tool[n] = rng.choice(TOOLS)
        # friend is a fixed derangement so friend(n) != n
        w.friend[n] = shuffled[(shuffled.index(n) + 1) % len(shuffled)]
    return w


# ---------------------------------------------------------------------------
# Corpus rendering
# ---------------------------------------------------------------------------

def _fact_sentences(w: World, n: str) -> List[str]:
    return [
        f"the color of {n} is {w.color[n]} .",
        f"{n} has a {w.color[n]} {w.obj[n]} .",
        f"{n} keeps the {w.obj[n]} in the {w.place[n]} .",
        f"{n} likes to eat {w.food[n]} .",
        f"{n} uses the {w.tool[n]} to fix the {w.obj[n]} .",
        f"the friend of {n} is {w.friend[n]} .",
        f"the {w.obj[n]} of {n} is in the {w.place[n]} .",
    ]


def _qa_sentences(w: World, n: str, rng: random.Random) -> List[str]:
    out = [f"question : is the color of {n} {w.color[n]} ? answer : yes ."]
    wrong = rng.choice([c for c in COLORS if c != w.color[n]])
    out.append(f"question : is the color of {n} {wrong} ? answer : no .")
    out.append(f"question : does {n} eat {w.food[n]} ? answer : yes .")
    wrongf = rng.choice([f for f in FOODS if f != w.food[n]])
    out.append(f"question : does {n} eat {wrongf} ? answer : no .")
    return out


def corpus_docs(w: World, n_docs: int, seed: int = 7) -> List[str]:
    """Training documents: 2-5 fact/QA sentences about random entities."""
    rng = random.Random(seed)
    docs = []
    for _ in range(n_docs):
        n_sent = rng.randint(2, 5)
        sents = []
        for _ in range(n_sent):
            n = rng.choice(NAMES)
            pool = _fact_sentences(w, n) + _qa_sentences(w, n, rng)
            sents.append(rng.choice(pool))
        docs.append(" ".join(sents))
    return docs


def analysis_samples(w: World, n_samples: int = 1024, seed: int = 99) -> List[str]:
    """Held-out 'C4' stand-in used for offline elbow/correlation analysis."""
    return corpus_docs(w, n_samples, seed=seed)


# ---------------------------------------------------------------------------
# Evaluation suites
# ---------------------------------------------------------------------------

def _mcq(prompt: str, correct: str, distract: List[str], rng: random.Random, k: int):
    choices = [correct] + rng.sample([d for d in distract if d != correct], k - 1)
    rng.shuffle(choices)
    return {"prompt": prompt, "choices": choices, "label": choices.index(correct)}


def eval_suites(w: World, seed: int = 5) -> Dict[str, List[dict]]:
    rng = random.Random(seed)
    piqa, hella, arc_c, arc_e, boolq = [], [], [], [], []
    for n in NAMES:
        # PIQA-like: 2-choice tool affordance.
        for _ in range(4):
            piqa.append(_mcq(
                f"{n} uses the", f" {w.tool[n]}",
                [f" {t}" for t in TOOLS], rng, 2))
        # HellaSwag-like: 4-choice continuation of a color sentence.
        for _ in range(4):
            hella.append(_mcq(
                f"the color of {n} is", f" {w.color[n]}",
                [f" {c}" for c in COLORS], rng, 4))
        # ARC-Challenge-like: compositional friend-of attribute.
        f = w.friend[n]
        for _ in range(4):
            arc_c.append(_mcq(
                f"the friend of {n} is {f} . the color of the friend of {n} is",
                f" {w.color[f]}", [f" {c}" for c in COLORS], rng, 4))
        # ARC-Easy-like: direct place fact.
        for _ in range(4):
            arc_e.append(_mcq(
                f"{n} keeps the {w.obj[n]} in the", f" {w.place[n]}",
                [f" {p}" for p in PLACES], rng, 4))
        # BoolQ-like: yes/no verification, half true half false.
        boolq.append({
            "prompt": f"question : is the color of {n} {w.color[n]} ? answer :",
            "choices": [" yes", " no"], "label": 0})
        wrong = rng.choice([c for c in COLORS if c != w.color[n]])
        boolq.append({
            "prompt": f"question : is the color of {n} {wrong} ? answer :",
            "choices": [" yes", " no"], "label": 1})
        boolq.append({
            "prompt": f"question : does {n} eat {w.food[n]} ? answer :",
            "choices": [" yes", " no"], "label": 0})
        wrongf = rng.choice([x for x in FOODS if x != w.food[n]])
        boolq.append({
            "prompt": f"question : does {n} eat {wrongf} ? answer :",
            "choices": [" yes", " no"], "label": 1})
    return {
        "piqa-syn": piqa,
        "hellaswag-syn": hella,
        "arc-challenge-syn": arc_c,
        "arc-easy-syn": arc_e,
        "boolq-syn": boolq,
    }


def write_eval_files(out_dir: str, w: World) -> None:
    import os

    os.makedirs(out_dir, exist_ok=True)
    for name, items in eval_suites(w).items():
        with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
            json.dump({"name": name, "items": items}, f, indent=1)
