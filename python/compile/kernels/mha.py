"""L1 Pallas kernel: dense multi-head attention (the MHA baseline).

Grid/tiling plan (the TPU mapping — see DESIGN.md §Hardware-Adaptation):
grid = (head, query-block). Each program holds in VMEM one query tile
``[block_q, dh]``, the head's full K and V panels ``[Tk, dh]`` and the score
tile ``[block_q, Tk]``. For the reproduction config (dh=8..16, Tk ≤ 2048,
block_q = 128) that is ≤ ~1.2 MiB f32 per program — comfortably inside the
~16 MiB VMEM budget, so no streaming-softmax (flash) accumulation pass is
needed; QKᵀ and A·V are each a single MXU contraction per tile.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the interpreter into plain
HLO (loops of dynamic-slice + dot), which is what ``aot.py`` exports and the
rust runtime executes. Correctness oracle: ``ref.mha_attention_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _attn_kernel(qo_ref, len_ref, q_ref, k_ref, v_ref, o_ref, *, block_q, dh,
                 with_probs=False, p_ref=None):
    """One (head, q-block) program: masked softmax(qKᵀ)·V."""
    iq = pl.program_id(1)
    q = q_ref[0]                       # [block_q, dh]
    k = k_ref[0]                       # [Tk, dh]
    v = v_ref[0]                       # [Tk, dh]
    tk = k.shape[0]
    scores = jnp.dot(q, k.T) / jnp.sqrt(jnp.float32(dh))   # [block_q, Tk]
    qpos = qo_ref[0] + iq * block_q + jax.lax.iota(jnp.int32, block_q)[:, None]
    kpos = jax.lax.iota(jnp.int32, tk)[None, :]
    mask = (kpos <= qpos) & (kpos < len_ref[0])
    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(probs, v)
    if with_probs:
        p_ref[0] = probs


def _block_q_for(tq: int, block_q: int) -> int:
    bq = min(block_q, tq)
    while tq % bq != 0:  # buckets are powers of two; this only trips in tests
        bq -= 1
    return bq


@functools.partial(jax.jit, static_argnames=("block_q", "with_probs"))
def mha_attention(q, k, v, q_offset, length, *, block_q=128, with_probs=False):
    """Dense MHA. q: [H,Tq,dh], k/v: [H,Tk,dh]; scalars q_offset/length.

    Returns out [H,Tq,dh] (and probs [H,Tq,Tk] when ``with_probs`` — only
    used by the probe/analyze artifacts where Tk is small).
    """
    h, tq, dh = q.shape
    tk = k.shape[1]
    bq = _block_q_for(tq, block_q)
    grid = (h, tq // bq)
    qo = jnp.asarray(q_offset, jnp.int32).reshape(1)
    ln = jnp.asarray(length, jnp.int32).reshape(1)

    out_shapes = [jax.ShapeDtypeStruct((h, tq, dh), jnp.float32)]
    out_specs = [pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0))]
    if with_probs:
        out_shapes.append(jax.ShapeDtypeStruct((h, tq, tk), jnp.float32))
        out_specs.append(pl.BlockSpec((1, bq, tk), lambda ih, iq: (ih, iq, 0)))

    kernel = functools.partial(
        _attn_kernel, block_q=bq, dh=dh, with_probs=with_probs)
    if with_probs:
        def kernel(qo_ref, len_ref, q_ref, k_ref, v_ref, o_ref, p_ref):
            _attn_kernel(qo_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         block_q=bq, dh=dh, with_probs=True, p_ref=p_ref)

    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda ih, iq: (0,)),        # q_offset
            pl.BlockSpec((1,), lambda ih, iq: (0,)),        # length
            pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0)),  # q tile
            pl.BlockSpec((1, tk, dh), lambda ih, iq: (ih, 0, 0)),   # K panel
            pl.BlockSpec((1, tk, dh), lambda ih, iq: (ih, 0, 0)),   # V panel
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=True,
    )(qo, ln, q, k, v)
    if with_probs:
        return res[0], res[1]
    return res[0]
