"""L1 Pallas kernels: CHAI clustered-head attention (the paper's hot path).

Two-stage kernel design (DESIGN.md §Hardware-Adaptation):

  stage 1 — ``clustered_scores``: grid (cluster, q-block). Computes the
    masked softmax(Q_rep·K_repᵀ) score tile once **per cluster** instead of
    once per head — this is CHAI's compute saving (K/H of the MHA score
    FLOPs) and its K-cache saving (K panels exist only for representatives,
    so HBM→VMEM key traffic shrinks by the same factor).

  stage 2 — ``broadcast_av``: grid (head, q-block). Each member head reuses
    its representative's score tile (selected through the ``membership``
    vector) against its **own** V panel (the paper keeps all V vectors;
    Table 4 shows pruning V too costs accuracy — that variant is
    ``broadcast_av_qkv``). The broadcast never materializes H full score
    matrices in HBM: the representative's tile is loaded once per member via
    a dynamic slice on the cluster axis. On a real TPU this index would come
    from scalar-prefetch (PrefetchScalarGridSpec) so the DMA engine can
    schedule the gather; under ``interpret=True`` we keep the portable
    dynamic-slice form, which lowers to identical HLO semantics.

VMEM per program (config dh=8..16, Tk ≤ 2048, block_q=128, K ≤ 16):
  stage 1: q tile 8 KiB + K panel 128 KiB + score tile 1 MiB
  stage 2: score panel K·block_q·Tk ≤ 16 MiB worst case → block_q drops to
           32 for Tk = 2048 to stay ≤ 4 MiB (see ``_block_q_for_bcast``).

Correctness oracles: ``ref.clustered_attention_ref`` / ``_qkv_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _scores_kernel(qo_ref, len_ref, q_ref, k_ref, p_ref, *, block_q, dh):
    iq = pl.program_id(1)
    q = q_ref[0]                      # [block_q, dh]
    k = k_ref[0]                      # [Tk, dh]
    tk = k.shape[0]
    scores = jnp.dot(q, k.T) / jnp.sqrt(jnp.float32(dh))
    qpos = qo_ref[0] + iq * block_q + jax.lax.iota(jnp.int32, block_q)[:, None]
    kpos = jax.lax.iota(jnp.int32, tk)[None, :]
    mask = (kpos <= qpos) & (kpos < len_ref[0])
    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores)
    p_ref[0] = e / jnp.sum(e, axis=-1, keepdims=True)


def _block_q_for(tq: int, block_q: int) -> int:
    bq = min(block_q, tq)
    while tq % bq != 0:
        bq -= 1
    return bq


@functools.partial(jax.jit, static_argnames=("block_q",))
def clustered_scores(q_rep, k_rep, q_offset, length, *, block_q=128):
    """Per-cluster attention probabilities.

    q_rep/k_rep: [K, T, dh] representative-head projections.
    Returns probs [K, Tq, Tk].
    """
    kk, tq, dh = q_rep.shape
    tk = k_rep.shape[1]
    bq = _block_q_for(tq, block_q)
    qo = jnp.asarray(q_offset, jnp.int32).reshape(1)
    ln = jnp.asarray(length, jnp.int32).reshape(1)
    return pl.pallas_call(
        functools.partial(_scores_kernel, block_q=bq, dh=dh),
        grid=(kk, tq // bq),
        in_specs=[
            pl.BlockSpec((1,), lambda ic, iq: (0,)),
            pl.BlockSpec((1,), lambda ic, iq: (0,)),
            pl.BlockSpec((1, bq, dh), lambda ic, iq: (ic, iq, 0)),
            pl.BlockSpec((1, tk, dh), lambda ic, iq: (ic, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, tk), lambda ic, iq: (ic, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((kk, tq, tk), jnp.float32),
        interpret=True,
    )(qo, ln, q_rep, k_rep)


def _bcast_kernel(mem_ref, p_ref, v_ref, o_ref):
    """One (head, q-block) program: o_h = probs[membership[h]] · V_h."""
    ih = pl.program_id(0)
    m = mem_ref[ih]
    # Dynamic slice on the cluster axis — scalar-prefetch analogue.
    probs = pl.load(p_ref, (pl.ds(m, 1), slice(None), slice(None)))[0]
    o_ref[0] = jnp.dot(probs, v_ref[0])


def _block_q_for_bcast(tq: int, tk: int, kk: int) -> int:
    """Shrink the query block so the K·bq·Tk score panel stays ≤ ~4 MiB."""
    budget = 4 * 1024 * 1024 // 4  # f32 elements
    bq = _block_q_for(tq, 128)
    while bq > 1 and kk * bq * tk > budget:
        bq //= 2
    while tq % bq != 0:
        bq -= 1
    return bq


@jax.jit
def broadcast_av(probs, v, membership):
    """Score broadcast + per-head A·V. probs [K,Tq,Tk], v [H,Tk,dh],
    membership [H] int32 → out [H,Tq,dh]."""
    kk, tq, tk = probs.shape
    h, _, dh = v.shape
    bq = _block_q_for_bcast(tq, tk, kk)
    return pl.pallas_call(
        _bcast_kernel,
        grid=(h, tq // bq),
        in_specs=[
            pl.BlockSpec((h,), lambda ih, iq: (0,)),                 # membership
            pl.BlockSpec((kk, bq, tk), lambda ih, iq: (0, iq, 0)),   # score panel
            pl.BlockSpec((1, tk, dh), lambda ih, iq: (ih, 0, 0)),    # V panel
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, tq, dh), jnp.float32),
        interpret=True,
    )(membership.astype(jnp.int32), probs, v)


def _bcast_qkv_kernel(mem_ref, p_ref, v_ref, o_ref):
    """CHAI-QKV ablation: V comes from the representative too. v_ref is the
    already-gathered representative V panel [K, Tk, dh]."""
    ih = pl.program_id(0)
    m = mem_ref[ih]
    probs = pl.load(p_ref, (pl.ds(m, 1), slice(None), slice(None)))[0]
    v = pl.load(v_ref, (pl.ds(m, 1), slice(None), slice(None)))[0]
    o_ref[0] = jnp.dot(probs, v)


@jax.jit
def broadcast_av_qkv(probs, v_rep, membership, n_heads: int = None):
    """Table-4 variant: whole-head reuse. probs [K,Tq,Tk], v_rep [K,Tk,dh]
    (V of representative heads), membership [H] → out [H,Tq,dh]."""
    kk, tq, tk = probs.shape
    _, _, dh = v_rep.shape
    h = membership.shape[0]
    bq = _block_q_for_bcast(tq, tk, kk)
    return pl.pallas_call(
        _bcast_qkv_kernel,
        grid=(h, tq // bq),
        in_specs=[
            pl.BlockSpec((h,), lambda ih, iq: (0,)),
            pl.BlockSpec((kk, bq, tk), lambda ih, iq: (0, iq, 0)),
            pl.BlockSpec((kk, tk, dh), lambda ih, iq: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, tq, dh), jnp.float32),
        interpret=True,
    )(membership.astype(jnp.int32), probs, v_rep)


def clustered_attention(q_rep, k_rep, v, membership, q_offset, length, *,
                        block_q=128):
    """Convenience wrapper: full CHAI attention = stage1 + stage2.

    Returns (out [H,Tq,dh], probs_rep [K,Tq,Tk]).
    """
    probs = clustered_scores(q_rep, k_rep, q_offset, length, block_q=block_q)
    return broadcast_av(probs, v, membership), probs
