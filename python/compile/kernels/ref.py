"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
is asserted allclose against these under pytest + hypothesis sweeps
(``python/tests/test_kernels.py``), and the L2 model can be lowered against
either implementation (``attn_impl='jnp'|'pallas'``) — both must produce the
same HLO-level numerics.

Shapes (unbatched; the serving path is B=1 and L2 vmaps where needed):
    q:   [H, Tq, dh]   queries for H heads (or [K, Tq, dh] representatives)
    k:   [H, Tk, dh]
    v:   [H, Tk, dh]
    membership: [H] int32 in [0, K)  — cluster id of each head
Masking: query i sits at absolute position q_offset + i; key j at position
j. Allowed iff j <= q_offset + i and j < length.
"""

import jax.numpy as jnp

NEG_INF = -1e9


def _mask(tq: int, tk: int, q_offset, length):
    qpos = q_offset + jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    return (kpos <= qpos) & (kpos < length)


def attention_scores_ref(q, k, q_offset, length):
    """softmax(q kᵀ / sqrt(dh)) with causal + length masking.

    q: [G, Tq, dh], k: [G, Tk, dh] -> [G, Tq, Tk] row-stochastic.
    """
    g, tq, dh = q.shape
    tk = k.shape[1]
    scores = jnp.einsum("gqd,gkd->gqk", q, k) / jnp.sqrt(jnp.float32(dh))
    mask = _mask(tq, tk, q_offset, length)[None, :, :]
    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def mha_attention_ref(q, k, v, q_offset, length):
    """Dense multi-head attention. Returns (out [H,Tq,dh], probs [H,Tq,Tk])."""
    probs = attention_scores_ref(q, k, q_offset, length)
    out = jnp.einsum("hqk,hkd->hqd", probs, v)
    return out, probs


def clustered_attention_ref(q_rep, k_rep, v, membership, q_offset, length):
    """CHAI clustered-head attention (paper §3.4).

    Attention scores are computed once per cluster representative
    (q_rep/k_rep: [K, Tq, dh], K = #clusters for this layer), broadcast to
    every member head via ``membership``, and applied to each head's own V
    (the paper keeps all V vectors — Table 4 shows pruning V hurts).

    Returns (out [H, Tq, dh], probs_rep [K, Tq, Tk]).
    """
    probs = attention_scores_ref(q_rep, k_rep, q_offset, length)  # [K,Tq,Tk]
    probs_full = probs[membership]  # [H,Tq,Tk] broadcast to members
    out = jnp.einsum("hqk,hkd->hqd", probs_full, v)
    return out, probs


def clustered_attention_qkv_ref(q_rep, k_rep, v, membership, rep_heads,
                                q_offset, length):
    """Table-4 ablation (CHAI-QKV): V is also taken from the representative
    head, i.e. the whole head is pruned. rep_heads: [K] int32 — original head
    index of each representative (indexes into v)."""
    probs = attention_scores_ref(q_rep, k_rep, q_offset, length)
    v_rep = v[rep_heads]                       # [K,Tk,dh]
    out_rep = jnp.einsum("kqt,ktd->kqd", probs, v_rep)
    return out_rep[membership], probs          # [H,Tq,dh]
