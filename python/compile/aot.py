"""AOT export: lower every (variant × bucket) graph to HLO **text** and
write ``artifacts/manifest.json`` describing the whole artifact set.

HLO text (not ``.serialize()``) is the interchange format: the image's
xla_extension 0.5.1 rejects jax≥0.5 protos with 64-bit instruction ids; the
text parser reassigns ids (see /opt/xla-example/README.md).

Weights are runtime inputs in **sorted tensor-name order** (the order the
rust runtime uploads buffers in, read from the manifest). Python runs once
— ``make artifacts`` — and never on the request path.

Pipeline (paper Fig 5 offline phase):
  1. train (or load) the tiny model                       → weights.cbt
  2. offline cluster identification on held-out samples   → clusters.json
  3. lower all graphs with per-layer k_l baked static     → *.hlo.txt
  4. emit eval suites, analysis samples, fixtures, manifest
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import clustering, data, tensorio, tokenizer
from .configs import (model_config, ANALYZE_BUCKET, DECODE_BUCKETS, DEJAVU_SPARSITIES,
                      LOGPROB_BUCKET, PREFILL_BUCKETS, PROBE_BUCKET,
                      PROBE_TOKENS, SPATTEN_HEAD_KEEP, SPATTEN_TOKEN_KEEP,
                      UNIFORM_K_SWEEP, ModelConfig, TrainConfig,
                      manifest_dict)
from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(name, arr_like):
    a = jax.ShapeDtypeStruct(np.shape(arr_like), np.asarray(arr_like).dtype) \
        if not isinstance(arr_like, jax.ShapeDtypeStruct) else arr_like
    return {"name": name, "dtype": str(a.dtype), "shape": list(a.shape)}


class Exporter:
    def __init__(self, cfg: ModelConfig, params, out_dir: str, impl: str):
        self.cfg = cfg
        self.out = out_dir
        self.impl = impl
        self.weight_names = sorted(params)
        self.weights = [params[n] for n in self.weight_names]
        self.manifest = manifest_dict(cfg)
        self.manifest["weight_order"] = self.weight_names
        self.manifest["attn_impl"] = impl

    def lower(self, name: str, fn, extra_inputs, output_names,
              static_meta=None, impl=None):
        """fn(weights_list, *extras) -> tuple of outputs."""
        impl = impl or self.impl
        t0 = time.time()
        specs = [jax.ShapeDtypeStruct(np.shape(w), np.asarray(w).dtype)
                 for w in self.weights]
        extra_specs = [jax.ShapeDtypeStruct(np.shape(v),
                                            np.asarray(v).dtype)
                       for _, v in extra_inputs]
        lowered = jax.jit(fn, keep_unused=True).lower(specs, *extra_specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out, path), "w") as f:
            f.write(text)
        out_avals = jax.tree.leaves(lowered.out_info)
        outs = [{"name": n, "dtype": str(a.dtype), "shape": list(a.shape)}
                for n, a in zip(output_names, out_avals)]
        assert len(outs) == len(output_names), \
            f"{name}: {len(out_avals)} outputs vs {len(output_names)} names"
        entry = {
            "name": name, "path": path, "impl": impl,
            "inputs": [_spec(n, v) for n, v in extra_inputs],
            "outputs": outs,
            "meta": static_meta or {},
        }
        self.manifest["artifacts"].append(entry)
        print(f"  lowered {name:32s} ({len(text)//1024} KiB, "
              f"{time.time()-t0:.1f}s)")
        return entry


def offline_clusters(cfg, params, out_dir, n_samples=96, seed=0):
    """Paper Fig 10a: analyze held-out samples, elbow per layer."""
    print(f"offline cluster identification ({n_samples} samples)...")
    w = data.build_world()
    samples = data.analysis_samples(w, n_samples, seed=42)
    t = ANALYZE_BUCKET

    @jax.jit
    def analyze(tok, ln):
        return M.analyze_graph(params, cfg, tok, ln)

    feats = [[] for _ in range(cfg.n_layers)]  # per layer: list of [H, T]
    for s in samples:
        ids = tokenizer.encode(s)[:t]
        ln = len(ids)
        ids = ids + [tokenizer.PAD] * (t - ln)
        maps = np.asarray(analyze(jnp.asarray(ids, jnp.int32),
                                  jnp.asarray(ln, jnp.int32)))
        for l in range(cfg.n_layers):
            feats[l].append(maps[l, :, ln - 1, :ln])  # last-query attention
    layers = []
    for l in range(cfg.n_layers):
        f = np.concatenate(feats[l], axis=1)  # [H, sum(ln)]
        layers.append(clustering.cluster_layer(f, seed=seed))
        print(f"  layer {l}: k={layers[l]['k']} "
              f"membership={layers[l]['membership']}")
    blob = {"model": cfg.name, "n_samples": n_samples,
            "k_list": [x["k"] for x in layers], "layers": layers}
    with open(os.path.join(out_dir, "clusters.json"), "w") as f:
        json.dump(blob, f, indent=1)
    return blob


def uniform_clusters(cfg, k):
    """Fig-1 sweep: k uniform clusters per layer, contiguous head blocks
    (membership overwritten at runtime for the random/static sweeps)."""
    h = cfg.n_heads
    mem = [min(i * k // h, k - 1) for i in range(h)]
    reps = sorted(set(mem.index(j) for j in range(k)))
    return [k] * cfg.n_layers, mem, reps


def export_all(cfg, params, clusters, out_dir, impl, buckets=None,
               logprob_only=False):
    ex = Exporter(cfg, params, out_dir, impl)
    mf = ex.manifest
    L, H, dh, V = cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.vocab_size
    k_list = clusters["k_list"]
    k_max = max(k_list)
    mf["k_list"] = k_list
    mf["k_max"] = k_max
    tok_i32 = np.int32(0)

    def wrap(fn):
        def g(wlist, *extras):
            p = dict(zip(ex.weight_names, wlist))
            return fn(p, *extras)
        return g

    # --- probe + analysis -------------------------------------------------
    ex.lower("probe_mha",
             wrap(lambda p, tok, ln:
                  (M.probe_graph(p, cfg, tok, ln, impl=ex.impl),)),
             [("tokens", np.zeros(PROBE_BUCKET, np.int32)),
              ("length", tok_i32)],
             ["probe_maps"], {"bucket": PROBE_BUCKET})
    ex.lower("analyze",
             wrap(lambda p, tok, ln:
                  (M.analyze_graph(p, cfg, tok, ln),)),
             [("tokens", np.zeros(ANALYZE_BUCKET, np.int32)),
              ("length", tok_i32)],
             ["attn_maps"], {"bucket": ANALYZE_BUCKET}, impl="jnp")

    # --- logprob (eval scoring) family ------------------------------------
    T = LOGPROB_BUCKET
    toks = np.zeros(T, np.int32)
    ex.lower("logprob_mha",
             wrap(lambda p, tok, ln:
                  (M.logprob_mha_graph(p, cfg, tok, ln, impl="jnp"),)),
             [("tokens", toks), ("length", tok_i32)],
             ["logits"], {"bucket": T}, impl="jnp")
    mem0 = np.zeros((L, H), np.int32)
    reps0 = np.zeros((L, k_max), np.int32)
    for nm, qkv in [("logprob_chai", False), ("logprob_chai_qkv", True)]:
        ex.lower(nm,
                 wrap(lambda p, tok, ln, mem, reps, qkv=qkv:
                      (M.logprob_chai_graph(p, cfg, tok, ln, mem, reps,
                                            k_list, impl="jnp", qkv=qkv),)),
                 [("tokens", toks), ("length", tok_i32),
                  ("membership", mem0), ("reps", reps0)],
                 ["logits"], {"bucket": T, "k_list": k_list, "qkv": qkv})
    for k in UNIFORM_K_SWEEP:
        kl, _, _ = uniform_clusters(cfg, k)
        ex.lower(f"logprob_chai_k{k}",
                 wrap(lambda p, tok, ln, mem, reps, kl=kl:
                      (M.logprob_chai_graph(p, cfg, tok, ln, mem, reps, kl,
                                            impl="jnp"),)),
                 [("tokens", toks), ("length", tok_i32),
                  ("membership", mem0),
                  ("reps", np.zeros((L, k), np.int32))],
                 ["logits"], {"bucket": T, "k_list": kl, "uniform_k": k})
    for sp in DEJAVU_SPARSITIES:
        n_keep = max(1, round(H * (100 - sp) / 100))
        ex.lower(f"logprob_dejavu_s{sp}",
                 wrap(lambda p, tok, ln, kept:
                      (M.logprob_dejavu_graph(p, cfg, tok, ln, kept,
                                              impl="jnp"),)),
                 [("tokens", toks), ("length", tok_i32),
                  ("kept", np.zeros((L, n_keep), np.int32))],
                 ["logits"], {"bucket": T, "sparsity": sp,
                              "n_keep": n_keep})
    # cascade schedule stretched/truncated to this model's depth
    spatten_keep = [SPATTEN_TOKEN_KEEP[min(i, len(SPATTEN_TOKEN_KEEP) - 1)]
                    for i in range(L)]
    ex.lower("logprob_spatten",
             wrap(lambda p, tok, ln:
                  (M.logprob_spatten_graph(p, cfg, tok, ln,
                                           spatten_keep,
                                           SPATTEN_HEAD_KEEP),)),
             [("tokens", toks), ("length", tok_i32)],
             ["logits"], {"bucket": T,
                          "token_keep": spatten_keep,
                          "head_keep": SPATTEN_HEAD_KEEP}, impl="jnp")

    if logprob_only:
        return ex

    # --- prefill + decode (serving/latency) family ------------------------
    for T in (buckets or PREFILL_BUCKETS):
        toks = np.zeros(T, np.int32)
        # Prefill + scoring artifacts use the XLA-fused jnp path: under
        # interpret=True the two-stage clustered kernel re-streams the
        # score panel per query block (no scalar-prefetch on CPU), which
        # measured 68x slower at T=2048 — see EXPERIMENTS.md §Perf. The
        # decode hot loop stays on the L1 Pallas kernels.
        ex.lower(f"prefill_mha_t{T}",
                 wrap(lambda p, tok, ln:
                      M.prefill_mha_graph(p, cfg, tok, ln, impl="jnp")),
                 [("tokens", toks), ("length", tok_i32)],
                 ["logits", "kcache", "vcache"],
                 {"bucket": T}, impl="jnp")
        ex.lower(f"prefill_chai_t{T}",
                 wrap(lambda p, tok, ln, mem, reps:
                      M.prefill_chai_graph(p, cfg, tok, ln, mem, reps,
                                           k_list, impl="jnp")),
                 [("tokens", toks), ("length", tok_i32),
                  ("membership", mem0), ("reps", reps0)],
                 ["logits"] + [f"krep{i}" for i in range(L)] + ["vcache"],
                 {"bucket": T, "k_list": k_list})
        kc = np.zeros((L, H, T, dh), np.float32)
        ex.lower(f"decode_mha_t{T}",
                 wrap(lambda p, tok, pos, kc, vc:
                      M.decode_mha_graph(p, cfg, tok, pos, kc, vc,
                                         impl=ex.impl)),
                 [("token", tok_i32), ("pos", tok_i32),
                  ("kcache", kc), ("vcache", kc)],
                 ["logits", "kcache", "vcache"], {"bucket": T})
        kreps = [np.zeros((k_list[i], T, dh), np.float32) for i in range(L)]
        ex.lower(f"decode_chai_t{T}",
                 wrap(lambda p, tok, pos, *rest:
                      M.decode_chai_graph(p, cfg, tok, pos,
                                          list(rest[:L]), rest[L],
                                          rest[L + 1], rest[L + 2],
                                          k_list, impl=ex.impl)),
                 [("token", tok_i32), ("pos", tok_i32)]
                 + [(f"krep{i}", kreps[i]) for i in range(L)]
                 + [("vcache", kc), ("membership", mem0), ("reps", reps0)],
                 ["logits"] + [f"krep{i}" for i in range(L)] + ["vcache"],
                 {"bucket": T, "k_list": k_list})
    return ex


def export_paged_stubs(ex, cfg, buckets, block_size=16, pool_blocks_per_bucket=4):
    """Block-table decode artifacts — **lowering stubs**, gated behind
    ``--paged-artifacts``.

    The rust reference backend already serves block-table-native decode
    end to end (``runtime::Backend::{decode_paged, prefill_paged}``:
    K,V read and appended in place against the block pool, ragged
    cross-request batching, zero bucket-shaped copies). The XLA path
    still executes the bucket-shaped ``decode_*_t{T}`` artifacts, so the
    rust ``XlaBackend`` keeps ``supports_paged() == false`` until fused
    ``decode_{mha,chai}_paged_t*`` graphs exist.

    This lowers the *gather stage* of that future artifact — block table
    → contiguous cache, i.e. the per-step copy the engine currently does
    on the host, moved on-device — so the fused kernel can land
    incrementally on top of it. On a real TPU the block gather would
    ride scalar-prefetch (``pltpu.PrefetchScalarGridSpec``, see
    ``kernels/chai.py``) so the DMA engine schedules block fetches;
    under ``interpret=True``/CPU it lowers to plain dynamic-gather HLO,
    which is what we export here.

    Pool shape is static per bucket (XLA needs fixed shapes):
    ``[pool_max, L, H, B, dh]`` with ``pool_max = (T/B) *
    pool_blocks_per_bucket`` — enough for a ``pool_blocks_per_bucket``-
    deep batch sharing one pool tensor.
    """
    L, H, dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
    B = block_size
    for T in buckets:
        nb = T // B
        if nb == 0 or T % B != 0:
            # the gather reshapes [nb, ..., B, dh] -> [..., nb*B, dh],
            # which only covers T when the bucket is block-aligned
            print(f"  skipping paged stub for bucket {T} "
                  f"(not a multiple of block_size {B})")
            continue
        pool_max = nb * pool_blocks_per_bucket

        def gather(wlist, pool_k, pool_v, table, T=T, nb=nb):
            # pool_*: [pool_max, L, H, B, dh]; table: [nb] block ids
            k = jnp.take(pool_k, table, axis=0)   # [nb, L, H, B, dh]
            v = jnp.take(pool_v, table, axis=0)
            k = jnp.transpose(k, (1, 2, 0, 3, 4)).reshape(L, H, T, dh)
            v = jnp.transpose(v, (1, 2, 0, 3, 4)).reshape(L, H, T, dh)
            return k, v

        pool0 = np.zeros((pool_max, L, H, B, dh), np.float32)
        ex.lower(f"paged_gather_mha_t{T}", gather,
                 [("pool_k", pool0), ("pool_v", pool0),
                  ("block_table", np.zeros(nb, np.int32))],
                 ["kcache", "vcache"],
                 {"bucket": T, "block_size": B, "pool_max": pool_max,
                  "stub": True}, impl="jnp")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="llama", choices=["llama", "opt", "llama33"])
    ap.add_argument("--impl", default="pallas", choices=["pallas", "jnp"],
                    help="attention impl baked into serving artifacts")
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--cluster-samples", type=int, default=96)
    ap.add_argument("--buckets", type=int, nargs="*", default=None)
    ap.add_argument("--logprob-only", action="store_true")
    ap.add_argument("--paged-artifacts", action="store_true",
                    help="also lower the block-table decode artifact stubs "
                         "(gather stage; the rust XLA backend does not "
                         "consume them yet — see export_paged_stubs)")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    cfg = model_config(args.model)

    wpath = os.path.join(out, "weights.cbt")
    if os.path.exists(wpath):
        print(f"loading weights from {wpath}")
        params = {k: jnp.asarray(v) for k, v in tensorio.load(wpath).items()}
    else:
        from .train import train
        params, _ = train(cfg, TrainConfig(steps=args.train_steps), out)
        params = {k: jnp.asarray(np.asarray(v)) for k, v in params.items()}

    cpath = os.path.join(out, "clusters.json")
    if os.path.exists(cpath):
        clusters = json.load(open(cpath))
    else:
        clusters = offline_clusters(cfg, params, out,
                                    n_samples=args.cluster_samples)

    ex = export_all(cfg, params, clusters, out, args.impl,
                    buckets=args.buckets, logprob_only=args.logprob_only)
    if args.paged_artifacts and not args.logprob_only:
        export_paged_stubs(ex, cfg, args.buckets or PREFILL_BUCKETS)

    # eval suites + analysis samples + tokenizer fixture for rust
    w = data.build_world()
    data.write_eval_files(os.path.join(out, "eval"), w)
    with open(os.path.join(out, "analysis_samples.json"), "w") as f:
        json.dump({"samples": data.analysis_samples(w, 1024)}, f)
    fixture = [{"text": t, "ids": tokenizer.encode(t)}
               for t in ["the color of tom is red .", "question : yes"]]
    with open(os.path.join(out, "tokenizer_fixture.json"), "w") as f:
        json.dump({"bos": tokenizer.BOS, "eos": tokenizer.EOS,
                   "pad": tokenizer.PAD, "vocab": tokenizer.VOCAB_SIZE,
                   "cases": fixture}, f, indent=1)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(ex.manifest, f, indent=1)
    print(f"wrote {len(ex.manifest['artifacts'])} artifacts + manifest to "
          f"{out}/")


if __name__ == "__main__":
    main()
