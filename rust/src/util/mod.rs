//! Offline-build substrates: JSON, PRNG, CLI args, statistics, logging and
//! a small property-testing harness (the vendored crates.io mirror only
//! ships `xla` + `anyhow`, so these are built from scratch — see DESIGN.md
//! system inventory).

pub mod args;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Monotonic wall-clock helper used by benches and metrics.
pub fn now_ms() -> f64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap()
        .as_secs_f64()
        * 1e3
}
