//! Offline-build substrates: JSON, PRNG, CLI args, statistics, logging and
//! a small property-testing harness (the vendored crates.io mirror only
//! ships `xla` + `anyhow`, so these are built from scratch — see DESIGN.md
//! system inventory).

pub mod args;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Monotonic clock helper used by benches and metrics: milliseconds since
/// the first call in this process. Anchored to a process-start `Instant`
/// (NOT `SystemTime`, which jumps under NTP slew and can hand negative
/// durations to the scheduler wait metrics and bench p99/TTFT gates).
/// Every caller takes differences of two readings, so the epoch is
/// irrelevant — only monotonicity matters.
pub fn now_ms() -> f64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e3
}
