//! Miniature property-testing harness (the real `proptest` crate is not
//! vendored). Runs a property over N seeded random cases; on failure it
//! reports the failing seed so the case replays deterministically.
//! Used for the coordinator/kv/clustering invariants per the repro
//! mandate ("proptest on coordinator invariants").

use super::rng::Rng;

/// Run `prop` over `cases` seeded RNGs; panic with the failing seed.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = 0x5eed_0000 + case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper returning Err for `check` properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("fail", 10, |rng| {
            let x = rng.below(100);
            if x > 1 {
                Err(format!("x = {x}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn rng_cases_are_distinct() {
        let mut first = Vec::new();
        check("distinct", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut sorted = first.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), first.len());
    }
}
