//! Tiny CLI argument parser (clap is not vendored). Supports
//! `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key} expects an integer, got {v:?}"),
            },
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key} expects a number, got {v:?}"),
            },
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    pub fn usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad integer {s:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["serve", "--port", "8080", "--verbose", "--x=1"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.usize("port", 0).unwrap(), 8080);
        assert!(a.bool("verbose"));
        assert_eq!(a.str("x", ""), "1");
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize("n", 7).unwrap(), 7);
        assert_eq!(a.str("s", "d"), "d");
        assert!(!a.bool("v"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["--n", "xyz"]);
        assert!(a.usize("n", 0).is_err());
    }

    #[test]
    fn usize_list() {
        let a = parse(&["--buckets", "32,128, 512"]);
        assert_eq!(a.usize_list("buckets", &[]).unwrap(), vec![32, 128, 512]);
        assert_eq!(a.usize_list("other", &[1]).unwrap(), vec![1]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "2"]);
        assert!(a.bool("a"));
        assert_eq!(a.usize("b", 0).unwrap(), 2);
    }
}
