//! Deterministic PRNG (xoshiro256** seeded via SplitMix64) — `rand` is not
//! vendored. Used by the k-means++ sampler, workload generators and the
//! property-testing harness; everything that uses it is seedable so every
//! bench/test is reproducible.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (Poisson inter-arrival times).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Sample an index proportionally to `weights` (k-means++ seeding).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices from [0, n).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..500 {
            let i = r.below(5);
            assert!(i < 5);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(13);
        let w = [0.0, 0.0, 10.0, 0.0];
        for _ in 0..50 {
            assert_eq!(r.weighted(&w), 2);
        }
    }

    #[test]
    fn choose_distinct_unique() {
        let mut r = Rng::new(17);
        let v = r.choose_distinct(10, 4);
        assert_eq!(v.len(), 4);
        let mut s = v.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 4);
    }
}
