//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic escapes (`\uXXXX` is
//! decoded for the BMP). Numbers parse to f64; helper accessors cover the
//! access patterns the manifest/config/eval files need.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn int(&self) -> Result<i64> {
        Ok(self.num()? as i64)
    }

    pub fn usize(&self) -> Result<usize> {
        let n = self.num()?;
        if n < 0.0 {
            bail!("negative where usize expected: {n}");
        }
        Ok(n as usize)
    }

    pub fn boolean(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.arr()?.iter().map(|v| v.usize()).collect()
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.arr()?.iter().map(|v| v.num()).collect()
    }

    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.arr()?.iter().map(|v| Ok(v.str()?.to_string())).collect()
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn from_usizes(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // re-sync to char boundary for multibyte UTF-8
                    let len = utf8_len(c);
                    let bytes = &self.b[self.i - 1..self.i - 1 + len];
                    s.push_str(std::str::from_utf8(bytes)?);
                    self.i += len - 1;
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] at byte {}, found {:?}", self.i, c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar_types() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().arr().unwrap()[2].get("b").unwrap().str().unwrap(),
            "x"
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line\n\"quote\"\tüñí".into());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn accessors_enforce_types() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1], "b": true}"#).unwrap();
        assert_eq!(v.get("n").unwrap().usize().unwrap(), 3);
        assert!(v.get("s").unwrap().num().is_err());
        assert!(v.get("a").unwrap().str().is_err());
        assert!(v.get("b").unwrap().boolean().unwrap());
        assert!(v.get("missing").is_err());
    }

    #[test]
    fn int_serialization_is_exact() {
        assert_eq!(Json::Num(1234567.0).to_string(), "1234567");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
