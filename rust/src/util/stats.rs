//! Statistics helpers for benches and metrics: mean/std, percentiles,
//! fixed-bucket latency histograms and a simple timing harness (criterion
//! is not vendored; `rust/benches/*` use these instead).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, q in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Benchmark summary for one measured configuration.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean_ms: f64,
    pub median_ms: f64,
    pub p95_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
}

impl Summary {
    pub fn of(samples_ms: &[f64]) -> Summary {
        Summary {
            n: samples_ms.len(),
            mean_ms: mean(samples_ms),
            median_ms: median(samples_ms),
            p95_ms: percentile(samples_ms, 95.0),
            std_ms: std_dev(samples_ms),
            min_ms: samples_ms.iter().cloned().fold(f64::INFINITY, f64::min),
        }
    }
}

/// Time `f` with `warmup` unmeasured and `iters` measured runs; ms samples.
pub fn time_ms<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t0 = std::time::Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect()
}

/// Streaming latency histogram with exponential bucket edges (µs..minutes).
#[derive(Debug, Clone)]
pub struct Histogram {
    edges_ms: Vec<f64>,
    counts: Vec<u64>,
    pub total: u64,
    pub sum_ms: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        // 0.001ms .. ~2min in ×2 steps
        let edges_ms: Vec<f64> = (0..28).map(|i| 0.001 * 2f64.powi(i)).collect();
        let counts = vec![0; edges_ms.len() + 1];
        Histogram { edges_ms, counts, total: 0, sum_ms: 0.0 }
    }

    pub fn record(&mut self, ms: f64) {
        let idx = self.edges_ms.partition_point(|e| *e <= ms);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_ms += ms;
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ms / self.total as f64
        }
    }

    /// Raw bucket counts (`edges + 1` entries; last is the overflow
    /// bucket). Every `Histogram` shares the same fixed edge set, so
    /// index-wise addition across threads/processes/replicas is sound —
    /// this is what the router's latency rollup merges.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Rebuild a histogram from raw bucket counts (the `buckets` array a
    /// replica exports in its stats JSON) plus the running sum.
    pub fn from_counts(counts: &[u64], sum_ms: f64) -> Histogram {
        let mut h = Histogram::new();
        h.absorb_counts(counts, sum_ms);
        h
    }

    /// Bucket-wise merge of another histogram's raw counts into this
    /// one. Extra trailing buckets (from a hypothetical wider exporter)
    /// are folded into the overflow bucket rather than dropped.
    pub fn absorb_counts(&mut self, counts: &[u64], sum_ms: f64) {
        let last = self.counts.len() - 1;
        for (i, c) in counts.iter().enumerate() {
            self.counts[i.min(last)] += c;
            self.total += c;
        }
        self.sum_ms += sum_ms;
    }

    /// Bucket-wise merge of a whole sibling histogram.
    pub fn absorb(&mut self, other: &Histogram) {
        self.absorb_counts(&other.counts, other.sum_ms);
    }

    /// Approximate quantile from bucket boundaries (upper edge).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.edges_ms.len() {
                    self.edges_ms[i]
                } else {
                    *self.edges_ms.last().unwrap() * 2.0
                };
            }
        }
        *self.edges_ms.last().unwrap() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-9);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((median(&xs) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 95.0) - 95.05).abs() < 0.1);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0];
        assert!((median(&xs) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.min_ms - 1.0).abs() < 1e-12);
        assert!((s.median_ms - 2.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = Histogram::new();
        for i in 0..1000 {
            h.record(0.1 + i as f64 * 0.01);
        }
        assert_eq!(h.total, 1000);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.mean() > 0.0);
    }

    #[test]
    fn histogram_bucketwise_merge_matches_combined_stream() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut both = Histogram::new();
        for i in 0..500 {
            let x = 0.05 + i as f64 * 0.11;
            a.record(x);
            both.record(x);
        }
        for i in 0..300 {
            let x = 40.0 + i as f64 * 1.7;
            b.record(x);
            both.record(x);
        }
        let mut merged = Histogram::from_counts(a.counts(), a.sum_ms);
        merged.absorb(&b);
        assert_eq!(merged.total, both.total);
        assert_eq!(merged.counts(), both.counts());
        assert!((merged.sum_ms - both.sum_ms).abs() < 1e-9);
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(merged.quantile(q), both.quantile(q));
        }
        assert!((merged.mean() - both.mean()).abs() < 1e-9);
    }

    #[test]
    fn time_ms_counts() {
        let mut n = 0;
        let samples = time_ms(2, 5, || n += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(n, 7);
    }
}
