//! TCP line-JSON serving protocol (one JSON object per line).
//!
//! ## Requests
//!
//! Generation:
//! `{"prompt": "...", "max_new": 32, "variant": "chai"}`
//! `{"prompt": "...", "stream": true}` — stream tokens as they decode
//!
//! Commands:
//! `{"cmd": "stats"}` `{"cmd": "kv"}` `{"cmd": "sched"}`
//! `{"cmd": "info"}` `{"cmd": "ping"}`
//! `{"cmd": "cancel", "id": N}` — abort request `N` wherever it lives
//! (pending, live mid-decode, or preempted); may be sent from ANY
//! connection, since request ids are global across the front-end
//! `{"cmd": "probe"}` — cheap liveness + load heartbeat (never blocks
//! on the engine thread; the mesh supervisor's health-check primitive)
//! `{"cmd": "trace"}` — drain the observability flight recorder as
//! Chrome trace-event JSON (`{"traceEvents": [...], "pid": ...,
//! "spans_dropped": N}`); on the router this stitches every live
//! process replica's dump into the same timeline (timestamps are
//! absolute unix microseconds). Disabled (`--no-obs`) servers answer
//! with an empty event list.
//!
//! ## Replica mesh extensions
//!
//! A `chai replica` child process serves this exact protocol over the
//! reactor transport; its handshake is one line on **stdout** —
//! `{"replica_listening": "<addr>"}` — printed once the socket is
//! bound. The router then drives it with three extensions:
//!
//! * `{"prompt": ..., "rid": N, "offset": K}` — submit under the
//!   caller-pinned id `N` instead of a server-assigned one (mesh
//!   requeues must keep the router-assigned id the client's stream is
//!   keyed by). With `"stream": true`, frames start at generated-token
//!   index `K`: a requeued request replays greedy decode from scratch
//!   but never re-emits frames its client already received.
//! * `{"cmd": "drain"}` (reactor only) — stop admitting, freeze every
//!   pending/live/preempted request, and reply
//!   `{"drained": [{"rid", "streamed", "session"}, ...]}` where
//!   `session` is the [`crate::mesh`] bit-exact wire form (absent when
//!   the request restarts from scratch). The reply line is written on
//!   the SAME connection after the final frame/terminal of everything
//!   drained — FIFO ordering is what makes migration race-free.
//! * `{"cmd": "adopt", "rid": N, "streamed": K, "max_new": M,
//!   "stream": B, "session": {...}}` (reactor only) — resume a
//!   migrated session under its original id; decode continues
//!   bit-exactly from the frozen KV.
//!
//! Submit and adopt lines may additionally carry `"trace": T` — the
//! router-minted observability trace id. The replica records its spans
//! under `T` instead of minting its own, so one cross-process request
//! (including a crash-requeued one) yields ONE stitched timeline in
//! `{"cmd": "trace"}` output. Absent or `0` means "mint locally".
//!
//! On the threaded transport `drain`/`adopt` answer with a
//! deterministic error line (its lockstep read loop cannot order the
//! drain reply behind in-flight streams).
//!
//! ## Responses
//!
//! Non-streaming generation returns one summary line:
//! `{"id": 1, "text": "...", "ttft_ms": ..., "e2e_ms": ...}` or
//! `{"error": "..."}`.
//!
//! With `"stream": true` the server first emits one frame line per
//! decoded token, in order, then a terminal line:
//!
//! ```text
//! {"id": 7, "i": 0, "tok": 104, "text": "h"}
//! {"id": 7, "i": 1, "tok": 105, "text": "i"}
//! {"id": 7, "text": "hi", "n_generated": 2, ...}          <- terminal
//! ```
//!
//! Frame lines always carry `"tok"`; the terminal line never does.
//! A cancelled request's terminal line is
//! `{"id": 7, "cancelled": true, "n_generated": k}` — frames already
//! delivered stand. Disconnecting mid-stream aborts the request on the
//! engine (the failed frame write cancels it), so a vanished client
//! cannot pin K,V blocks.
//!
//! Under overload the front end sheds instead of queueing without
//! bound: a submission that finds the coordinator's bounded inbox full
//! receives the terminal line `{"id": N, "error": "overloaded"}`
//! immediately (no frames precede it; nothing was admitted, so there
//! is no session state to unwind). Clients should treat any terminal
//! line without `"tok"` — summary, error, or cancelled — as the end of
//! that request.
//!
//! ## Connection handling
//!
//! Two transports serve this protocol (`--net`, [`crate::net`]):
//!
//! * **threads** (default, portable) — thread-per-connection (requests
//!   are forwarded to the engine replica(s) through a [`Frontend`]: a
//!   single coordinator or the multi-replica router — the server
//!   threads only do I/O). Accepted sockets block in `read` and are
//!   woken by [`Server::stop`] through a socket-shutdown registry
//!   (with a coarse idle-poll timeout as a backstop), so idle
//!   connections cost near-zero wakeups. Request/response lines on one
//!   connection are strictly sequential.
//! * **reactor** (Linux) — one epoll I/O thread multiplexes every
//!   connection ([`crate::net::reactor`]). Protocol semantics are
//!   identical with one extension: because the reactor never blocks a
//!   connection on an in-flight generation, commands sent while a
//!   generation streams are answered immediately (lines are
//!   disambiguated by `"id"`). Lockstep clients — write one request,
//!   read until its terminal — observe byte-identical behavior on both
//!   transports.
//!
//! Malformed JSON, unknown commands, and oversized prompts each
//! produce an `{"error": ...}` line without killing the connection. A
//! matching [`Client`] is provided for examples/benches. The `stats`
//! command carries a `net` section (`net_*` transport counters: active
//! connections, ring high-water marks, shed/wakeup counts) alongside
//! the engine metrics.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::engine::Variant;
use crate::net::{NetMode, NetStats};
use crate::router::Frontend;
use crate::scheduler::{RespSink, StreamFrame, SubmitOpts};
use crate::util::json::Json;

/// Reject prompts above this many bytes at the protocol layer — far
/// above any servable sequence, so the engine never tokenizes a
/// pathological line (the pool/bucket checks still guard everything
/// below this).
pub const MAX_PROMPT_BYTES: usize = 1 << 20;

/// Hard cap on one buffered request line, enforced at READ time (not
/// after parsing): a client streaming bytes without a newline can
/// never grow the line buffer past this. Sized so that any prompt the
/// protocol accepts still fits on the wire even under worst-case JSON
/// escaping (`\uXXXX` = 6 bytes per character) — a legal prompt is
/// answered with an error LINE, never a closed connection; only lines
/// no legal request could produce close the stream.
pub const MAX_LINE_BYTES: usize = 6 * MAX_PROMPT_BYTES + (64 << 10);

/// Error reported when a client closes the connection with buffered
/// bytes and no trailing newline. The partial line is REJECTED, never
/// processed — one deterministic behavior, byte-identical across the
/// threads and reactor transports (a half-line could be a truncated
/// prompt; guessing at it would make the two transports diverge on the
/// same byte stream).
pub const TRUNCATED_EOF_ERROR: &str = "truncated request line at EOF (missing trailing newline)";

/// Poll interval for in-flight work: how quickly a connection thread
/// streaming frames (or waiting on a terminal) observes `stop`.
const POLL_MS: u64 = 25;

/// Read timeout for IDLE threaded connections. Deliberately coarse:
/// `stop` wakes blocked reads through the socket registry (shutdown)
/// rather than by polling, so this timeout is only a backstop — each
/// idle connection costs 4 wakeups/s instead of the 40/s a `POLL_MS`
/// read timeout would burn.
const IDLE_POLL_MS: u64 = 250;

/// Sockets a threaded-transport server currently serves, keyed by an
/// internal connection id. [`Server::stop`] shuts these down to yank
/// connection threads out of blocked reads immediately instead of
/// waiting out the idle-poll timeout.
type ConnRegistry = Arc<Mutex<HashMap<u64, TcpStream>>>;

pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<AtomicUsize>,
    net: Arc<NetStats>,
    mode: NetMode,
    registry: ConnRegistry,
    #[cfg(target_os = "linux")]
    ready: Option<Arc<crate::net::ReadyQueue>>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve in background threads until `stop`/drop, on the
    /// default (portable, thread-per-connection) transport.
    pub fn start<F: Frontend>(api: F, bind: &str) -> Result<Server> {
        Server::start_with(api, bind, NetMode::Threads)
    }

    /// Bind and serve until `stop`/drop on an explicit transport:
    /// [`NetMode::Threads`] spawns one I/O thread per connection;
    /// [`NetMode::Reactor`] (Linux) multiplexes every connection on a
    /// single epoll thread with lock-free rings on the token-frame
    /// path.
    pub fn start_with<F: Frontend>(api: F, bind: &str, mode: NetMode) -> Result<Server> {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(AtomicUsize::new(0));
        let net = Arc::new(NetStats::default());
        let registry: ConnRegistry = Arc::new(Mutex::new(HashMap::new()));
        match mode {
            NetMode::Threads => {
                let accept_thread = spawn_threaded_accept(
                    listener,
                    api,
                    stop.clone(),
                    conns.clone(),
                    net.clone(),
                    registry.clone(),
                )?;
                Ok(Server {
                    addr,
                    stop,
                    conns,
                    net,
                    mode,
                    registry,
                    #[cfg(target_os = "linux")]
                    ready: None,
                    accept_thread: Some(accept_thread),
                })
            }
            #[cfg(target_os = "linux")]
            NetMode::Reactor => {
                listener.set_nonblocking(true)?;
                let ready = Arc::new(crate::net::ReadyQueue::new(
                    crate::net::READY_RING_CAPACITY,
                    net.clone(),
                )?);
                let accept_thread = crate::net::reactor::spawn(
                    listener,
                    api,
                    stop.clone(),
                    ready.clone(),
                    net.clone(),
                    conns.clone(),
                )?;
                Ok(Server {
                    addr,
                    stop,
                    conns,
                    net,
                    mode,
                    registry,
                    ready: Some(ready),
                    accept_thread: Some(accept_thread),
                })
            }
        }
    }

    /// Connections currently being served (observability/tests).
    pub fn active_connections(&self) -> usize {
        self.conns.load(Ordering::Relaxed)
    }

    /// The live connection counter itself — lets tests observe thread
    /// exit after [`Server::stop`] has consumed the server.
    pub fn conn_counter(&self) -> Arc<AtomicUsize> {
        self.conns.clone()
    }

    /// Transport counters (`net_*`): accepted/active connections, ring
    /// high-water marks, shed and wakeup counts.
    pub fn net_stats(&self) -> Arc<NetStats> {
        self.net.clone()
    }

    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        match self.mode {
            NetMode::Threads => {
                // the accept thread blocks in accept(): a throwaway
                // self-connection is the wake-up call
                let _ = TcpStream::connect(self.addr);
                // yank connection threads out of blocked reads NOW —
                // read returns 0/err and the thread sees `stop`
                if let Ok(reg) = self.registry.lock() {
                    for s in reg.values() {
                        let _ = s.shutdown(Shutdown::Both);
                    }
                }
            }
            #[cfg(target_os = "linux")]
            NetMode::Reactor => {
                if let Some(r) = &self.ready {
                    r.wake();
                }
            }
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // best-effort wait for connection threads to notice the flag
        // (the registry shutdown above wakes them; the idle-poll
        // timeout is the backstop; bounded so a conn blocked writing to
        // a dead peer cannot wedge shutdown)
        for _ in 0..200 {
            if self.conns.load(Ordering::Relaxed) == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(POLL_MS));
        }
    }
}

fn spawn_threaded_accept<F: Frontend>(
    listener: TcpListener,
    api: F,
    stop: Arc<AtomicBool>,
    conns: Arc<AtomicUsize>,
    net: Arc<NetStats>,
    registry: ConnRegistry,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    let next_id = AtomicU64::new(1);
    std::thread::Builder::new().name("chai-accept".into()).spawn(move || {
        // blocking accept — zero wakeups while idle; Server::stop
        // unblocks it with a self-connection
        while !stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    if stop.load(Ordering::Relaxed) {
                        break; // the stop self-connection itself
                    }
                    net.accepted.fetch_add(1, Ordering::Relaxed);
                    let conn_id = next_id.fetch_add(1, Ordering::Relaxed);
                    if let Ok(dup) = stream.try_clone() {
                        registry.lock().unwrap().insert(conn_id, dup);
                    }
                    let api = api.clone();
                    let stop = stop.clone();
                    let conns = conns.clone();
                    let net = net.clone();
                    let registry = registry.clone();
                    conns.fetch_add(1, Ordering::Relaxed);
                    // Detached, but not unbounded: the registry entry
                    // (stop-wake) plus the idle read timeout let every
                    // connection thread observe `stop` and exit even
                    // while its client idles silently.
                    let spawned = std::thread::Builder::new().name("chai-conn".into()).spawn(
                        move || {
                            let _ = handle_conn(stream, &api, &stop, &net, &conns);
                            registry.lock().unwrap().remove(&conn_id);
                            conns.fetch_sub(1, Ordering::Relaxed);
                        },
                    );
                    if spawned.is_err() {
                        // the closure owning the decrement never ran
                        // (thread exhaustion) — undo the increment or
                        // the counter stays inflated forever
                        conns.fetch_sub(1, Ordering::Relaxed);
                        registry.lock().unwrap().remove(&conn_id);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(POLL_MS));
                }
                Err(_) => break,
            }
        }
    })
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Live transport facts a command handler may report — threaded and
/// reactor transports both inject their view into `{"cmd":"stats"}`.
pub(crate) struct NetView<'a> {
    pub(crate) net: &'a NetStats,
    pub(crate) conns: &'a AtomicUsize,
    pub(crate) transport: &'static str,
}

impl NetView<'_> {
    fn json(&self) -> Json {
        self.net.to_json(self.conns.load(Ordering::Relaxed), self.transport)
    }
}

fn handle_conn<F: Frontend>(
    stream: TcpStream,
    api: &F,
    stop: &AtomicBool,
    net: &NetStats,
    conns: &AtomicUsize,
) -> Result<()> {
    // same terminal-latency behavior as the reactor transport, so the
    // two are comparable under the serving bench
    let _ = stream.set_nodelay(true);
    // coarse idle timeout: a backstop only — Server::stop wakes blocked
    // reads through the socket registry, so this no longer bounds
    // shutdown latency and can be lazy about it
    stream.set_read_timeout(Some(Duration::from_millis(IDLE_POLL_MS)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let view = NetView { net, conns, transport: "threads" };
    // raw bytes, not a String: a read timeout can land mid-UTF-8
    // sequence, and `read_line`'s UTF-8 guard would throw those partial
    // bytes away — `read_until` keeps them across timeouts. Decoding
    // happens once per complete line.
    let mut buf: Vec<u8> = Vec::new();
    loop {
        // cap enforced at read time: `take` bounds how much one line
        // can ever buffer, no matter how much the client sends
        let budget = (MAX_LINE_BYTES.saturating_sub(buf.len())) as u64;
        match (&mut reader).take(budget).read_until(b'\n', &mut buf) {
            Ok(0) if buf.is_empty() => return Ok(()), // client closed
            Ok(_) => {
                if buf.last() == Some(&b'\n') {
                    {
                        let line = String::from_utf8_lossy(&buf);
                        let trimmed = line.trim();
                        if !trimmed.is_empty() {
                            net.lines_in.fetch_add(1, Ordering::Relaxed);
                            handle_request(trimmed, api, &mut writer, stop, &view)?;
                        }
                    }
                    buf.clear();
                } else if buf.len() >= MAX_LINE_BYTES {
                    // no newline within the cap: report and close (the
                    // stream cannot be resynced mid-line)
                    let _ = write_line(
                        &mut writer,
                        &Json::obj(vec![(
                            "error",
                            Json::Str(format!(
                                "request line exceeds the {MAX_LINE_BYTES} byte protocol limit"
                            )),
                        )]),
                    );
                    return Ok(());
                } else {
                    // client closed mid-line (EOF before the newline):
                    // reject the partial line with the same error line
                    // as the reactor transport, then close — it is
                    // never processed as a request
                    net.truncated_eof.fetch_add(1, Ordering::Relaxed);
                    let _ = write_line(
                        &mut writer,
                        &Json::obj(vec![("error", Json::Str(TRUNCATED_EOF_ERROR.into()))]),
                    );
                    return Ok(());
                }
            }
            // timeout: bytes read so far stay in `buf`; either exit
            // (server stopping) or poll again
            Err(e) if is_timeout(&e) => {
                net.idle_wakeups.fetch_add(1, Ordering::Relaxed);
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

fn write_line(writer: &mut TcpStream, j: &Json) -> std::io::Result<()> {
    writer.write_all(j.to_string().as_bytes())?;
    writer.write_all(b"\n")
}

/// Dispatch one request line. Protocol errors (bad JSON, unknown cmd,
/// oversized prompt) become `{"error": ...}` lines — the connection
/// survives them all.
fn handle_request<F: Frontend>(
    line: &str,
    api: &F,
    writer: &mut TcpStream,
    stop: &AtomicBool,
    view: &NetView<'_>,
) -> Result<()> {
    let parsed = (|| -> Result<(bool, Json)> {
        let req = Json::parse(line)?;
        // commands are never streamed — `{"cmd":..., "stream":true}`
        // must still dispatch as the command, not as a generation
        let stream = req.opt("cmd").is_none()
            && req
                .opt("stream")
                .map(|v| v.boolean())
                .transpose()?
                .unwrap_or(false);
        Ok((stream, req))
    })();
    match parsed {
        Err(e) => {
            write_line(writer, &Json::obj(vec![("error", Json::Str(format!("{e:#}")))]))?;
            Ok(())
        }
        Ok((false, req)) => {
            let reply = match handle_line(&req, api, stop, view) {
                Ok(j) => j,
                Err(e) => Json::obj(vec![("error", Json::Str(format!("{e:#}")))]),
            };
            write_line(writer, &reply)?;
            Ok(())
        }
        Ok((true, req)) => handle_streaming(&req, api, writer, stop),
    }
}

/// Wait for a terminal response, polling so this thread stays
/// responsive to `stop`: when the server is stopping, the in-flight
/// request is aborted (its blocks are reclaimed) and the terminal
/// cancelled/error line still reaches the client. This is what keeps
/// connection threads from outliving [`Server::stop`] mid-generation.
fn recv_terminal<F: Frontend>(
    rx: &Receiver<crate::scheduler::Response>,
    id: u64,
    api: &F,
    stop: &AtomicBool,
) -> Result<crate::scheduler::Response> {
    let mut abort_sent = false;
    loop {
        match rx.recv_timeout(Duration::from_millis(POLL_MS)) {
            Ok(resp) => return Ok(resp),
            Err(RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::Relaxed) && !abort_sent {
                    api.cancel(id);
                    abort_sent = true;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                anyhow::bail!("engine dropped request")
            }
        }
    }
}

/// A streaming generation: frames as tokens decode, then the terminal
/// summary. A failed frame write (client disconnected mid-stream) or
/// a stopping server aborts the request on the engine — either way
/// the session's blocks are reclaimed and a terminal line is produced.
fn handle_streaming<F: Frontend>(
    req: &Json,
    api: &F,
    writer: &mut TcpStream,
    stop: &AtomicBool,
) -> Result<()> {
    let (frame_tx, frame_rx) = channel();
    let submitted = parse_generation(req).and_then(|opts| {
        submit_with_channel(req, api, SubmitOpts { stream: Some(frame_tx.into()), ..opts })
    });
    let (id, resp_rx) = match submitted {
        Ok(p) => p,
        Err(e) => {
            write_line(writer, &Json::obj(vec![("error", Json::Str(format!("{e:#}")))]))?;
            return Ok(());
        }
    };
    let mut abort_sent = false;
    loop {
        match frame_rx.recv_timeout(Duration::from_millis(POLL_MS)) {
            Ok(f) => {
                // check stop here too: a stream whose frames arrive
                // faster than the poll interval would otherwise never
                // reach the Timeout arm
                if stop.load(Ordering::Relaxed) && !abort_sent {
                    api.cancel(id);
                    abort_sent = true;
                }
                let frame = frame_json(&f);
                if let Err(e) = write_line(writer, &frame) {
                    // disconnect-abort: free the session's blocks
                    // mid-decode; wait (bounded) for the terminal
                    // response so the abort is confirmed before the
                    // thread exits
                    api.cancel(id);
                    let _ = resp_rx.recv_timeout(Duration::from_secs(60));
                    return Err(e.into());
                }
            }
            // channel closed: the terminal response is in flight
            Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => {
                // a stopping server aborts in-flight streams (the
                // terminal cancelled line is still delivered below)
                if stop.load(Ordering::Relaxed) && !abort_sent {
                    api.cancel(id);
                    abort_sent = true;
                }
            }
        }
    }
    let resp = recv_terminal(&resp_rx, id, api, stop)?;
    write_line(writer, &response_json(&resp))?;
    Ok(())
}

pub(crate) fn parse_generation(req: &Json) -> Result<SubmitOpts> {
    let prompt = req.get("prompt")?.str()?.to_string();
    if prompt.len() > MAX_PROMPT_BYTES {
        anyhow::bail!(
            "prompt of {} bytes exceeds the {} byte protocol limit",
            prompt.len(),
            MAX_PROMPT_BYTES
        );
    }
    let max_new = req.opt("max_new").map(|v| v.usize()).transpose()?.unwrap_or(32);
    let variant =
        Variant::parse(req.opt("variant").map(|v| v.str()).transpose()?.unwrap_or("chai"))?;
    let mut opts = SubmitOpts::new(&prompt, max_new, variant);
    // mesh requeues replay from scratch but must not re-emit frames the
    // client already received (see Request::stream_offset)
    opts.stream_offset = req.opt("offset").map(|v| v.usize()).transpose()?.unwrap_or(0);
    // cross-process trace propagation: a router-minted trace id rides
    // the wire so the child's spans land on the parent's timeline
    // (absent/0 = mint locally at admission if obs is on)
    opts.trace = req.opt("trace").map(|v| v.usize()).transpose()?.unwrap_or(0) as u64;
    Ok(opts)
}

/// Submit honoring a caller-pinned `"rid"` (the mesh path: requeues and
/// adopts keep the router-assigned id); plain requests get a fresh id.
pub(crate) fn submit_with_channel<F: Frontend>(
    req: &Json,
    api: &F,
    opts: SubmitOpts,
) -> Result<(u64, Receiver<crate::scheduler::Response>)> {
    match req.opt("rid") {
        Some(v) => {
            let id = v.usize()? as u64;
            let (tx, rx) = channel();
            api.submit_rid(id, opts, RespSink::Channel(tx));
            Ok((id, rx))
        }
        None => Ok(api.submit_opts(opts)),
    }
}

/// One stream frame as its wire line (`"tok"` marks it non-terminal).
pub(crate) fn frame_json(f: &StreamFrame) -> Json {
    Json::obj(vec![
        ("id", Json::Num(f.id as f64)),
        ("i", Json::Num(f.index as f64)),
        ("tok", Json::Num(f.token as f64)),
        ("text", Json::Str(f.text.clone())),
    ])
}

pub(crate) fn response_json(resp: &crate::scheduler::Response) -> Json {
    if let Some(e) = &resp.error {
        return Json::obj(vec![
            ("id", Json::Num(resp.id as f64)),
            ("error", Json::Str(e.clone())),
        ]);
    }
    if resp.cancelled {
        return Json::obj(vec![
            ("id", Json::Num(resp.id as f64)),
            ("cancelled", Json::Bool(true)),
            ("n_generated", Json::Num(resp.n_generated as f64)),
        ]);
    }
    Json::obj(vec![
        ("id", Json::Num(resp.id as f64)),
        ("text", Json::Str(resp.text.clone())),
        ("n_generated", Json::Num(resp.n_generated as f64)),
        ("queue_ms", Json::Num(resp.queue_ms)),
        ("ttft_ms", Json::Num(resp.timing.ttft_ms)),
        ("e2e_ms", Json::Num(resp.e2e_ms)),
    ])
}

/// Dispatch one `{"cmd": ...}` line — shared verbatim by the threaded
/// transport and the epoll reactor, so command semantics cannot drift
/// between them.
pub(crate) fn command_json<F: Frontend>(req: &Json, api: &F, view: &NetView<'_>) -> Result<Json> {
    match req.get("cmd")?.str()? {
        "ping" => Ok(Json::obj(vec![("pong", Json::Bool(true))])),
        // engine metrics plus this transport's `net` section
        "stats" => {
            let mut j = api.stats_json();
            if let Json::Obj(m) = &mut j {
                m.insert("net".into(), view.json());
            }
            Ok(j)
        }
        // paged-KV occupancy + sharing view (subset of stats gauges)
        "kv" => Ok(api.kv_json()),
        // scheduler view: queue depths, live/preempted counts,
        // preemption + swap-tier counters and occupancy
        "sched" => Ok(api.sched_json()),
        // static serving facts: compute backend, model name
        "info" => Ok(api.info_json()),
        // liveness + load heartbeat: reads gauges only, never waits on
        // the engine thread, so the mesh supervisor can call it at high
        // frequency without perturbing decode
        "probe" => Ok(api.probe_json()),
        // flight recorder drain: Chrome trace-event JSON of every span
        // still resident in the per-thread rings (the router stitches
        // its process children's dumps into one timeline)
        "trace" => Ok(api.trace_json()),
        // mesh migration needs the reply FIFO-ordered behind in-flight
        // frames on the same connection — only the reactor transport
        // can provide that (it intercepts these before dispatching
        // here); the threaded transport refuses deterministically
        "drain" | "adopt" => Ok(Json::obj(vec![(
            "error",
            Json::Str("drain/adopt require the reactor transport (--net reactor)".into()),
        )])),
        // abort by id, from any connection (ids are front-end
        // global); ack is immediate, the abort lands on the next
        // engine tick and the submitting connection receives the
        // terminal cancelled line
        "cancel" => {
            let id = req.get("id")?.usize()? as u64;
            api.cancel(id);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("id", Json::Num(id as f64)),
            ]))
        }
        other => Ok(Json::obj(vec![(
            "error",
            Json::Str(format!("unknown cmd {other:?}")),
        )])),
    }
}

fn handle_line<F: Frontend>(
    req: &Json,
    api: &F,
    stop: &AtomicBool,
    view: &NetView<'_>,
) -> Result<Json> {
    if req.opt("cmd").is_some() {
        return command_json(req, api, view);
    }
    let opts = parse_generation(req)?;
    let (id, rx) = submit_with_channel(req, api, opts)?;
    let resp = recv_terminal(&rx, id, api, stop)?;
    Ok(response_json(&resp))
}

/// Line-JSON client for examples and the serving bench.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        Client::from_stream(stream)
    }

    /// Wrap an already-connected stream (the mesh control path: the
    /// caller sets socket timeouts before handing the stream over so a
    /// wedged replica fails a probe instead of hanging it).
    pub fn from_stream(stream: TcpStream) -> Result<Client> {
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one request line (without reading a reply).
    pub fn send(&mut self, req: &Json) -> Result<()> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    /// Send raw bytes verbatim (protocol-error tests: malformed JSON).
    pub fn send_raw(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        Ok(())
    }

    /// Read one reply line.
    pub fn read_json(&mut self) -> Result<Json> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            anyhow::bail!("server closed the connection");
        }
        Json::parse(line.trim())
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.send(req)?;
        self.read_json()
    }

    pub fn generate(&mut self, prompt: &str, max_new: usize, variant: &str) -> Result<Json> {
        self.call(&Json::obj(vec![
            ("prompt", Json::Str(prompt.into())),
            ("max_new", Json::Num(max_new as f64)),
            ("variant", Json::Str(variant.into())),
        ]))
    }

    /// Streaming generation: `on_frame` sees every `{"id","i","tok"}`
    /// frame as it arrives; returns the terminal line (summary, error,
    /// or `{"cancelled": true}`).
    pub fn generate_stream(
        &mut self,
        prompt: &str,
        max_new: usize,
        variant: &str,
        mut on_frame: impl FnMut(&Json),
    ) -> Result<Json> {
        self.send(&Json::obj(vec![
            ("prompt", Json::Str(prompt.into())),
            ("max_new", Json::Num(max_new as f64)),
            ("variant", Json::Str(variant.into())),
            ("stream", Json::Bool(true)),
        ]))?;
        loop {
            let j = self.read_json()?;
            if j.opt("tok").is_none() {
                return Ok(j); // terminal line
            }
            on_frame(&j);
        }
    }

    /// Abort request `id` (any connection may cancel any id).
    pub fn cancel(&mut self, id: u64) -> Result<Json> {
        self.call(&Json::obj(vec![
            ("cmd", Json::Str("cancel".into())),
            ("id", Json::Num(id as f64)),
        ]))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.call(&Json::obj(vec![("cmd", Json::Str("ping".into()))]))?;
        Ok(r.opt("pong").is_some())
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.call(&Json::obj(vec![("cmd", Json::Str("stats".into()))]))
    }

    pub fn kv(&mut self) -> Result<Json> {
        self.call(&Json::obj(vec![("cmd", Json::Str("kv".into()))]))
    }

    pub fn sched(&mut self) -> Result<Json> {
        self.call(&Json::obj(vec![("cmd", Json::Str("sched".into()))]))
    }

    pub fn info(&mut self) -> Result<Json> {
        self.call(&Json::obj(vec![("cmd", Json::Str("info".into()))]))
    }
}
