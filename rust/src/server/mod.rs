//! TCP line-JSON serving protocol (one JSON object per line).
//!
//! Request:  `{"prompt": "...", "max_new": 32, "variant": "chai"}`
//!           `{"cmd": "stats"}` `{"cmd": "kv"}` `{"cmd": "sched"}`
//!           `{"cmd": "info"}` `{"cmd": "ping"}`
//! Response: `{"id": 1, "text": "...", "ttft_ms": ..., "e2e_ms": ...}`
//!           or `{"error": "..."}`.
//!
//! Connection handling is thread-per-connection (requests are forwarded to
//! the single engine thread through the coordinator, so the server threads
//! only do I/O). A matching [`Client`] is provided for examples/benches.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::Coordinator;
use crate::engine::Variant;
use crate::util::json::Json;

pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve in background threads until `stop`/drop.
    pub fn start(coordinator: Coordinator, bind: &str) -> Result<Server> {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name("chai-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let coord = coordinator.clone();
                            // Detached: a connection thread lives until its
                            // client disconnects (joining here would block
                            // shutdown on clients idling in read_line).
                            let _ = std::thread::Builder::new()
                                .name("chai-conn".into())
                                .spawn(move || {
                                    let _ = handle_conn(stream, &coord);
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { addr, stop, accept_thread: Some(accept_thread) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(stream: TcpStream, coord: &Coordinator) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = match handle_line(trimmed, coord) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![("error", Json::Str(format!("{e:#}")))]),
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
}

fn handle_line(line: &str, coord: &Coordinator) -> Result<Json> {
    let req = Json::parse(line)?;
    if let Some(cmd) = req.opt("cmd") {
        return match cmd.str()? {
            "ping" => Ok(Json::obj(vec![("pong", Json::Bool(true))])),
            "stats" => Ok(coord.metrics.to_json()),
            // paged-KV occupancy + sharing view (subset of stats gauges)
            "kv" => Ok(coord
                .metrics
                .to_json()
                .opt("gauges")
                .cloned()
                .unwrap_or_else(|| Json::obj(vec![]))),
            // scheduler view: queue depths, live/preempted counts,
            // preemption + swap-tier counters and occupancy
            "sched" => Ok(coord.metrics.subset_json(&["sched_", "swap_", "kv_defer"])),
            // static serving facts: compute backend, model name
            "info" => Ok(coord
                .metrics
                .to_json()
                .opt("info")
                .cloned()
                .unwrap_or_else(|| Json::obj(vec![]))),
            other => Ok(Json::obj(vec![(
                "error",
                Json::Str(format!("unknown cmd {other:?}")),
            )])),
        };
    }
    let prompt = req.get("prompt")?.str()?.to_string();
    let max_new = req.opt("max_new").map(|v| v.usize()).transpose()?.unwrap_or(32);
    let variant =
        Variant::parse(req.opt("variant").map(|v| v.str()).transpose()?.unwrap_or("chai"))?;
    let rx = coord.submit(&prompt, max_new, variant);
    let resp = rx.recv().context("engine dropped request")?;
    if let Some(e) = resp.error {
        return Ok(Json::obj(vec![("error", Json::Str(e))]));
    }
    Ok(Json::obj(vec![
        ("id", Json::Num(resp.id as f64)),
        ("text", Json::Str(resp.text)),
        ("n_generated", Json::Num(resp.n_generated as f64)),
        ("queue_ms", Json::Num(resp.queue_ms)),
        ("ttft_ms", Json::Num(resp.timing.ttft_ms)),
        ("e2e_ms", Json::Num(resp.e2e_ms)),
    ]))
}

/// Line-JSON client for examples and the serving bench.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim())
    }

    pub fn generate(&mut self, prompt: &str, max_new: usize, variant: &str) -> Result<Json> {
        self.call(&Json::obj(vec![
            ("prompt", Json::Str(prompt.into())),
            ("max_new", Json::Num(max_new as f64)),
            ("variant", Json::Str(variant.into())),
        ]))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.call(&Json::obj(vec![("cmd", Json::Str("ping".into()))]))?;
        Ok(r.opt("pong").is_some())
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.call(&Json::obj(vec![("cmd", Json::Str("stats".into()))]))
    }

    pub fn sched(&mut self) -> Result<Json> {
        self.call(&Json::obj(vec![("cmd", Json::Str("sched".into()))]))
    }

    pub fn info(&mut self) -> Result<Json> {
        self.call(&Json::obj(vec![("cmd", Json::Str("info".into()))]))
    }
}
