//! Generation engine: drives the paper's probe → cluster → CHAI pipeline
//! (Figure 10) plus every baseline, on top of a pluggable compute
//! backend ([`crate::runtime::Backend`]: the AOT/PJRT runtime or the
//! pure-rust reference interpreter — selected by
//! [`ServingConfig::backend`]).
//!
//! Request flow for CHAI (Figure 10b/c):
//!   1. dense-MHA **probe** over the first 5 tokens (`probe_mha` artifact)
//!   2. online k-means **membership identification** per layer
//!      (`clustering::membership`, cluster count fixed offline)
//!   3. **CHAI prefill** over the full prompt (clustered heads, clustered
//!      K-cache) and **CHAI decode** steps with the clustered cache.
//!
//! MHA / DejaVu / SpAtten / CHAI-static run through the same engine with
//! different artifacts + selector inputs. All timings are measured here
//! and surfaced per phase (Figure 12 needs probe+cluster overhead included
//! in time-to-first-token).
//!
//! The serving hot path is **block-table-native** on backends with paged
//! kernels (the ref backend today): prefill computes only the non-adopted
//! prompt suffix and writes K,V rows straight into the paged blocks, and
//! [`Engine::decode_tick`] fuses all live paged sessions of a variant
//! into one ragged batched `decode_paged` call that reads/appends
//! block-resident K,V in place — zero bucket-shaped gather/scatter
//! copies (asserted via `PagedStats::decode_{gather,scatter}_copies`).
//! `--no-batched-decode` restores the per-session bucket path, which the
//! XLA backend still uses until paged artifacts are re-lowered.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::clustering::membership::{identify, Membership};
use crate::config::{Manifest, ServingConfig};
use crate::kv::paged::{
    KvLayout, PagedKv, PagedSnapshot, SwapHandle, SwapPool, SwapSnapshot, SwappedSeq,
};
use crate::kv::CacheKind;
use crate::model::tokenizer;
use crate::runtime::{backend_for, Backend, ClusterAssignment, In, PagedDecodeRow, RelayRef};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Attention variant served by the engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Variant {
    Mha,
    /// online membership (the paper's CHAI)
    Chai,
    /// offline membership from clusters.json (CHAI-static baseline)
    ChaiStatic,
    /// Table-4 ablation: V pruned too
    ChaiQkv,
    /// Figure-1 sweep: uniform k clusters/layer with the given membership
    /// source ("random" or "static")
    UniformK { k: usize, random: bool },
    /// DejaVu head pruning at the given sparsity (percent)
    Dejavu(usize),
    Spatten,
}

impl Variant {
    pub fn parse(s: &str) -> Result<Variant> {
        Ok(match s {
            "mha" => Variant::Mha,
            "chai" => Variant::Chai,
            "chai-static" => Variant::ChaiStatic,
            "chai-qkv" => Variant::ChaiQkv,
            "spatten" => Variant::Spatten,
            _ if s.starts_with("dejavu-") => {
                Variant::Dejavu(s[7..].trim_end_matches('%').parse()?)
            }
            _ if s.starts_with("random-k") => {
                Variant::UniformK { k: s[8..].parse()?, random: true }
            }
            _ if s.starts_with("static-k") => {
                Variant::UniformK { k: s[8..].parse()?, random: false }
            }
            _ => bail!("unknown variant {s:?} (mha|chai|chai-static|chai-qkv|dejavu-P|spatten|random-kK|static-kK)"),
        })
    }

    pub fn name(&self) -> String {
        match self {
            Variant::Mha => "mha".into(),
            Variant::Chai => "chai".into(),
            Variant::ChaiStatic => "chai-static".into(),
            Variant::ChaiQkv => "chai-qkv".into(),
            Variant::UniformK { k, random: true } => format!("random-k{k}"),
            Variant::UniformK { k, random: false } => format!("static-k{k}"),
            Variant::Dejavu(p) => format!("dejavu-{p}"),
            Variant::Spatten => "spatten".into(),
        }
    }

    pub fn cache_kind(&self) -> CacheKind {
        match self {
            Variant::Mha | Variant::Dejavu(_) | Variant::Spatten => CacheKind::Mha,
            _ => CacheKind::Chai,
        }
    }
}

/// Outcome of the coordinator's paged admission check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// reserve and start now
    Admit,
    /// not enough free/evictable blocks at the moment — retry later
    Defer,
    /// larger than the whole pool — can never be served
    Reject,
}

/// Phase timing for one request (Figure 12 decomposition).
#[derive(Debug, Clone, Default)]
pub struct Timing {
    pub probe_ms: f64,
    pub cluster_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: Vec<f64>,
    pub ttft_ms: f64,
}

#[derive(Debug, Clone)]
pub struct Generation {
    pub tokens: Vec<i32>,
    pub text: String,
    pub timing: Timing,
}

pub struct Engine {
    /// Compute backend behind the [`Backend`] seam: the AOT/XLA runtime
    /// or the pure-rust reference interpreter — the engine drives both
    /// through the same artifact-name contract.
    pub rt: Box<dyn Backend>,
    pub cfg: ServingConfig,
    static_membership: Vec<Vec<usize>>,
    static_reps: Vec<Vec<usize>>,
    pub rng: std::cell::RefCell<Rng>,
    /// Memoized online memberships keyed by probe prefix (§Perf: the
    /// scoring path evaluates 2-4 choices per item that share a prompt —
    /// the paper clusters once per request, so reusing the membership for
    /// an identical probe prefix is semantics-preserving).
    membership_cache: std::cell::RefCell<
        std::collections::BTreeMap<Vec<i32>, (Vec<Vec<usize>>, Vec<Vec<usize>>)>,
    >,
    /// Paged K,V block store (None on the legacy contiguous path). The
    /// engine is single-threaded, so RefCell suffices; sessions hold
    /// sequence ids into it rather than cache tensors.
    paged: Option<std::cell::RefCell<PagedKv>>,
    /// Host-side spill tier for preempted sessions (None when
    /// `swap_blocks == 0` or on the legacy path): frozen sessions stage
    /// their sole-owner blocks here instead of recomputing on resume.
    swap: Option<std::cell::RefCell<SwapPool>>,
    /// Persistent worker pool for intra-tick kernel parallelism. Owned
    /// by the engine (workers join on drop) and installed into the
    /// constructing thread's dispatch slot, so the kernels this engine
    /// runs fan out over it; sized by `cfg.threads` (0 = allowed-cpu
    /// mask divided across replicas, 1 = exact legacy serial path).
    pool: std::sync::Arc<crate::runtime::pool::Pool>,
    next_seq: std::cell::Cell<u64>,
}

impl Engine {
    pub fn load(cfg: ServingConfig) -> Result<Engine> {
        let rt = backend_for(&cfg)?;
        Engine::with_backend(rt, cfg)
    }

    /// Build an engine around an already-constructed backend (the
    /// router uses this to hand each replica a backend over `Arc`'d
    /// shared weights instead of loading N copies of the model).
    pub fn with_backend(rt: Box<dyn Backend>, cfg: ServingConfig) -> Result<Engine> {
        let (static_membership, static_reps) = rt.manifest().static_clusters()?;
        let seed = cfg.seed;
        let paged = cfg.paged_kv.then(|| {
            std::cell::RefCell::new(PagedKv::new(
                cfg.kv_block_size.max(1),
                cfg.kv_capacity_bytes,
            ))
        });
        // swap-tier budget is counted in MHA-sized blocks (the largest
        // layout), so `--swap-blocks N` holds at least N blocks of any
        // variant
        let swap = (cfg.paged_kv && cfg.swap_blocks > 0).then(|| {
            let block = KvLayout::from_manifest(rt.manifest(), CacheKind::Mha)
                .block_bytes(cfg.kv_block_size.max(1));
            std::cell::RefCell::new(SwapPool::new(cfg.swap_blocks * block))
        });
        // the engine runs on the thread that built it (the coordinator
        // spawns one engine thread per replica and constructs there),
        // so installing here routes this engine's kernels to its pool
        let threads = crate::runtime::pool::resolve_threads(cfg.threads, cfg.replicas);
        let pool = std::sync::Arc::new(crate::runtime::pool::Pool::new(threads, cfg.pin_cores));
        crate::runtime::pool::install(&pool);
        Ok(Engine {
            rt,
            cfg,
            static_membership,
            static_reps,
            rng: std::cell::RefCell::new(Rng::new(seed)),
            membership_cache: std::cell::RefCell::new(Default::default()),
            paged,
            swap,
            pool,
            next_seq: std::cell::Cell::new(0),
        })
    }

    pub fn from_dir(dir: &Path) -> Result<Engine> {
        Engine::load(ServingConfig { artifacts_dir: dir.to_path_buf(), ..Default::default() })
    }

    pub fn manifest(&self) -> &Manifest {
        self.rt.manifest()
    }

    /// Short name of the active compute backend ("xla" | "ref").
    pub fn backend_name(&self) -> &'static str {
        self.rt.name()
    }

    /// Worker-pool counters for the metrics roll-up:
    /// `(threads, tasks_completed, busy_ns)`.
    pub fn pool_stats(&self) -> (usize, u64, u64) {
        self.pool.stats()
    }

    // ------------------------------------------------------------------
    // Paged KV plumbing
    // ------------------------------------------------------------------

    pub fn paged_enabled(&self) -> bool {
        self.paged.is_some()
    }

    pub fn paged_snapshot(&self) -> Option<PagedSnapshot> {
        self.paged.as_ref().map(|p| p.borrow().snapshot())
    }

    /// Block-level admission decision for the coordinator, computed in
    /// one pass (one tokenization): `Admit` when the pool can cover the
    /// prompt's prefill blocks plus one decode block (counting evictable
    /// cached blocks), `Defer` when it can't right now, `Reject` when it
    /// never could. Variants the serving path doesn't route through the
    /// paged store are admitted so `start_session` surfaces its own
    /// error. Always `Admit` on the legacy path, where `KvPool` does its
    /// own bucket accounting.
    pub fn paged_admission(&self, variant: &Variant, prompt: &str) -> Admission {
        let Some(store) = &self.paged else { return Admission::Admit };
        if !matches!(variant, Variant::Mha | Variant::Chai | Variant::ChaiStatic) {
            return Admission::Admit;
        }
        let layout = KvLayout::from_manifest(self.manifest(), variant.cache_kind());
        let n = tokenizer::encode(prompt, true, false).len();
        let st = store.borrow();
        if !st.fits_ever(&layout, n) {
            Admission::Reject
        } else if !st.can_admit(&layout, n) {
            Admission::Defer
        } else {
            Admission::Admit
        }
    }

    /// Reserve and map a new sequence's prompt blocks (adopting shared
    /// prefix blocks where the token-hash chain matches).
    fn paged_admit(&self, variant: &Variant, prompt_tokens: &[i32]) -> Result<u64> {
        let store = self.paged.as_ref().expect("paged_admit without store");
        let m = self.manifest();
        let kind = variant.cache_kind();
        let layout = KvLayout::from_manifest(m, kind);
        let mut st = store.borrow_mut();
        // CHAI rows depend on the cluster membership, a deterministic
        // function of the probe prefix; sharing is sound only when the
        // first block covers that prefix (see kv::paged docs).
        let allow_share = kind == CacheKind::Mha || st.block_size >= m.probe_tokens;
        let seq = self.next_seq.get();
        self.next_seq.set(seq + 1);
        st.admit(seq, layout, &variant.name(), allow_share, prompt_tokens)?;
        Ok(seq)
    }

    /// Return a session's blocks to the pool. Idempotent: safe to call
    /// on error paths and again from [`Self::finish_session`].
    pub fn release_session(&self, s: &mut Session) {
        if let Caches::Paged { seq, .. } = &mut s.caches {
            if let (Some(store), Some(seq)) = (&self.paged, seq.take()) {
                let _ = store.borrow_mut().release(seq);
            }
        }
    }

    // ------------------------------------------------------------------
    // Preemption: session freeze / thaw
    // ------------------------------------------------------------------

    /// Whether the scheduler may preempt this session: freeze/thaw is
    /// implemented for block-table-native paged sessions only (the
    /// resume path is a suffix `prefill_paged`).
    pub fn can_freeze(&self, s: &Session) -> bool {
        self.paged_native() && matches!(s.caches, Caches::Paged { seq: Some(_), .. })
    }

    /// Inputs to the scheduler's swap-vs-recompute cost model:
    /// `(tokens_to_replay, bytes_to_swap)`. Replay cost is the cached
    /// positions a recompute-resume would run through `prefill_paged`;
    /// swap bytes exclude blocks other live sessions read (pinned).
    pub fn preempt_cost(&self, s: &Session) -> (usize, usize) {
        let (Some(store), Some(seq)) = (&self.paged, paged_seq_of(s)) else {
            return (0, 0);
        };
        let st = store.borrow();
        let replay = st.table(seq).map(|t| t.len).unwrap_or(0);
        let bytes = st.swap_cost(seq).unwrap_or(0);
        (replay, bytes)
    }

    /// Free bytes in the swap tier (0 when the tier is disabled).
    pub fn swap_free_bytes(&self) -> usize {
        self.swap.as_ref().map(|s| s.borrow().free_bytes()).unwrap_or(0)
    }

    /// Swap-tier occupancy/counters for gauges (None when disabled).
    pub fn swap_snapshot(&self) -> Option<SwapSnapshot> {
        self.swap.as_ref().map(|s| s.borrow().snapshot())
    }

    /// Drop a frozen session without resuming it (the scheduler's
    /// errored-resume path): releases its swap-tier entry, if any —
    /// a bare drop of [`FrozenSession`] would leak the staged bytes
    /// and silently shrink the tier forever.
    pub fn discard_frozen(&self, f: FrozenSession) {
        if let (Some(tier), Some(h)) = (&self.swap, f.swap) {
            tier.borrow_mut().discard(h);
        }
    }

    /// Preempt a live session: capture everything a later
    /// [`Self::thaw_session`] needs and give its blocks back to the
    /// pool. With `prefer_swap` the sole-owner blocks are staged into
    /// the spill tier first (falling back to plain eviction when the
    /// tier is full or missing); shared prefix blocks are never
    /// swapped — they stay pinned by their other readers. Returns the
    /// frozen state and whether the K,V actually swapped (false =
    /// recompute on resume).
    pub fn freeze_session(&self, mut s: Session, prefer_swap: bool) -> (FrozenSession, bool) {
        let mut handle: Option<SwapHandle> = None;
        if prefer_swap {
            if let (Some(store), Some(tier), Some(seq)) =
                (&self.paged, &self.swap, paged_seq_of(&s))
            {
                handle = store.borrow_mut().swap_out(seq, &mut tier.borrow_mut()).ok();
                if handle.is_some() {
                    // swap_out released the table; don't release twice
                    if let Caches::Paged { seq, .. } = &mut s.caches {
                        let _ = seq.take();
                    }
                }
            }
        }
        if handle.is_none() {
            self.release_session(&mut s);
        }
        let swapped = handle.is_some();
        (
            FrozenSession {
                variant: s.variant,
                tokens: s.tokens,
                prompt_len: s.prompt_len,
                max_new: s.max_new,
                bucket: s.bucket,
                clusters: s.clusters,
                timing: s.timing,
                swap: handle,
            },
            swapped,
        )
    }

    /// Can a frozen session's K,V reservation be re-taken right now?
    /// Mirrors [`Self::paged_admission`] for the resume path (the cache
    /// holds one row fewer than the token stream: the last sampled
    /// token's row is appended by the next decode tick).
    pub fn resume_admission(&self, f: &FrozenSession) -> Admission {
        let Some(store) = &self.paged else { return Admission::Reject };
        let layout = KvLayout::from_manifest(self.manifest(), f.variant.cache_kind());
        let n = f.tokens.len().saturating_sub(1);
        let st = store.borrow();
        if !st.fits_ever(&layout, n) {
            Admission::Reject
        } else if !st.can_admit(&layout, n) {
            Admission::Defer
        } else {
            Admission::Admit
        }
    }

    /// Resume a preempted session: re-admit its cached positions
    /// (re-adopting any blocks still reachable through the prefix
    /// index), restore swapped blocks bit-exactly, and recompute
    /// whatever remains via the suffix `prefill_paged` path — the same
    /// `adopted_prefix_len`-style skip contract prefill uses, so the
    /// resumed stream is bit-identical to an uncontended run. The
    /// sampled-but-not-yet-cached last token is untouched; the next
    /// decode tick appends its row exactly as it would have.
    pub fn thaw_session(&self, f: FrozenSession) -> Result<Session> {
        let discard = |h: Option<SwapHandle>| {
            if let (Some(tier), Some(h)) = (&self.swap, h) {
                tier.borrow_mut().discard(h);
            }
        };
        if !self.paged_native() {
            discard(f.swap);
            bail!("thaw requires a block-table-native paged backend");
        }
        let store = self.paged.as_ref().expect("paged_native without store");
        let cache_len = f.tokens.len().saturating_sub(1);
        if cache_len == 0 {
            discard(f.swap);
            bail!("thaw of an empty session");
        }
        let seq = match self.paged_admit(&f.variant, &f.tokens[..cache_len]) {
            Ok(seq) => seq,
            Err(e) => {
                discard(f.swap);
                return Err(e);
            }
        };
        let restore = || -> Result<f64> {
            let mut st = store.borrow_mut();
            let restored = match f.swap {
                Some(h) => {
                    let tier = self.swap.as_ref().expect("swap handle without tier");
                    st.restore_swapped(seq, h, &mut tier.borrow_mut())?
                }
                None => st.adopted_prefix_len(seq)?,
            };
            st.stats.prefill_skipped_tokens += restored as u64;
            let t0 = Instant::now();
            // logits are discarded: the post-prefill token was already
            // sampled before the preemption and lives in `tokens`
            let _ = self.rt.prefill_paged(seq, restored, f.clusters.as_ref(), &mut st)?;
            st.commit_prefill(seq)?;
            Ok(t0.elapsed().as_secs_f64() * 1e3)
        };
        match restore() {
            Ok(thaw_ms) => {
                let mut timing = f.timing;
                timing.prefill_ms += thaw_ms;
                Ok(Session {
                    variant: f.variant.clone(),
                    tokens: f.tokens,
                    prompt_len: f.prompt_len,
                    max_new: f.max_new,
                    bucket: f.bucket,
                    caches: Caches::Paged {
                        seq: Some(seq),
                        kind: f.variant.cache_kind(),
                    },
                    membership_tensors: None,
                    clusters: f.clusters,
                    timing,
                    done: false,
                })
            }
            Err(e) => {
                let _ = store.borrow_mut().release(seq);
                Err(e)
            }
        }
    }

    // ------------------------------------------------------------------
    // Membership machinery
    // ------------------------------------------------------------------

    /// Run the probe artifact over the first `probe_tokens` of `tokens`
    /// and k-means per-layer membership (paper §3.3).
    pub fn online_membership(&self, tokens: &[i32]) -> Result<(Vec<Membership>, f64, f64)> {
        let m = self.manifest();
        let pb = m.probe_bucket;
        let n = tokens.len().min(m.probe_tokens).max(2);
        let mut padded = vec![tokenizer::PAD; pb];
        for (i, t) in tokens.iter().take(n).enumerate() {
            padded[i] = *t;
        }
        let t0 = Instant::now();
        let outs = self.rt.run(
            "probe_mha",
            &[In::Host(&Tensor::i32(vec![pb], padded)), In::Host(&Tensor::scalar_i32(n as i32))],
        )?;
        let maps = outs[0].to_tensor()?;
        let probe_ms = t0.elapsed().as_secs_f64() * 1e3;

        let t1 = Instant::now();
        let memberships = self.membership_from_maps(&maps, n, &m.k_list)?;
        let cluster_ms = t1.elapsed().as_secs_f64() * 1e3;
        Ok((memberships, probe_ms, cluster_ms))
    }

    /// k-means each layer of probe maps `[L,H,P,P]` into `k_list[l]`
    /// clusters.
    pub fn membership_from_maps(
        &self,
        maps: &Tensor,
        n_tokens: usize,
        k_list: &[usize],
    ) -> Result<Vec<Membership>> {
        let m = self.manifest();
        let (l, h, p) = (m.model.n_layers, m.model.n_heads, maps.shape[2]);
        let v = maps.as_f32()?;
        let mut out = Vec::with_capacity(l);
        for li in 0..l {
            let mut heads = Vec::with_capacity(h);
            for hi in 0..h {
                let mut rows = Vec::with_capacity(p);
                for q in 0..p {
                    let base = ((li * h + hi) * p + q) * p;
                    rows.push(v[base..base + p].to_vec());
                }
                heads.push(rows);
            }
            out.push(identify(&heads, n_tokens, k_list[li], self.cfg.seed));
        }
        Ok(out)
    }

    /// Membership/reps tensors for the CHAI artifacts: membership [L,H],
    /// reps [L,k_max] (padded with 0).
    pub fn membership_tensors(
        &self,
        mem: &[Vec<usize>],
        reps: &[Vec<usize>],
        k_max: usize,
    ) -> (Tensor, Tensor) {
        let l = mem.len();
        let h = mem[0].len();
        let mut mv = Vec::with_capacity(l * h);
        for row in mem {
            mv.extend(row.iter().map(|x| *x as i32));
        }
        let mut rv = vec![0i32; l * k_max];
        for (li, row) in reps.iter().enumerate() {
            for (j, r) in row.iter().enumerate() {
                rv[li * k_max + j] = *r as i32;
            }
        }
        (Tensor::i32(vec![l, h], mv), Tensor::i32(vec![l, k_max], rv))
    }

    /// Static (offline) membership — the CHAI-static baseline.
    pub fn static_membership(&self) -> (Vec<Vec<usize>>, Vec<Vec<usize>>) {
        (self.static_membership.clone(), self.static_reps.clone())
    }

    /// Random membership with uniform k per layer (Figure 1 "random head
    /// selection"): k distinct representative heads, randomly assigned
    /// members, canonicalized.
    pub fn random_membership(&self, k: usize) -> (Vec<Vec<usize>>, Vec<Vec<usize>>) {
        let m = self.manifest();
        let (l, h) = (m.model.n_layers, m.model.n_heads);
        let mut rng = self.rng.borrow_mut();
        let mut mems = Vec::new();
        let mut repss = Vec::new();
        for _ in 0..l {
            let mut reps = rng.choose_distinct(h, k);
            reps.sort();
            let mut mem = vec![0usize; h];
            for (hh, slot) in mem.iter_mut().enumerate() {
                // rep heads map to themselves; others random cluster
                *slot = reps.iter().position(|r| *r == hh).unwrap_or_else(|| rng.below(k));
            }
            mems.push(mem);
            repss.push(reps);
        }
        (mems, repss)
    }

    // ------------------------------------------------------------------
    // Scoring path (accuracy tables)
    // ------------------------------------------------------------------

    /// Log-probabilities [T, V] for a token sequence under a variant.
    pub fn logits(&self, tokens: &[i32], variant: &Variant) -> Result<Tensor> {
        let m = self.manifest();
        let t = m.logprob_bucket;
        if tokens.len() > t {
            bail!("sequence {} exceeds logprob bucket {t}", tokens.len());
        }
        let len = tokens.len();
        let mut padded = vec![tokenizer::PAD; t];
        padded[..len].copy_from_slice(tokens);
        let toks = Tensor::i32(vec![t], padded);
        let ln = Tensor::scalar_i32(len as i32);

        let outs = match variant {
            Variant::Mha => self.rt.run("logprob_mha", &[In::Host(&toks), In::Host(&ln)])?,
            Variant::Spatten => {
                self.rt.run("logprob_spatten", &[In::Host(&toks), In::Host(&ln)])?
            }
            Variant::Dejavu(p) => {
                let kept = self.dejavu_kept(tokens, *p)?;
                self.rt.run(
                    &format!("logprob_dejavu_s{p}"),
                    &[In::Host(&toks), In::Host(&ln), In::Host(&kept)],
                )?
            }
            Variant::Chai | Variant::ChaiStatic | Variant::ChaiQkv => {
                let (mem, reps) = match variant {
                    Variant::Chai | Variant::ChaiQkv => {
                        self.online_membership_cached(tokens)?
                    }
                    _ => self.static_membership(),
                };
                let (mt, rt_) = self.membership_tensors(&mem, &reps, self.manifest().k_max);
                let name = if *variant == Variant::ChaiQkv { "logprob_chai_qkv" } else { "logprob_chai" };
                self.rt.run(
                    name,
                    &[In::Host(&toks), In::Host(&ln), In::Host(&mt), In::Host(&rt_)],
                )?
            }
            Variant::UniformK { k, random } => {
                let (mem, reps) = if *random {
                    self.random_membership(*k)
                } else {
                    self.uniform_static_membership(tokens, *k)?
                };
                let (mt, rt_) = self.membership_tensors(&mem, &reps, *k);
                self.rt.run(
                    &format!("logprob_chai_k{k}"),
                    &[In::Host(&toks), In::Host(&ln), In::Host(&mt), In::Host(&rt_)],
                )?
            }
        };
        outs[0].to_tensor()
    }

    /// Memoized wrapper over [`Self::online_membership`] keyed by the
    /// probe prefix (first `probe_tokens` tokens). Used by the scoring
    /// path; the serving/latency path measures the probe cost for real.
    pub fn online_membership_cached(
        &self,
        tokens: &[i32],
    ) -> Result<(Vec<Vec<usize>>, Vec<Vec<usize>>)> {
        let n = tokens.len().min(self.manifest().probe_tokens).max(2);
        let key: Vec<i32> = tokens[..n].to_vec();
        if let Some(hit) = self.membership_cache.borrow().get(&key) {
            return Ok(hit.clone());
        }
        let (ms, _, _) = self.online_membership(tokens)?;
        let mem: Vec<Vec<usize>> = ms.iter().map(|x| x.membership.clone()).collect();
        let reps: Vec<Vec<usize>> = ms.iter().map(|x| x.reps.clone()).collect();
        let mut cache = self.membership_cache.borrow_mut();
        if cache.len() >= 4096 {
            cache.clear();
        }
        cache.insert(key, (mem.clone(), reps.clone()));
        Ok((mem, reps))
    }

    /// "Static head selection" for the Figure-1 sweep: cluster THIS
    /// sequence's probe activations into exactly k clusters per layer
    /// (activation-informed, unlike random).
    pub fn uniform_static_membership(
        &self,
        tokens: &[i32],
        k: usize,
    ) -> Result<(Vec<Vec<usize>>, Vec<Vec<usize>>)> {
        let m = self.manifest();
        let klist = vec![k; m.model.n_layers];
        let pb = m.probe_bucket;
        let n = tokens.len().min(m.probe_tokens).max(2);
        let mut padded = vec![tokenizer::PAD; pb];
        for (i, t) in tokens.iter().take(n).enumerate() {
            padded[i] = *t;
        }
        let outs = self.rt.run(
            "probe_mha",
            &[In::Host(&Tensor::i32(vec![pb], padded)), In::Host(&Tensor::scalar_i32(n as i32))],
        )?;
        let maps = outs[0].to_tensor()?;
        let ms = self.membership_from_maps(&maps, n, &klist)?;
        Ok((
            ms.iter().map(|x| x.membership.clone()).collect(),
            ms.iter().map(|x| x.reps.clone()).collect(),
        ))
    }

    /// DejaVu head selector: prune the heads with the most-uniform probe
    /// attention (highest entropy) — the criterion the paper's Figure 4
    /// shows DejaVu exploits on OPT. kept: [L, n_keep] head indices.
    pub fn dejavu_kept(&self, tokens: &[i32], sparsity_pct: usize) -> Result<Tensor> {
        let m = self.manifest();
        let l = m.model.n_layers;
        // n_keep is a static shape baked at lowering; the manifest is the
        // source of truth (python and rust rounding must not diverge).
        let n_keep = m
            .artifact(&format!("logprob_dejavu_s{sparsity_pct}"))?
            .meta
            .get("n_keep")?
            .usize()?;
        let pb = m.probe_bucket;
        let n = tokens.len().min(m.probe_tokens).max(2);
        let mut padded = vec![tokenizer::PAD; pb];
        for (i, t) in tokens.iter().take(n).enumerate() {
            padded[i] = *t;
        }
        let outs = self.rt.run(
            "probe_mha",
            &[In::Host(&Tensor::i32(vec![pb], padded)), In::Host(&Tensor::scalar_i32(n as i32))],
        )?;
        let maps = outs[0].to_tensor()?;
        let kept = crate::baselines::dejavu::select_heads(&maps, n, n_keep)?;
        let mut v = Vec::with_capacity(l * n_keep);
        for row in &kept {
            v.extend(row.iter().map(|x| *x as i32));
        }
        Ok(Tensor::i32(vec![l, n_keep], v))
    }

    /// Length-normalized logprob of `choice` continuing `prompt_tokens`.
    pub fn score_choice(&self, logits: &Tensor, tokens: &[i32], prompt_len: usize) -> f64 {
        let v = self.manifest().model.vocab_size;
        let lf = logits.as_f32().unwrap();
        let mut total = 0.0f64;
        let mut n = 0usize;
        for pos in prompt_len..tokens.len() {
            // logits row pos-1 predicts token at pos
            let row = &lf[(pos - 1) * v..pos * v];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = mx + row.iter().map(|x| (x - mx).exp()).sum::<f32>().ln();
            total += (row[tokens[pos] as usize] - lse) as f64;
            n += 1;
        }
        total / n.max(1) as f64
    }

    // ------------------------------------------------------------------
    // Serving path (latency benches + server)
    // ------------------------------------------------------------------

    /// Greedy/temperature generation with phase timings (single request;
    /// the coordinator drives the same [`Session`] API token-by-token for
    /// continuous batching).
    pub fn generate(&self, prompt: &str, max_new: usize, variant: &Variant) -> Result<Generation> {
        let mut s = self.start_session(prompt, max_new, variant)?;
        loop {
            match self.step_session(&mut s) {
                Ok(true) => {}
                Ok(false) => break,
                Err(e) => {
                    // return the session's blocks before surfacing the error
                    self.release_session(&mut s);
                    return Err(e);
                }
            }
        }
        Ok(self.finish_session(s))
    }

    fn sample(&self, logits: &Tensor) -> i32 {
        let v = logits.as_f32().unwrap();
        if self.cfg.temperature <= 0.0 {
            // total_cmp: NaN logits (a poisoned forward) must pick a
            // deterministic index, not panic the engine thread
            return v
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0 as i32;
        }
        let t = self.cfg.temperature as f32;
        let mx = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let ws: Vec<f64> = v.iter().map(|x| (((x - mx) / t) as f64).exp()).collect();
        self.rng.borrow_mut().weighted(&ws) as i32
    }

    /// Start a generation session: probe+cluster (CHAI), prefill, first
    /// token. Returns a [`Session`] the caller steps to completion.
    ///
    /// On the default paged path this first reserves the prompt's KV
    /// blocks (adopting indexed prefix blocks), then runs prefill and
    /// scatters the computed rows into the owned blocks; the session
    /// carries only a sequence id, not cache tensors.
    pub fn start_session(&self, prompt: &str, max_new: usize, variant: &Variant) -> Result<Session> {
        let prompt_tokens = tokenizer::encode(prompt, true, false);
        let paged_seq = if self.paged.is_some()
            && matches!(variant, Variant::Mha | Variant::Chai | Variant::ChaiStatic)
        {
            Some(self.paged_admit(variant, &prompt_tokens)?)
        } else {
            None
        };
        match self.start_session_inner(prompt_tokens, max_new, variant, paged_seq) {
            Ok(s) => Ok(s),
            Err(e) => {
                // roll back the block reservation on any prefill failure
                if let (Some(store), Some(seq)) = (&self.paged, paged_seq) {
                    let _ = store.borrow_mut().release(seq);
                }
                Err(e)
            }
        }
    }

    /// Whether the serving hot path runs block-table-native: the
    /// backend brings paged kernels and `--no-batched-decode` has not
    /// forced the legacy bucket gather/scatter path. (The session must
    /// additionally hold `Caches::Paged` storage.)
    fn paged_native(&self) -> bool {
        self.cfg.batched_decode && self.rt.supports_paged()
    }

    fn start_session_inner(
        &self,
        prompt_tokens: Vec<i32>,
        max_new: usize,
        variant: &Variant,
        paged_seq: Option<u64>,
    ) -> Result<Session> {
        let m = self.manifest().clone();
        let total = prompt_tokens.len() + max_new;
        let bucket = crate::config::Manifest::bucket_for(&m.decode_buckets, total)
            .with_context(|| format!("sequence {total} exceeds max bucket"))?;
        let l = m.model.n_layers;

        // membership identification runs up front (Figure 10 steps 1-2);
        // both prefill paths — bucket artifact and block-native — consume
        // the same assignment
        let (clusters, probe_ms, cluster_ms) = match variant {
            Variant::Mha => (None, 0.0, 0.0),
            Variant::Chai => {
                let (ms, p, c) = self.online_membership(&prompt_tokens)?;
                (
                    Some(ClusterAssignment {
                        membership: ms.iter().map(|x| x.membership.clone()).collect(),
                        reps: ms.iter().map(|x| x.reps.clone()).collect(),
                    }),
                    p,
                    c,
                )
            }
            Variant::ChaiStatic => {
                let (membership, reps) = self.static_membership();
                (Some(ClusterAssignment { membership, reps }), 0.0, 0.0)
            }
            _ => bail!(
                "serving path supports mha|chai|chai-static (got {}); other variants are accuracy-only",
                variant.name()
            ),
        };

        // Block-table-native prefill (paged store + paged-capable
        // backend): compute only the non-adopted prompt suffix and write
        // K,V rows straight into the owned blocks — no bucket-shaped
        // caches exist at any point, and prefill *compute* (not just the
        // KV writes) is skipped for prefix blocks adopted via the
        // hash-chain index.
        if let Some(seq) = paged_seq {
            if self.paged_native() {
                let store = self.paged.as_ref().expect("paged seq without store");
                let mut st = store.borrow_mut();
                let shared = st.adopted_prefix_len(seq)?;
                st.stats.prefill_skipped_tokens += shared as u64;
                let t0 = Instant::now();
                let logits = self.rt.prefill_paged(seq, shared, clusters.as_ref(), &mut st)?;
                let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
                st.commit_prefill(seq)?;
                drop(st);
                let prompt_len = prompt_tokens.len();
                let mut tokens = prompt_tokens;
                tokens.push(self.sample(&logits));
                return Ok(Session {
                    variant: variant.clone(),
                    tokens,
                    prompt_len,
                    max_new,
                    bucket,
                    caches: Caches::Paged { seq: Some(seq), kind: variant.cache_kind() },
                    membership_tensors: None,
                    clusters,
                    timing: Timing {
                        probe_ms,
                        cluster_ms,
                        prefill_ms,
                        ttft_ms: probe_ms + cluster_ms + prefill_ms,
                        ..Default::default()
                    },
                    done: false,
                });
            }
        }

        // legacy bucket-artifact prefill (XLA backend until paged
        // artifacts are re-lowered, `--no-batched-decode`, or the
        // `--no-paged` contiguous path)
        let mut padded = vec![tokenizer::PAD; bucket];
        padded[..prompt_tokens.len()].copy_from_slice(&prompt_tokens);
        let toks = Tensor::i32(vec![bucket], padded);
        let ln = Tensor::scalar_i32(prompt_tokens.len() as i32);

        let (caches, logits, timing, mts) = match variant {
            Variant::Mha => {
                let t0 = Instant::now();
                let outs = self
                    .rt
                    .run(&format!("prefill_mha_t{bucket}"), &[In::Host(&toks), In::Host(&ln)])?;
                let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
                let logits = outs[0].to_tensor()?;
                let kc = outs[1].to_tensor()?;
                let vc = outs[2].to_tensor()?;
                (
                    Caches::Mha { kc, vc },
                    logits,
                    Timing { prefill_ms, ttft_ms: prefill_ms, ..Default::default() },
                    None,
                )
            }
            Variant::Chai | Variant::ChaiStatic => {
                let cl = clusters.as_ref().expect("chai prefill without clusters");
                let (mt, rt_) = self.membership_tensors(&cl.membership, &cl.reps, m.k_max);
                let t0 = Instant::now();
                let outs = self.rt.run(
                    &format!("prefill_chai_t{bucket}"),
                    &[In::Host(&toks), In::Host(&ln), In::Host(&mt), In::Host(&rt_)],
                )?;
                let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
                let logits = outs[0].to_tensor()?;
                let kreps: Vec<Tensor> =
                    (1..=l).map(|i| outs[i].to_tensor()).collect::<Result<_>>()?;
                let vc = outs[l + 1].to_tensor()?;
                (
                    Caches::Chai { kreps, vc },
                    logits,
                    Timing {
                        probe_ms,
                        cluster_ms,
                        prefill_ms,
                        ttft_ms: probe_ms + cluster_ms + prefill_ms,
                        ..Default::default()
                    },
                    Some((mt, rt_)),
                )
            }
            _ => unreachable!("non-serving variants rejected above"),
        };

        // migrate the prefill caches into the block store and drop the
        // monolithic tensors — the session then reads/appends K,V
        // through its block table only
        let caches = match paged_seq {
            Some(seq) => {
                let store = self.paged.as_ref().expect("paged seq without store");
                let mut st = store.borrow_mut();
                match &caches {
                    Caches::Mha { kc, vc } => {
                        st.write_prefill_mha(seq, kc, vc, prompt_tokens.len())?
                    }
                    Caches::Chai { kreps, vc } => {
                        st.write_prefill_chai(seq, kreps, vc, prompt_tokens.len())?
                    }
                    Caches::Paged { .. } => unreachable!("prefill produced paged caches"),
                }
                st.commit_prefill(seq)?;
                Caches::Paged { seq: Some(seq), kind: variant.cache_kind() }
            }
            None => caches,
        };

        let mut tokens = prompt_tokens.clone();
        tokens.push(self.sample(&logits));
        Ok(Session {
            variant: variant.clone(),
            tokens,
            prompt_len: prompt_tokens.len(),
            max_new,
            bucket,
            caches,
            membership_tensors: mts,
            clusters,
            timing,
            done: false,
        })
    }

    /// One decode step. Returns false when the session is finished.
    ///
    /// Paged-native sessions route through [`Self::decode_tick`] as a
    /// batch of one (block-table-native kernels, zero bucket copies);
    /// everything else takes the legacy bucket-artifact path.
    pub fn step_session(&self, s: &mut Session) -> Result<bool> {
        if self.paged_native() && matches!(s.caches, Caches::Paged { seq: Some(_), .. }) {
            return self
                .decode_tick(&mut [s])
                .pop()
                .expect("one outcome per session");
        }
        self.step_session_bucket(s)
    }

    /// Advance every live session by one token in a single fused tick.
    ///
    /// Paged-native sessions (block-table storage + a backend with
    /// paged kernels) are grouped per attention variant and dispatched
    /// as ONE ragged batched [`Backend::decode_paged`] call: each row's
    /// K,V is appended into its own tail block and attention reads the
    /// block-resident cache in place, so the tick performs zero
    /// bucket-shaped gather/scatter copies and pays one backend
    /// dispatch regardless of occupancy. (The ref backend still
    /// computes rows sequentially inside the call — its win is the
    /// copy elimination and per-row `len`-bounded attention; a device
    /// backend would additionally vectorize across rows.) Sessions the
    /// native path cannot serve (legacy contiguous caches, XLA bucket
    /// artifacts, `--no-batched-decode`) fall back to their per-session
    /// bucket step within the same tick.
    ///
    /// Returns one outcome per session, in order: `Ok(true)` = more to
    /// generate, `Ok(false)` = finished. Rows are mathematically
    /// independent, so token streams are identical to stepping each
    /// session alone.
    pub fn decode_tick(&self, sessions: &mut [&mut Session]) -> Vec<Result<bool>> {
        let n = sessions.len();
        let mut results: Vec<Option<Result<bool>>> = Vec::with_capacity(n);
        results.resize_with(n, || None);
        let mut batch: Vec<usize> = Vec::new();
        for (i, s) in sessions.iter_mut().enumerate() {
            if session_finished(s) {
                results[i] = Some(Ok(false));
                continue;
            }
            if self.paged_native() && matches!(s.caches, Caches::Paged { seq: Some(_), .. }) {
                batch.push(i);
            } else {
                results[i] = Some(self.step_session_bucket(&mut **s));
            }
        }
        for kind in [CacheKind::Mha, CacheKind::Chai] {
            let group: Vec<usize> = batch
                .iter()
                .copied()
                .filter(|&i| {
                    matches!(&sessions[i].caches, Caches::Paged { kind: k, .. } if *k == kind)
                })
                .collect();
            if !group.is_empty() {
                self.decode_group(sessions, &group, &mut results);
            }
        }
        results
            .into_iter()
            .map(|r| r.expect("every session resolved"))
            .collect()
    }

    /// One fused `decode_paged` call over `group` (indices into
    /// `sessions`; all paged-native, same cache kind).
    fn decode_group(
        &self,
        sessions: &mut [&mut Session],
        group: &[usize],
        results: &mut [Option<Result<bool>>],
    ) {
        let store = self.paged.as_ref().expect("paged sessions without store");
        let mut st = store.borrow_mut();
        // make every row's tail writable first (CoW / fresh block) so
        // allocation failures surface per-session before any compute
        let mut ready: Vec<usize> = Vec::new();
        for &i in group {
            let seq = paged_seq_of(&sessions[i]).expect("native session without seq");
            match st.ensure_append_slot(seq) {
                Ok(()) => ready.push(i),
                Err(e) => results[i] = Some(Err(e)),
            }
        }
        if ready.is_empty() {
            return;
        }
        // relay grouping: partition the ready rows by their longest
        // common run of shared physical blocks. Recomputed fresh every
        // tick AFTER `ensure_append_slot` CoW'd any diverging tails, so
        // a session that forked off a shared chain regroups (or falls
        // out) the very tick its table diverges — a group can never
        // reference a stale panel.
        let mut relay_of: Vec<Option<RelayRef>> = vec![None; ready.len()];
        if self.cfg.relay && ready.len() >= 2 {
            let seqs: Vec<u64> = ready
                .iter()
                .map(|&i| paged_seq_of(&sessions[i]).expect("native session without seq"))
                .collect();
            let bsz = st.block_size;
            let mut gid = 0usize;
            for grp in st.relay_groups(&seqs) {
                // CHAI soundness: one prefix pass per rep panel serves
                // the whole group only if every member agrees on the
                // cluster assignment. A chain match pins the probe
                // prefix, which determines membership — verify anyway.
                let lead = &sessions[ready[grp.members[0]]];
                let coherent = grp.members.iter().all(|&mi| {
                    match (&lead.clusters, &sessions[ready[mi]].clusters) {
                        (None, None) => true,
                        (Some(a), Some(b)) => a.membership == b.membership && a.reps == b.reps,
                        _ => false,
                    }
                });
                if !coherent {
                    st.stats.relay_fallback += grp.members.len() as u64;
                    continue;
                }
                let prefix_len = grp.prefix_blocks * bsz;
                for &mi in &grp.members {
                    relay_of[mi] = Some(RelayRef { group: gid, prefix_len });
                }
                st.stats.relay_groups += 1;
                st.stats.relay_prefix_tokens_saved +=
                    (grp.members.len() as u64 - 1) * prefix_len as u64;
                gid += 1;
            }
            // rows whose first block is shared but that ended up without
            // a groupmate decode fused — the missed-saving counter
            for (mi, &seq) in seqs.iter().enumerate() {
                if relay_of[mi].is_none() {
                    let t = st.table(seq).expect("ready row has a table");
                    if t.full_blocks() > 0 && st.block_shared(t.blocks[0]) {
                        st.stats.relay_fallback += 1;
                    }
                }
            }
        }
        let rows: Vec<PagedDecodeRow> = ready
            .iter()
            .enumerate()
            .map(|(mi, &i)| {
                let s = &sessions[i];
                PagedDecodeRow {
                    seq: paged_seq_of(s).expect("native session without seq"),
                    token: *s.tokens.last().unwrap(),
                    pos: s.tokens.len() - 1,
                    clusters: s.clusters.as_ref(),
                    relay: relay_of[mi],
                }
            })
            .collect();
        let t0 = Instant::now();
        let w0 = crate::util::now_ms();
        let outs = self.rt.decode_paged(&rows, &mut st);
        let w1 = crate::util::now_ms();
        crate::obs::record(0, crate::obs::SpanKind::Fused, w0, w1);
        crate::obs::tick_phase_add(crate::obs::SpanKind::Fused, w1 - w0);
        // one fused call serves the whole batch; attribute wall time
        // evenly for the per-session Figure-12 decomposition
        let per_row_ms = t0.elapsed().as_secs_f64() * 1e3 / ready.len() as f64;
        drop(rows);
        debug_assert_eq!(outs.len(), ready.len(), "one outcome per decode row");
        for (out, &i) in outs.into_iter().zip(ready.iter()) {
            let s: &mut Session = &mut *sessions[i];
            let seq = paged_seq_of(s).expect("native session without seq");
            let outcome = match out {
                Ok(logits) => (|| -> Result<bool> {
                    st.append_committed(seq, *s.tokens.last().unwrap())?;
                    let next = self.sample(&logits);
                    s.timing.decode_ms.push(per_row_ms);
                    s.tokens.push(next);
                    Ok(!session_finished(s))
                })(),
                // rows are independent: only this session fails
                Err(e) => Err(e.context("batched paged decode")),
            };
            results[i] = Some(outcome);
        }
    }

    /// Legacy per-session decode step over bucket-shaped caches: gather
    /// the session's K,V into contiguous tensors, run the bucket decode
    /// artifact, scatter the new row back. Kept for the XLA backend
    /// (until paged artifacts are re-lowered), `--no-batched-decode`
    /// comparisons, and the `--no-paged` contiguous path.
    fn step_session_bucket(&self, s: &mut Session) -> Result<bool> {
        if session_finished(s) {
            return Ok(false);
        }
        let l = self.manifest().model.n_layers;
        let pos = s.tokens.len() - 1;
        let tok = Tensor::scalar_i32(*s.tokens.last().unwrap());
        let pos_t = Tensor::scalar_i32(pos as i32);
        let td = Instant::now();
        let next = match &mut s.caches {
            Caches::Mha { kc, vc } => {
                let outs = self.rt.run(
                    &format!("decode_mha_t{}", s.bucket),
                    &[In::Host(&tok), In::Host(&pos_t), In::Host(kc), In::Host(vc)],
                )?;
                let logits = outs[0].to_tensor()?;
                *kc = outs[1].to_tensor()?;
                *vc = outs[2].to_tensor()?;
                self.sample(&logits)
            }
            Caches::Chai { kreps, vc } => {
                let (mt, rt_) = s.membership_tensors.as_ref().unwrap();
                let mut ins: Vec<In> = vec![In::Host(&tok), In::Host(&pos_t)];
                for kr in kreps.iter() {
                    ins.push(In::Host(kr));
                }
                ins.push(In::Host(vc));
                ins.push(In::Host(mt));
                ins.push(In::Host(rt_));
                let outs = self.rt.run(&format!("decode_chai_t{}", s.bucket), &ins)?;
                let logits = outs[0].to_tensor()?;
                for (i, kr) in kreps.iter_mut().enumerate() {
                    *kr = outs[1 + i].to_tensor()?;
                }
                *vc = outs[l + 1].to_tensor()?;
                self.sample(&logits)
            }
            Caches::Paged { seq, kind } => {
                let seq =
                    (*seq).ok_or_else(|| anyhow::anyhow!("stepping a released session"))?;
                let kind = *kind;
                let store = self.paged.as_ref().expect("paged session without store");
                let mut st = store.borrow_mut();
                // make position `pos` writable first (CoW / fresh block)
                // so allocation failures surface before any compute
                st.ensure_append_slot(seq)?;
                let logits = match kind {
                    CacheKind::Mha => {
                        let (kc, vc) = st.gather_mha(seq, s.bucket)?;
                        let outs = self.rt.run(
                            &format!("decode_mha_t{}", s.bucket),
                            &[In::Host(&tok), In::Host(&pos_t), In::Host(&kc), In::Host(&vc)],
                        )?;
                        let logits = outs[0].to_tensor()?;
                        let kc2 = outs[1].to_tensor()?;
                        let vc2 = outs[2].to_tensor()?;
                        st.write_decode_row(seq, Some(&kc2), None, &vc2, pos)?;
                        logits
                    }
                    CacheKind::Chai => {
                        let (kreps, vc) = st.gather_chai(seq, s.bucket)?;
                        let (mt, rt_) = s.membership_tensors.as_ref().unwrap();
                        let mut ins: Vec<In> = vec![In::Host(&tok), In::Host(&pos_t)];
                        for kr in kreps.iter() {
                            ins.push(In::Host(kr));
                        }
                        ins.push(In::Host(&vc));
                        ins.push(In::Host(mt));
                        ins.push(In::Host(rt_));
                        let outs = self.rt.run(&format!("decode_chai_t{}", s.bucket), &ins)?;
                        let logits = outs[0].to_tensor()?;
                        let kreps2: Vec<Tensor> =
                            (1..=l).map(|i| outs[i].to_tensor()).collect::<Result<_>>()?;
                        let vc2 = outs[l + 1].to_tensor()?;
                        st.write_decode_row(seq, None, Some(&kreps2), &vc2, pos)?;
                        logits
                    }
                };
                st.append_committed(seq, *s.tokens.last().unwrap())?;
                self.sample(&logits)
            }
        };
        s.timing.decode_ms.push(td.elapsed().as_secs_f64() * 1e3);
        s.tokens.push(next);
        Ok(!session_finished(s))
    }

    pub fn finish_session(&self, mut s: Session) -> Generation {
        self.release_session(&mut s);
        let text = tokenizer::decode(&s.tokens[s.prompt_len..]);
        Generation { tokens: s.tokens, text, timing: s.timing }
    }
}

/// KV caches of a live session. The legacy variants hold monolithic
/// host tensors (the CPU PJRT device memory *is* host memory, so this
/// stages without extra copies of consequence); the default `Paged`
/// variant holds only a sequence id into the engine's block store.
/// Paged-capable backends read and append block-resident K,V in place
/// (zero bucket copies); the bucket fallback gathers per step and
/// scatters the new row back. Either way physical memory is
/// block-granular and prefix blocks are shared across sessions.
pub enum Caches {
    Mha { kc: Tensor, vc: Tensor },
    Chai { kreps: Vec<Tensor>, vc: Tensor },
    Paged { seq: Option<u64>, kind: CacheKind },
}

/// A live generation (one request) owned by the engine thread.
pub struct Session {
    pub variant: Variant,
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub max_new: usize,
    pub bucket: usize,
    caches: Caches,
    /// membership/reps tensors for the bucket CHAI artifacts (legacy
    /// decode path only; paged-native sessions carry `clusters` instead)
    membership_tensors: Option<(Tensor, Tensor)>,
    /// parsed cluster assignment for the block-table-native kernels
    clusters: Option<ClusterAssignment>,
    pub timing: Timing,
    pub done: bool,
}

impl Session {
    pub fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }
}

/// A preempted session, off the live set: everything
/// [`Engine::thaw_session`] needs to rebuild the live [`Session`]
/// bit-identically. The cluster assignment is carried verbatim (no
/// re-probe on resume — membership is part of the session's identity),
/// and `swap` holds the spill-tier ticket when the K,V state was
/// swapped out rather than dropped for recompute.
pub struct FrozenSession {
    pub variant: Variant,
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub max_new: usize,
    pub bucket: usize,
    clusters: Option<ClusterAssignment>,
    pub timing: Timing,
    swap: Option<SwapHandle>,
}

impl FrozenSession {
    /// Whether resume will restore from the swap tier (vs recompute).
    pub fn is_swapped(&self) -> bool {
        self.swap.is_some()
    }
}

/// A session detached from any engine, for migration between replicas
/// (the mesh drain path). Unlike [`FrozenSession`], whose `swap` field
/// is a ticket into ONE engine's spill tier, this is fully
/// self-contained: the serialized K,V rows travel inside it, so it can
/// cross a process boundary (see `crate::mesh` for the wire codec) and
/// be re-adopted by [`Engine::import_frozen`] on a different replica.
/// Resume stays bit-deterministic either way: restored rows are
/// bit-exact and anything unrecoverable (e.g. blocks pinned by the
/// source's batchmates) is recomputed through the suffix-prefill path.
pub struct MigratedSession {
    pub variant: Variant,
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub max_new: usize,
    pub bucket: usize,
    pub clusters: Option<ClusterAssignment>,
    pub timing: Timing,
    /// compact per-panel K,V serialization (`None` = recompute on the
    /// target; `blocks[i] = None` = pinned at freeze, re-adopt or
    /// recompute)
    pub kv: Option<SwappedSeq>,
}

impl Engine {
    /// Detach a frozen session from this engine: redeem its swap ticket
    /// (if any) out of the local spill tier into the self-contained
    /// [`MigratedSession`] form a peer replica can adopt.
    pub fn export_frozen(&self, f: FrozenSession) -> MigratedSession {
        let kv = match (&self.swap, f.swap) {
            (Some(tier), Some(h)) => tier.borrow_mut().take(h).ok(),
            _ => None,
        };
        MigratedSession {
            variant: f.variant,
            tokens: f.tokens,
            prompt_len: f.prompt_len,
            max_new: f.max_new,
            bucket: f.bucket,
            clusters: f.clusters,
            timing: f.timing,
            kv,
        }
    }

    /// Adopt a migrated session: stage its K,V payload into this
    /// engine's spill tier and hand back a [`FrozenSession`] that
    /// [`Self::thaw_session`] resumes exactly like a local preemption.
    /// A missing/full tier or absent payload degrades to
    /// recompute-on-resume — never an error, and still bit-identical.
    pub fn import_frozen(&self, m: MigratedSession) -> FrozenSession {
        let MigratedSession { variant, tokens, prompt_len, max_new, bucket, clusters, timing, kv } =
            m;
        let mut handle: Option<SwapHandle> = None;
        if let (Some(tier), Some(entry)) = (&self.swap, kv) {
            let mut t = tier.borrow_mut();
            if t.fits(entry.bytes) {
                handle = t.insert(entry).ok();
            }
        }
        FrozenSession { variant, tokens, prompt_len, max_new, bucket, clusters, timing, swap: handle }
    }
}

/// Paged-store sequence id of a session, if it has block-table storage.
fn paged_seq_of(s: &Session) -> Option<u64> {
    match &s.caches {
        Caches::Paged { seq, .. } => *seq,
        _ => None,
    }
}

/// The single source of truth for session termination, shared by the
/// batched tick and the bucket step (so the paths cannot diverge):
/// a session is finished once it is marked done, its generation budget
/// is spent, or its last token was EOS. Marks `done` as a side effect.
fn session_finished(s: &mut Session) -> bool {
    if !s.done
        && (s.tokens.len() - s.prompt_len >= s.max_new
            || *s.tokens.last().unwrap() == tokenizer::EOS)
    {
        s.done = true;
    }
    s.done
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse_roundtrip() {
        for s in ["mha", "chai", "chai-static", "chai-qkv", "dejavu-30", "spatten", "random-k4", "static-k8"] {
            let v = Variant::parse(s).unwrap();
            assert_eq!(v.name(), s);
        }
        assert!(Variant::parse("nope").is_err());
    }

    #[test]
    fn cache_kinds() {
        assert_eq!(Variant::Mha.cache_kind(), CacheKind::Mha);
        assert_eq!(Variant::Chai.cache_kind(), CacheKind::Chai);
        assert_eq!(Variant::Dejavu(50).cache_kind(), CacheKind::Mha);
    }

    fn toy_engine(seed: u64) -> Engine {
        Engine::load(ServingConfig {
            artifacts_dir: std::path::PathBuf::from("definitely-no-artifacts-here"),
            backend: "ref".into(),
            seed,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn sample_is_nan_safe() {
        let e = toy_engine(0);
        assert_eq!(e.sample(&Tensor::f32(vec![4], vec![0.25, 0.5, 0.75, -1.0])), 2);
        // a NaN logit must yield a deterministic index, not panic the
        // engine thread (total_cmp orders +NaN greatest)
        let idx = e.sample(&Tensor::f32(vec![4], vec![0.25, f32::NAN, 0.75, -1.0]));
        assert_eq!(idx, 1);
        // all-NaN still terminates deterministically
        let idx = e.sample(&Tensor::f32(vec![2], vec![f32::NAN, f32::NAN]));
        assert!(idx == 0 || idx == 1);
    }

    #[test]
    fn freeze_thaw_resumes_bit_identically() {
        // a session frozen mid-decode and thawed — via the swap tier or
        // via recompute — must emit exactly the uncontended token
        // stream, for both cache layouts
        for prefer_swap in [true, false] {
            for variant in [Variant::Mha, Variant::Chai] {
                let prompt = "the color of tom is a long tale";
                let oracle = toy_engine(9);
                let want = oracle.generate(prompt, 10, &variant).unwrap().tokens;

                let e = toy_engine(9);
                let mut s = e.start_session(prompt, 10, &variant).unwrap();
                for _ in 0..3 {
                    assert!(e.step_session(&mut s).unwrap());
                }
                let (frozen, swapped) = e.freeze_session(s, prefer_swap);
                assert_eq!(
                    swapped, prefer_swap,
                    "default swap tier must accept a lone session's blocks"
                );
                assert_eq!(frozen.is_swapped(), swapped);
                let snap = e.paged_snapshot().unwrap();
                assert_eq!(snap.live_tables, 0, "frozen session holds no live blocks");
                if swapped {
                    assert!(e.swap_snapshot().unwrap().used_bytes > 0);
                }

                assert_eq!(e.resume_admission(&frozen), Admission::Admit);
                let mut s = e.thaw_session(frozen).unwrap();
                if swapped {
                    assert_eq!(
                        e.swap_snapshot().unwrap().used_bytes,
                        0,
                        "thaw must drain the swap tier"
                    );
                }
                while e.step_session(&mut s).unwrap() {}
                assert_eq!(
                    s.tokens,
                    want,
                    "{} swap={prefer_swap}: preempted stream must be bit-identical",
                    variant.name()
                );
                e.finish_session(s);
            }
        }
    }

    #[test]
    fn discard_frozen_releases_swap_entry() {
        // an errored resume must not strand the staged bytes in the tier
        let e = toy_engine(4);
        let s = e.start_session("the color of tom is", 6, &Variant::Chai).unwrap();
        let (frozen, swapped) = e.freeze_session(s, true);
        assert!(swapped);
        assert!(e.swap_snapshot().unwrap().used_bytes > 0);
        e.discard_frozen(frozen);
        let snap = e.swap_snapshot().unwrap();
        assert_eq!(snap.used_bytes, 0);
        assert_eq!(snap.stats.discarded, 1);
    }

    #[test]
    fn freeze_thaw_survives_repeated_preemption() {
        // freeze/thaw on every single decode step — the most hostile
        // schedule — still reproduces the uncontended stream
        let variant = Variant::Chai;
        let prompt = "tom keeps the hat in the box";
        let want = toy_engine(3).generate(prompt, 6, &variant).unwrap().tokens;
        let e = toy_engine(3);
        let mut s = e.start_session(prompt, 6, &variant).unwrap();
        let mut alternate = true;
        loop {
            let (frozen, _) = e.freeze_session(s, alternate);
            alternate = !alternate;
            s = e.thaw_session(frozen).unwrap();
            if !e.step_session(&mut s).unwrap() {
                break;
            }
        }
        assert_eq!(s.tokens, want);
        e.finish_session(s);
        let snap = e.paged_snapshot().unwrap();
        assert_eq!(snap.live_tables, 0);
        assert_eq!(e.swap_snapshot().unwrap().used_bytes, 0);
    }

    #[test]
    fn decode_tick_matches_per_session_steps() {
        // one decode_tick over three live sessions advances each by one
        // token, identically to stepping a fresh engine session-by-session
        let e = toy_engine(1);
        let prompts = ["the color of tom is", "tom keeps the hat", "the color of tom is"];
        let mut sessions: Vec<Session> = prompts
            .iter()
            .map(|p| e.start_session(p, 4, &Variant::Chai).unwrap())
            .collect();
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        let outcomes = e.decode_tick(&mut refs);
        assert_eq!(outcomes.len(), 3);
        for o in &outcomes {
            assert!(o.is_ok(), "tick must succeed: {o:?}");
        }
        let streams: Vec<Vec<i32>> = sessions.iter().map(|s| s.tokens.clone()).collect();
        for mut s in sessions {
            e.release_session(&mut s);
        }

        let e2 = toy_engine(1);
        for (p, want) in prompts.iter().zip(&streams) {
            let mut s = e2.start_session(p, 4, &Variant::Chai).unwrap();
            e2.step_session(&mut s).unwrap();
            assert_eq!(&s.tokens, want, "batched tick == sequential step for {p:?}");
            e2.release_session(&mut s);
        }
        // the native path never materialized bucket-shaped caches
        let snap = e2.paged_snapshot().unwrap();
        assert_eq!(snap.stats.decode_gather_copies, 0);
        assert_eq!(snap.stats.decode_scatter_copies, 0);
    }
}
