//! Benchmark substrate: synthetic serving workloads (Poisson arrivals,
//! length distributions drawn from the corpus statistics) and table
//! rendering for the bench binaries.

use crate::util::rng::Rng;

/// One request in a serving trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub arrival_ms: f64,
    pub prompt: String,
    pub max_new: usize,
}

/// Prompt pool drawn from the world's fact templates (same distribution
/// the model was trained on, so generations are meaningful).
pub fn prompt_pool() -> Vec<String> {
    let names = ["tom", "ana", "raj", "mia", "leo", "zoe", "kai", "eva"];
    let mut pool = Vec::new();
    for n in names {
        pool.push(format!("the color of {n} is"));
        pool.push(format!("{n} keeps the"));
        pool.push(format!("question : does {n} eat"));
        pool.push(format!("the friend of {n} is"));
    }
    pool
}

/// Poisson-arrival trace with geometric-ish output lengths.
pub fn poisson_trace(
    n: usize,
    rate_per_s: f64,
    max_new_lo: usize,
    max_new_hi: usize,
    seed: u64,
) -> Vec<TraceRequest> {
    let mut rng = Rng::new(seed);
    let pool = prompt_pool();
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += rng.exp(rate_per_s) * 1e3;
            TraceRequest {
                arrival_ms: t,
                prompt: pool[rng.below(pool.len())].clone(),
                max_new: rng.range(max_new_lo, max_new_hi + 1),
            }
        })
        .collect()
}

/// Fixed-width table printer for bench output (criterion is unavailable;
/// benches print paper-style rows instead).
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format ms with adaptive precision.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 100.0 {
        format!("{ms:.0}")
    } else if ms >= 1.0 {
        format!("{ms:.2}")
    } else {
        format!("{ms:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_sorted_and_sized() {
        let t = poisson_trace(50, 10.0, 4, 16, 0);
        assert_eq!(t.len(), 50);
        for w in t.windows(2) {
            assert!(w[0].arrival_ms <= w[1].arrival_ms);
        }
        assert!(t.iter().all(|r| (4..=16).contains(&r.max_new)));
    }

    #[test]
    fn trace_deterministic() {
        let a = poisson_trace(10, 5.0, 4, 8, 7);
        let b = poisson_trace(10, 5.0, 4, 8, 7);
        assert_eq!(a.iter().map(|r| r.arrival_ms.to_bits()).collect::<Vec<_>>(),
                   b.iter().map(|r| r.arrival_ms.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn trace_rate_roughly_matches() {
        let t = poisson_trace(2000, 50.0, 1, 2, 3);
        let span_s = t.last().unwrap().arrival_ms / 1e3;
        let rate = 2000.0 / span_s;
        assert!((rate - 50.0).abs() < 10.0, "rate {rate}");
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("Demo") && s.contains("bb"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
