//! Baseline head/token selectors (the comparison systems of Tables 1-3).
//!
//! * [`dejavu`] — runtime head pruning by attention *uniformity* (the
//!   criterion DEJAVU exploits on OPT, paper Figure 4): heads whose scores
//!   are closest to uniform carry the least token-selective signal and are
//!   pruned first. (The original uses trained MLP predictors; our
//!   substitution implements the criterion the predictors learn —
//!   DESIGN.md §Substitutions.)
//! * SpAtten's cascade token+head pruning is compiled **into** the
//!   `logprob_spatten` artifact (in-graph top-k, `model.py`); no host-side
//!   selector is needed.

pub mod dejavu {
    use anyhow::Result;

    use crate::tensor::Tensor;

    /// Normalized entropy (0..1) of one attention row.
    fn row_entropy(row: &[f32]) -> f64 {
        let n = row.len();
        if n <= 1 {
            return 0.0;
        }
        let mut h = 0.0f64;
        for &p in row {
            if p > 1e-9 {
                h -= (p as f64) * (p as f64).ln();
            }
        }
        h / (n as f64).ln()
    }

    /// Mean normalized attention entropy per head from probe maps
    /// `[L, H, P, P]` over the first `n_tokens` (queries 1..n, keys ≤ q).
    pub fn head_entropy(maps: &Tensor, n_tokens: usize) -> Result<Vec<Vec<f64>>> {
        let (l, h, p) = (maps.shape[0], maps.shape[1], maps.shape[2]);
        let v = maps.as_f32()?;
        let mut out = vec![vec![0.0f64; h]; l];
        for li in 0..l {
            for hi in 0..h {
                let mut acc = 0.0;
                let mut cnt = 0usize;
                for q in 1..n_tokens.min(p) {
                    let base = ((li * h + hi) * p + q) * p;
                    acc += row_entropy(&v[base..base + q + 1]);
                    cnt += 1;
                }
                out[li][hi] = if cnt == 0 { 0.0 } else { acc / cnt as f64 };
            }
        }
        Ok(out)
    }

    /// Keep the `n_keep` *least-uniform* (lowest-entropy) heads per layer,
    /// sorted ascending. Returns [L][n_keep] head indices.
    pub fn select_heads(maps: &Tensor, n_tokens: usize, n_keep: usize) -> Result<Vec<Vec<usize>>> {
        let ent = head_entropy(maps, n_tokens)?;
        Ok(ent
            .iter()
            .map(|layer| {
                let mut idx: Vec<usize> = (0..layer.len()).collect();
                idx.sort_by(|a, b| layer[*a].partial_cmp(&layer[*b]).unwrap());
                let mut kept: Vec<usize> = idx.into_iter().take(n_keep).collect();
                kept.sort();
                kept
            })
            .collect())
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        /// maps [1, 3, 4, 4]: head 0 peaked, head 1 uniform, head 2 mixed.
        fn toy_maps() -> Tensor {
            let p = 4;
            let mut v = vec![0.0f32; 3 * p * p];
            let head = |h: usize, q: usize| (h * p + q) * p;
            for q in 0..p {
                // head 0: all mass on token 0
                v[head(0, q)] = 1.0;
                // head 1: uniform over q+1 keys
                for k in 0..=q {
                    v[head(1, q) + k] = 1.0 / (q + 1) as f32;
                }
                // head 2: linear ramp
                let s: f32 = (0..=q).map(|k| (k + 1) as f32).sum();
                for k in 0..=q {
                    v[head(2, q) + k] = (k + 1) as f32 / s;
                }
            }
            Tensor::f32(vec![1, 3, p, p], v)
        }

        #[test]
        fn entropy_ordering() {
            let ent = head_entropy(&toy_maps(), 4).unwrap();
            assert!(ent[0][0] < ent[0][2], "{:?}", ent);
            assert!(ent[0][2] < ent[0][1], "{:?}", ent);
            assert!((ent[0][1] - 1.0).abs() < 1e-6, "uniform head entropy {:?}", ent[0][1]);
        }

        #[test]
        fn select_prunes_uniform_first() {
            let kept = select_heads(&toy_maps(), 4, 2).unwrap();
            assert_eq!(kept[0], vec![0, 2]); // uniform head 1 pruned
            let kept1 = select_heads(&toy_maps(), 4, 1).unwrap();
            assert_eq!(kept1[0], vec![0]);
        }

        #[test]
        fn kept_sorted_and_bounded() {
            let kept = select_heads(&toy_maps(), 4, 3).unwrap();
            assert_eq!(kept[0].len(), 3);
            assert!(kept[0].windows(2).all(|w| w[0] < w[1]));
        }
    }
}
