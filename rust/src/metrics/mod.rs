//! Serving metrics: counters + latency histograms, thread-safe, exported
//! as JSON by the server's `stats` command and printed by benches.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats::Histogram;

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    latencies: BTreeMap<String, Histogram>,
    /// last-write-wins values (pool occupancy, hit rates, ...)
    gauges: BTreeMap<String, f64>,
    /// static string facts (backend name, model name, ...)
    infos: BTreeMap<String, String>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, n: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += n;
    }

    pub fn observe_ms(&self, name: &str, ms: f64) {
        self.observe(name, ms);
    }

    /// Record a unitless histogram observation (e.g. per-tick decode
    /// batch occupancy). Shares the latency histogram machinery; the
    /// `_ms` suffix in the JSON summary is cosmetic.
    pub fn observe(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.latencies.entry(name.to_string()).or_default().record(value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// Set a point-in-time gauge (overwrites the previous value).
    pub fn set_gauge(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().gauges.get(name).copied().unwrap_or(0.0)
    }

    /// Record a static string fact (e.g. `backend` = "ref").
    pub fn set_info(&self, name: &str, value: &str) {
        let mut g = self.inner.lock().unwrap();
        g.infos.insert(name.to_string(), value.to_string());
    }

    pub fn info(&self, name: &str) -> Option<String> {
        self.inner.lock().unwrap().infos.get(name).cloned()
    }

    pub fn mean_ms(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .latencies
            .get(name)
            .map(|h| h.mean())
            .unwrap_or(0.0)
    }

    /// Focused view: counters and gauges whose names start with any of
    /// `prefixes`, flattened into one object. The server's `sched`
    /// command is built from this (queue depths, preemption/swap
    /// counters) without shipping the whole metrics dump.
    pub fn subset_json(&self, prefixes: &[&str]) -> Json {
        let g = self.inner.lock().unwrap();
        let keep = |k: &str| prefixes.iter().any(|p| k.starts_with(p));
        let mut fields: BTreeMap<String, Json> = BTreeMap::new();
        for (k, v) in g.counters.iter().filter(|(k, _)| keep(k)) {
            fields.insert(k.clone(), Json::Num(*v as f64));
        }
        for (k, v) in g.gauges.iter().filter(|(k, _)| keep(k)) {
            fields.insert(k.clone(), Json::Num(*v));
        }
        Json::Obj(fields)
    }

    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let counters = Json::Obj(
            g.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
        );
        let lat = Json::Obj(
            g.latencies
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(h.total as f64)),
                            ("mean_ms", Json::Num(h.mean())),
                            ("p50_ms", Json::Num(h.quantile(0.5))),
                            ("p95_ms", Json::Num(h.quantile(0.95))),
                            ("p99_ms", Json::Num(h.quantile(0.99))),
                        ]),
                    )
                })
                .collect(),
        );
        let gauges = Json::Obj(
            g.gauges.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
        );
        let infos = Json::Obj(
            g.infos.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("latency", lat),
            ("gauges", gauges),
            ("info", infos),
        ])
    }
}

/// Key-wise sum of the numeric fields of several JSON objects — the
/// router's per-replica rollup primitive (counters and gauges are both
/// flat `name → number` objects). Non-numeric fields are skipped; a key
/// missing from some replicas contributes only where present.
pub fn sum_json_objects<'a>(objs: impl IntoIterator<Item = &'a Json>) -> Json {
    let mut out: BTreeMap<String, f64> = BTreeMap::new();
    for o in objs {
        if let Json::Obj(m) = o {
            for (k, v) in m {
                if let Json::Num(n) = v {
                    *out.entry(k.clone()).or_insert(0.0) += n;
                }
            }
        }
    }
    Json::Obj(out.into_iter().map(|(k, v)| (k, Json::Num(v))).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_json_objects_is_keywise() {
        let a = Json::obj(vec![("x", Json::Num(1.0)), ("y", Json::Num(2.0))]);
        let b = Json::obj(vec![("x", Json::Num(10.0)), ("z", Json::Str("skip".into()))]);
        let s = sum_json_objects([&a, &b]);
        assert_eq!(s.get("x").unwrap().num().unwrap(), 11.0);
        assert_eq!(s.get("y").unwrap().num().unwrap(), 2.0);
        assert!(s.opt("z").is_none(), "non-numeric fields are dropped");
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("req");
        m.add("req", 4);
        assert_eq!(m.counter("req"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn latency_summary() {
        let m = Metrics::new();
        for i in 0..100 {
            m.observe_ms("ttft", 1.0 + i as f64 * 0.1);
        }
        assert!(m.mean_ms("ttft") > 1.0);
        let j = m.to_json();
        assert_eq!(
            j.get("latency").unwrap().get("ttft").unwrap().get("count").unwrap().usize().unwrap(),
            100
        );
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        assert_eq!(m.gauge("kv_used_bytes"), 0.0);
        m.set_gauge("kv_used_bytes", 123.0);
        m.set_gauge("kv_used_bytes", 456.0);
        assert_eq!(m.gauge("kv_used_bytes"), 456.0);
        let j = m.to_json();
        assert_eq!(
            j.get("gauges").unwrap().get("kv_used_bytes").unwrap().usize().unwrap(),
            456
        );
    }

    #[test]
    fn subset_filters_counters_and_gauges_by_prefix() {
        let m = Metrics::new();
        m.inc("sched_preempt_swap");
        m.inc("tokens");
        m.set_gauge("sched_pending", 3.0);
        m.set_gauge("swap_used_bytes", 64.0);
        m.set_gauge("kv_used_bytes", 9.0);
        let j = m.subset_json(&["sched_", "swap_"]);
        assert_eq!(j.get("sched_preempt_swap").unwrap().usize().unwrap(), 1);
        assert_eq!(j.get("sched_pending").unwrap().usize().unwrap(), 3);
        assert_eq!(j.get("swap_used_bytes").unwrap().usize().unwrap(), 64);
        assert!(j.opt("tokens").is_none());
        assert!(j.opt("kv_used_bytes").is_none());
    }

    #[test]
    fn infos_surface_in_json() {
        let m = Metrics::new();
        assert_eq!(m.info("backend"), None);
        m.set_info("backend", "ref");
        assert_eq!(m.info("backend").as_deref(), Some("ref"));
        let j = m.to_json();
        assert_eq!(j.get("info").unwrap().get("backend").unwrap().str().unwrap(), "ref");
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.inc("x");
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.counter("x"), 4000);
    }
}
