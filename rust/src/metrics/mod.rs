//! Serving metrics: counters + latency histograms, thread-safe, exported
//! as JSON by the server's `stats` command and printed by benches.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats::Histogram;

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    latencies: BTreeMap<String, Histogram>,
    /// last-write-wins values (pool occupancy, hit rates, ...)
    gauges: BTreeMap<String, f64>,
    /// static string facts (backend name, model name, ...)
    infos: BTreeMap<String, String>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, n: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += n;
    }

    pub fn observe_ms(&self, name: &str, ms: f64) {
        self.observe(name, ms);
    }

    /// Record a unitless histogram observation (e.g. per-tick decode
    /// batch occupancy). Shares the latency histogram machinery; the
    /// `_ms` suffix in the JSON summary is cosmetic.
    pub fn observe(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.latencies.entry(name.to_string()).or_default().record(value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// Set a point-in-time gauge (overwrites the previous value).
    pub fn set_gauge(&self, name: &str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().gauges.get(name).copied().unwrap_or(0.0)
    }

    /// Record a static string fact (e.g. `backend` = "ref").
    pub fn set_info(&self, name: &str, value: &str) {
        let mut g = self.inner.lock().unwrap();
        g.infos.insert(name.to_string(), value.to_string());
    }

    pub fn info(&self, name: &str) -> Option<String> {
        self.inner.lock().unwrap().infos.get(name).cloned()
    }

    pub fn mean_ms(&self, name: &str) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .latencies
            .get(name)
            .map(|h| h.mean())
            .unwrap_or(0.0)
    }

    /// Focused view: counters and gauges whose names start with any of
    /// `prefixes`, flattened into one object. The server's `sched`
    /// command is built from this (queue depths, preemption/swap
    /// counters) without shipping the whole metrics dump.
    pub fn subset_json(&self, prefixes: &[&str]) -> Json {
        let g = self.inner.lock().unwrap();
        let keep = |k: &str| prefixes.iter().any(|p| k.starts_with(p));
        let mut fields: BTreeMap<String, Json> = BTreeMap::new();
        for (k, v) in g.counters.iter().filter(|(k, _)| keep(k)) {
            fields.insert(k.clone(), Json::Num(*v as f64));
        }
        for (k, v) in g.gauges.iter().filter(|(k, _)| keep(k)) {
            fields.insert(k.clone(), Json::Num(*v));
        }
        Json::Obj(fields)
    }

    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let counters = Json::Obj(
            g.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
        );
        let lat = Json::Obj(
            g.latencies
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(h.total as f64)),
                            ("mean_ms", Json::Num(h.mean())),
                            ("p50_ms", Json::Num(h.quantile(0.5))),
                            ("p95_ms", Json::Num(h.quantile(0.95))),
                            ("p99_ms", Json::Num(h.quantile(0.99))),
                            ("sum_ms", Json::Num(h.sum_ms)),
                            // raw bucket counts: the mergeable form —
                            // quantiles of sums are nonsense, sums of
                            // buckets are exact.
                            (
                                "buckets",
                                Json::Arr(
                                    h.counts()
                                        .iter()
                                        .map(|c| Json::Num(*c as f64))
                                        .collect(),
                                ),
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        let gauges = Json::Obj(
            g.gauges.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
        );
        let infos = Json::Obj(
            g.infos.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("latency", lat),
            ("gauges", gauges),
            ("info", infos),
        ])
    }
}

/// Key-wise sum of the numeric fields of several JSON objects — the
/// router's rollup primitive for *counters*, which are the only metric
/// kind where plain addition is always the right merge. Non-numeric
/// fields are skipped; a key missing from some replicas contributes
/// only where present. Gauges go through [`merge_gauge_objects`] and
/// latency histograms through [`merge_latency_objects`] instead.
pub fn sum_json_objects<'a>(objs: impl IntoIterator<Item = &'a Json>) -> Json {
    let mut out: BTreeMap<String, f64> = BTreeMap::new();
    for o in objs {
        if let Json::Obj(m) = o {
            for (k, v) in m {
                if let Json::Num(n) = v {
                    *out.entry(k.clone()).or_insert(0.0) += n;
                }
            }
        }
    }
    Json::Obj(out.into_iter().map(|(k, v)| (k, Json::Num(v))).collect())
}

/// How a gauge combines across replicas, declared by name suffix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GaugeKind {
    /// Totals (bytes, blocks, queue depths, entry counts): add.
    Sum,
    /// Rates / fractions / per-core ids: the sum of N rates is
    /// meaningless — report the mean across replicas that have the key.
    Avg,
    /// High-water marks and peaks: the fleet-wide peak is the max.
    Max,
}

/// Classify a gauge by its name. The convention is enforced here rather
/// than carried per-value over the wire: `_rate`/`_frac`/`_ratio` are
/// averaged, `_hwm`/`_peak` are maxed, everything else (bytes, blocks,
/// depths, counts) sums.
pub fn gauge_kind(name: &str) -> GaugeKind {
    if name.ends_with("_rate") || name.ends_with("_frac") || name.ends_with("_ratio") {
        GaugeKind::Avg
    } else if name.ends_with("_hwm") || name.ends_with("_peak") {
        GaugeKind::Max
    } else {
        GaugeKind::Sum
    }
}

/// Kind-aware merge of per-replica gauge objects ([`gauge_kind`] picks
/// sum/avg/max per key). Avg divides by the number of replicas that
/// reported the key, not the fleet size.
pub fn merge_gauge_objects<'a>(objs: impl IntoIterator<Item = &'a Json>) -> Json {
    let mut acc: BTreeMap<String, (f64, f64, u64)> = BTreeMap::new(); // (sum, max, n)
    for o in objs {
        if let Json::Obj(m) = o {
            for (k, v) in m {
                if let Json::Num(n) = v {
                    let e = acc.entry(k.clone()).or_insert((0.0, f64::NEG_INFINITY, 0));
                    e.0 += n;
                    e.1 = e.1.max(*n);
                    e.2 += 1;
                }
            }
        }
    }
    Json::Obj(
        acc.into_iter()
            .map(|(k, (sum, max, n))| {
                let v = match gauge_kind(&k) {
                    GaugeKind::Sum => sum,
                    GaugeKind::Avg => sum / n as f64,
                    GaugeKind::Max => max,
                };
                (k, Json::Num(v))
            })
            .collect(),
    )
}

/// Merge per-replica latency sections bucket-wise. Each input is a
/// `name → {count, …, sum_ms, buckets}` object as produced by
/// [`Metrics::to_json`]; the output has the same shape with exact
/// merged buckets and quantiles recomputed from them (quantiles of
/// sums would be nonsense). Entries without a `buckets` array (older
/// replicas) contribute nothing rather than poisoning the merge.
pub fn merge_latency_objects<'a>(objs: impl IntoIterator<Item = &'a Json>) -> Json {
    let mut acc: BTreeMap<String, Histogram> = BTreeMap::new();
    for o in objs {
        if let Json::Obj(m) = o {
            for (k, v) in m {
                let (Some(Json::Arr(buckets)), Some(sum)) =
                    (v.opt("buckets"), v.opt("sum_ms").and_then(|s| s.num().ok()))
                else {
                    continue;
                };
                let counts: Vec<u64> =
                    buckets.iter().map(|b| b.num().unwrap_or(0.0) as u64).collect();
                acc.entry(k.clone())
                    .or_insert_with(Histogram::new)
                    .absorb_counts(&counts, sum);
            }
        }
    }
    Json::Obj(
        acc.into_iter()
            .map(|(k, h)| {
                let j = Json::obj(vec![
                    ("count", Json::Num(h.total as f64)),
                    ("mean_ms", Json::Num(h.mean())),
                    ("p50_ms", Json::Num(h.quantile(0.5))),
                    ("p95_ms", Json::Num(h.quantile(0.95))),
                    ("p99_ms", Json::Num(h.quantile(0.99))),
                    ("sum_ms", Json::Num(h.sum_ms)),
                    (
                        "buckets",
                        Json::Arr(h.counts().iter().map(|c| Json::Num(*c as f64)).collect()),
                    ),
                ]);
                (k, j)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_json_objects_is_keywise() {
        let a = Json::obj(vec![("x", Json::Num(1.0)), ("y", Json::Num(2.0))]);
        let b = Json::obj(vec![("x", Json::Num(10.0)), ("z", Json::Str("skip".into()))]);
        let s = sum_json_objects([&a, &b]);
        assert_eq!(s.get("x").unwrap().num().unwrap(), 11.0);
        assert_eq!(s.get("y").unwrap().num().unwrap(), 2.0);
        assert!(s.opt("z").is_none(), "non-numeric fields are dropped");
    }

    #[test]
    fn gauge_merge_is_kind_aware() {
        let a = Json::obj(vec![
            ("kv_used_bytes", Json::Num(100.0)),
            ("paged_prefix_hit_rate", Json::Num(0.8)),
            ("net_inbox_hwm", Json::Num(7.0)),
        ]);
        let b = Json::obj(vec![
            ("kv_used_bytes", Json::Num(50.0)),
            ("paged_prefix_hit_rate", Json::Num(0.4)),
            ("net_inbox_hwm", Json::Num(3.0)),
        ]);
        let c = Json::obj(vec![("kv_used_bytes", Json::Num(25.0))]);
        let m = merge_gauge_objects([&a, &b, &c]);
        // totals add
        assert_eq!(m.get("kv_used_bytes").unwrap().num().unwrap(), 175.0);
        // rates average over replicas that reported the key (2, not 3)
        assert!((m.get("paged_prefix_hit_rate").unwrap().num().unwrap() - 0.6).abs() < 1e-12);
        // high-water marks take the fleet max
        assert_eq!(m.get("net_inbox_hwm").unwrap().num().unwrap(), 7.0);
    }

    #[test]
    fn latency_merge_is_bucketwise_not_summed() {
        // Two replicas with identical latency distributions: the merged
        // p50 must equal the per-replica p50, not double it (the old
        // sum-everything rollup produced 2x quantiles).
        let m1 = Metrics::new();
        let m2 = Metrics::new();
        for i in 0..200 {
            let x = 1.0 + (i % 50) as f64 * 0.37;
            m1.observe_ms("ttft", x);
            m2.observe_ms("ttft", x);
        }
        let j1 = m1.to_json();
        let j2 = m2.to_json();
        let l1 = j1.get("latency").unwrap();
        let l2 = j2.get("latency").unwrap();
        let merged = merge_latency_objects([l1, l2]);
        let t = merged.get("ttft").unwrap();
        let t1 = l1.get("ttft").unwrap();
        assert_eq!(t.get("count").unwrap().num().unwrap(), 400.0);
        assert_eq!(
            t.get("p50_ms").unwrap().num().unwrap(),
            t1.get("p50_ms").unwrap().num().unwrap()
        );
        assert_eq!(
            t.get("p99_ms").unwrap().num().unwrap(),
            t1.get("p99_ms").unwrap().num().unwrap()
        );
        assert!(
            (t.get("mean_ms").unwrap().num().unwrap()
                - t1.get("mean_ms").unwrap().num().unwrap())
            .abs()
                < 1e-9
        );
        // raw buckets survive the merge for downstream re-merging
        let bk = t.get("buckets").unwrap();
        match bk {
            Json::Arr(xs) => {
                let total: f64 = xs.iter().map(|x| x.num().unwrap()).sum();
                assert_eq!(total, 400.0);
            }
            _ => panic!("buckets must be an array"),
        }
    }

    #[test]
    fn latency_json_exposes_raw_buckets() {
        let m = Metrics::new();
        m.observe_ms("ttft", 5.0);
        let j = m.to_json();
        let t = j.get("latency").unwrap().get("ttft").unwrap();
        assert_eq!(t.get("sum_ms").unwrap().num().unwrap(), 5.0);
        match t.get("buckets").unwrap() {
            Json::Arr(xs) => {
                assert_eq!(xs.iter().map(|x| x.num().unwrap()).sum::<f64>(), 1.0)
            }
            _ => panic!("buckets must be an array"),
        }
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("req");
        m.add("req", 4);
        assert_eq!(m.counter("req"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn latency_summary() {
        let m = Metrics::new();
        for i in 0..100 {
            m.observe_ms("ttft", 1.0 + i as f64 * 0.1);
        }
        assert!(m.mean_ms("ttft") > 1.0);
        let j = m.to_json();
        assert_eq!(
            j.get("latency").unwrap().get("ttft").unwrap().get("count").unwrap().usize().unwrap(),
            100
        );
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        assert_eq!(m.gauge("kv_used_bytes"), 0.0);
        m.set_gauge("kv_used_bytes", 123.0);
        m.set_gauge("kv_used_bytes", 456.0);
        assert_eq!(m.gauge("kv_used_bytes"), 456.0);
        let j = m.to_json();
        assert_eq!(
            j.get("gauges").unwrap().get("kv_used_bytes").unwrap().usize().unwrap(),
            456
        );
    }

    #[test]
    fn subset_filters_counters_and_gauges_by_prefix() {
        let m = Metrics::new();
        m.inc("sched_preempt_swap");
        m.inc("tokens");
        m.set_gauge("sched_pending", 3.0);
        m.set_gauge("swap_used_bytes", 64.0);
        m.set_gauge("kv_used_bytes", 9.0);
        let j = m.subset_json(&["sched_", "swap_"]);
        assert_eq!(j.get("sched_preempt_swap").unwrap().usize().unwrap(), 1);
        assert_eq!(j.get("sched_pending").unwrap().usize().unwrap(), 3);
        assert_eq!(j.get("swap_used_bytes").unwrap().usize().unwrap(), 64);
        assert!(j.opt("tokens").is_none());
        assert!(j.opt("kv_used_bytes").is_none());
    }

    #[test]
    fn infos_surface_in_json() {
        let m = Metrics::new();
        assert_eq!(m.info("backend"), None);
        m.set_info("backend", "ref");
        assert_eq!(m.info("backend").as_deref(), Some("ref"));
        let j = m.to_json();
        assert_eq!(j.get("info").unwrap().get("backend").unwrap().str().unwrap(), "ref");
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.inc("x");
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.counter("x"), 4000);
    }
}
