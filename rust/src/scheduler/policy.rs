//! Scheduling policy: knobs + the swap-vs-recompute cost model, pure
//! and unit-tested in isolation from the engine.

use crate::config::ServingConfig;

/// What to do with a preemption victim's K,V state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptAction {
    /// stage sole-owner blocks into the host spill tier; restore
    /// bit-exactly on resume
    Swap,
    /// drop the blocks; resume replays the cached positions through the
    /// suffix `prefill_paged` path
    Recompute,
}

/// Scheduler knobs, derived from [`ServingConfig`].
#[derive(Debug, Clone)]
pub struct SchedPolicy {
    /// max live sessions per tick (continuous-batching width)
    pub max_batch: usize,
    /// enable preempt-and-requeue of live sessions under overload
    pub preempt: bool,
    /// consecutive ticks the queue head may be deferred before the
    /// scheduler preempts a live session for it
    pub starve_ticks: u64,
    /// sessions with at most this many cached positions always
    /// recompute (replaying a short prefix is cheaper than a swap
    /// round-trip)
    pub recompute_max_tokens: usize,
    /// legacy contiguous-pool budget (`--no-paged` path)
    pub kv_capacity_bytes: usize,
}

impl SchedPolicy {
    pub fn from_config(cfg: &ServingConfig) -> SchedPolicy {
        SchedPolicy {
            max_batch: cfg.max_batch,
            preempt: cfg.preempt,
            starve_ticks: cfg.starve_ticks,
            recompute_max_tokens: cfg.recompute_max_tokens,
            kv_capacity_bytes: cfg.kv_capacity_bytes,
        }
    }
}

/// Per-session cost model (tokens-to-replay vs bytes-to-swap): swap
/// when the session is expensive to replay AND the spill tier can hold
/// its sole-owner bytes; recompute when the replay is cheap, the tier
/// is full, or nothing would actually be staged (a fully prefix-shared
/// session swaps zero bytes — its blocks stay pinned by its
/// batchmates, so recompute-resume re-adopts them for free).
pub fn preempt_action(
    replay_tokens: usize,
    swap_bytes: usize,
    swap_free_bytes: usize,
    recompute_max_tokens: usize,
) -> PreemptAction {
    if swap_bytes == 0 || swap_bytes > swap_free_bytes {
        return PreemptAction::Recompute;
    }
    if replay_tokens <= recompute_max_tokens {
        return PreemptAction::Recompute;
    }
    PreemptAction::Swap
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_tier_forces_recompute() {
        assert_eq!(preempt_action(1000, 4096, 1024, 0), PreemptAction::Recompute);
        assert_eq!(preempt_action(1000, 4096, 4096, 0), PreemptAction::Swap);
    }

    #[test]
    fn cheap_replay_prefers_recompute() {
        assert_eq!(preempt_action(8, 4096, 1 << 20, 16), PreemptAction::Recompute);
        assert_eq!(preempt_action(17, 4096, 1 << 20, 16), PreemptAction::Swap);
    }

    #[test]
    fn fully_shared_sessions_never_swap() {
        // zero sole-owner bytes: nothing to stage, recompute re-adopts
        assert_eq!(preempt_action(1000, 0, 1 << 20, 0), PreemptAction::Recompute);
    }
}
