//! Pure batching/scheduling policy, unit- and property-tested in
//! isolation from the engine thread.
//!
//! Policy: prefill-prioritized continuous batching (vLLM-default-like).
//! Each tick admits as many waiting requests as fit under `max_batch`
//! live sessions; every live session then decodes one token. Fairness is
//! FCFS at admission; within a tick every live session makes progress, so
//! no request starves once admitted.

/// How many new requests may be admitted this tick.
pub fn admission_quota(live: usize, max_batch: usize) -> usize {
    max_batch.saturating_sub(live)
}

/// Bucket-aware admission ordering: FCFS, but requests that would land in
/// an already-hot bucket are preferred among equals (cache-friendly for
/// the XLA executable cache). Stable: never reorders across different
/// arrival times by more than the window.
pub fn order_admissions(
    waiting: &[(u64, usize)], // (request id, bucket)
    hot_buckets: &[usize],
    window: usize,
) -> Vec<u64> {
    let mut out: Vec<(usize, u64, usize)> = waiting
        .iter()
        .enumerate()
        .map(|(i, (id, b))| (i, *id, *b))
        .collect();
    // within each `window`-sized chunk, hot buckets first (stable sort)
    for chunk in out.chunks_mut(window.max(1)) {
        chunk.sort_by_key(|(i, _, b)| (!hot_buckets.contains(b) as usize, *i));
    }
    out.into_iter().map(|(_, id, _)| id).collect()
}

/// Invariant checks used by tests and debug assertions.
pub fn check_tick_invariants(
    live_before: usize,
    admitted: usize,
    max_batch: usize,
) -> Result<(), String> {
    if live_before + admitted > max_batch {
        return Err(format!(
            "overcommit: {live_before} live + {admitted} admitted > max_batch {max_batch}"
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn quota_never_overcommits() {
        assert_eq!(admission_quota(0, 8), 8);
        assert_eq!(admission_quota(5, 8), 3);
        assert_eq!(admission_quota(8, 8), 0);
        assert_eq!(admission_quota(9, 8), 0);
    }

    #[test]
    fn ordering_prefers_hot_buckets_within_window() {
        let waiting = [(1, 128), (2, 512), (3, 128), (4, 2048)];
        let ord = order_admissions(&waiting, &[512], 4);
        assert_eq!(ord[0], 2); // hot bucket first
        // relative order of the cold ones preserved
        let pos = |id: u64| ord.iter().position(|x| *x == id).unwrap();
        assert!(pos(1) < pos(3) && pos(3) < pos(4));
    }

    #[test]
    fn ordering_is_fcfs_across_windows() {
        let waiting: Vec<(u64, usize)> = (0..10).map(|i| (i, 128)).collect();
        let ord = order_admissions(&waiting, &[], 3);
        assert_eq!(ord, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn property_quota_plus_live_bounded() {
        check("batcher-quota", 50, |rng| {
            let max_batch = rng.range(1, 17);
            let live = rng.below(32);
            let q = admission_quota(live, max_batch);
            crate::prop_assert!(
                live >= max_batch || live + q == max_batch,
                "live {live} + quota {q} != max_batch {max_batch}"
            );
            crate::prop_assert!(
                check_tick_invariants(live.min(max_batch), q, max_batch).is_ok(),
                "invariant violated"
            );
            Ok(())
        });
    }

    #[test]
    fn property_ordering_is_permutation() {
        check("batcher-permutation", 50, |rng| {
            let n = rng.range(0, 20);
            let waiting: Vec<(u64, usize)> = (0..n as u64)
                .map(|i| (i, [32usize, 128, 512, 2048][rng.below(4)]))
                .collect();
            let hot = vec![[32usize, 128, 512, 2048][rng.below(4)]];
            let window = rng.range(1, 6);
            let ord = order_admissions(&waiting, &hot, window);
            let mut sorted = ord.clone();
            sorted.sort();
            crate::prop_assert!(
                sorted == (0..n as u64).collect::<Vec<_>>(),
                "not a permutation: {ord:?}"
            );
            Ok(())
        });
    }
}
