//! Scheduler subsystem: the policy-driven heart of the serving stack.
//!
//! Extracted from the coordinator's engine loop, the [`Scheduler`] owns
//! the three request populations and every transition between them:
//!
//! ```text
//!   pending ──admit──▶ live ──finish──▶ retired (response sent)
//!      ▲                 │
//!      │               preempt (starved queue head / pool exhausted)
//!      │                 ▼
//!      └──────── preempted { swapped | evicted } ──resume──▶ live
//! ```
//!
//! * **pending** — FCFS arrival queue. Admission is strictly in arrival
//!   order: a deferred head blocks everything behind it (nothing can
//!   overtake), and deferral leaves the queue untouched — requests are
//!   only ever popped when they actually start, so repeated deferrals
//!   cannot reorder or drop them.
//! * **live** — continuous-batching set, at most `max_batch` wide;
//!   every live session decodes one token per tick through
//!   [`Engine::decode_tick`].
//! * **preempted** — frozen sessions off the live set. When admission
//!   would defer and the queue head has been starved past
//!   `starve_ticks` consecutive ticks (and `--preempt` is on), the
//!   scheduler freezes the LRU live session (by last-decode-tick, ties
//!   to the newest arrival): its K,V blocks are either **swapped** to
//!   the host spill tier or dropped for **recompute**, chosen
//!   per-session by the cost model in [`policy`] (tokens-to-replay vs
//!   bytes-to-swap; the tier being full forces recompute). Blocks other
//!   live sessions read are never staged — they stay pinned in the hot
//!   pool. A mid-decode pool-exhaustion on a session likewise preempts
//!   it (instead of failing the request) when preemption is enabled.
//!   Frozen sessions resume with priority over fresh admissions, FCFS,
//!   and the preempted front gets the same starvation escalation as
//!   the pending head — after `starve_ticks` failed resume attempts it
//!   preempts a live session itself, so neither queue can park the
//!   other indefinitely.
//!
//! Freeze/thaw is bit-deterministic: the thawed session re-adopts or
//! restores its cached rows exactly and recomputes the rest through the
//! suffix-prefill path, so token streams under forced preemption equal
//! uncontended runs (property-tested in `tests/preempt.rs`).
//!
//! Two request-lifecycle extensions ride on the same populations:
//! **streaming** (a request carrying a frame channel receives one
//! [`StreamFrame`] per sampled token, tracked by a per-session
//! `streamed` counter so freeze/thaw never duplicates or drops a
//! frame) and **cancellation** ([`Scheduler::cancel`] aborts a request
//! wherever it lives — pending is dequeued, live is removed mid-decode
//! with its sole-owner blocks released, preempted is discarded along
//! with any staged swap bytes — and the client gets a terminal
//! cancelled [`Response`]). [`Scheduler::fail_all`] is the shutdown
//! counterpart: every held request is answered with a terminal error
//! so no client ever blocks on a dropped channel.
//!
//! The coordinator is now a thin wrapper: it drains its cross-thread
//! inbox (submissions + cancels) into the scheduler and calls
//! [`Scheduler::run_tick`].

pub mod batcher;
pub mod policy;

use std::collections::{HashSet, VecDeque};
use std::sync::mpsc::Sender;

use crate::config::Manifest;
use crate::engine::{Admission, Engine, FrozenSession, MigratedSession, Session, Timing, Variant};
use crate::kv::paged::is_pool_exhausted;
use crate::kv::KvPool;
use crate::metrics::Metrics;
use crate::obs::{self, SpanKind};
use crate::util::now_ms;

pub use policy::{preempt_action, PreemptAction, SchedPolicy};

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    pub variant: Variant,
    pub submitted_ms: f64,
    pub resp_tx: RespSink,
    /// per-token frame sink (`"stream": true` requests); `None`
    /// means the client only wants the final summary
    pub stream: Option<FrameSink>,
    /// generated tokens the client has ALREADY received frames for —
    /// nonzero only on mesh requeues, where a request replays from
    /// scratch on a survivor replica after its original replica died.
    /// Greedy decode regenerates the same tokens; this offset keeps
    /// them from being re-emitted, so the client's stream stays
    /// exactly-once and bit-identical.
    pub stream_offset: usize,
    /// observability trace id ([`crate::obs`]): minted once at admission
    /// to the serving stack (router or bare coordinator) and carried
    /// across the wire, preemption, and mesh requeue, so every span a
    /// request produces — in any process — lands on one timeline. 0
    /// means untraced (obs disabled).
    pub trace: u64,
}

/// Where a request's terminal [`Response`] goes: a per-request channel
/// (threaded transport, direct [`crate::coordinator`] submitters) or
/// the request's lock-free event ring (epoll reactor transport, which
/// serializes the response to its wire line on the engine thread).
#[derive(Debug)]
pub enum RespSink {
    Channel(Sender<Response>),
    #[cfg(target_os = "linux")]
    Net(crate::net::NetSink),
}

impl RespSink {
    /// Deliver the terminal response. Never blocks; a vanished receiver
    /// is the receiver's problem (the request is over either way).
    pub fn send(&self, resp: Response) {
        match self {
            RespSink::Channel(tx) => {
                let _ = tx.send(resp);
            }
            #[cfg(target_os = "linux")]
            RespSink::Net(sink) => sink.send_response(&resp),
        }
    }
}

impl From<Sender<Response>> for RespSink {
    fn from(tx: Sender<Response>) -> RespSink {
        RespSink::Channel(tx)
    }
}

/// Where a streaming request's per-token [`StreamFrame`]s go. The net
/// sink is bounded: `send` reports whether the frame was accepted so
/// the emitter can hold its position and retry instead of dropping.
#[derive(Debug)]
pub enum FrameSink {
    Channel(Sender<StreamFrame>),
    #[cfg(target_os = "linux")]
    Net(crate::net::NetSink),
}

impl FrameSink {
    /// `false` means the bounded sink was momentarily full — the caller
    /// must NOT advance its streamed counter (retry next tick). The
    /// channel arm always accepts (a dropped receiver discards frames,
    /// matching the threaded transport's disconnect semantics).
    pub fn send(&self, frame: StreamFrame) -> bool {
        match self {
            FrameSink::Channel(tx) => {
                let _ = tx.send(frame);
                true
            }
            #[cfg(target_os = "linux")]
            FrameSink::Net(sink) => sink.send_frame(&frame),
        }
    }
}

impl From<Sender<StreamFrame>> for FrameSink {
    fn from(tx: Sender<StreamFrame>) -> FrameSink {
        FrameSink::Channel(tx)
    }
}

/// Front-end submission options (everything a [`Request`] carries
/// besides the id and the response channel, which the coordinator or
/// router assigns).
#[derive(Debug)]
pub struct SubmitOpts {
    pub prompt: String,
    pub max_new: usize,
    pub variant: Variant,
    pub stream: Option<FrameSink>,
    /// see [`Request::stream_offset`] (0 for fresh submissions)
    pub stream_offset: usize,
    /// see [`Request::trace`] (0 = mint one at admission)
    pub trace: u64,
}

impl SubmitOpts {
    pub fn new(prompt: &str, max_new: usize, variant: Variant) -> SubmitOpts {
        SubmitOpts {
            prompt: prompt.to_string(),
            max_new,
            variant,
            stream: None,
            stream_offset: 0,
            trace: 0,
        }
    }
}

/// One streamed token: emitted by the scheduler the moment a session
/// samples it (the first at admission, one more per decode tick), long
/// before the final [`Response`]. Frames arrive strictly in `index`
/// order; the channel closes once the terminal response has been sent
/// and the request is dropped.
#[derive(Debug, Clone)]
pub struct StreamFrame {
    pub id: u64,
    /// 0-based generated-token index
    pub index: usize,
    pub token: i32,
    /// decoded text of this token alone
    pub text: String,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub n_prompt: usize,
    pub n_generated: usize,
    pub queue_ms: f64,
    pub e2e_ms: f64,
    pub timing: Timing,
    pub error: Option<String>,
    /// terminal cancelled marker: the request was aborted by
    /// `{"cmd":"cancel"}` or a client disconnect, its sole-owner blocks
    /// were reclaimed, and `n_generated` counts what was produced
    /// before the abort
    pub cancelled: bool,
}

impl Response {
    pub fn error(id: u64, msg: String) -> Response {
        Response {
            id,
            text: String::new(),
            n_prompt: 0,
            n_generated: 0,
            queue_ms: 0.0,
            e2e_ms: 0.0,
            timing: Timing::default(),
            error: Some(msg),
            cancelled: false,
        }
    }

    /// Terminal frame for an aborted request.
    pub fn aborted(id: u64, n_generated: usize) -> Response {
        Response {
            id,
            text: String::new(),
            n_prompt: 0,
            n_generated,
            queue_ms: 0.0,
            e2e_ms: 0.0,
            timing: Timing::default(),
            error: None,
            cancelled: true,
        }
    }
}

/// A live session plus its scheduling bookkeeping.
struct Live {
    req: Request,
    session: Session,
    started_ms: f64,
    /// tick of the session's last decoded token (LRU preemption key)
    last_decode_tick: u64,
    /// tick the session was (re)admitted — a session is never chosen as
    /// a starvation victim in its own admission tick (it decodes once
    /// first, so every admission makes progress)
    admitted_tick: u64,
    /// generated tokens already emitted as [`StreamFrame`]s — survives
    /// preemption (a thawed session resumes at its pre-freeze count),
    /// so every token streams exactly once
    streamed: usize,
    /// when this session last emitted a frame batch — `None` until the
    /// first frame, so the frame path can tell TTFT (first frame) from
    /// inter-token time (every later batch); survives preemption
    last_frame_ms: Option<f64>,
}

impl Live {
    /// Stream every not-yet-emitted generated token, in order. Cheap
    /// no-op for non-streaming requests and when nothing new exists.
    /// A bounded sink that momentarily refuses a frame holds the
    /// counter in place — the frame is re-offered on the next tick (and
    /// at retire/cancel), so nothing is ever skipped or duplicated.
    ///
    /// This is also the request's frame-path observation point: each
    /// accepted batch records a `frame_write` span on the request's
    /// trace and one `obs_ttft_ms` (first frame ever) or `obs_tbt_ms`
    /// (time since the previous batch) observation.
    fn emit_new_frames(&mut self, metrics: &Metrics) {
        let n = self.session.generated();
        let Some(tx) = &self.req.stream else {
            self.streamed = n;
            return;
        };
        if self.streamed >= n {
            return;
        }
        let t0 = now_ms();
        let before = self.streamed;
        while self.streamed < n {
            let tok = self.session.tokens[self.session.prompt_len + self.streamed];
            let accepted = tx.send(StreamFrame {
                id: self.req.id,
                index: self.streamed,
                token: tok,
                text: crate::model::tokenizer::decode(&[tok]),
            });
            if !accepted {
                break;
            }
            self.streamed += 1;
        }
        if self.streamed > before {
            let now = now_ms();
            obs::record(self.req.trace, SpanKind::FrameWrite, t0, now);
            match self.last_frame_ms {
                None => metrics.observe_ms("obs_ttft_ms", now - self.req.submitted_ms),
                Some(prev) => metrics.observe_ms("obs_tbt_ms", now - prev),
            }
            self.last_frame_ms = Some(now);
        }
    }
}

/// One evacuated request from [`Scheduler::drain`]: the request, how
/// many frames its client has already received, and the exported
/// session state (`None` = never started, or unfreezable — the adopter
/// resubmits it from scratch with `stream_offset = streamed` so the
/// replayed tokens never reach the client twice).
pub struct DrainedItem {
    pub req: Request,
    pub streamed: usize,
    pub session: Option<MigratedSession>,
}

/// A preempted session awaiting resume.
struct Preempted {
    req: Request,
    frozen: FrozenSession,
    started_ms: f64,
    /// stream frames emitted before the freeze (resume continues here)
    streamed: usize,
    /// see [`Live::last_frame_ms`] — preserved across freeze/thaw so a
    /// resumed session's next frame records a (long) inter-token gap,
    /// not a bogus second TTFT
    last_frame_ms: Option<f64>,
}

/// Monotonic scheduler counters (mirrored into [`Metrics`]).
#[derive(Debug, Default, Clone)]
pub struct SchedStats {
    pub preempt_swap: u64,
    pub preempt_recompute: u64,
    /// preemptions triggered by mid-decode pool exhaustion rather than
    /// queue-head starvation (subset of the two counters above)
    pub preempt_oom: u64,
    pub resume_swap: u64,
    pub resume_recompute: u64,
    /// cancels that raced ahead of their submit and were applied from
    /// the tombstone set at submit time (the cancel-vs-inbox race)
    pub cancelled_unseen: u64,
}

/// Bound on the cancelled-unseen tombstone set. Ids are globally unique
/// and never reused (router-owned id space), so a tombstone can only
/// ever match its own request; the cap just bounds memory against a
/// client spraying cancels for ids that will never arrive.
const TOMBSTONE_CAP: usize = 1024;

pub struct Scheduler {
    policy: SchedPolicy,
    pending: VecDeque<Request>,
    live: Vec<Live>,
    preempted: VecDeque<Preempted>,
    /// legacy contiguous-pool accounting (`--no-paged` path only)
    legacy_pool: KvPool,
    /// monotonic decode-tick counter
    tick: u64,
    /// consecutive ticks the current queue head has been deferred
    head_starved_ticks: u64,
    /// consecutive ticks the preempted-queue front has failed to resume
    resume_starved_ticks: u64,
    /// cancelled-unseen ids: cancels that arrived before their submit
    /// was drained from the inbox (FIFO eviction at [`TOMBSTONE_CAP`])
    tombstones: VecDeque<u64>,
    tombstone_set: HashSet<u64>,
    pub stats: SchedStats,
}

impl Scheduler {
    pub fn new(policy: SchedPolicy) -> Scheduler {
        let legacy_pool = KvPool::new(policy.kv_capacity_bytes);
        Scheduler {
            policy,
            pending: VecDeque::new(),
            live: Vec::new(),
            preempted: VecDeque::new(),
            legacy_pool,
            tick: 0,
            head_starved_ticks: 0,
            resume_starved_ticks: 0,
            tombstones: VecDeque::new(),
            tombstone_set: HashSet::new(),
            stats: SchedStats::default(),
        }
    }

    /// Enqueue a request (FCFS). A request whose cancel already raced
    /// past it (see [`Scheduler::note_cancelled_unseen`]) is aborted
    /// right here instead of queued — the client gets the same terminal
    /// cancelled response it would have gotten had the cancel landed
    /// after the submit.
    pub fn submit(&mut self, req: Request) {
        if self.tombstone_set.remove(&req.id) {
            self.tombstones.retain(|t| *t != req.id);
            self.stats.cancelled_unseen += 1;
            req.resp_tx.send(Response::aborted(req.id, 0));
            return;
        }
        self.pending.push_back(req);
    }

    /// Record a cancel for an id the scheduler has never seen. The
    /// coordinator calls this when [`Scheduler::cancel`] misses: with
    /// the bounded MPSC inbox, a cancel can be processed before its
    /// matching submit is drained (the submitter is still mid-push), and
    /// dropping it would let the request run to completion. The id joins
    /// a bounded tombstone set consulted by [`Scheduler::submit`].
    pub fn note_cancelled_unseen(&mut self, id: u64) {
        if !self.tombstone_set.insert(id) {
            return;
        }
        self.tombstones.push_back(id);
        while self.tombstones.len() > TOMBSTONE_CAP {
            if let Some(old) = self.tombstones.pop_front() {
                self.tombstone_set.remove(&old);
            }
        }
    }

    /// Nothing pending, live, or frozen.
    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.live.is_empty() && self.preempted.is_empty()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn live_len(&self) -> usize {
        self.live.len()
    }

    pub fn preempted_len(&self) -> usize {
        self.preempted.len()
    }

    /// One full scheduling tick: resume frozen sessions, admit pending
    /// (preempting under starvation), decode every live session once,
    /// retire the finished, publish gauges.
    pub fn run_tick(&mut self, engine: &Engine, metrics: &Metrics) {
        self.tick += 1;
        self.resume_preempted(engine, metrics);
        self.admit_pending(engine, metrics);
        self.decode_and_retire(engine, metrics);
        self.publish_gauges(engine, metrics);
    }

    // ------------------------------------------------------------------
    // Resume
    // ------------------------------------------------------------------

    /// Thaw frozen sessions, oldest first, while batch slots and blocks
    /// allow. Preempted sessions outrank fresh admissions: they already
    /// held the resources once and their requests are older than
    /// anything still pending. The front gets the same starvation
    /// escalation as the pending head — once it has failed to resume
    /// for `starve_ticks` consecutive ticks, a live session is
    /// preempted to make room, so fresh admissions can never park a
    /// frozen session indefinitely.
    fn resume_preempted(&mut self, engine: &Engine, metrics: &Metrics) {
        while self.live.len() < self.policy.max_batch {
            let Some(front) = self.preempted.front() else {
                self.resume_starved_ticks = 0;
                break;
            };
            match engine.resume_admission(&front.frozen) {
                Admission::Defer => {
                    if self.resume_starved_ticks >= self.policy.starve_ticks
                        && self.preempt_for_starvation(engine, metrics)
                    {
                        continue; // blocks freed — retry the front now
                    }
                    self.resume_starved_ticks += 1;
                    break; // FCFS: retry next tick
                }
                Admission::Reject => {
                    // grew past what an empty pool could ever hold
                    let p = self.preempted.pop_front().unwrap();
                    self.resume_starved_ticks = 0;
                    metrics.inc("errors");
                    p.req.resp_tx.send(Response::error(
                        p.req.id,
                        "preempted session exceeds kv pool capacity".into(),
                    ));
                    // free the staged swap bytes — dropping the frozen
                    // session bare would leak them in the tier
                    engine.discard_frozen(p.frozen);
                }
                Admission::Admit => {
                    let p = self.preempted.pop_front().unwrap();
                    self.resume_starved_ticks = 0;
                    let swapped = p.frozen.is_swapped();
                    let trace = p.req.trace;
                    let t0 = now_ms();
                    match engine.thaw_session(p.frozen) {
                        Ok(session) => {
                            obs::record(trace, SpanKind::SwapIn, t0, now_ms());
                            if swapped {
                                self.stats.resume_swap += 1;
                                metrics.inc("sched_resume_swap");
                            } else {
                                self.stats.resume_recompute += 1;
                                metrics.inc("sched_resume_recompute");
                            }
                            self.live.push(Live {
                                req: p.req,
                                session,
                                started_ms: p.started_ms,
                                last_decode_tick: self.tick,
                                admitted_tick: self.tick,
                                streamed: p.streamed,
                                last_frame_ms: p.last_frame_ms,
                            });
                        }
                        Err(e) => {
                            metrics.inc("errors");
                            p.req.resp_tx.send(Response::error(p.req.id, format!("{e:#}")));
                        }
                    }
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Admission
    // ------------------------------------------------------------------

    /// Strictly-FCFS admission: peek the head, pop only on an actual
    /// start. A deferred head ends the phase (nothing overtakes it) —
    /// unless it has starved past the threshold and preempting a live
    /// session frees the blocks it needs.
    fn admit_pending(&mut self, engine: &Engine, metrics: &Metrics) {
        let paged = engine.paged_enabled();
        loop {
            if batcher::admission_quota(self.live.len(), self.policy.max_batch) == 0 {
                break;
            }
            let Some(head) = self.pending.front() else {
                self.head_starved_ticks = 0;
                break;
            };
            let decision = if paged {
                engine.paged_admission(&head.variant, &head.prompt)
            } else {
                // free function: `head` borrows self.pending, so this
                // must borrow only the disjoint legacy_pool field
                legacy_admission(&mut self.legacy_pool, engine.manifest(), head)
            };
            match decision {
                Admission::Reject => {
                    // larger than the whole pool: deferring would spin
                    // the scheduler forever
                    let req = self.pending.pop_front().unwrap();
                    self.head_starved_ticks = 0;
                    metrics.inc("errors");
                    req.resp_tx
                        .send(Response::error(req.id, "prompt exceeds kv pool capacity".into()));
                }
                Admission::Defer => {
                    metrics.inc("kv_defer");
                    if self.policy.preempt
                        && self.head_starved_ticks >= self.policy.starve_ticks
                        && self.preempt_for_starvation(engine, metrics)
                    {
                        continue; // blocks freed — retry the head now
                    }
                    self.head_starved_ticks += 1;
                    break;
                }
                Admission::Admit => {
                    let req = self.pending.pop_front().unwrap();
                    self.head_starved_ticks = 0;
                    let t0 = now_ms();
                    let queue_ms = t0 - req.submitted_ms;
                    metrics.observe_ms("queue", queue_ms);
                    metrics.observe_ms("obs_queue_wait_ms", queue_ms);
                    obs::record(req.trace, SpanKind::Queue, req.submitted_ms, t0);
                    match engine.start_session(&req.prompt, req.max_new, &req.variant) {
                        Ok(session) => {
                            obs::record(req.trace, SpanKind::Prefill, t0, now_ms());
                            metrics.inc("admitted");
                            metrics.observe_ms("ttft", session.timing.ttft_ms);
                            let offset = req.stream_offset;
                            let mut l = Live {
                                req,
                                session,
                                started_ms: t0,
                                last_decode_tick: self.tick,
                                admitted_tick: self.tick,
                                streamed: offset,
                                last_frame_ms: None,
                            };
                            // prefill sampled the first generated token
                            l.emit_new_frames(metrics);
                            self.live.push(l);
                        }
                        Err(e) => {
                            if !paged {
                                let _ = self.legacy_pool.release(req.id);
                            }
                            metrics.inc("errors");
                            req.resp_tx.send(Response::error(req.id, format!("{e:#}")));
                        }
                    }
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Preemption
    // ------------------------------------------------------------------

    /// Freeze the LRU live session to unblock a starved queue head.
    /// Victim: least-recently-decoded freezable session, ties broken
    /// toward the newest arrival (the oldest keeps its progress);
    /// sessions admitted this very tick are exempt. Returns whether a
    /// victim was preempted.
    fn preempt_for_starvation(&mut self, engine: &Engine, metrics: &Metrics) -> bool {
        let victim = self
            .live
            .iter()
            .enumerate()
            .filter(|(_, l)| l.admitted_tick < self.tick && engine.can_freeze(&l.session))
            .min_by_key(|(_, l)| (l.last_decode_tick, std::cmp::Reverse(l.req.id)))
            .map(|(i, _)| i);
        let Some(i) = victim else { return false };
        let l = self.live.remove(i);
        self.freeze_and_requeue(engine, metrics, l, false);
        true
    }

    /// Freeze one live session (swap or recompute per the cost model)
    /// and park it on the preempted queue.
    fn freeze_and_requeue(&mut self, engine: &Engine, metrics: &Metrics, l: Live, oom: bool) {
        let (replay, swap_bytes) = engine.preempt_cost(&l.session);
        let action = preempt_action(
            replay,
            swap_bytes,
            engine.swap_free_bytes(),
            self.policy.recompute_max_tokens,
        );
        let t0 = now_ms();
        let (frozen, swapped) =
            engine.freeze_session(l.session, action == PreemptAction::Swap);
        obs::record(l.req.trace, SpanKind::SwapOut, t0, now_ms());
        if swapped {
            self.stats.preempt_swap += 1;
            metrics.inc("sched_preempt_swap");
        } else {
            self.stats.preempt_recompute += 1;
            metrics.inc("sched_preempt_recompute");
        }
        if oom {
            self.stats.preempt_oom += 1;
            metrics.inc("sched_preempt_oom");
        }
        self.preempted.push_back(Preempted {
            req: l.req,
            frozen,
            started_ms: l.started_ms,
            streamed: l.streamed,
            last_frame_ms: l.last_frame_ms,
        });
    }

    // ------------------------------------------------------------------
    // Cancellation / shutdown
    // ------------------------------------------------------------------

    /// Abort request `id` wherever it lives — pending (dequeue), live
    /// (release its sole-owner blocks mid-decode; blocks shared with
    /// other sessions stay pinned by their refcounts), or preempted
    /// (discard the frozen state, draining any staged swap bytes). The
    /// client receives a terminal cancelled [`Response`]; frames already
    /// streamed stand. Unknown ids (finished, never submitted, or
    /// routed to another replica) are a no-op.
    pub fn cancel(&mut self, id: u64, engine: &Engine, metrics: &Metrics) -> bool {
        if let Some(i) = self.pending.iter().position(|r| r.id == id) {
            if i == 0 {
                self.head_starved_ticks = 0;
            }
            let req = self.pending.remove(i).expect("position came from iter");
            metrics.inc("sched_cancelled");
            req.resp_tx.send(Response::aborted(id, 0));
            return true;
        }
        if let Some(i) = self.live.iter().position(|l| l.req.id == id) {
            let mut l = self.live.swap_remove(i);
            if engine.paged_enabled() {
                engine.release_session(&mut l.session);
            } else {
                let _ = self.legacy_pool.release(l.req.id);
            }
            // flush sampled-but-unsent frames so "frames already
            // streamed stand" holds before the terminal goes out
            l.emit_new_frames(metrics);
            metrics.inc("sched_cancelled");
            l.req.resp_tx.send(Response::aborted(id, l.session.generated()));
            return true;
        }
        if let Some(i) = self.preempted.iter().position(|p| p.req.id == id) {
            if i == 0 {
                self.resume_starved_ticks = 0;
            }
            let p = self.preempted.remove(i).expect("position came from iter");
            let generated = p.frozen.tokens.len().saturating_sub(p.frozen.prompt_len);
            engine.discard_frozen(p.frozen);
            metrics.inc("sched_cancelled");
            p.req.resp_tx.send(Response::aborted(id, generated));
            return true;
        }
        false
    }

    /// Fail every request the scheduler still holds (pending, live,
    /// preempted) with a terminal error response, returning all K,V
    /// resources. The coordinator calls this at shutdown so no client
    /// is ever left blocked on a dropped channel.
    pub fn fail_all(&mut self, engine: &Engine, metrics: &Metrics, msg: &str) {
        let paged = engine.paged_enabled();
        for req in self.pending.drain(..) {
            metrics.inc("errors");
            req.resp_tx.send(Response::error(req.id, msg.into()));
        }
        for mut l in self.live.drain(..) {
            if paged {
                engine.release_session(&mut l.session);
            } else {
                let _ = self.legacy_pool.release(l.req.id);
            }
            metrics.inc("errors");
            l.req.resp_tx.send(Response::error(l.req.id, msg.into()));
        }
        for p in self.preempted.drain(..) {
            engine.discard_frozen(p.frozen);
            metrics.inc("errors");
            p.req.resp_tx.send(Response::error(p.req.id, msg.into()));
        }
        self.head_starved_ticks = 0;
        self.resume_starved_ticks = 0;
    }

    // ------------------------------------------------------------------
    // Mesh drain / adopt
    // ------------------------------------------------------------------

    /// Evacuate every request this scheduler holds, for migration to a
    /// peer replica. Pending requests leave verbatim (never started);
    /// live sessions first flush sampled-but-unsent frames, then freeze
    /// preferring swap (so the cached K,V travels with them) and export;
    /// already-preempted sessions export their frozen state directly.
    /// Sessions the engine cannot freeze (legacy contiguous path) are
    /// released and leave with `session: None` — the adopter replays
    /// them from scratch, and [`Request::stream_offset`] keeps the
    /// regenerated tokens from reaching the client twice. The scheduler
    /// is idle afterwards.
    pub fn drain(&mut self, engine: &Engine, metrics: &Metrics) -> Vec<DrainedItem> {
        let mut out = Vec::new();
        for req in self.pending.drain(..) {
            let streamed = req.stream_offset;
            out.push(DrainedItem { req, streamed, session: None });
        }
        let paged = engine.paged_enabled();
        for mut l in self.live.drain(..) {
            l.emit_new_frames(metrics);
            let Live { req, mut session, streamed, .. } = l;
            let item = if engine.can_freeze(&session) {
                let (frozen, _) = engine.freeze_session(session, true);
                DrainedItem { req, streamed, session: Some(engine.export_frozen(frozen)) }
            } else {
                if paged {
                    engine.release_session(&mut session);
                } else {
                    let _ = self.legacy_pool.release(req.id);
                }
                DrainedItem { req, streamed, session: None }
            };
            out.push(item);
        }
        for p in self.preempted.drain(..) {
            out.push(DrainedItem {
                req: p.req,
                streamed: p.streamed,
                session: Some(engine.export_frozen(p.frozen)),
            });
        }
        self.head_starved_ticks = 0;
        self.resume_starved_ticks = 0;
        metrics.add("sched_drained", out.len() as u64);
        out
    }

    /// Adopt a migrated session from a draining or dead peer: stage its
    /// K,V payload into this engine (degrading to recompute-on-resume
    /// when the spill tier can't take it — still bit-identical) and
    /// park it on the preempted queue, where it resumes with priority
    /// exactly like a local preemption. A cancel that already raced in
    /// through the tombstone set aborts the adoption instead, same as
    /// [`Scheduler::submit`].
    pub fn adopt(
        &mut self,
        req: Request,
        m: MigratedSession,
        streamed: usize,
        engine: &Engine,
        metrics: &Metrics,
    ) {
        if self.tombstone_set.remove(&req.id) {
            self.tombstones.retain(|t| *t != req.id);
            self.stats.cancelled_unseen += 1;
            let generated = m.tokens.len().saturating_sub(m.prompt_len);
            req.resp_tx.send(Response::aborted(req.id, generated));
            return;
        }
        let frozen = engine.import_frozen(m);
        metrics.inc("sched_adopted");
        self.preempted.push_back(Preempted {
            req,
            frozen,
            started_ms: now_ms(),
            streamed,
            // adopted sessions time their next frame from adoption (a
            // fresh TTFT on the survivor), not the dead peer's clock
            last_frame_ms: None,
        });
    }

    // ------------------------------------------------------------------
    // Decode + retire
    // ------------------------------------------------------------------

    fn decode_and_retire(&mut self, engine: &Engine, metrics: &Metrics) {
        if self.live.is_empty() {
            return;
        }
        let paged = engine.paged_enabled();
        if !paged {
            for l in &self.live {
                self.legacy_pool.touch(l.req.id);
            }
        }
        metrics.observe("decode_batch", self.live.len() as f64);
        let mut sessions: Vec<&mut Session> =
            self.live.iter_mut().map(|l| &mut l.session).collect();
        let t0 = now_ms();
        let outcomes = engine.decode_tick(&mut sessions);
        drop(sessions);
        let t1 = now_ms();
        // batch-level span (trace 0: a tick serves many requests) plus
        // the per-phase profiler summary the engine/backend accumulated
        // on this thread during the tick, drained into obs_* histograms
        obs::record(0, SpanKind::DecodeTick, t0, t1);
        if obs::enabled() {
            metrics.observe_ms("obs_decode_tick_ms", t1 - t0);
            for (kind, ms) in obs::take_tick_phases() {
                metrics.observe_ms(&format!("obs_{}_ms", kind.as_str()), ms);
            }
        }

        // classify per session: keep decoding, retire, requeue (pool
        // exhausted mid-decode → preempt instead of failing), or fail
        let mut finished: Vec<usize> = Vec::new();
        let mut oom: Vec<usize> = Vec::new();
        for (i, outcome) in outcomes.into_iter().enumerate() {
            match outcome {
                Ok(more) => {
                    metrics.inc("tokens");
                    self.live[i].last_decode_tick = self.tick;
                    self.live[i].emit_new_frames(metrics);
                    if let Some(ms) = self.live[i].session.timing.decode_ms.last() {
                        metrics.observe_ms("decode_step", *ms);
                    }
                    if !more {
                        finished.push(i);
                    }
                }
                Err(e) => {
                    if self.policy.preempt
                        && is_pool_exhausted(&e)
                        && engine.can_freeze(&self.live[i].session)
                    {
                        oom.push(i);
                    } else {
                        metrics.inc("errors");
                        self.live[i]
                            .req
                            .resp_tx
                            .send(Response::error(self.live[i].req.id, format!("{e:#}")));
                        finished.push(i);
                    }
                }
            }
        }

        // remove back-to-front so indices stay valid (swap_remove)
        let mut removals: Vec<(usize, bool)> = finished
            .into_iter()
            .map(|i| (i, false))
            .chain(oom.into_iter().map(|i| (i, true)))
            .collect();
        removals.sort_by(|a, b| b.0.cmp(&a.0));
        for (i, is_oom) in removals {
            let l = self.live.swap_remove(i);
            if is_oom {
                self.freeze_and_requeue(engine, metrics, l, true);
            } else {
                self.retire(engine, metrics, l, paged);
            }
        }
    }

    fn retire(&mut self, engine: &Engine, metrics: &Metrics, mut l: Live, paged: bool) {
        // re-offer any frame a bounded sink refused earlier: the
        // terminal line must never overtake a frame
        l.emit_new_frames(metrics);
        if paged {
            // idempotent: finish_session would release too, but errored
            // sessions never reach it
            engine.release_session(&mut l.session);
        } else {
            let _ = self.legacy_pool.release(l.req.id);
        }
        if l.session.done {
            let timing = l.session.timing.clone();
            let n_prompt = l.session.prompt_len;
            let n_generated = l.session.generated();
            let gen = engine.finish_session(l.session);
            metrics.inc("completed");
            let e2e = now_ms() - l.req.submitted_ms;
            metrics.observe_ms("e2e", e2e);
            l.req.resp_tx.send(Response {
                id: l.req.id,
                text: gen.text,
                n_prompt,
                n_generated,
                queue_ms: l.started_ms - l.req.submitted_ms,
                e2e_ms: e2e,
                timing,
                error: None,
                cancelled: false,
            });
        }
    }

    // ------------------------------------------------------------------
    // Observability
    // ------------------------------------------------------------------

    /// Publish paged-KV, swap-tier, and scheduler gauges (served
    /// verbatim by the server's `stats`/`kv`/`sched` commands).
    fn publish_gauges(&self, engine: &Engine, metrics: &Metrics) {
        metrics.set_gauge("sched_pending", self.pending.len() as f64);
        metrics.set_gauge("sched_live", self.live.len() as f64);
        metrics.set_gauge("sched_preempted", self.preempted.len() as f64);
        metrics.set_gauge("sched_cancelled_unseen", self.stats.cancelled_unseen as f64);
        if let Some(snap) = engine.swap_snapshot() {
            metrics.set_gauge("swap_capacity_bytes", snap.capacity_bytes as f64);
            metrics.set_gauge("swap_used_bytes", snap.used_bytes as f64);
            metrics.set_gauge("swap_entries", snap.entries as f64);
            metrics.set_gauge("swap_blocks", snap.blocks as f64);
            metrics.set_gauge("swap_out_bytes", snap.stats.out_bytes as f64);
            metrics.set_gauge("swap_in_bytes", snap.stats.in_bytes as f64);
            metrics.set_gauge("swap_pinned_blocks", snap.stats.pinned_blocks as f64);
            metrics.set_gauge("swap_denied_full", snap.stats.denied_full as f64);
        }
        if let Some(snap) = engine.paged_snapshot() {
            metrics.set_gauge("kv_capacity_bytes", snap.capacity_bytes as f64);
            metrics.set_gauge("kv_used_bytes", snap.used_bytes as f64);
            metrics.set_gauge("kv_cached_bytes", snap.cached_bytes as f64);
            metrics.set_gauge("kv_live_blocks", snap.live_blocks as f64);
            metrics.set_gauge("kv_cached_blocks", snap.cached_blocks as f64);
            metrics.set_gauge("kv_live_tables", snap.live_tables as f64);
            metrics.set_gauge("paged_prefix_hit_blocks", snap.stats.prefix_hit_blocks as f64);
            metrics.set_gauge("paged_prefix_miss_blocks", snap.stats.prefix_miss_blocks as f64);
            metrics.set_gauge("paged_prefix_hit_rate", snap.stats.prefix_hit_rate());
            metrics.set_gauge("paged_cow_copies", snap.stats.cow_copies as f64);
            metrics.set_gauge("paged_evictions", snap.stats.evictions as f64);
            metrics.set_gauge("paged_alloc_failures", snap.stats.alloc_failures as f64);
            // block-native hot-path accounting: bucket-shaped copies on
            // the decode path must stay 0 while batched decode is on
            metrics.set_gauge(
                "paged_decode_gather_copies",
                snap.stats.decode_gather_copies as f64,
            );
            metrics.set_gauge(
                "paged_decode_scatter_copies",
                snap.stats.decode_scatter_copies as f64,
            );
            metrics.set_gauge(
                "paged_prefill_skipped_tokens",
                snap.stats.prefill_skipped_tokens as f64,
            );
            // relay decode: shared-prefix groups formed, positions of
            // prefix attention skipped, and rows that fell back to the
            // fully fused path
            metrics.set_gauge("relay_groups", snap.stats.relay_groups as f64);
            metrics.set_gauge(
                "relay_prefix_tokens_saved",
                snap.stats.relay_prefix_tokens_saved as f64,
            );
            metrics.set_gauge("relay_fallback", snap.stats.relay_fallback as f64);
        }
    }
}

/// Legacy contiguous-pool admission (worst-case bucket bytes);
/// reserves on `Admit`, released at retire. A free function so the
/// caller can hold a borrow of its pending queue while reserving.
fn legacy_admission(pool: &mut KvPool, m: &Manifest, req: &Request) -> Admission {
    let total = req.prompt.len() + 1 + req.max_new;
    let bucket = Manifest::bucket_for(&m.decode_buckets, total)
        .unwrap_or(*m.decode_buckets.last().unwrap());
    let kind = req.variant.cache_kind();
    if pool.admit(req.id, kind, m, bucket).is_ok() {
        Admission::Admit
    } else {
        Admission::Defer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use std::path::PathBuf;
    use std::sync::mpsc::{channel, Receiver};

    fn toy_cfg() -> ServingConfig {
        ServingConfig {
            artifacts_dir: PathBuf::from("definitely-no-artifacts-here"),
            backend: "ref".into(),
            ..Default::default()
        }
    }

    fn make_req(id: u64, prompt: &str, max_new: usize) -> (Request, Receiver<Response>) {
        let (tx, rx) = channel();
        (
            Request {
                id,
                prompt: prompt.into(),
                max_new,
                variant: Variant::Chai,
                submitted_ms: now_ms(),
                resp_tx: tx.into(),
                stream: None,
                stream_offset: 0,
                trace: 0,
            },
            rx,
        )
    }

    /// Pool sized to `blocks` MHA toy blocks (block_size 16), derived
    /// from the toy manifest so the tests track its dimensions.
    fn tiny_pool_cfg(blocks: usize) -> ServingConfig {
        use crate::kv::paged::KvLayout;
        use crate::runtime::{reference::RefBackend, Backend};
        let block_bytes =
            KvLayout::from_manifest(RefBackend::toy(0).manifest(), crate::kv::CacheKind::Mha)
                .block_bytes(16);
        ServingConfig { kv_capacity_bytes: blocks * block_bytes, ..toy_cfg() }
    }

    fn drive(sched: &mut Scheduler, engine: &Engine, metrics: &Metrics, max_ticks: u64) {
        let mut n = 0;
        while !sched.is_idle() {
            sched.run_tick(engine, metrics);
            n += 1;
            assert!(n < max_ticks, "scheduler failed to drain in {max_ticks} ticks");
        }
    }

    /// Regression (deferred-requeue fairness): with a pool that forces
    /// repeated deferrals, arrival order is preserved across every tick
    /// — the pending queue is only ever popped at an actual admission,
    /// so nothing can overtake a deferred head — and every request
    /// completes.
    #[test]
    fn repeated_deferrals_preserve_arrival_order() {
        let engine = Engine::load(tiny_pool_cfg(4)).unwrap();
        let metrics = Metrics::new();
        let mut sched = Scheduler::new(SchedPolicy {
            max_batch: 8,
            preempt: false,
            ..SchedPolicy::from_config(&tiny_pool_cfg(4))
        });
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                // distinct prompts (23 tokens → a 3-block admission
                // against a 4-block pool): at most one session fits at
                // a time, so later arrivals defer repeatedly
                let (req, rx) = make_req(i, &format!("a tale of tom number {i}"), 6);
                sched.submit(req);
                rx
            })
            .collect();
        let mut deferred_ticks = 0u64;
        let mut n = 0u64;
        while !sched.is_idle() {
            let before: Vec<u64> = sched.pending.iter().map(|r| r.id).collect();
            sched.run_tick(&engine, &metrics);
            let after: Vec<u64> = sched.pending.iter().map(|r| r.id).collect();
            // arrival order invariant: pending is always a contiguous
            // suffix of the previous pending (admissions pop the front,
            // nothing is reordered or dropped)
            assert_eq!(
                after.as_slice(),
                &before[before.len() - after.len()..],
                "deferral must not reorder the queue"
            );
            if after.len() == before.len() && !after.is_empty() {
                deferred_ticks += 1;
            }
            n += 1;
            assert!(n < 10_000, "queue failed to drain");
        }
        assert!(deferred_ticks > 0, "the tiny pool must actually defer admissions");
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.try_recv().expect("every request must be answered");
            assert!(r.error.is_none(), "request {i}: {:?}", r.error);
        }
    }

    /// With preemption ON, a starved head is admitted by freezing a
    /// live session — before that session finishes — and nothing
    /// starves indefinitely.
    #[test]
    fn starved_head_preempts_lru_live_session() {
        let cfg = ServingConfig {
            preempt: true,
            starve_ticks: 1,
            swap_blocks: 0, // recompute path
            ..tiny_pool_cfg(4)
        };
        let engine = Engine::load(cfg.clone()).unwrap();
        let metrics = Metrics::new();
        let mut sched = Scheduler::new(SchedPolicy::from_config(&cfg));
        // a long-running hog that fills the pool, then a second request
        let (hog, hog_rx) = make_req(1, "the color of tom is quite a long story", 24);
        let (late, late_rx) = make_req(2, "tom keeps the hat somewhere else entirely", 6);
        sched.submit(hog);
        sched.submit(late);
        drive(&mut sched, &engine, &metrics, 10_000);
        assert!(
            sched.stats.preempt_recompute + sched.stats.preempt_swap >= 1,
            "the hog must have been preempted at least once"
        );
        let hr = hog_rx.try_recv().unwrap();
        let lr = late_rx.try_recv().unwrap();
        assert!(hr.error.is_none(), "{:?}", hr.error);
        assert!(lr.error.is_none(), "{:?}", lr.error);
        assert_eq!(lr.n_generated, 6, "the starved request must run to completion");
        assert_eq!(hr.n_generated, 24, "the preempted hog must also finish");
        assert_eq!(metrics.gauge("kv_live_tables"), 0.0, "no leaked tables");
    }

    /// Streaming emits exactly one frame per generated token, in
    /// order, and the concatenated frame text equals the final text.
    #[test]
    fn streaming_frames_match_final_text() {
        let engine = Engine::load(toy_cfg()).unwrap();
        let metrics = Metrics::new();
        let mut sched = Scheduler::new(SchedPolicy::from_config(&toy_cfg()));
        let (tx, frames_rx) = channel();
        let (mut req, rx) = make_req(1, "the color of tom is", 6);
        req.stream = Some(tx.into());
        sched.submit(req);
        drive(&mut sched, &engine, &metrics, 10_000);
        let r = rx.try_recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        let frames: Vec<StreamFrame> = frames_rx.try_iter().collect();
        assert_eq!(frames.len(), r.n_generated, "one frame per generated token");
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(f.index, i, "frames arrive in order");
            assert_eq!(f.id, 1);
        }
        let cat: String = frames.iter().map(|f| f.text.as_str()).collect();
        assert_eq!(cat, r.text, "frame concat must equal the final text");
    }

    /// Cancelling a mid-decode streaming session frees its sole-owner
    /// blocks (occupancy returns to the pre-request baseline) and the
    /// client receives a terminal cancelled response; a pending request
    /// cancels straight out of the queue.
    #[test]
    fn cancel_aborts_live_and_pending() {
        let engine = Engine::load(toy_cfg()).unwrap();
        let metrics = Metrics::new();
        let mut sched = Scheduler::new(SchedPolicy {
            max_batch: 1, // the second request stays pending
            ..SchedPolicy::from_config(&toy_cfg())
        });
        let baseline = engine.paged_snapshot().unwrap().live_blocks;
        let (tx, frames_rx) = channel();
        let (mut live_req, live_rx) = make_req(1, "the color of tom is quite a story", 24);
        live_req.stream = Some(tx.into());
        let (pend_req, pend_rx) = make_req(2, "tom keeps the hat", 4);
        sched.submit(live_req);
        sched.submit(pend_req);
        for _ in 0..3 {
            sched.run_tick(&engine, &metrics);
        }
        assert!(frames_rx.try_iter().count() >= 3, "session must be mid-decode");
        assert!(sched.cancel(1, &engine, &metrics), "live session must cancel");
        let r = live_rx.try_recv().unwrap();
        assert!(r.cancelled && r.error.is_none(), "{r:?}");
        assert!(r.n_generated >= 3);
        assert_eq!(
            engine.paged_snapshot().unwrap().live_blocks,
            baseline,
            "cancel must return occupancy to the pre-request baseline"
        );
        assert!(sched.cancel(2, &engine, &metrics), "pending request must cancel");
        let r = pend_rx.try_recv().unwrap();
        assert!(r.cancelled && r.n_generated == 0);
        assert!(!sched.cancel(99, &engine, &metrics), "unknown id is a no-op");
        assert!(sched.is_idle());
        assert_eq!(metrics.counter("sched_cancelled"), 2);
    }

    /// `fail_all` answers every population with a terminal error and
    /// releases all K,V state (the coordinator's shutdown contract).
    #[test]
    fn fail_all_answers_every_request() {
        let engine = Engine::load(toy_cfg()).unwrap();
        let metrics = Metrics::new();
        let mut sched = Scheduler::new(SchedPolicy {
            max_batch: 1,
            ..SchedPolicy::from_config(&toy_cfg())
        });
        let (live_req, live_rx) = make_req(1, "the color of tom is", 24);
        let (pend_req, pend_rx) = make_req(2, "tom keeps the hat", 4);
        sched.submit(live_req);
        sched.submit(pend_req);
        sched.run_tick(&engine, &metrics);
        assert_eq!(sched.live_len(), 1);
        assert_eq!(sched.pending_len(), 1);
        sched.fail_all(&engine, &metrics, "shutting down");
        for rx in [live_rx, pend_rx] {
            let r = rx.try_recv().expect("every request must be answered");
            assert_eq!(r.error.as_deref(), Some("shutting down"));
        }
        assert!(sched.is_idle());
        assert_eq!(engine.paged_snapshot().unwrap().live_tables, 0, "no leaked tables");
    }

    /// Regression (cancel-vs-inbox race): a cancel that arrives before
    /// its submit is drained must not be a silent no-op. The tombstone
    /// recorded by `note_cancelled_unseen` aborts the submit at drain
    /// time with the same terminal cancelled response, is consumed
    /// exactly once, and never touches other ids.
    #[test]
    fn cancelled_unseen_tombstone_aborts_late_submit() {
        let engine = Engine::load(toy_cfg()).unwrap();
        let metrics = Metrics::new();
        let mut sched = Scheduler::new(SchedPolicy::from_config(&toy_cfg()));
        // the cancel misses (id 7 was never submitted) → tombstone
        assert!(!sched.cancel(7, &engine, &metrics));
        sched.note_cancelled_unseen(7);
        // the racing submit drains afterwards: aborted, never enqueued
        let (req, rx) = make_req(7, "the color of tom is", 8);
        sched.submit(req);
        let r = rx.try_recv().expect("tombstoned submit must be answered");
        assert!(r.cancelled && r.error.is_none(), "{r:?}");
        assert_eq!(r.n_generated, 0);
        assert_eq!(sched.pending_len(), 0, "tombstoned request must not queue");
        assert_eq!(sched.stats.cancelled_unseen, 1);
        // consumed: a later submit under a fresh id (ids are never
        // reused, but the tombstone must still be one-shot) runs
        let (req, rx) = make_req(7, "the color of tom is", 2);
        sched.submit(req);
        drive(&mut sched, &engine, &metrics, 10_000);
        assert!(rx.try_recv().unwrap().error.is_none());
        // other ids are unaffected by an outstanding tombstone
        sched.note_cancelled_unseen(42);
        let (req, rx) = make_req(43, "tom keeps the hat", 2);
        sched.submit(req);
        drive(&mut sched, &engine, &metrics, 10_000);
        assert!(rx.try_recv().unwrap().error.is_none());
        // FIFO eviction caps the set: after CAP more ids, 42 is gone
        for i in 0..(TOMBSTONE_CAP as u64) {
            sched.note_cancelled_unseen(1000 + i);
        }
        assert!(!sched.tombstone_set.contains(&42), "oldest tombstone evicted");
        assert_eq!(sched.tombstones.len(), TOMBSTONE_CAP);
    }

    /// Preemption is off by default: the same overload defers but never
    /// freezes anything.
    #[test]
    fn no_preemption_when_disabled() {
        let cfg = tiny_pool_cfg(4);
        let engine = Engine::load(cfg.clone()).unwrap();
        let metrics = Metrics::new();
        let mut sched = Scheduler::new(SchedPolicy::from_config(&cfg));
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (req, rx) = make_req(i, &format!("a long prompt number {i} right here"), 4);
            rxs.push(rx);
            sched.submit(req);
        }
        drive(&mut sched, &engine, &metrics, 10_000);
        for rx in rxs {
            assert!(rx.try_recv().unwrap().error.is_none());
        }
        assert_eq!(sched.stats.preempt_swap + sched.stats.preempt_recompute, 0);
        assert_eq!(metrics.counter("sched_preempt_swap"), 0);
    }
}
