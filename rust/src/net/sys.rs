//! Raw Linux epoll/eventfd/rlimit bindings (the vendored mirror has no
//! `libc` crate, so the handful of syscall wrappers the reactor needs
//! are declared here directly against glibc — which the binary already
//! links). Linux-only; the module is `cfg`-gated out elsewhere.

use std::io;
use std::os::unix::io::RawFd;

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;

// O_CLOEXEC / O_NONBLOCK (asm-generic values; x86_64 and aarch64 agree)
const EPOLL_CLOEXEC: i32 = 0o2000000;
const EFD_CLOEXEC: i32 = 0o2000000;
const EFD_NONBLOCK: i32 = 0o4000;

const RLIMIT_NOFILE: i32 = 7;

/// Mirror of the kernel's `struct epoll_event`. Packed on x86_64 only,
/// matching the UAPI header (`__attribute__((packed))` there; natural
/// alignment everywhere else).
#[derive(Clone, Copy)]
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
pub struct EpollEvent {
    events: u32,
    data: u64,
}

impl EpollEvent {
    pub fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }

    /// Readiness mask of this event (copied out — the struct may be
    /// packed, so fields are never borrowed).
    pub fn events(&self) -> u32 {
        self.events
    }

    /// The `u64` token registered with the fd.
    pub fn token(&self) -> u64 {
        self.data
    }
}

#[repr(C)]
struct Rlimit {
    cur: u64,
    max: u64,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
    fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
    fn sched_getcpu() -> i32;
}

fn cvt(ret: i32) -> io::Result<i32> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// Owned epoll instance (level-triggered; the reactor re-arms write
/// interest explicitly, so edge-triggered semantics are not needed).
pub struct Epoll {
    fd: RawFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        let evp = if op == EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev };
        cvt(unsafe { epoll_ctl(self.fd, op, fd, evp) }).map(|_| ())
    }

    pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    pub fn modify(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    pub fn del(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for events (`timeout_ms < 0` blocks indefinitely); EINTR
    /// retries transparently. Returns how many entries of `events` were
    /// filled.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let n = unsafe {
                epoll_wait(
                    self.fd,
                    events.as_mut_ptr(),
                    events.len().min(i32::MAX as usize) as i32,
                    timeout_ms,
                )
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let e = io::Error::last_os_error();
            if e.kind() != io::ErrorKind::Interrupted {
                return Err(e);
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

/// Owned nonblocking eventfd: the reactor's cross-thread waker (engine
/// threads `wake()` it after queueing work; the reactor keeps it in its
/// epoll set and `drain()`s it every loop).
pub struct EventFd {
    fd: RawFd,
}

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        Ok(EventFd { fd })
    }

    pub fn raw_fd(&self) -> RawFd {
        self.fd
    }

    /// Wake the waiter. A full counter (`EAGAIN`) still leaves the fd
    /// readable, so the error is safely ignored.
    pub fn wake(&self) {
        let one: u64 = 1;
        unsafe {
            write(self.fd, (&one as *const u64).cast(), 8);
        }
    }

    /// Drain the counter so a level-triggered poll stops reporting the
    /// fd readable until the next `wake`.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        unsafe {
            read(self.fd, buf.as_mut_ptr(), 8);
        }
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe {
            close(self.fd);
        }
    }
}

/// `(soft, hard)` RLIMIT_NOFILE.
pub fn nofile_limit() -> io::Result<(u64, u64)> {
    let mut r = Rlimit { cur: 0, max: 0 };
    cvt(unsafe { getrlimit(RLIMIT_NOFILE, &mut r) })?;
    Ok((r.cur, r.max))
}

/// Best-effort raise of the soft RLIMIT_NOFILE toward `want` (capped at
/// the hard limit); returns the effective soft limit. The 1k-connection
/// serving bench calls this so a conservative default soft limit does
/// not cap the fleet.
pub fn raise_nofile_limit(want: u64) -> u64 {
    let Ok((soft, hard)) = nofile_limit() else {
        return 1024;
    };
    if soft >= want {
        return soft;
    }
    let target = want.min(hard);
    let r = Rlimit { cur: target, max: hard };
    if unsafe { setrlimit(RLIMIT_NOFILE, &r) } == 0 {
        target
    } else {
        soft
    }
}

// ---------------------------------------------------------------------
// Core affinity (`--pin-cores`)
// ---------------------------------------------------------------------

/// glibc's `cpu_set_t` is 128 bytes (1024 CPUs) — mirrored here as u64
/// words for the raw `sched_setaffinity` call.
const CPU_SET_WORDS: usize = 16;

/// Round-robin core cursor shared by every pinned thread in the
/// process (engine replicas + reactor), indexing into the allowed-CPU
/// list.
static NEXT_CORE: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// CPUs this thread may run on (its inherited affinity mask — pinning
/// must stay inside a container/cgroup cpuset). Falls back to
/// `available_parallelism` if the syscall fails; never empty. Public
/// so pool sizing and bench fleet sizing see the same cgroup-aware
/// count instead of raw `available_parallelism`.
pub fn allowed_cpus() -> Vec<usize> {
    let mut mask = [0u64; CPU_SET_WORDS];
    if unsafe { sched_getaffinity(0, CPU_SET_WORDS * 8, mask.as_mut_ptr()) } == 0 {
        let cpus: Vec<usize> = (0..CPU_SET_WORDS * 64)
            .filter(|&c| mask[c / 64] & (1u64 << (c % 64)) != 0)
            .collect();
        if !cpus.is_empty() {
            return cpus;
        }
    }
    let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    (0..n).collect()
}

/// Pin the calling thread to a single CPU; returns the CPU the thread
/// is actually running on afterwards (as reported by `sched_getcpu`).
pub fn pin_current_thread(cpu: usize) -> io::Result<usize> {
    let mut mask = [0u64; CPU_SET_WORDS];
    let cpu = cpu % (CPU_SET_WORDS * 64);
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    // pid 0 = the calling thread
    cvt(unsafe { sched_setaffinity(0, CPU_SET_WORDS * 8, mask.as_ptr()) })?;
    Ok(unsafe { sched_getcpu() }.max(0) as usize)
}

/// `--pin-cores`: pin the calling thread to the next core in the
/// process-wide round-robin over the allowed-CPU list (engine tick
/// threads and the reactor each take one). `None` when the syscall
/// failed — pinning is strictly best-effort and never takes a thread
/// down. Callers gate on the config flag; this function always pins.
pub fn pin_next_core() -> Option<usize> {
    let cpus = allowed_cpus();
    let core = cpus[NEXT_CORE.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % cpus.len()];
    pin_current_thread(core).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::os::unix::io::AsRawFd;

    #[test]
    fn eventfd_wakes_epoll_and_drains() {
        let ep = Epoll::new().unwrap();
        let efd = EventFd::new().unwrap();
        ep.add(efd.raw_fd(), EPOLLIN, 7).unwrap();
        let mut evs = [EpollEvent::zeroed(); 4];
        // nothing pending: a zero-timeout wait returns no events
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
        efd.wake();
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(evs[0].token(), 7);
        assert!(evs[0].events() & EPOLLIN != 0);
        efd.drain();
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0, "drained fd must go quiet");
        // level-triggered: an undrained wake keeps reporting readable
        efd.wake();
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 1);
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 1);
    }

    #[test]
    fn epoll_tracks_socket_readability_and_write_interest() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(server.as_raw_fd(), EPOLLIN | EPOLLRDHUP, 42).unwrap();
        let mut evs = [EpollEvent::zeroed(); 4];
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
        client.write_all(b"hi\n").unwrap();
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(evs[0].token(), 42);
        assert!(evs[0].events() & EPOLLIN != 0);
        // toggling write interest on an idle socket reports writable
        ep.modify(server.as_raw_fd(), EPOLLIN | EPOLLOUT, 42).unwrap();
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert!(n >= 1);
        assert!(evs[0].events() & EPOLLOUT != 0);
        ep.del(server.as_raw_fd()).unwrap();
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0, "deleted fd must not report");
    }

    #[test]
    fn nofile_limit_reads_and_raises_best_effort() {
        let (soft, hard) = nofile_limit().unwrap();
        assert!(soft > 0 && hard >= soft);
        let eff = raise_nofile_limit(soft); // no-op raise
        assert!(eff >= soft);
    }

    #[test]
    fn pin_next_core_lands_on_an_allowed_cpu() {
        let allowed = allowed_cpus();
        assert!(!allowed.is_empty());
        // pin a scratch thread (so this test thread's affinity is
        // untouched); the core is drawn from the allowed list, so the
        // pin must succeed and sched_getcpu must report a member of it
        std::thread::spawn(move || {
            let cpu = pin_next_core().expect("pinning to an allowed core must succeed");
            assert!(allowed.contains(&cpu), "pinned to {cpu}, allowed {allowed:?}");
        })
        .join()
        .unwrap();
    }
}
