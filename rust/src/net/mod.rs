//! Event-driven net subsystem: lock-free rings + a single-thread epoll
//! reactor for the streaming front end.
//!
//! Two transports serve the same line-JSON protocol (`crate::server`):
//!
//! * **threads** (`--net threads`, default, portable) — one OS thread
//!   per connection, blocking I/O with an idle-poll read timeout.
//! * **reactor** (`--net reactor`, Linux) — ONE I/O thread multiplexes
//!   every connection through raw epoll ([`sys`]): non-blocking
//!   accept/read/write, per-connection line-framing state machines, and
//!   write-interest toggling for token fan-out ([`reactor`]).
//!
//! The reactor never blocks on the engine: each request carries a
//! bounded [`ring::Spsc`] of [`NetEvent`]s (serialized frame/terminal
//! lines) that the engine thread fills and the reactor drains, and a
//! shared [`ReadyQueue`] ([`ring::Mpsc`] + eventfd) tells the reactor
//! *which* connections have events pending. Backpressure is explicit
//! end to end: the coordinator's submission inbox is a bounded
//! [`ring::Mpsc`] that sheds-on-full with a terminal
//! `{"error":"overloaded"}` line, per-request event rings are sized so
//! every frame plus the terminal always fits, and a slow reader only
//! grows (and eventually kills) its own connection's write buffer —
//! never another session's.
//!
//! Everything reactor-specific is `cfg(target_os = "linux")`; the rings,
//! [`NetMode`], and [`NetStats`] are portable (the threaded transport
//! reports through the same `net_*` stats surface).

pub mod ring;

#[cfg(target_os = "linux")]
pub(crate) mod reactor;
#[cfg(target_os = "linux")]
pub mod sys;

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Streaming front-end transport (`--net`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetMode {
    /// one OS thread per connection (portable baseline)
    Threads,
    /// single epoll I/O thread for all connections (Linux)
    #[cfg(target_os = "linux")]
    Reactor,
}

impl NetMode {
    pub fn parse(s: &str) -> Result<NetMode> {
        match s {
            "threads" | "thread" => Ok(NetMode::Threads),
            #[cfg(target_os = "linux")]
            "reactor" | "epoll" => Ok(NetMode::Reactor),
            #[cfg(not(target_os = "linux"))]
            "reactor" | "epoll" => bail!("--net reactor requires Linux (epoll)"),
            other => bail!("unknown net mode {other:?} (threads|reactor)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            NetMode::Threads => "threads",
            #[cfg(target_os = "linux")]
            NetMode::Reactor => "reactor",
        }
    }
}

/// Transport counters for the `net` section of `{"cmd":"stats"}`,
/// shared by both transports (fields a transport does not exercise stay
/// zero). Plain atomics — these sit on I/O hot paths.
#[derive(Default)]
pub struct NetStats {
    /// connections accepted over the server's lifetime
    pub accepted: AtomicU64,
    /// complete request lines parsed off sockets
    pub lines_in: AtomicU64,
    /// response lines written (frames + terminals + command replies)
    pub lines_out: AtomicU64,
    /// threaded transport: read-timeout wakeups with no data (the
    /// busy-wake regression gauge)
    pub idle_wakeups: AtomicU64,
    /// reactor transport: epoll_wait returns
    pub reactor_wakeups: AtomicU64,
    /// high-water mark of the ready-connection ring
    pub ready_ring_hwm: AtomicU64,
    /// high-water mark across all per-request event rings
    pub frame_ring_hwm: AtomicU64,
    /// connections killed because a slow reader grew its write buffer
    /// past the cap (the reader only ever kills itself)
    pub conn_buffer_kills: AtomicU64,
    /// connections that closed mid-line (bytes with no trailing
    /// newline at EOF) — the partial line is rejected with an error
    /// line, never processed (identical across both transports)
    pub truncated_eof: AtomicU64,
    /// terminal events that found their (correctly-sized) ring full —
    /// always 0 unless an invariant broke
    pub lost_terminals: AtomicU64,
    /// `--pin-cores`: 1 + the CPU the reactor thread pinned itself to
    /// (0 = not pinned; the +1 keeps "pinned to CPU 0" observable)
    pub pinned_cpu_plus1: AtomicU64,
}

impl NetStats {
    /// Monotonic max update (relaxed; these are observability gauges).
    pub fn record_hwm(cell: &AtomicU64, v: u64) {
        cell.fetch_max(v, Ordering::Relaxed);
    }

    /// The `net` section: every counter under a `net_` key so the
    /// router's `sum_json_objects` rollup can sum them numerically.
    pub fn to_json(&self, active: usize, transport: &str) -> Json {
        let n = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("net_transport", Json::Str(transport.into())),
            ("net_active_connections", Json::Num(active as f64)),
            ("net_accepted_total", n(&self.accepted)),
            ("net_lines_in", n(&self.lines_in)),
            ("net_lines_out", n(&self.lines_out)),
            ("net_idle_wakeups", n(&self.idle_wakeups)),
            ("net_reactor_wakeups", n(&self.reactor_wakeups)),
            ("net_ready_ring_hwm", n(&self.ready_ring_hwm)),
            ("net_frame_ring_hwm", n(&self.frame_ring_hwm)),
            ("net_conn_buffer_kills", n(&self.conn_buffer_kills)),
            ("net_truncated_eof", n(&self.truncated_eof)),
            ("net_lost_terminals", n(&self.lost_terminals)),
            ("net_pinned_cpu_plus1", n(&self.pinned_cpu_plus1)),
        ])
    }
}

/// One serialized response line bound for a connection (already JSON,
/// no trailing newline). Terminal events end their request's
/// subscription on the connection.
#[cfg(target_os = "linux")]
pub struct NetEvent {
    pub line: String,
    pub terminal: bool,
}

/// Default capacity of the [`ReadyQueue`] id ring. Overflow is safe
/// (it degrades one reactor pass to a full-connection scan), so this
/// only needs to cover the common case of distinct connections with
/// pending events between two reactor passes.
#[cfg(target_os = "linux")]
pub const READY_RING_CAPACITY: usize = 4096;

/// Wakes the reactor and tells it *which* connections have pending
/// events: a bounded [`ring::Mpsc`] of connection ids (many engine
/// threads push, the reactor pops) plus an eventfd registered in the
/// reactor's epoll set. If the id ring ever fills, `scan_all` degrades
/// one reactor pass to checking every connection — wakeups may coalesce
/// but are never lost.
#[cfg(target_os = "linux")]
pub struct ReadyQueue {
    ids: ring::Mpsc<u64>,
    scan_all: std::sync::atomic::AtomicBool,
    efd: sys::EventFd,
    stats: std::sync::Arc<NetStats>,
}

#[cfg(target_os = "linux")]
impl ReadyQueue {
    pub fn new(capacity: usize, stats: std::sync::Arc<NetStats>) -> std::io::Result<ReadyQueue> {
        Ok(ReadyQueue {
            ids: ring::Mpsc::new(capacity),
            scan_all: std::sync::atomic::AtomicBool::new(false),
            efd: sys::EventFd::new()?,
            stats,
        })
    }

    /// Mark connection `conn` as having pending events and wake the
    /// reactor. Ring push happens-before the eventfd write, so a wakeup
    /// always finds its id (or the scan_all fallback) visible.
    pub fn notify(&self, conn: u64) {
        if self.ids.push(conn).is_err() {
            self.scan_all.store(true, Ordering::Release);
        }
        NetStats::record_hwm(&self.stats.ready_ring_hwm, self.ids.high_water() as u64);
        self.efd.wake();
    }

    /// Bare wakeup with no connection attached (stop requests).
    pub fn wake(&self) {
        self.efd.wake();
    }

    pub fn raw_fd(&self) -> std::os::unix::io::RawFd {
        self.efd.raw_fd()
    }

    /// Drain the eventfd and collect pending connection ids into `out`
    /// (reactor thread only). Returns `true` when the id ring
    /// overflowed since the last drain — the caller must then check
    /// every connection.
    pub fn drain(&self, out: &mut Vec<u64>) -> bool {
        self.efd.drain();
        while let Some(id) = self.ids.pop() {
            out.push(id);
        }
        self.scan_all.swap(false, Ordering::Acquire)
    }
}

/// The engine-side handle of one request's event ring: the scheduler's
/// response/frame sinks serialize into it and nudge the [`ReadyQueue`].
/// Cloned once when a request streams (frame sink + response sink share
/// the ring, and both live on the same engine thread, preserving the
/// SPSC contract; a submission-refusal terminal is pushed by the
/// submitting thread *before* the request could ever reach an engine,
/// so the single-producer discipline holds there too).
#[cfg(target_os = "linux")]
#[derive(Clone)]
pub struct NetSink {
    conn: u64,
    ring: std::sync::Arc<ring::Spsc<NetEvent>>,
    ready: std::sync::Arc<ReadyQueue>,
    stats: std::sync::Arc<NetStats>,
}

#[cfg(target_os = "linux")]
impl NetSink {
    pub fn new(
        conn: u64,
        ring: std::sync::Arc<ring::Spsc<NetEvent>>,
        ready: std::sync::Arc<ReadyQueue>,
        stats: std::sync::Arc<NetStats>,
    ) -> NetSink {
        NetSink { conn, ring, ready, stats }
    }

    /// Ring a per-request event ring must have so `max_new` frames plus
    /// one terminal can never shed.
    pub fn ring_capacity(max_new: usize) -> usize {
        (max_new + 2).next_power_of_two()
    }

    /// Queue one frame line; `false` means the ring was momentarily
    /// full and the caller should retry on its next tick.
    pub fn send_frame(&self, f: &crate::scheduler::StreamFrame) -> bool {
        let line = crate::server::frame_json(f).to_string();
        let ok = self.ring.push(NetEvent { line, terminal: false }).is_ok();
        if ok {
            NetStats::record_hwm(&self.stats.frame_ring_hwm, self.ring.high_water() as u64);
            self.ready.notify(self.conn);
        }
        ok
    }

    /// Queue the terminal response line. Rings are sized so this cannot
    /// shed; if it ever does, the loss is counted rather than silent.
    pub fn send_response(&self, r: &crate::scheduler::Response) {
        let line = crate::server::response_json(r).to_string();
        self.send_line(line, true);
    }

    /// Queue an already-serialized reply line (no trailing newline) —
    /// the mesh drain path, whose reply is not a per-request
    /// [`crate::scheduler::Response`]. Terminal lines end the
    /// subscription; a full ring is counted, never silent.
    pub fn send_line(&self, line: String, terminal: bool) {
        if self.ring.push(NetEvent { line, terminal }).is_err() {
            self.stats.lost_terminals.fetch_add(1, Ordering::Relaxed);
        }
        NetStats::record_hwm(&self.stats.frame_ring_hwm, self.ring.high_water() as u64);
        self.ready.notify(self.conn);
    }
}

#[cfg(target_os = "linux")]
impl std::fmt::Debug for NetSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "NetSink(conn {})", self.conn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_mode_parses() {
        assert_eq!(NetMode::parse("threads").unwrap(), NetMode::Threads);
        #[cfg(target_os = "linux")]
        assert_eq!(NetMode::parse("reactor").unwrap(), NetMode::Reactor);
        assert!(NetMode::parse("uring").is_err());
    }

    #[test]
    fn stats_json_uses_net_prefixed_keys() {
        let s = NetStats::default();
        s.accepted.fetch_add(3, Ordering::Relaxed);
        let j = s.to_json(2, "threads");
        assert_eq!(j.get("net_transport").unwrap().str().unwrap(), "threads");
        assert_eq!(j.get("net_active_connections").unwrap().usize().unwrap(), 2);
        assert_eq!(j.get("net_accepted_total").unwrap().usize().unwrap(), 3);
        assert_eq!(j.get("net_lost_terminals").unwrap().usize().unwrap(), 0);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn ready_queue_collects_ids_and_degrades_to_scan_all() {
        let stats = std::sync::Arc::new(NetStats::default());
        let rq = ReadyQueue::new(4, stats.clone()).unwrap();
        rq.notify(7);
        rq.notify(9);
        let mut ids = Vec::new();
        assert!(!rq.drain(&mut ids));
        assert_eq!(ids, vec![7, 9]);
        // overflow the id ring: wakeups coalesce into a full scan
        for i in 0..10 {
            rq.notify(i);
        }
        ids.clear();
        assert!(rq.drain(&mut ids), "overflow must force a full scan");
        assert_eq!(ids.len(), 4, "ring kept its capacity worth of ids");
        assert!(stats.ready_ring_hwm.load(Ordering::Relaxed) >= 4);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn sink_ring_capacity_always_fits_frames_plus_terminal() {
        for max_new in [0usize, 1, 2, 31, 32, 100] {
            assert!(NetSink::ring_capacity(max_new) >= max_new + 1);
        }
    }
}
