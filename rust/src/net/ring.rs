//! Cache-padded lock-free bounded ring buffers.
//!
//! Two shapes, both bounded and shed-on-full (a rejected push returns
//! the value to the caller instead of blocking or reallocating — the
//! event-driven front end turns that into an explicit `overloaded`
//! response rather than letting queues grow without bound):
//!
//! * [`Spsc`] — single-producer single-consumer, plain monotonic
//!   head/tail indices. Used for the per-request event rings (engine
//!   thread → reactor thread): one producer, one consumer, sized so
//!   every frame plus the terminal always fits.
//! * [`Mpsc`] — multi-producer single-consumer bounded queue (Vyukov
//!   style: a per-slot sequence number arbitrates producers, so a
//!   stalled producer never blocks the consumer behind a half-written
//!   slot). Used for the coordinator submission inbox (many server
//!   threads or the reactor → one engine thread) and the reactor's
//!   ready-connection queue (many engine threads → one reactor).
//!
//! Both rings track a high-water mark and a shed count for the `net_*`
//! gauges. Capacities round up to a power of two.
//!
//! Safety contract (documented, not type-enforced, because both ends
//! are shared through `Arc`): at most one thread pops at a time; for
//! [`Spsc`], at most one thread pushes at a time. Producer *handoff* is
//! fine as long as it is ordered through some other synchronization
//! (the serving stack hands a request's ring from the submitting thread
//! to the engine thread through the [`Mpsc`] inbox, which provides that
//! ordering).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Pad to a cache line so the producer's tail and the consumer's head
/// never false-share.
#[repr(align(64))]
struct CachePadded<T>(T);

/// Single-producer single-consumer bounded ring.
pub struct Spsc<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    mask: usize,
    /// pop index (consumer-owned, monotonic)
    head: CachePadded<AtomicUsize>,
    /// push index (producer-owned, monotonic)
    tail: CachePadded<AtomicUsize>,
    high_water: AtomicUsize,
    sheds: AtomicUsize,
}

unsafe impl<T: Send> Send for Spsc<T> {}
unsafe impl<T: Send> Sync for Spsc<T> {}

impl<T> Spsc<T> {
    /// `capacity` rounds up to a power of two (min 2).
    pub fn new(capacity: usize) -> Spsc<T> {
        let cap = capacity.next_power_of_two().max(2);
        let buf: Vec<UnsafeCell<MaybeUninit<T>>> =
            (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
        Spsc {
            buf: buf.into_boxed_slice(),
            mask: cap - 1,
            head: CachePadded(AtomicUsize::new(0)),
            tail: CachePadded(AtomicUsize::new(0)),
            high_water: AtomicUsize::new(0),
            sheds: AtomicUsize::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Push (single producer); a full ring sheds — the value comes back
    /// in `Err` so the caller can answer/retry instead of losing it.
    pub fn push(&self, v: T) -> Result<(), T> {
        let tail = self.tail.0.load(Ordering::Relaxed);
        let head = self.head.0.load(Ordering::Acquire);
        let occ = tail.wrapping_sub(head);
        if occ == self.capacity() {
            self.sheds.fetch_add(1, Ordering::Relaxed);
            return Err(v);
        }
        unsafe {
            (*self.buf[tail & self.mask].get()).write(v);
        }
        self.tail.0.store(tail.wrapping_add(1), Ordering::Release);
        self.high_water.fetch_max(occ + 1, Ordering::Relaxed);
        Ok(())
    }

    /// Pop (single consumer).
    pub fn pop(&self) -> Option<T> {
        let head = self.head.0.load(Ordering::Relaxed);
        let tail = self.tail.0.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        let v = unsafe { (*self.buf[head & self.mask].get()).assume_init_read() };
        self.head.0.store(head.wrapping_add(1), Ordering::Release);
        Some(v)
    }

    pub fn len(&self) -> usize {
        self.tail
            .0
            .load(Ordering::Relaxed)
            .wrapping_sub(self.head.0.load(Ordering::Relaxed))
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Highest occupancy ever observed by a push.
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }

    /// Pushes rejected because the ring was full.
    pub fn sheds(&self) -> usize {
        self.sheds.load(Ordering::Relaxed)
    }
}

impl<T> Drop for Spsc<T> {
    fn drop(&mut self) {
        // owned exclusively here: drain so T's destructors run
        while self.pop().is_some() {}
    }
}

struct Slot<T> {
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Multi-producer single-consumer bounded ring (Vyukov bounded queue,
/// with the consumer side simplified to a plain store since only one
/// thread ever pops).
pub struct Mpsc<T> {
    buf: Box<[Slot<T>]>,
    mask: usize,
    /// enqueue cursor (producers race on it with CAS)
    tail: CachePadded<AtomicUsize>,
    /// dequeue cursor (consumer-owned)
    head: CachePadded<AtomicUsize>,
    high_water: AtomicUsize,
    sheds: AtomicUsize,
}

unsafe impl<T: Send> Send for Mpsc<T> {}
unsafe impl<T: Send> Sync for Mpsc<T> {}

impl<T> Mpsc<T> {
    /// `capacity` rounds up to a power of two (min 2).
    pub fn new(capacity: usize) -> Mpsc<T> {
        let cap = capacity.next_power_of_two().max(2);
        let buf: Vec<Slot<T>> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        Mpsc {
            buf: buf.into_boxed_slice(),
            mask: cap - 1,
            tail: CachePadded(AtomicUsize::new(0)),
            head: CachePadded(AtomicUsize::new(0)),
            high_water: AtomicUsize::new(0),
            sheds: AtomicUsize::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Push from any thread; a full ring sheds (`Err` returns the
    /// value). Lock-free: a producer that loses the CAS race retries
    /// at the new cursor, never spinning on another producer's slot.
    pub fn push(&self, v: T) -> Result<(), T> {
        let mut pos = self.tail.0.load(Ordering::Relaxed);
        loop {
            let slot = &self.buf[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = (seq as isize).wrapping_sub(pos as isize);
            if dif == 0 {
                match self.tail.0.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe {
                            (*slot.val.get()).write(v);
                        }
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        let occ = pos
                            .wrapping_add(1)
                            .wrapping_sub(self.head.0.load(Ordering::Relaxed));
                        self.high_water.fetch_max(occ.min(self.capacity()), Ordering::Relaxed);
                        return Ok(());
                    }
                    Err(now) => pos = now,
                }
            } else if dif < 0 {
                // slot not yet consumed a full lap ago: ring is full
                self.sheds.fetch_add(1, Ordering::Relaxed);
                return Err(v);
            } else {
                // another producer claimed this slot; advance
                pos = self.tail.0.load(Ordering::Relaxed);
            }
        }
    }

    /// Pop (single consumer). Returns `None` when empty OR when the
    /// producer that claimed the next slot has not finished writing it
    /// yet — the consumer simply retries on its next pass instead of
    /// spinning.
    pub fn pop(&self) -> Option<T> {
        let pos = self.head.0.load(Ordering::Relaxed);
        let slot = &self.buf[pos & self.mask];
        let seq = slot.seq.load(Ordering::Acquire);
        let dif = (seq as isize).wrapping_sub(pos.wrapping_add(1) as isize);
        if dif != 0 {
            return None;
        }
        let v = unsafe { (*slot.val.get()).assume_init_read() };
        slot.seq
            .store(pos.wrapping_add(self.capacity()), Ordering::Release);
        self.head.0.store(pos.wrapping_add(1), Ordering::Relaxed);
        Some(v)
    }

    /// Occupancy (approximate under concurrent pushes).
    pub fn len(&self) -> usize {
        self.tail
            .0
            .load(Ordering::Relaxed)
            .wrapping_sub(self.head.0.load(Ordering::Relaxed))
            .min(self.capacity())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Highest occupancy ever observed by a push.
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }

    /// Pushes rejected because the ring was full.
    pub fn sheds(&self) -> usize {
        self.sheds.load(Ordering::Relaxed)
    }
}

impl<T> Drop for Mpsc<T> {
    fn drop(&mut self) {
        while self.pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn spsc_fifo_and_shed_accounting() {
        let r: Spsc<u32> = Spsc::new(4);
        assert_eq!(r.capacity(), 4);
        for i in 0..4 {
            assert!(r.push(i).is_ok());
        }
        // full: push sheds and hands the value back
        assert_eq!(r.push(99), Err(99));
        assert_eq!(r.push(100), Err(100));
        assert_eq!(r.sheds(), 2);
        assert_eq!(r.len(), 4);
        assert_eq!(r.high_water(), 4);
        for i in 0..4 {
            assert_eq!(r.pop(), Some(i), "FIFO order");
        }
        assert_eq!(r.pop(), None);
        assert!(r.is_empty());
        // below capacity nothing is ever lost, across the wrap point
        for lap in 0..10u32 {
            for i in 0..3 {
                assert!(r.push(lap * 3 + i).is_ok());
            }
            for i in 0..3 {
                assert_eq!(r.pop(), Some(lap * 3 + i));
            }
        }
        assert_eq!(r.sheds(), 2, "no new sheds below capacity");
    }

    #[test]
    fn spsc_concurrent_producer_consumer() {
        const N: usize = 100_000;
        let r: Arc<Spsc<usize>> = Arc::new(Spsc::new(64));
        let p = {
            let r = r.clone();
            std::thread::spawn(move || {
                for i in 0..N {
                    let mut v = i;
                    // bounded ring: spin until the consumer makes room
                    while let Err(back) = r.push(v) {
                        v = back;
                        std::thread::yield_now();
                    }
                }
            })
        };
        let mut next = 0usize;
        while next < N {
            match r.pop() {
                Some(v) => {
                    assert_eq!(v, next, "in order, nothing lost");
                    next += 1;
                }
                None => std::thread::yield_now(),
            }
        }
        p.join().unwrap();
        assert!(r.is_empty());
        assert!(r.high_water() <= r.capacity());
    }

    #[test]
    fn mpsc_shed_accounting_when_full() {
        let r: Mpsc<u32> = Mpsc::new(4);
        for i in 0..4 {
            assert!(r.push(i).is_ok());
        }
        assert_eq!(r.push(9), Err(9));
        assert_eq!(r.sheds(), 1);
        assert_eq!(r.len(), 4);
        assert_eq!(r.high_water(), 4);
        for i in 0..4 {
            assert_eq!(r.pop(), Some(i));
        }
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn mpsc_concurrent_producer_stress_preserves_per_producer_order() {
        const PRODUCERS: usize = 4;
        const PER: usize = 20_000;
        let r: Arc<Mpsc<(usize, usize)>> = Arc::new(Mpsc::new(128));
        let handles: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let r = r.clone();
                std::thread::spawn(move || {
                    for i in 0..PER {
                        let mut v = (p, i);
                        while let Err(back) = r.push(v) {
                            v = back;
                            std::thread::yield_now();
                        }
                    }
                })
            })
            .collect();
        let mut got = 0usize;
        let mut next_per_producer = [0usize; PRODUCERS];
        while got < PRODUCERS * PER {
            match r.pop() {
                Some((p, i)) => {
                    assert_eq!(
                        i, next_per_producer[p],
                        "per-producer FIFO order must hold"
                    );
                    next_per_producer[p] += 1;
                    got += 1;
                }
                None => std::thread::yield_now(),
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(r.is_empty());
        assert_eq!(next_per_producer, [PER; PRODUCERS]);
    }

    #[test]
    fn drop_releases_unconsumed_items() {
        // Arc strong counts observe the queued clones being dropped
        let token = Arc::new(());
        {
            let r: Spsc<Arc<()>> = Spsc::new(8);
            for _ in 0..5 {
                r.push(token.clone()).unwrap();
            }
            assert_eq!(Arc::strong_count(&token), 6);
        }
        assert_eq!(Arc::strong_count(&token), 1, "Spsc drop must run destructors");
        {
            let r: Mpsc<Arc<()>> = Mpsc::new(8);
            for _ in 0..5 {
                r.push(token.clone()).unwrap();
            }
            assert_eq!(Arc::strong_count(&token), 6);
        }
        assert_eq!(Arc::strong_count(&token), 1, "Mpsc drop must run destructors");
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(Spsc::<u8>::new(0).capacity(), 2);
        assert_eq!(Spsc::<u8>::new(3).capacity(), 4);
        assert_eq!(Mpsc::<u8>::new(5).capacity(), 8);
        assert_eq!(Mpsc::<u8>::new(1024).capacity(), 1024);
    }
}
