//! Single-thread epoll reactor: every streaming connection multiplexed
//! on ONE I/O thread.
//!
//! ## Event loop
//!
//! The reactor owns the listener, an eventfd waker (the engine side of
//! the [`ReadyQueue`]) and one [`Conn`] per accepted socket, all
//! registered in a level-triggered epoll set. Each pass:
//!
//! 1. `epoll_wait` (1 s timeout — the backstop for the `stop` flag).
//! 2. Socket events: accept new connections; on readable, pull bytes
//!    into the connection's line-framing buffer and dispatch every
//!    complete line; on writable, flush the pending write buffer.
//! 3. Engine events: drain the [`ReadyQueue`] and copy each named
//!    connection's pending [`NetEvent`] lines (token frames, terminal
//!    responses — pushed by engine threads through [`NetSink`]s) into
//!    its write buffer.
//! 4. Write-interest toggling: `EPOLLOUT` is registered only while a
//!    connection has unflushed bytes, so a mostly-drained fan-out never
//!    spins the loop.
//!
//! ## Connection states
//!
//! A connection is **open** (reading + dispatching), **closing**
//! (protocol violation: flush the error line, then die), or **dead**
//! (reaped at the end of the pass: in-flight requests cancelled on the
//! engine, fd deregistered). A slow reader grows only its own write
//! buffer; past [`MAX_WBUF_BYTES`] the connection is killed
//! (`net_conn_buffer_kills`) — it can never delay another session,
//! because per-request rings and the write buffers are per-connection
//! and the engine never blocks on either.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::net::ring::Spsc;
use crate::net::sys::{Epoll, EpollEvent, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP};
use crate::net::{NetEvent, NetSink, NetStats, ReadyQueue};
use crate::router::Frontend;
use crate::scheduler::{FrameSink, RespSink, SubmitOpts};
use crate::server::{self, NetView, MAX_LINE_BYTES};
use crate::util::json::Json;

const TOKEN_LISTENER: u64 = u64::MAX;
const TOKEN_WAKER: u64 = u64::MAX - 1;

/// Kill a connection whose unread replies exceed this (slow reader
/// with unbounded fan-out); its own sessions are cancelled, nobody
/// else's are touched.
const MAX_WBUF_BYTES: usize = 16 << 20;
/// Bytes per read(2) into the line-framing buffer.
const READ_CHUNK: usize = 16 << 10;
/// Epoll events drained per wait (level-triggered: leftovers re-arm).
const EVENTS_PER_WAIT: usize = 256;
/// epoll_wait timeout — backstop for observing `stop` even if the
/// waker write were ever lost.
const WAIT_MS: i32 = 1000;

/// One in-flight request submitted from a connection: the reactor end
/// of its event ring.
struct Sub {
    id: u64,
    ring: Arc<Spsc<NetEvent>>,
    done: bool,
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    /// line-framing read buffer (bytes up to `scanned` hold no newline)
    rbuf: Vec<u8>,
    scanned: usize,
    /// serialized reply bytes not yet accepted by the socket
    wbuf: Vec<u8>,
    wpos: usize,
    /// whether EPOLLOUT is currently registered for this fd
    registered_write: bool,
    /// flush remaining wbuf, then die (unrecoverable protocol error)
    closing: bool,
    dead: bool,
    subs: Vec<Sub>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            scanned: 0,
            wbuf: Vec::new(),
            wpos: 0,
            registered_write: false,
            closing: false,
            dead: false,
            subs: Vec::new(),
        }
    }

    fn pending_write(&self) -> usize {
        self.wbuf.len() - self.wpos
    }
}

/// Register the listener + waker on the CALLING thread (so setup errors
/// surface as a `Result` from `Server::start_with`), then hand the
/// epoll set to the reactor thread.
pub(crate) fn spawn<F: Frontend>(
    listener: TcpListener,
    api: F,
    stop: Arc<AtomicBool>,
    ready: Arc<ReadyQueue>,
    net: Arc<NetStats>,
    active: Arc<AtomicUsize>,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    let ep = Epoll::new()?;
    ep.add(listener.as_raw_fd(), EPOLLIN, TOKEN_LISTENER)?;
    ep.add(ready.raw_fd(), EPOLLIN, TOKEN_WAKER)?;
    std::thread::Builder::new()
        .name("chai-reactor".into())
        .spawn(move || run(&ep, &listener, &api, &stop, &ready, &net, &active))
}

fn run<F: Frontend>(
    ep: &Epoll,
    listener: &TcpListener,
    api: &F,
    stop: &AtomicBool,
    ready: &Arc<ReadyQueue>,
    net: &Arc<NetStats>,
    active: &Arc<AtomicUsize>,
) {
    // --pin-cores (asked of the frontend — this thread is spawned by
    // the server, which holds no config): dedicate a core to the I/O
    // loop and surface it through the `net` stats section
    if api.pin_cores() {
        if let Some(cpu) = crate::net::sys::pin_next_core() {
            net.pinned_cpu_plus1.store(cpu as u64 + 1, Ordering::Relaxed);
        }
    }
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_id: u64 = 1;
    let mut events = vec![EpollEvent::zeroed(); EVENTS_PER_WAIT];
    let mut ready_ids: Vec<u64> = Vec::new();
    loop {
        let n = match ep.wait(&mut events, WAIT_MS) {
            Ok(n) => n,
            Err(_) => break,
        };
        net.reactor_wakeups.fetch_add(1, Ordering::Relaxed);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        for ev in events.iter().take(n) {
            let (token, flags) = (ev.token(), ev.events());
            match token {
                TOKEN_LISTENER => {
                    accept_all(ep, listener, &mut conns, &mut next_id, net, active)
                }
                TOKEN_WAKER => {} // drained below, once per pass
                id => {
                    if let Some(c) = conns.get_mut(&id) {
                        if flags & (EPOLLERR | EPOLLHUP) != 0 {
                            c.dead = true;
                        } else {
                            if flags & EPOLLOUT != 0 {
                                flush_conn(c);
                            }
                            if flags & (EPOLLIN | EPOLLRDHUP) != 0 {
                                read_conn(api, ready, net, active, id, c);
                            }
                            flush_and_toggle(ep, id, c);
                        }
                    }
                }
            }
        }
        // engine events: copy pending frames/terminals into write
        // buffers. The ring push happens-before the eventfd write, so
        // every notify lands either in this drain or the next wakeup.
        ready_ids.clear();
        let scan_all = ready.drain(&mut ready_ids);
        if scan_all {
            // id ring overflowed: one coalesced pass over everything
            for (id, c) in conns.iter_mut() {
                drain_subs(c, net);
                flush_and_toggle(ep, *id, c);
            }
        } else {
            for id in &ready_ids {
                if let Some(c) = conns.get_mut(id) {
                    drain_subs(c, net);
                    flush_and_toggle(ep, *id, c);
                }
            }
        }
        // reap: cancel whatever a dead connection still had in flight
        // (the engine reclaims its blocks; terminals land in rings we
        // drop here), deregister, forget
        conns.retain(|_, c| {
            if c.dead {
                for s in &c.subs {
                    if !s.done {
                        api.cancel(s.id);
                    }
                }
                let _ = ep.del(c.stream.as_raw_fd());
                active.fetch_sub(1, Ordering::Relaxed);
                false
            } else {
                true
            }
        });
    }
    // stopping: abort every in-flight request so the engine reclaims
    // their sessions, then drop all sockets (clients see EOF)
    for c in conns.values() {
        for s in &c.subs {
            if !s.done {
                api.cancel(s.id);
            }
        }
    }
    active.fetch_sub(conns.len(), Ordering::Relaxed);
}

fn accept_all(
    ep: &Epoll,
    listener: &TcpListener,
    conns: &mut HashMap<u64, Conn>,
    next_id: &mut u64,
    net: &NetStats,
    active: &AtomicUsize,
) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                let id = *next_id;
                *next_id += 1;
                if ep.add(stream.as_raw_fd(), EPOLLIN | EPOLLRDHUP, id).is_err() {
                    continue;
                }
                net.accepted.fetch_add(1, Ordering::Relaxed);
                active.fetch_add(1, Ordering::Relaxed);
                conns.insert(id, Conn::new(stream));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
}

/// Non-blocking read until WouldBlock/EOF, dispatching every complete
/// line as it appears.
fn read_conn<F: Frontend>(
    api: &F,
    ready: &Arc<ReadyQueue>,
    net: &Arc<NetStats>,
    active: &Arc<AtomicUsize>,
    id: u64,
    c: &mut Conn,
) {
    loop {
        let old = c.rbuf.len();
        c.rbuf.resize(old + READ_CHUNK, 0);
        match (&c.stream).read(&mut c.rbuf[old..]) {
            Ok(0) => {
                c.rbuf.truncate(old);
                if !c.rbuf.is_empty() {
                    // EOF with a partial line buffered: reject it with
                    // the same error line as the threaded transport
                    // (flush-then-close), never process it
                    net.truncated_eof.fetch_add(1, Ordering::Relaxed);
                    push_line(
                        c,
                        net,
                        &Json::obj(vec![(
                            "error",
                            Json::Str(server::TRUNCATED_EOF_ERROR.into()),
                        )]),
                    );
                    c.closing = true;
                } else {
                    c.dead = true;
                }
                return;
            }
            Ok(n) => {
                c.rbuf.truncate(old + n);
                process_lines(api, ready, net, active, id, c);
                if c.dead || c.closing {
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                c.rbuf.truncate(old);
                return;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {
                c.rbuf.truncate(old);
            }
            Err(_) => {
                c.rbuf.truncate(old);
                c.dead = true;
                return;
            }
        }
    }
}

/// Pop complete lines off the read buffer. `scanned` remembers how far
/// the newline scan got, so a drip-fed client costs amortized O(bytes),
/// not O(bytes × reads). Enforces the same `MAX_LINE_BYTES` contract as
/// the threaded transport: over-long lines get an error line and a
/// close (the stream cannot be resynced mid-line).
fn process_lines<F: Frontend>(
    api: &F,
    ready: &Arc<ReadyQueue>,
    net: &Arc<NetStats>,
    active: &Arc<AtomicUsize>,
    id: u64,
    c: &mut Conn,
) {
    loop {
        match c.rbuf[c.scanned..].iter().position(|b| *b == b'\n') {
            Some(off) => {
                let end = c.scanned + off;
                if end > MAX_LINE_BYTES {
                    oversized_line(c, net);
                    return;
                }
                let line = String::from_utf8_lossy(&c.rbuf[..end]).into_owned();
                c.rbuf.drain(..=end);
                c.scanned = 0;
                let trimmed = line.trim();
                if !trimmed.is_empty() {
                    net.lines_in.fetch_add(1, Ordering::Relaxed);
                    handle_line(api, ready, net, active, id, c, trimmed);
                }
                if c.dead || c.closing {
                    return;
                }
            }
            None => {
                c.scanned = c.rbuf.len();
                if c.rbuf.len() > MAX_LINE_BYTES {
                    oversized_line(c, net);
                }
                return;
            }
        }
    }
}

fn oversized_line(c: &mut Conn, net: &NetStats) {
    push_line(
        c,
        net,
        &Json::obj(vec![(
            "error",
            Json::Str(format!(
                "request line exceeds the {MAX_LINE_BYTES} byte protocol limit"
            )),
        )]),
    );
    c.closing = true;
}

/// Dispatch one request line. Commands answer inline (the reactor never
/// blocks, so they interleave with streaming frames); generations
/// submit to the engine with this connection's [`NetSink`] and return
/// immediately — replies arrive through the ready queue.
fn handle_line<F: Frontend>(
    api: &F,
    ready: &Arc<ReadyQueue>,
    net: &Arc<NetStats>,
    active: &Arc<AtomicUsize>,
    conn_id: u64,
    c: &mut Conn,
    line: &str,
) {
    let req = match Json::parse(line) {
        Ok(r) => r,
        Err(e) => {
            push_error(c, net, &e);
            return;
        }
    };
    if let Some(cmd) = req.opt("cmd") {
        // drain/adopt are reactor-native: their replies ride this
        // connection's event rings so they serialize FIFO behind every
        // in-flight frame/terminal — command_json cannot provide that
        match cmd.str() {
            Ok("drain") => {
                // a couple of slots is plenty: the drain reply is one
                // line (plus headroom for the refusal path)
                let ring = Arc::new(Spsc::new(8));
                let sink = NetSink::new(conn_id, ring.clone(), ready.clone(), net.clone());
                match api.drain_net(sink) {
                    // id 0 never collides: real request ids start at 1
                    Ok(()) => c.subs.push(Sub { id: 0, ring, done: false }),
                    Err(e) => push_error(c, net, &e),
                }
                return;
            }
            Ok("adopt") => {
                adopt_line(api, ready, net, conn_id, c, req);
                return;
            }
            _ => {}
        }
        let view = NetView { net, conns: active, transport: "reactor" };
        let reply = match server::command_json(&req, api, &view) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![("error", Json::Str(format!("{e:#}")))]),
        };
        push_line(c, net, &reply);
        return;
    }
    let stream = match req.opt("stream").map(|v| v.boolean()).transpose() {
        Ok(s) => s.unwrap_or(false),
        Err(e) => {
            push_error(c, net, &e);
            return;
        }
    };
    // a caller-pinned id (mesh requeues keep the router-assigned id the
    // client's stream is keyed by); None → the frontend assigns one
    let rid = match req.opt("rid").map(|v| v.usize()).transpose() {
        Ok(r) => r.map(|r| r as u64),
        Err(e) => {
            push_error(c, net, &e);
            return;
        }
    };
    let opts = match server::parse_generation(&req) {
        Ok(o) => o,
        Err(e) => {
            push_error(c, net, &e);
            return;
        }
    };
    // sized so max_new frames + the terminal can never shed
    let ring = Arc::new(Spsc::new(NetSink::ring_capacity(opts.max_new)));
    let sink = NetSink::new(conn_id, ring.clone(), ready.clone(), net.clone());
    let opts = if stream {
        SubmitOpts { stream: Some(FrameSink::Net(sink.clone())), ..opts }
    } else {
        opts
    };
    let id = match rid {
        Some(rid) => {
            api.submit_rid(rid, opts, RespSink::Net(sink));
            rid
        }
        None => api.submit_sink(opts, RespSink::Net(sink)),
    };
    c.subs.push(Sub { id, ring, done: false });
}

/// `{"cmd":"adopt"}`: resume a migrated session under its original
/// request id. The session record travels as the `"session"` value in
/// [`crate::mesh`] wire form; frames (when `"stream":true`) resume at
/// index `"streamed"` and the terminal rides the same event ring as a
/// native generation.
fn adopt_line<F: Frontend>(
    api: &F,
    ready: &Arc<ReadyQueue>,
    net: &Arc<NetStats>,
    conn_id: u64,
    c: &mut Conn,
    mut req: Json,
) {
    let parsed = (|| -> anyhow::Result<(u64, usize, usize, bool, u64, Json)> {
        let rid = req.get("rid")?.usize()? as u64;
        let streamed = req.opt("streamed").map(|v| v.usize()).transpose()?.unwrap_or(0);
        let max_new = req.opt("max_new").map(|v| v.usize()).transpose()?.unwrap_or(32);
        let stream = req.opt("stream").map(|v| v.boolean()).transpose()?.unwrap_or(false);
        // the adopted request keeps its original trace id so the
        // migrated half of the timeline stitches onto the first half
        let trace = req.opt("trace").map(|v| v.usize()).transpose()?.unwrap_or(0) as u64;
        let record = match &mut req {
            Json::Obj(m) => m.remove("session"),
            _ => None,
        };
        let record = record.ok_or_else(|| anyhow::anyhow!("adopt: missing \"session\""))?;
        Ok((rid, streamed, max_new, stream, trace, record))
    })();
    let (rid, streamed, max_new, stream, trace, record) = match parsed {
        Ok(p) => p,
        Err(e) => {
            push_error(c, net, &e);
            return;
        }
    };
    let ring = Arc::new(Spsc::new(NetSink::ring_capacity(max_new)));
    let sink = NetSink::new(conn_id, ring.clone(), ready.clone(), net.clone());
    let adopt = crate::coordinator::AdoptNet {
        rid,
        streamed,
        max_new,
        trace,
        record,
        stream: if stream { Some(FrameSink::Net(sink.clone())) } else { None },
        resp: RespSink::Net(sink),
    };
    match api.adopt_net(adopt) {
        Ok(()) => c.subs.push(Sub { id: rid, ring, done: false }),
        Err(e) => push_error(c, net, &e),
    }
}

/// Copy pending engine events (frames, terminals) into the write
/// buffer; retire finished subscriptions; kill the connection if its
/// reader has fallen hopelessly behind.
fn drain_subs(c: &mut Conn, net: &NetStats) {
    if c.dead {
        return;
    }
    for s in c.subs.iter_mut() {
        while let Some(ev) = s.ring.pop() {
            c.wbuf.extend_from_slice(ev.line.as_bytes());
            c.wbuf.push(b'\n');
            net.lines_out.fetch_add(1, Ordering::Relaxed);
            if ev.terminal {
                s.done = true;
            }
        }
    }
    c.subs.retain(|s| !s.done);
    if c.pending_write() > MAX_WBUF_BYTES {
        net.conn_buffer_kills.fetch_add(1, Ordering::Relaxed);
        c.dead = true;
    }
}

fn push_line(c: &mut Conn, net: &NetStats, j: &Json) {
    c.wbuf.extend_from_slice(j.to_string().as_bytes());
    c.wbuf.push(b'\n');
    net.lines_out.fetch_add(1, Ordering::Relaxed);
}

fn push_error(c: &mut Conn, net: &NetStats, e: &anyhow::Error) {
    push_line(c, net, &Json::obj(vec![("error", Json::Str(format!("{e:#}")))]));
}

/// Write until the socket would block. Compacts the consumed prefix
/// lazily so steady streaming doesn't memmove on every flush.
fn flush_conn(c: &mut Conn) {
    while c.wpos < c.wbuf.len() {
        match (&c.stream).write(&c.wbuf[c.wpos..]) {
            Ok(0) => {
                c.dead = true;
                return;
            }
            Ok(n) => c.wpos += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
    if c.wpos == c.wbuf.len() {
        c.wbuf.clear();
        c.wpos = 0;
    } else if c.wpos > (64 << 10) {
        c.wbuf.drain(..c.wpos);
        c.wpos = 0;
    }
}

/// Flush, then reconcile EPOLLOUT registration with whether bytes
/// remain: write interest exists only while the write buffer is
/// non-empty, so an idle fan-out target costs zero wakeups.
fn flush_and_toggle(ep: &Epoll, id: u64, c: &mut Conn) {
    if c.dead {
        return;
    }
    flush_conn(c);
    if c.dead {
        return;
    }
    if c.closing && c.pending_write() == 0 {
        c.dead = true;
        return;
    }
    let want = c.pending_write() > 0;
    if want != c.registered_write {
        let flags = EPOLLIN | EPOLLRDHUP | if want { EPOLLOUT } else { 0 };
        if ep.modify(c.stream.as_raw_fd(), flags, id).is_ok() {
            c.registered_write = want;
        } else {
            c.dead = true;
        }
    }
}
