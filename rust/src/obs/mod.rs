//! Always-on, low-overhead observability: span tracing, a flight
//! recorder, and the per-tick profiler feed.
//!
//! ## Span tracing
//!
//! Every request carries a **trace id** minted at admission
//! ([`next_trace_id`]: `pid << 32 | counter`, unique across the
//! processes of one serving mesh). Instrumented code records
//! fixed-size [`SpanEvent`]s — `(trace, kind, start, duration)` — into
//! a **per-thread ring** ([`TraceRing`]), so the hot path never takes a
//! lock and never allocates: recording a span is a TLS lookup plus a
//! seqlock-guarded slot write. The trace id travels over the line-JSON
//! wire (`"trace"` on submit/adopt lines) to `chai replica` children,
//! so one cross-process request yields ONE stitched timeline — the
//! parent's `frame_write` spans and the child's `queue`/`prefill`/
//! decode spans share the id, including across a SIGKILL requeue (the
//! router's entry registry keeps the id and replays it to the
//! survivor).
//!
//! ## Flight recorder
//!
//! The rings double as a bounded postmortem buffer: a full ring
//! **overwrites the oldest span** (unlike `net::ring`, which sheds the
//! newest — for a crash investigation the most recent history is the
//! valuable part). [`dump_json`] snapshots every registered ring —
//! rings outlive their threads, so an engine thread that already
//! exited still contributes — and emits Chrome trace-event JSON
//! (`chrome://tracing` / Perfetto loadable): complete `"X"` events
//! only, so a torn or dropped span can never leave an unmatched
//! begin/end pair. Timestamps are anchored to the unix epoch
//! ([`unix_anchor_ms`]), so dumps from different processes land on one
//! common clock with no merge-time shifting.
//!
//! ## Per-tick profiler
//!
//! Engine-thread phase code additionally accumulates per-phase wall
//! time into a thread-local tick summary ([`tick_phase_add`]); the
//! scheduler drains it once per tick ([`take_tick_phases`]) into the
//! `obs_*` latency histograms, so `{"cmd":"stats"}` and the
//! `bench_serving --obs` gate can assert *where* tick time goes.
//!
//! ## Overhead contract
//!
//! Tracing is ON by default and must cost ≤2% decode tok/s (enforced
//! by the `bench_serving --obs` CI gate). `--no-obs` is the escape
//! hatch: it clears the process-global [`set_enabled`] flag, and every
//! recording entry point early-outs on that one relaxed atomic load.
//! Token streams are bit-identical either way — obs only ever reads
//! clocks.

use std::cell::{Cell, UnsafeCell};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;
use crate::util::now_ms;

/// Span taxonomy. Fixed small set so events stay `Copy` and the wire
/// names stay stable (DESIGN.md "Observability").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// submit → admission (scheduler pending queue wait)
    Queue,
    /// probe + cluster + prefill of one request
    Prefill,
    /// one fused scheduler/engine decode tick (trace 0: per tick, not
    /// per request)
    DecodeTick,
    /// relay phase P: shared-prefix attention, once per group
    RelayP,
    /// relay phase S: per-row private-suffix attention + LSE merge
    RelayS,
    /// the fused `decode_paged` backend call of one tick
    Fused,
    /// preemption swap-out (freeze) of one session
    SwapOut,
    /// resume thaw (swap restore or recompute) of one session
    SwapIn,
    /// delivery of one request's newly decoded frames to its sink
    FrameWrite,
    /// one worker-pool kernel task (trace 0)
    PoolTask,
}

impl SpanKind {
    pub const COUNT: usize = 10;

    pub const ALL: [SpanKind; SpanKind::COUNT] = [
        SpanKind::Queue,
        SpanKind::Prefill,
        SpanKind::DecodeTick,
        SpanKind::RelayP,
        SpanKind::RelayS,
        SpanKind::Fused,
        SpanKind::SwapOut,
        SpanKind::SwapIn,
        SpanKind::FrameWrite,
        SpanKind::PoolTask,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Queue => "queue",
            SpanKind::Prefill => "prefill",
            SpanKind::DecodeTick => "decode_tick",
            SpanKind::RelayP => "relay_p",
            SpanKind::RelayS => "relay_s",
            SpanKind::Fused => "fused",
            SpanKind::SwapOut => "swap_out",
            SpanKind::SwapIn => "swap_in",
            SpanKind::FrameWrite => "frame_write",
            SpanKind::PoolTask => "pool_task",
        }
    }
}

/// One recorded span: fixed-size and `Copy`, so a ring slot write is a
/// handful of stores and a snapshot can read slots without ownership.
/// `start_ms` is [`now_ms`] (process-monotonic); [`dump_json`] rebases
/// onto the unix anchor at export time.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SpanEvent {
    pub trace: u64,
    pub kind: u8,
    pub start_ms: f64,
    pub dur_ms: f64,
}

/// Pad to a cache line (same idiom as `net::ring`): the producer's
/// cursor must not false-share with whatever the allocator packed next
/// to it.
#[repr(align(64))]
struct CachePadded<T>(T);

struct Slot {
    /// seqlock: 0 = never written, odd = write in progress, even>0 =
    /// committed (value encodes the generation, so a reader catches a
    /// wrap-around overwrite between its two loads)
    seq: AtomicUsize,
    val: UnsafeCell<SpanEvent>,
}

/// Single-producer flight-recorder ring: bounded, lock-free, and —
/// unlike the shed-on-full `net::ring` queues — **overwriting**: a full
/// ring drops the OLDEST span, because the recorder's job is to hold
/// the most recent history at a crash. Readers ([`TraceRing::snapshot`])
/// run concurrently with the producer and skip torn slots via the
/// per-slot seqlock instead of blocking it.
pub struct TraceRing {
    slots: Box<[Slot]>,
    mask: usize,
    /// monotonic write cursor (single producer; readers only load)
    cursor: CachePadded<AtomicUsize>,
}

unsafe impl Send for TraceRing {}
unsafe impl Sync for TraceRing {}

/// Per-thread recorder capacity. 8192 × 32-byte spans = 256 KiB per
/// recording thread — hours of steady-state serving history per ring
/// at the span rates the taxonomy produces.
pub const RING_CAPACITY: usize = 8192;

impl TraceRing {
    /// `capacity` rounds up to a power of two (min 2).
    pub fn new(capacity: usize) -> TraceRing {
        let cap = capacity.next_power_of_two().max(2);
        let slots: Vec<Slot> = (0..cap)
            .map(|_| Slot { seq: AtomicUsize::new(0), val: UnsafeCell::new(SpanEvent::default()) })
            .collect();
        TraceRing {
            slots: slots.into_boxed_slice(),
            mask: cap - 1,
            cursor: CachePadded(AtomicUsize::new(0)),
        }
    }

    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Record one span (single producer). Never blocks, never fails:
    /// past capacity the oldest span is overwritten.
    pub fn push(&self, ev: SpanEvent) {
        let pos = self.cursor.0.load(Ordering::Relaxed);
        let slot = &self.slots[pos & self.mask];
        // odd = mid-write: a concurrent snapshot skips this slot
        slot.seq.store(2 * pos + 1, Ordering::Relaxed);
        // the odd marker must be visible before the value changes
        std::sync::atomic::fence(Ordering::Release);
        unsafe {
            *slot.val.get() = ev;
        }
        slot.seq.store(2 * (pos + 1), Ordering::Release);
        self.cursor.0.store(pos + 1, Ordering::Release);
    }

    /// Spans recorded over this ring's lifetime (including overwritten
    /// ones).
    pub fn recorded(&self) -> usize {
        self.cursor.0.load(Ordering::Relaxed)
    }

    /// Spans lost to overwrite so far (oldest-first, by construction).
    pub fn overwritten(&self) -> usize {
        self.recorded().saturating_sub(self.capacity())
    }

    /// Snapshot the retained spans, oldest first. Concurrent with the
    /// producer: a slot that is mid-write — or overwritten between the
    /// seqlock's two loads — is skipped, never returned torn. The ring
    /// is not consumed; repeated snapshots are idempotent.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let end = self.cursor.0.load(Ordering::Acquire);
        let start = end.saturating_sub(self.capacity());
        let mut out = Vec::with_capacity(end - start);
        for pos in start..end {
            let slot = &self.slots[pos & self.mask];
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 != 2 * (pos + 1) {
                continue; // torn, overwritten, or never committed
            }
            let ev = unsafe { *slot.val.get() };
            // pairs with the Release fence in push: if the slot was
            // re-entered since the first load, the value may be torn
            std::sync::atomic::fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) != s1 {
                continue;
            }
            out.push(ev);
        }
        out
    }
}

/// Process-global enable flag (`--no-obs` clears it). Relaxed loads on
/// the hot path: a toggle only has to become visible eventually, and
/// recording itself is side-effect-free.
static ENABLED: AtomicBool = AtomicBool::new(true);

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// All rings ever created in this process, in creation order (the
/// dump's `tid`). Rings are `Arc`'d out of the registry so a thread's
/// history survives its exit — postmortems outlive their threads.
fn registry() -> &'static Mutex<Vec<Arc<TraceRing>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<TraceRing>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static TLS_RING: std::cell::OnceCell<Arc<TraceRing>> = const { std::cell::OnceCell::new() };
    /// per-thread tick-phase accumulator (engine threads): total ms and
    /// event count per span kind since the last `take_tick_phases`
    static TICK_MS: Cell<[f64; SpanKind::COUNT]> = const { Cell::new([0.0; SpanKind::COUNT]) };
}

fn with_ring<R>(f: impl FnOnce(&TraceRing) -> R) -> R {
    TLS_RING.with(|cell| {
        let ring = cell.get_or_init(|| {
            let ring = Arc::new(TraceRing::new(RING_CAPACITY));
            registry().lock().unwrap().push(ring.clone());
            ring
        });
        f(ring)
    })
}

/// Mint a trace id: `(pid & 0xfffff) << 32 | counter`, so ids stay
/// unique across every process of one serving mesh without
/// coordination. The pid is masked to 20 bits and the counter wraps at
/// 32 so the id stays below 2^53 — it travels as a JSON number (f64)
/// on the wire and in trace dumps, and must survive that round-trip
/// exactly. Never 0 — 0 on the wire and in [`SpanEvent::trace`] means
/// "no request attribution" (per-tick spans).
pub fn next_trace_id() -> u64 {
    static CTR: AtomicU64 = AtomicU64::new(0);
    let n = (CTR.fetch_add(1, Ordering::Relaxed) + 1) & 0xffff_ffff;
    ((std::process::id() as u64 & 0xf_ffff) << 32) | n
}

/// Record one span into this thread's flight-recorder ring.
/// `start_ms`/`end_ms` are [`now_ms`] readings. No-op when disabled.
pub fn record(trace: u64, kind: SpanKind, start_ms: f64, end_ms: f64) {
    if !enabled() {
        return;
    }
    with_ring(|r| {
        r.push(SpanEvent {
            trace,
            kind: kind as u8,
            start_ms,
            dur_ms: (end_ms - start_ms).max(0.0),
        })
    });
}

/// Accumulate `ms` of phase time into this thread's tick summary (the
/// per-tick profiler feed). No-op when disabled.
pub fn tick_phase_add(kind: SpanKind, ms: f64) {
    if !enabled() {
        return;
    }
    TICK_MS.with(|c| {
        let mut a = c.get();
        a[kind as usize] += ms;
        c.set(a);
    });
}

/// Drain this thread's tick summary: `(kind, total_ms)` for every
/// phase that accrued time since the last call, then reset. The
/// scheduler calls this once per tick and feeds `obs_<kind>_ms`
/// histograms.
pub fn take_tick_phases() -> Vec<(SpanKind, f64)> {
    TICK_MS.with(|c| {
        let a = c.replace([0.0; SpanKind::COUNT]);
        SpanKind::ALL
            .iter()
            .filter(|k| a[**k as usize] > 0.0)
            .map(|k| (*k, a[*k as usize]))
            .collect()
    })
}

/// Offset that rebases [`now_ms`] readings onto the unix epoch:
/// `unix_ms = unix_anchor_ms() + now_ms_reading`. Captured once per
/// process; parent and children each anchor their own monotonic clock
/// to the shared wall clock, which is what lets their dumps stitch
/// without any merge-time shifting.
pub fn unix_anchor_ms() -> f64 {
    static ANCHOR: OnceLock<f64> = OnceLock::new();
    *ANCHOR.get_or_init(|| {
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs_f64() * 1e3)
            .unwrap_or(0.0);
        unix - now_ms()
    })
}

/// Snapshot every ring in this process as Chrome trace-event JSON:
/// `{"traceEvents": [...], "pid": N, "spans_dropped": M}`. Events are
/// complete (`"ph":"X"`) with µs timestamps on the unix epoch; `tid`
/// is the ring's registration index and `args.trace` carries the
/// request attribution. Idempotent — the recorder is not consumed.
pub fn dump_json() -> Json {
    let anchor = unix_anchor_ms();
    let pid = std::process::id() as f64;
    let rings: Vec<Arc<TraceRing>> = registry().lock().unwrap().clone();
    let mut events = Vec::new();
    let mut dropped = 0usize;
    for (tid, ring) in rings.iter().enumerate() {
        dropped += ring.overwritten();
        for ev in ring.snapshot() {
            events.push(Json::obj(vec![
                ("name", Json::Str(SpanKind::ALL[ev.kind as usize].as_str().into())),
                ("cat", Json::Str("obs".into())),
                ("ph", Json::Str("X".into())),
                ("ts", Json::Num((anchor + ev.start_ms) * 1e3)),
                ("dur", Json::Num(ev.dur_ms * 1e3)),
                ("pid", Json::Num(pid)),
                ("tid", Json::Num(tid as f64)),
                ("args", Json::obj(vec![("trace", Json::Num(ev.trace as f64))])),
            ]));
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("pid", Json::Num(pid)),
        ("spans_dropped", Json::Num(dropped as f64)),
    ])
}

/// Merge trace dumps from other processes into `base` (concatenating
/// `traceEvents` and summing `spans_dropped`) — the router stitches
/// its own dump with each `chai replica` child's `{"cmd":"trace"}`
/// reply. Events already share the unix-epoch clock, so a merge is a
/// plain concatenation.
pub fn merge_dumps(base: Json, others: impl IntoIterator<Item = Json>) -> Json {
    let mut events = match base.opt("traceEvents").and_then(|v| v.arr().ok()) {
        Some(a) => a.to_vec(),
        None => Vec::new(),
    };
    let mut dropped = base.opt("spans_dropped").and_then(|v| v.num().ok()).unwrap_or(0.0);
    let pid = base.opt("pid").and_then(|v| v.num().ok()).unwrap_or(0.0);
    for o in others {
        if let Some(a) = o.opt("traceEvents").and_then(|v| v.arr().ok()) {
            events.extend(a.iter().cloned());
        }
        dropped += o.opt("spans_dropped").and_then(|v| v.num().ok()).unwrap_or(0.0);
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("pid", Json::Num(pid)),
        ("spans_dropped", Json::Num(dropped)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overflow_drops_oldest_not_newest() {
        let r = TraceRing::new(8);
        assert_eq!(r.capacity(), 8);
        for i in 0..20u64 {
            r.push(SpanEvent { trace: i, kind: 0, start_ms: i as f64, dur_ms: 1.0 });
        }
        assert_eq!(r.recorded(), 20);
        assert_eq!(r.overwritten(), 12);
        let got: Vec<u64> = r.snapshot().iter().map(|e| e.trace).collect();
        assert_eq!(got, (12..20).collect::<Vec<_>>(), "newest 8 retained, oldest dropped");
    }

    #[test]
    fn snapshot_is_idempotent_and_ordered() {
        let r = TraceRing::new(16);
        for i in 0..5u64 {
            r.push(SpanEvent { trace: 100 + i, kind: 1, start_ms: i as f64, dur_ms: 0.5 });
        }
        let a = r.snapshot();
        let b = r.snapshot();
        assert_eq!(a, b, "snapshot must not consume the recorder");
        assert_eq!(a.len(), 5);
        assert!(a.windows(2).all(|w| w[0].start_ms <= w[1].start_ms));
    }

    #[test]
    fn snapshot_races_with_producer_without_torn_reads() {
        let r = Arc::new(TraceRing::new(64));
        let stop = Arc::new(AtomicBool::new(false));
        let producer = {
            let (r, stop) = (r.clone(), stop.clone());
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    // trace doubles as a checksum of the payload
                    r.push(SpanEvent { trace: i, kind: 2, start_ms: i as f64, dur_ms: i as f64 });
                    i += 1;
                }
            })
        };
        for _ in 0..200 {
            for ev in r.snapshot() {
                assert_eq!(ev.start_ms, ev.trace as f64, "torn slot leaked");
                assert_eq!(ev.dur_ms, ev.trace as f64, "torn slot leaked");
            }
        }
        stop.store(true, Ordering::Relaxed);
        producer.join().unwrap();
    }

    #[test]
    fn trace_ids_are_unique_and_pid_prefixed() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, b);
        assert_ne!(a, 0);
        assert_eq!(a >> 32, std::process::id() as u64 & 0xf_ffff);
        assert!(a < (1u64 << 53), "trace ids must survive a JSON f64 round-trip");
        assert_eq!(a >> 32, b >> 32);
    }

    #[test]
    fn tick_phases_accumulate_and_reset() {
        // serialized against nothing: TICK_MS is thread-local
        let _ = take_tick_phases();
        tick_phase_add(SpanKind::Fused, 1.5);
        tick_phase_add(SpanKind::Fused, 0.5);
        tick_phase_add(SpanKind::RelayP, 2.0);
        let got = take_tick_phases();
        assert_eq!(
            got,
            vec![(SpanKind::RelayP, 2.0), (SpanKind::Fused, 2.0)],
            "per-kind totals in taxonomy order"
        );
        assert!(take_tick_phases().is_empty(), "drain must reset");
    }

    #[test]
    fn dump_merges_across_processes_by_concatenation() {
        let a = Json::obj(vec![
            (
                "traceEvents",
                Json::Arr(vec![Json::obj(vec![("name", Json::Str("queue".into()))])]),
            ),
            ("spans_dropped", Json::Num(1.0)),
        ]);
        let b = Json::obj(vec![
            (
                "traceEvents",
                Json::Arr(vec![Json::obj(vec![("name", Json::Str("fused".into()))])]),
            ),
            ("spans_dropped", Json::Num(2.0)),
        ]);
        let m = merge_dumps(a, vec![b]);
        assert_eq!(m.get("traceEvents").unwrap().arr().unwrap().len(), 2);
        assert_eq!(m.get("spans_dropped").unwrap().num().unwrap(), 3.0);
    }
}
