//! Model-side helpers on the rust path: the byte-level tokenizer (mirror of
//! `python/compile/tokenizer.py`, cross-checked against the shared fixture)
//! and analytic FLOP accounting per attention variant (Figure 1's x-axis).

pub mod tokenizer {
    pub const BOS: i32 = 256;
    pub const EOS: i32 = 257;
    pub const PAD: i32 = 258;
    pub const SEP: i32 = 259;
    pub const VOCAB_SIZE: usize = 260;

    pub fn encode(text: &str, bos: bool, eos: bool) -> Vec<i32> {
        let mut ids = Vec::with_capacity(text.len() + 2);
        if bos {
            ids.push(BOS);
        }
        ids.extend(text.bytes().map(|b| b as i32));
        if eos {
            ids.push(EOS);
        }
        ids
    }

    pub fn decode(ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids.iter().filter(|&&i| (0..256).contains(&i)).map(|&i| i as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// Analytic FLOP accounting (fused-multiply-add = 2 flops) per attention
/// variant, for a full forward over `t` tokens. This regenerates the
/// x-axis of Figure 1 / Figure 14.
pub mod flops {
    use crate::config::Manifest;

    /// FLOPs of everything except the attention-score path (projections,
    /// MLP, lm head) — identical across variants except the Q/K gather.
    fn common(m: &Manifest, t: usize, qk_heads: &[usize]) -> f64 {
        let c = &m.model;
        let (d, hd, f, v) = (
            c.d_model as f64,
            (c.n_heads * c.head_dim) as f64,
            c.d_ff as f64,
            c.vocab_size as f64,
        );
        let t = t as f64;
        let mut fl = 0.0;
        for &kh in qk_heads {
            let qk_dim = (kh * c.head_dim) as f64;
            // q,k projections only for surviving heads; v,o full
            fl += 2.0 * t * d * qk_dim * 2.0; // wq, wk
            fl += 2.0 * t * d * hd; // wv
            fl += 2.0 * t * hd * d; // wo
            fl += 3.0 * 2.0 * t * d * f; // swiglu
        }
        fl += 2.0 * t * d * v; // lm head
        fl
    }

    /// Attention-score + AV FLOPs with `score_heads[l]` score computations
    /// and `av_heads[l]` A·V computations per layer.
    fn attn(m: &Manifest, t: usize, score_heads: &[usize], av_heads: &[usize]) -> f64 {
        let dh = m.model.head_dim as f64;
        let t = t as f64;
        let mut fl = 0.0;
        for (&sh, &ah) in score_heads.iter().zip(av_heads) {
            fl += 2.0 * sh as f64 * t * t * dh; // QK^T
            fl += 2.0 * ah as f64 * t * t * dh; // A·V
        }
        fl
    }

    /// MHA forward FLOPs over `t` tokens.
    pub fn mha(m: &Manifest, t: usize) -> f64 {
        let h = vec![m.model.n_heads; m.model.n_layers];
        common(m, t, &h) + attn(m, t, &h, &h)
    }

    /// CHAI: scores once per cluster; A·V per head (V kept).
    pub fn chai(m: &Manifest, t: usize, k_list: &[usize]) -> f64 {
        let h = vec![m.model.n_heads; m.model.n_layers];
        common(m, t, k_list) + attn(m, t, k_list, &h)
    }

    /// DejaVu at `n_keep` heads/layer: whole heads removed.
    pub fn dejavu(m: &Manifest, t: usize, n_keep: usize) -> f64 {
        let h = vec![n_keep; m.model.n_layers];
        common(m, t, &h) + attn(m, t, &h, &h)
    }

    /// Relative FLOPs vs MHA (the paper reports CHAI at ~0.75× for
    /// LLaMA-7B-scale models).
    pub fn ratio_vs_mha(m: &Manifest, t: usize, fl: f64) -> f64 {
        fl / mha(m, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn tokenizer_matches_python_fixture() {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tokenizer_fixture.json");
        if !p.exists() {
            return;
        }
        let j = crate::util::json::Json::parse_file(&p).unwrap();
        assert_eq!(j.get("bos").unwrap().int().unwrap(), tokenizer::BOS as i64);
        assert_eq!(j.get("vocab").unwrap().usize().unwrap(), tokenizer::VOCAB_SIZE);
        for case in j.get("cases").unwrap().arr().unwrap() {
            let text = case.get("text").unwrap().str().unwrap();
            let ids: Vec<i32> = case
                .get("ids")
                .unwrap()
                .arr()
                .unwrap()
                .iter()
                .map(|v| v.int().unwrap() as i32)
                .collect();
            assert_eq!(tokenizer::encode(text, true, false), ids, "text {text:?}");
            assert_eq!(tokenizer::decode(&ids), text);
        }
    }

    #[test]
    fn tokenizer_roundtrip() {
        let t = "the color of tom is red .";
        let ids = tokenizer::encode(t, true, true);
        assert_eq!(ids[0], tokenizer::BOS);
        assert_eq!(*ids.last().unwrap(), tokenizer::EOS);
        assert_eq!(tokenizer::decode(&ids), t);
    }

    #[test]
    fn flops_ordering() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = crate::config::Manifest::load(&dir).unwrap();
        let t = 512;
        let mha = flops::mha(&m, t);
        let chai = flops::chai(&m, t, &m.k_list);
        let dv50 = flops::dejavu(&m, t, m.model.n_heads / 2);
        assert!(chai < mha, "chai {chai} !< mha {mha}");
        assert!(dv50 < mha);
        // CHAI with k=H degenerates to MHA
        let kfull = vec![m.model.n_heads; m.model.n_layers];
        assert!((flops::chai(&m, t, &kfull) - mha).abs() / mha < 1e-9);
        assert!(flops::ratio_vs_mha(&m, t, chai) < 1.0);
    }
}
