//! Mesh wire codec: bit-exact JSON serialization of migrated sessions.
//!
//! The replica mesh moves live work between `chai replica` processes as
//! single line-JSON records (the same framing every other protocol
//! command uses — see `crate::server`). The payload is an
//! [`Engine::export_frozen`] [`MigratedSession`]: tokens, generation
//! budget, the CHAI cluster assignment, timing, and the compact
//! per-panel K,V serialization the swap tier produces
//! ([`SwappedSeq`]).
//!
//! **Bit-exactness.** Resume on the target must be bit-identical to
//! resume on the source, so f32 K,V rows cross the wire as their `u32`
//! bit patterns — every `u32` is exactly representable as an f64, and
//! the JSON serializer prints integer-valued numbers through `i64`
//! formatting, so the round trip is lossless by construction (floats
//! printed as decimals would not be, and NaN payloads would not even
//! parse). Timing floats use the serializer's shortest-roundtrip `f64`
//! path, which is also exact.
//!
//! **Layout is NOT serialized.** A [`SwappedSeq`] embeds the source's
//! [`KvLayout`]; on the wire only the variant name travels, and the
//! decoder rebuilds the layout from the TARGET engine's manifest
//! (`KvLayout::from_manifest(manifest, variant.cache_kind())`). The
//! mesh requires identical manifests across replicas anyway (same
//! model, same clustering artifacts), and deriving locally means a
//! mismatched fleet fails loudly at the data-length check below instead
//! of scribbling rows into a wrong-shaped slab.
//!
//! Blocks pinned in the source's hot tier at freeze time serialize as
//! `null` placeholders; the target's `restore_swapped` sees the hole,
//! truncates the bit-exact leading prefix there, and recomputes the
//! suffix through the deterministic prefill path — still bit-identical
//! (greedy decode), just more FLOPs.

use anyhow::{bail, Context, Result};

use crate::config::Manifest;
use crate::engine::{MigratedSession, Timing, Variant};
use crate::kv::paged::{KvLayout, SwappedBlock, SwappedSeq};
use crate::runtime::ClusterAssignment;
use crate::util::json::Json;

/// Serialize a migrated session to its wire object (one line once
/// `to_string`'d by the caller).
pub fn encode_migrated(m: &MigratedSession) -> Json {
    let tokens = Json::Arr(m.tokens.iter().map(|&t| Json::Num(t as f64)).collect());
    let clusters = match &m.clusters {
        None => Json::Null,
        Some(c) => Json::obj(vec![
            (
                "membership",
                Json::Arr(c.membership.iter().map(|v| Json::from_usizes(v)).collect()),
            ),
            ("reps", Json::Arr(c.reps.iter().map(|v| Json::from_usizes(v)).collect())),
        ]),
    };
    let timing = Json::obj(vec![
        ("probe_ms", Json::Num(m.timing.probe_ms)),
        ("cluster_ms", Json::Num(m.timing.cluster_ms)),
        ("prefill_ms", Json::Num(m.timing.prefill_ms)),
        ("ttft_ms", Json::Num(m.timing.ttft_ms)),
        ("decode_ms", Json::from_f64s(&m.timing.decode_ms)),
    ]);
    let kv = match &m.kv {
        None => Json::Null,
        Some(seq) => {
            let blocks = seq
                .blocks
                .iter()
                .map(|b| match b {
                    None => Json::Null,
                    Some(b) => Json::obj(vec![
                        ("filled", Json::Num(b.filled as f64)),
                        (
                            "data",
                            Json::Arr(
                                b.data
                                    .iter()
                                    .map(|f| Json::Num(f.to_bits() as f64))
                                    .collect(),
                            ),
                        ),
                    ]),
                })
                .collect();
            Json::obj(vec![
                ("block_size", Json::Num(seq.block_size as f64)),
                ("len", Json::Num(seq.len as f64)),
                ("blocks", Json::Arr(blocks)),
            ])
        }
    };
    Json::obj(vec![
        ("variant", Json::Str(m.variant.name())),
        ("tokens", tokens),
        ("prompt_len", Json::Num(m.prompt_len as f64)),
        ("max_new", Json::Num(m.max_new as f64)),
        ("bucket", Json::Num(m.bucket as f64)),
        ("clusters", clusters),
        ("timing", timing),
        ("kv", kv),
    ])
}

fn decode_timing(j: &Json) -> Result<Timing> {
    Ok(Timing {
        probe_ms: j.get("probe_ms")?.num()?,
        cluster_ms: j.get("cluster_ms")?.num()?,
        prefill_ms: j.get("prefill_ms")?.num()?,
        decode_ms: j.get("decode_ms")?.f64_vec()?,
        ttft_ms: j.get("ttft_ms")?.num()?,
    })
}

fn decode_f32_bits(j: &Json) -> Result<f32> {
    let n = j.num()?;
    if n < 0.0 || n > u32::MAX as f64 || n.fract() != 0.0 {
        bail!("kv data value {n} is not a u32 bit pattern");
    }
    Ok(f32::from_bits(n as u32))
}

fn decode_kv(j: &Json, layout: &KvLayout) -> Result<SwappedSeq> {
    let block_size = j.get("block_size")?.usize()?;
    let len = j.get("len")?.usize()?;
    if block_size == 0 {
        bail!("kv record has block_size 0");
    }
    let mut blocks: Vec<Option<SwappedBlock>> = Vec::new();
    for (i, b) in j.get("blocks")?.arr()?.iter().enumerate() {
        if matches!(b, Json::Null) {
            blocks.push(None);
            continue;
        }
        let filled = b.get("filled")?.usize()?;
        if filled == 0 || filled > block_size {
            bail!("kv block {i}: filled {filled} outside 1..={block_size}");
        }
        let raw = b.get("data")?.arr()?;
        // the capture format is `floats_per_token * filled` rows; a
        // mismatch means the fleet's manifests disagree — refuse rather
        // than restore into a wrong-shaped slab
        let want = layout.floats_per_token() * filled;
        if raw.len() != want {
            bail!(
                "kv block {i}: {} floats on the wire, layout expects {want} \
                 (mismatched replica manifests?)",
                raw.len()
            );
        }
        let mut data = Vec::with_capacity(raw.len());
        for v in raw {
            data.push(decode_f32_bits(v).with_context(|| format!("kv block {i}"))?);
        }
        blocks.push(Some(SwappedBlock { filled, data }));
    }
    if blocks.len() != (len + block_size - 1) / block_size {
        bail!(
            "kv record covers len {len} with {} blocks (block_size {block_size})",
            blocks.len()
        );
    }
    // accounting size recomputed locally, identically to how the source
    // tier charged it (sum of serialized block payloads)
    let bytes = blocks.iter().flatten().map(|b| b.bytes()).sum();
    Ok(SwappedSeq { layout: layout.clone(), block_size, len, blocks, bytes })
}

/// Parse a wire record back into a [`MigratedSession`], rebuilding the
/// K,V layout from the TARGET's `manifest` (see module docs). Runs on
/// the adopting engine's thread.
pub fn decode_migrated(j: &Json, manifest: &Manifest) -> Result<MigratedSession> {
    let variant = Variant::parse(j.get("variant")?.str()?)?;
    let tokens: Vec<i32> = j
        .get("tokens")?
        .arr()?
        .iter()
        .map(|t| t.int().map(|v| v as i32))
        .collect::<Result<_>>()?;
    let prompt_len = j.get("prompt_len")?.usize()?;
    if prompt_len > tokens.len() {
        bail!("prompt_len {prompt_len} exceeds {} tokens", tokens.len());
    }
    let clusters = match j.get("clusters")? {
        Json::Null => None,
        c => {
            let membership: Vec<Vec<usize>> = c
                .get("membership")?
                .arr()?
                .iter()
                .map(|v| v.usize_vec())
                .collect::<Result<_>>()?;
            let reps: Vec<Vec<usize>> =
                c.get("reps")?.arr()?.iter().map(|v| v.usize_vec()).collect::<Result<_>>()?;
            Some(ClusterAssignment { membership, reps })
        }
    };
    let layout = KvLayout::from_manifest(manifest, variant.cache_kind());
    let kv = match j.get("kv")? {
        Json::Null => None,
        k => Some(decode_kv(k, &layout).context("kv payload")?),
    };
    if let Some(seq) = &kv {
        if seq.len > tokens.len() {
            bail!("kv record covers {} positions but only {} tokens", seq.len, tokens.len());
        }
    }
    Ok(MigratedSession {
        variant,
        tokens,
        prompt_len,
        max_new: j.get("max_new")?.usize()?,
        bucket: j.get("bucket")?.usize()?,
        clusters,
        timing: decode_timing(j.get("timing")?)?,
        kv,
    })
}

// ---------------------------------------------------------------------------
// Drain protocol records
// ---------------------------------------------------------------------------

/// One entry of a `{"cmd":"drain"}` reply: a request the replica gave
/// back. `session: None` means the request never started decoding (or
/// could not be frozen) — the parent resubmits it from its own copy of
/// the prompt; `Some` carries the encoded [`MigratedSession`] for
/// bit-deterministic resume elsewhere. `streamed` is the replica's
/// frame count at drain time — informational; the parent's own
/// forwarded-frame counter is authoritative for dedup.
#[derive(Debug)]
pub struct DrainRecord {
    pub rid: u64,
    pub streamed: usize,
    pub session: Option<Json>,
}

impl DrainRecord {
    pub fn parse(j: &Json) -> Result<DrainRecord> {
        Ok(DrainRecord {
            rid: j.get("rid")?.usize()? as u64,
            streamed: j.opt("streamed").map(|v| v.usize()).transpose()?.unwrap_or(0),
            session: match j.opt("session") {
                None | Some(Json::Null) => None,
                Some(s) => Some(s.clone()),
            },
        })
    }
}

/// Build one drain-reply record (see [`DrainRecord`]).
pub fn drain_record(rid: u64, streamed: usize, session: Option<Json>) -> Json {
    Json::obj(vec![
        ("rid", Json::Num(rid as f64)),
        ("streamed", Json::Num(streamed as f64)),
        ("session", session.unwrap_or(Json::Null)),
    ])
}

/// The full `{"cmd":"drain"}` reply line: every held request, encoded.
pub fn drain_reply(records: Vec<Json>) -> Json {
    Json::obj(vec![("drained", Json::Arr(records))])
}

/// Parse a drain reply into its records.
pub fn parse_drain_reply(j: &Json) -> Result<Vec<DrainRecord>> {
    j.get("drained")?.arr()?.iter().map(DrainRecord::parse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{reference::RefBackend, Backend};
    use crate::util::rng::Rng;

    fn toy_manifest() -> Manifest {
        RefBackend::toy(0).manifest().clone()
    }

    fn sample_session(with_kv: bool, with_holes: bool) -> MigratedSession {
        let m = toy_manifest();
        let variant = Variant::Chai;
        let layout = KvLayout::from_manifest(&m, variant.cache_kind());
        let block_size = 16usize;
        let len = 40usize; // 2 full blocks + 8 rows
        let mut rng = Rng::new(0x5eed);
        let mut blocks: Vec<Option<SwappedBlock>> = Vec::new();
        for bi in 0..(len + block_size - 1) / block_size {
            if with_holes && bi == 1 {
                blocks.push(None); // pinned at freeze time
                continue;
            }
            let filled = (len - bi * block_size).min(block_size);
            // varied finite values with negatives and long mantissas —
            // everything attention math actually produces
            let data: Vec<f32> = (0..layout.floats_per_token() * filled)
                .map(|_| (rng.next_u64() as u32) as f32 * 1.1920929e-7 - 256.0)
                .collect();
            blocks.push(Some(SwappedBlock { filled, data }));
        }
        let bytes = blocks.iter().flatten().map(|b| b.bytes()).sum();
        let kv = with_kv.then(|| SwappedSeq { layout, block_size, len, blocks, bytes });
        MigratedSession {
            variant,
            tokens: (0..41).map(|t| t as i32).collect(),
            prompt_len: 17,
            max_new: 64,
            bucket: 128,
            clusters: Some(ClusterAssignment {
                membership: vec![vec![0, 0, 1, 1], vec![1, 0, 1, 0]],
                reps: vec![vec![0, 2], vec![1, 0]],
            }),
            timing: Timing {
                probe_ms: 1.25,
                cluster_ms: 0.5,
                prefill_ms: 3.75,
                decode_ms: vec![0.125, 0.25, 0.0625],
                ttft_ms: 4.0,
            },
            kv: None,
        }
        .with_kv(kv)
    }

    trait WithKv {
        fn with_kv(self, kv: Option<SwappedSeq>) -> MigratedSession;
    }
    impl WithKv for MigratedSession {
        fn with_kv(mut self, kv: Option<SwappedSeq>) -> MigratedSession {
            self.kv = kv;
            self
        }
    }

    /// The acceptance contract: encode → line string → parse → decode
    /// reproduces every f32 bit pattern, token, and cluster exactly.
    #[test]
    fn roundtrip_is_bit_exact() {
        let m = toy_manifest();
        for (with_kv, with_holes) in [(true, false), (true, true), (false, false)] {
            let orig = sample_session(with_kv, with_holes);
            let line = encode_migrated(&orig).to_string();
            let back = decode_migrated(&Json::parse(&line).unwrap(), &m).unwrap();
            assert_eq!(back.variant, orig.variant);
            assert_eq!(back.tokens, orig.tokens);
            assert_eq!(back.prompt_len, orig.prompt_len);
            assert_eq!(back.max_new, orig.max_new);
            assert_eq!(back.bucket, orig.bucket);
            let (bc, oc) = (back.clusters.unwrap(), orig.clusters.unwrap());
            assert_eq!(bc.membership, oc.membership);
            assert_eq!(bc.reps, oc.reps);
            assert_eq!(back.timing.decode_ms, orig.timing.decode_ms);
            assert_eq!(back.timing.ttft_ms, orig.timing.ttft_ms);
            match (&back.kv, &orig.kv) {
                (None, None) => {}
                (Some(b), Some(o)) => {
                    assert_eq!(b.block_size, o.block_size);
                    assert_eq!(b.len, o.len);
                    assert_eq!(b.bytes, o.bytes, "accounting must be recomputed identically");
                    assert_eq!(b.layout, o.layout, "layout rebuilt from the manifest");
                    assert_eq!(b.blocks.len(), o.blocks.len());
                    for (bb, ob) in b.blocks.iter().zip(&o.blocks) {
                        match (bb, ob) {
                            (None, None) => {}
                            (Some(bb), Some(ob)) => {
                                assert_eq!(bb.filled, ob.filled);
                                let bits: Vec<u32> =
                                    bb.data.iter().map(|f| f.to_bits()).collect();
                                let obits: Vec<u32> =
                                    ob.data.iter().map(|f| f.to_bits()).collect();
                                assert_eq!(bits, obits, "f32 rows must round-trip bit-exactly");
                            }
                            _ => panic!("hole placement must survive the round trip"),
                        }
                    }
                }
                _ => panic!("kv presence must survive the round trip"),
            }
        }
    }

    /// Corrupted records fail loudly instead of restoring garbage.
    #[test]
    fn decode_rejects_malformed_records() {
        let m = toy_manifest();
        let good = encode_migrated(&sample_session(true, false));

        // truncated kv data (wrong row count for the layout)
        let mut j = good.clone();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Obj(kv)) = o.get_mut("kv") {
                if let Some(Json::Arr(blocks)) = kv.get_mut("blocks") {
                    if let Some(Json::Obj(b0)) = blocks.get_mut(0) {
                        if let Some(Json::Arr(data)) = b0.get_mut("data") {
                            data.pop();
                        }
                    }
                }
            }
        }
        assert!(decode_migrated(&j, &m).is_err(), "short kv rows must be rejected");

        // prompt_len beyond the token list
        let mut j = good.clone();
        if let Json::Obj(o) = &mut j {
            o.insert("prompt_len".into(), Json::Num(10_000.0));
        }
        assert!(decode_migrated(&j, &m).is_err());

        // unknown variant
        let mut j = good;
        if let Json::Obj(o) = &mut j {
            o.insert("variant".into(), Json::Str("definitely-not-a-variant".into()));
        }
        assert!(decode_migrated(&j, &m).is_err());
    }

    /// Drain records: pending (no session) and migrated forms parse
    /// back to what was written.
    #[test]
    fn drain_records_roundtrip() {
        let session = encode_migrated(&sample_session(true, true));
        let reply = drain_reply(vec![
            drain_record(7, 0, None),
            drain_record(9, 4, Some(session.clone())),
        ]);
        let line = reply.to_string();
        let records = parse_drain_reply(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].rid, 7);
        assert!(records[0].session.is_none());
        assert_eq!(records[1].rid, 9);
        assert_eq!(records[1].streamed, 4);
        assert_eq!(
            records[1].session.as_ref().unwrap().to_string(),
            session.to_string(),
            "the embedded session record must pass through untouched"
        );
    }
}
