//! Clustering: the paper's core machinery, reimplemented on the request
//! path (rust) and cross-checked against the python offline pipeline
//! (`python/compile/clustering.py`) via shared fixtures.
//!
//! * [`kmeans`] — seeded k-means++ over per-head feature rows.
//! * [`correlation`] — Pearson correlation matrices (figures 2/6/7).
//! * [`elbow`] — offline cluster-count selection (figure 8).
//! * [`membership`] — online 5-token cluster-membership identification
//!   (paper §3.3, figure 9) from probe attention maps.

pub mod correlation;
pub mod elbow;
pub mod kmeans;
pub mod membership;

/// Center + L2-normalize feature rows so euclidean k-means groups heads by
/// score *correlation* (mirrors `clustering.normalize_features`).
pub fn normalize_features(feats: &mut [Vec<f32>]) {
    for row in feats.iter_mut() {
        let n = row.len() as f32;
        let mean = row.iter().sum::<f32>() / n;
        for x in row.iter_mut() {
            *x -= mean;
        }
        let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-8);
        for x in row.iter_mut() {
            *x /= norm;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_gives_unit_centered_rows() {
        let mut f = vec![vec![1.0, 2.0, 3.0], vec![10.0, 10.0, 10.0]];
        normalize_features(&mut f);
        let mean0: f32 = f[0].iter().sum::<f32>() / 3.0;
        assert!(mean0.abs() < 1e-6);
        let norm0: f32 = f[0].iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm0 - 1.0).abs() < 1e-5);
        // constant row -> zero vector (no NaN)
        assert!(f[1].iter().all(|x| x.is_finite()));
    }

    #[test]
    fn correlated_rows_align_after_normalization() {
        let a: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let b: Vec<f32> = a.iter().map(|x| 3.0 * x + 5.0).collect(); // corr 1
        let c: Vec<f32> = a.iter().map(|x| -x).collect(); // corr -1
        let mut f = vec![a, b, c];
        normalize_features(&mut f);
        let dot = |x: &[f32], y: &[f32]| -> f32 {
            x.iter().zip(y).map(|(a, b)| a * b).sum()
        };
        assert!((dot(&f[0], &f[1]) - 1.0).abs() < 1e-5);
        assert!((dot(&f[0], &f[2]) + 1.0).abs() < 1e-5);
    }
}
