//! Online cluster-membership identification (paper §3.3, Figure 10b):
//! after the first 5 tokens of a request run under dense MHA, k-means the
//! per-head probe attention into the layer's (offline-fixed) k clusters.
//! Mirrors `python/compile/clustering.py::online_membership`.

use super::kmeans::{canonicalize, kmeans, representatives};

/// Per-layer online membership result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Membership {
    /// cluster id per head, in [0, k)
    pub membership: Vec<usize>,
    /// representative head per cluster (sorted ascending — canonical form)
    pub reps: Vec<usize>,
}

/// Build per-head features from one layer's probe attention maps
/// `[H][P][P]` (causal; row q has q+1 valid entries): the flattened
/// strictly-causal rows for queries 1..P-1 — query 0 is identically 1.0.
pub fn probe_features(maps: &[Vec<Vec<f32>>], n_tokens: usize) -> Vec<Vec<f32>> {
    maps.iter()
        .map(|head| {
            let mut f = Vec::new();
            for q in 1..n_tokens {
                f.extend_from_slice(&head[q][..q + 1]);
            }
            f
        })
        .collect()
}

/// Identify membership for one layer given its probe maps and offline k.
pub fn identify(maps: &[Vec<Vec<f32>>], n_tokens: usize, k: usize, seed: u64) -> Membership {
    let mut feats = probe_features(maps, n_tokens);
    crate::clustering::normalize_features(&mut feats);
    let res = kmeans(&feats, k, seed, 50);
    let reps = representatives(&feats, &res);
    let (membership, reps) = canonicalize(&res.labels, &reps);
    Membership { membership, reps }
}

/// Count membership changes between consecutive prefix lengths — the
/// stability experiment behind Figure 9 ("after five tokens the
/// membership rarely changes").
pub fn stability_curve(maps: &[Vec<Vec<f32>>], max_tokens: usize, k: usize, seed: u64) -> Vec<usize> {
    let mut prev: Option<Vec<usize>> = None;
    let mut changes = Vec::new();
    for n in 2..=max_tokens {
        let m = identify(maps, n, k, seed);
        if let Some(p) = &prev {
            changes.push(m.membership.iter().zip(p).filter(|(a, b)| a != b).count());
        }
        prev = Some(m.membership);
    }
    changes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Synthetic causal probe maps with `groups` score patterns.
    fn probe_maps(rng: &mut Rng, h: usize, p: usize, groups: usize) -> Vec<Vec<Vec<f32>>> {
        let mut patterns = Vec::new();
        for _ in 0..groups {
            let mut m = vec![vec![0.0f32; p]; p];
            for q in 0..p {
                let mut row: Vec<f32> = (0..=q).map(|_| rng.f32() + 0.05).collect();
                let s: f32 = row.iter().sum();
                row.iter_mut().for_each(|x| *x /= s);
                m[q][..q + 1].copy_from_slice(&row);
            }
            patterns.push(m);
        }
        (0..h)
            .map(|i| {
                let base = &patterns[i * groups / h];
                base.iter()
                    .map(|row| row.iter().map(|x| x + rng.normal() as f32 * 1e-4).collect())
                    .collect()
            })
            .collect()
    }

    #[test]
    fn probe_features_lengths() {
        let mut rng = Rng::new(0);
        let maps = probe_maps(&mut rng, 4, 5, 2);
        let f = probe_features(&maps, 5);
        assert_eq!(f.len(), 4);
        assert_eq!(f[0].len(), 2 + 3 + 4 + 5);
    }

    #[test]
    fn identify_groups_same_pattern_heads() {
        let mut rng = Rng::new(1);
        let maps = probe_maps(&mut rng, 16, 5, 2);
        let m = identify(&maps, 5, 2, 0);
        assert_eq!(m.membership.len(), 16);
        assert!(m.membership[..8].iter().all(|x| *x == m.membership[0]));
        assert!(m.membership[8..].iter().all(|x| *x == m.membership[8]));
        assert_ne!(m.membership[0], m.membership[8]);
        for (j, &r) in m.reps.iter().enumerate() {
            assert_eq!(m.membership[r], j);
        }
    }

    #[test]
    fn stability_settles_with_clear_structure() {
        let mut rng = Rng::new(2);
        let maps = probe_maps(&mut rng, 16, 8, 4);
        let curve = stability_curve(&maps, 8, 4, 0);
        assert_eq!(curve.len(), 6);
        // with near-identical group patterns the tail must be stable
        assert_eq!(*curve.last().unwrap(), 0, "curve: {curve:?}");
    }

    #[test]
    fn identify_is_deterministic() {
        let mut rng = Rng::new(3);
        let maps = probe_maps(&mut rng, 8, 5, 3);
        assert_eq!(identify(&maps, 5, 3, 7), identify(&maps, 5, 3, 7));
    }
}
