//! Pearson correlation across attention heads (paper figures 2b, 6, 7).

/// Pearson correlation matrix of feature rows. Constant rows correlate 0
/// with everything (paper treats them as their own degenerate cluster).
pub fn correlation_matrix(feats: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let h = feats.len();
    let mut normed: Vec<Vec<f32>> = feats.to_vec();
    crate::clustering::normalize_features(&mut normed);
    let mut out = vec![vec![0.0f32; h]; h];
    for i in 0..h {
        for j in i..h {
            let c: f32 = normed[i].iter().zip(&normed[j]).map(|(a, b)| a * b).sum();
            out[i][j] = c;
            out[j][i] = c;
        }
    }
    out
}

/// Mean of the off-diagonal (upper-triangle) correlations — the per-layer
/// redundancy statistic plotted in Figure 6.
pub fn mean_offdiag(corr: &[Vec<f32>]) -> f64 {
    let h = corr.len();
    if h < 2 {
        return 0.0;
    }
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for i in 0..h {
        for j in i + 1..h {
            sum += corr[i][j] as f64;
            n += 1;
        }
    }
    sum / n as f64
}

/// Fraction of head pairs whose correlation exceeds `thresh` (the ">0.95
/// within clusters" observation in §1).
pub fn frac_above(corr: &[Vec<f32>], thresh: f32) -> f64 {
    let h = corr.len();
    if h < 2 {
        return 0.0;
    }
    let mut above = 0usize;
    let mut n = 0usize;
    for i in 0..h {
        for j in i + 1..h {
            if corr[i][j] > thresh {
                above += 1;
            }
            n += 1;
        }
    }
    above as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_rows_correlate_1() {
        let a: Vec<f32> = (0..10).map(|i| (i as f32).sin()).collect();
        let corr = correlation_matrix(&[a.clone(), a.clone()]);
        assert!((corr[0][1] - 1.0).abs() < 1e-5);
        assert!((corr[0][0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn anticorrelated_rows() {
        let a: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let b: Vec<f32> = a.iter().map(|x| 10.0 - x).collect();
        let corr = correlation_matrix(&[a, b]);
        assert!((corr[0][1] + 1.0).abs() < 1e-5);
    }

    #[test]
    fn affine_invariance() {
        let a: Vec<f32> = (0..16).map(|i| (i * i) as f32).collect();
        let b: Vec<f32> = a.iter().map(|x| 0.5 * x - 3.0).collect();
        let corr = correlation_matrix(&[a, b]);
        assert!((corr[0][1] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn summary_stats() {
        let a: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let b = a.clone();
        let c: Vec<f32> = a.iter().map(|x| -x).collect();
        let corr = correlation_matrix(&[a, b, c]);
        // pairs: (a,b)=1, (a,c)=-1, (b,c)=-1 -> mean = -1/3
        assert!((mean_offdiag(&corr) + 1.0 / 3.0).abs() < 1e-5);
        assert!((frac_above(&corr, 0.95) - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn symmetric() {
        let f: Vec<Vec<f32>> =
            (0..4).map(|i| (0..6).map(|j| ((i * 7 + j * 3) % 5) as f32).collect()).collect();
        let corr = correlation_matrix(&f);
        for i in 0..4 {
            for j in 0..4 {
                assert!((corr[i][j] - corr[j][i]).abs() < 1e-6);
            }
        }
    }
}
