//! Seeded k-means++ over per-head attention features (mirrors
//! `python/compile/clustering.py::kmeans`). Deterministic given a seed.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct KMeansResult {
    pub labels: Vec<usize>,
    pub centroids: Vec<Vec<f32>>,
    pub sse: f64,
}

fn dist2(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum()
}

/// k-means++ with at most `iters` Lloyd iterations.
pub fn kmeans(feats: &[Vec<f32>], k: usize, seed: u64, iters: usize) -> KMeansResult {
    let h = feats.len();
    assert!(h > 0, "empty feature set");
    let k = k.min(h).max(1);
    let mut rng = Rng::new(seed);

    // k-means++ seeding
    let mut centroids: Vec<Vec<f32>> = vec![feats[rng.below(h)].clone()];
    while centroids.len() < k {
        let d2: Vec<f64> = feats
            .iter()
            .map(|f| centroids.iter().map(|c| dist2(f, c)).fold(f64::INFINITY, f64::min))
            .collect();
        let total: f64 = d2.iter().sum();
        let idx = if total <= 1e-12 { rng.below(h) } else { rng.weighted(&d2) };
        centroids.push(feats[idx].clone());
    }

    let mut labels = vec![0usize; h];
    for it in 0..iters {
        let mut changed = false;
        for (i, f) in feats.iter().enumerate() {
            let mut best = (f64::INFINITY, 0usize);
            for (j, c) in centroids.iter().enumerate() {
                let d = dist2(f, c);
                if d < best.0 {
                    best = (d, j);
                }
            }
            if labels[i] != best.1 {
                labels[i] = best.1;
                changed = true;
            }
        }
        if !changed && it > 0 {
            break;
        }
        for (j, c) in centroids.iter_mut().enumerate() {
            let members: Vec<&Vec<f32>> =
                feats.iter().zip(&labels).filter(|(_, l)| **l == j).map(|(f, _)| f).collect();
            if members.is_empty() {
                continue;
            }
            for (d, slot) in c.iter_mut().enumerate() {
                *slot = members.iter().map(|m| m[d]).sum::<f32>() / members.len() as f32;
            }
        }
    }
    let sse = feats.iter().zip(&labels).map(|(f, l)| dist2(f, &centroids[*l])).sum();
    KMeansResult { labels, centroids, sse }
}

/// Head closest to each centroid — its Q/K projections survive pruning.
pub fn representatives(feats: &[Vec<f32>], res: &KMeansResult) -> Vec<usize> {
    let k = res.centroids.len();
    let mut reps = vec![0usize; k];
    for j in 0..k {
        let mut best = (f64::INFINITY, usize::MAX);
        for (i, f) in feats.iter().enumerate() {
            if res.labels[i] == j {
                let d = dist2(f, &res.centroids[j]);
                if d < best.0 {
                    best = (d, i);
                }
            }
        }
        reps[j] = if best.1 == usize::MAX { j % feats.len() } else { best.1 };
    }
    reps
}

/// Re-index clusters so representatives are sorted by head index — the
/// canonical form shared with python so memberships compare exactly.
pub fn canonicalize(labels: &[usize], reps: &[usize]) -> (Vec<usize>, Vec<usize>) {
    let mut order: Vec<usize> = (0..reps.len()).collect();
    order.sort_by_key(|&j| reps[j]);
    let mut remap = vec![0usize; reps.len()];
    for (new, &old) in order.iter().enumerate() {
        remap[old] = new;
    }
    let new_labels = labels.iter().map(|&l| remap[l]).collect();
    let new_reps = order.iter().map(|&j| reps[j]).collect();
    (new_labels, new_reps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn blobs(rng: &mut Rng, k: usize, per: usize, dim: usize, spread: f32) -> (Vec<Vec<f32>>, Vec<usize>) {
        let mut feats = Vec::new();
        let mut truth = Vec::new();
        for c in 0..k {
            let center: Vec<f32> = (0..dim).map(|_| rng.normal() as f32 * 3.0).collect();
            for _ in 0..per {
                feats.push(center.iter().map(|x| x + rng.normal() as f32 * spread).collect());
                truth.push(c);
            }
        }
        (feats, truth)
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::new(0);
        let (feats, truth) = blobs(&mut rng, 3, 5, 8, 0.02);
        let res = kmeans(&feats, 3, 1, 50);
        for c in 0..3 {
            let ls: Vec<usize> =
                (0..15).filter(|i| truth[*i] == c).map(|i| res.labels[i]).collect();
            assert!(ls.iter().all(|l| *l == ls[0]), "blob {c} split: {ls:?}");
        }
        assert!(res.sse < 0.5, "sse {}", res.sse);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(5);
        let (feats, _) = blobs(&mut rng, 4, 4, 6, 0.5);
        let a = kmeans(&feats, 4, 9, 50);
        let b = kmeans(&feats, 4, 9, 50);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn property_labels_in_range_sse_monotone() {
        check("kmeans-invariants", 30, |rng| {
            let h = rng.range(2, 17);
            let dim = rng.range(2, 10);
            let feats: Vec<Vec<f32>> = (0..h)
                .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
                .collect();
            let k = rng.range(1, h + 1);
            let res = kmeans(&feats, k, 3, 30);
            crate::prop_assert!(res.labels.len() == h, "label count");
            crate::prop_assert!(
                res.labels.iter().all(|l| *l < k),
                "label out of range: {:?} (k={k})", res.labels
            );
            let res1 = kmeans(&feats, 1, 3, 30);
            crate::prop_assert!(
                res.sse <= res1.sse + 1e-6,
                "sse not monotone: k={k} sse={} vs k=1 sse={}", res.sse, res1.sse
            );
            Ok(())
        });
    }

    #[test]
    fn representatives_belong_to_their_cluster() {
        let mut rng = Rng::new(2);
        let (feats, _) = blobs(&mut rng, 4, 4, 6, 0.1);
        let res = kmeans(&feats, 4, 0, 50);
        let reps = representatives(&feats, &res);
        for (j, &r) in reps.iter().enumerate() {
            assert_eq!(res.labels[r], j);
        }
    }

    #[test]
    fn canonicalize_sorts_reps() {
        let labels = vec![1, 1, 0, 2];
        let reps = vec![9, 3, 5];
        let (mem, reps2) = canonicalize(&labels, &reps);
        assert_eq!(reps2, vec![3, 5, 9]);
        assert_eq!(mem, vec![0, 0, 2, 1]);
    }

    #[test]
    fn k_larger_than_points_clamped() {
        let feats = vec![vec![0.0f32, 1.0], vec![5.0, 5.0]];
        let res = kmeans(&feats, 10, 0, 20);
        assert!(res.centroids.len() <= 2);
        assert!(res.sse < 1e-9);
    }
}
