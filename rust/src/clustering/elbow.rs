//! Offline cluster-count selection (paper §3.2, Figure 8): SSE curve over
//! k = 1..H plus the automated elbow read. Mirrors
//! `python/compile/clustering.py::{cluster_layer, elbow_pick}` — the
//! integration tests assert both sides picked identical `k_list` for the
//! shipped `clusters.json`.

use super::kmeans::{canonicalize, kmeans, representatives};

#[derive(Debug, Clone)]
pub struct LayerClusters {
    pub k: usize,
    pub membership: Vec<usize>,
    pub reps: Vec<usize>,
    pub errors: Vec<f64>,
}

/// Smallest k whose residual SSE falls below `rel_tol` of the k=1 SSE;
/// layers with no redundancy return H (no pruning).
pub fn elbow_pick(errors: &[f64], rel_tol: f64) -> usize {
    if errors.is_empty() {
        return 1;
    }
    if errors[0] < 1e-6 {
        return 1; // all heads already identical
    }
    let base = errors[0];
    for (i, e) in errors.iter().enumerate() {
        if e / base <= rel_tol {
            return i + 1;
        }
    }
    errors.len()
}

/// Full per-layer offline pipeline over raw [H][F] attention features.
pub fn cluster_layer(feats_raw: &[Vec<f32>], seed: u64) -> LayerClusters {
    let h = feats_raw.len();
    let mut feats = feats_raw.to_vec();
    crate::clustering::normalize_features(&mut feats);
    let mut errors = Vec::with_capacity(h);
    let mut results = Vec::with_capacity(h);
    for k in 1..=h {
        let res = kmeans(&feats, k, seed, 50);
        errors.push(res.sse);
        results.push(res);
    }
    let k = elbow_pick(&errors, 0.08);
    let res = &results[k - 1];
    let reps = representatives(&feats, res);
    let (membership, reps) = canonicalize(&res.labels, &reps);
    LayerClusters { k, membership, reps, errors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn elbow_rules() {
        assert_eq!(elbow_pick(&[100.0, 40.0, 5.0, 4.5, 4.0], 0.08), 3);
        let lin: Vec<f64> = (0..16).map(|i| 16.0 - i as f64).collect();
        assert_eq!(elbow_pick(&lin, 0.08), 16);
        assert_eq!(elbow_pick(&[1e-9, 0.0], 0.08), 1);
        assert_eq!(elbow_pick(&[], 0.08), 1);
    }

    #[test]
    fn cluster_layer_recovers_redundant_groups() {
        // 16 heads in 3 groups of near-identical attention rows.
        let mut rng = Rng::new(0);
        let mut patterns = Vec::new();
        for _ in 0..3 {
            let p: Vec<f32> = (0..32).map(|_| rng.f32()).collect();
            patterns.push(p);
        }
        let sizes = [6usize, 6, 4];
        let mut feats = Vec::new();
        for (g, &sz) in sizes.iter().enumerate() {
            for _ in 0..sz {
                feats.push(
                    patterns[g].iter().map(|x| x + rng.normal() as f32 * 1e-3).collect(),
                );
            }
        }
        let res = cluster_layer(&feats, 0);
        assert_eq!(res.k, 3, "errors: {:?}", res.errors);
        assert!(res.membership[..6].iter().all(|m| *m == res.membership[0]));
        assert!(res.membership[6..12].iter().all(|m| *m == res.membership[6]));
        assert_eq!(res.reps.len(), 3);
        // reps sorted canonical
        let mut sorted = res.reps.clone();
        sorted.sort();
        assert_eq!(sorted, res.reps);
    }

    #[test]
    fn errors_monotone_nonincreasing() {
        let mut rng = Rng::new(3);
        let feats: Vec<Vec<f32>> =
            (0..8).map(|_| (0..16).map(|_| rng.f32()).collect()).collect();
        let res = cluster_layer(&feats, 1);
        for w in res.errors.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "{:?}", res.errors);
        }
    }
}
