//! Multi-replica router front-end: the subsystem that turns one engine
//! into a service.
//!
//! The [`Router`] fronts N data-parallel engine **replicas**, each
//! reached through a location-transparent [`ReplicaTransport`]:
//!
//! * `--transport local` — every replica is a full in-process
//!   [`Coordinator`] with its own engine thread, [`crate::scheduler`],
//!   and paged K,V pool (PR 5's shape, zero serialization).
//! * `--transport process` (Linux) — every replica is a separate
//!   `chai replica` child process serving the same line-JSON protocol
//!   over [`crate::net`]'s epoll reactor; the router keeps one data
//!   connection (submits, frames, terminals, drain) and one control
//!   connection (lockstep probe/cancel/stats) per replica. A replica
//!   crash — up to `kill -9` — cannot take the router down.
//!
//! Placement is a pluggable [`RoutePolicy`]:
//!
//! * **round-robin** (`--route rr`) — classic rotation, the baseline.
//! * **least-loaded** (`--route least-loaded`) — picks the replica with
//!   the smallest `pending + live + preempted` population (the same
//!   numbers the server's `{"cmd":"sched"}` view reports), so a replica
//!   stuck behind a long generation stops receiving new work.
//! * **prefix-affinity** (`--route prefix`) — hashes the prompt's
//!   shareable prefix ([`prompt_fingerprint`]: the token-hash chain of
//!   its leading full blocks, the exact keys the paged pool's prefix
//!   index uses) and looks the digest up on a consistent-hash ring
//!   ([`hashring::HashRing`], one entry per live replica). Repeated
//!   system prompts land on the replica that already holds those
//!   blocks; when a replica dies only ~1/N of the keyspace moves, so
//!   the survivors' warmed prefixes stay put.
//!
//! **Failure handling** (process transport): a supervisor thread probes
//! every replica on a `--probe-ms` cadence; `--probe-suspect`
//! consecutive failed probes — or the child process exiting — declares
//! the replica dead. Death tears its ring points out and **requeues**
//! every request the router had accepted onto survivors at the request's
//! recorded stream offset, so a `kill -9` loses zero accepted requests
//! and streaming clients see an exactly-once, bit-identical token
//! sequence (greedy decode). [`Router::drain_replica`] is the graceful
//! version: the replica freezes its live sessions ([`crate::mesh`] wire
//! form, bit-deterministic) and survivors adopt them mid-generation
//! instead of recomputing from scratch.
//!
//! Replicas share model weights in-process: on the reference backend the
//! router loads/synthesizes the model once ([`SharedRefModel`]) and each
//! replica's engine thread wraps the `Arc`'d weights in its own
//! backend, so N local replicas cost one model copy plus N K,V pools.
//! The router owns the request-id space (ids stay unique across
//! replicas); cancellation broadcasts to every replica (exactly one
//! holds the id; the rest no-op), so the front-end needs no id→replica
//! bookkeeping that could leak.
//!
//! [`Frontend`] is the seam the TCP server drives — both a bare
//! [`Coordinator`] (single replica, zero router overhead) and the
//! [`Router`] implement it, so every protocol feature (streaming,
//! cancellation, stats/kv/sched/info views) works identically at both
//! scales. Router views roll up counters and gauges across replicas
//! (prefix hit rate recomputed from the summed block counts), attach a
//! `router` section (`router_*` counters, per-replica routed counts,
//! live load costs), and keep the per-replica breakdown.

pub mod hashring;
mod transport;

pub use transport::{LocalReplica, MeshDrained, MeshSession, ReplicaTransport};
#[cfg(target_os = "linux")]
pub use transport::ProcessReplica;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::config::ServingConfig;
use crate::coordinator::Coordinator;
use crate::engine::Engine;
use crate::kv::paged::prompt_fingerprint;
use crate::metrics::{merge_gauge_objects, merge_latency_objects, sum_json_objects, Metrics};
use crate::model::tokenizer;
use crate::runtime::reference::{RefBackend, SharedRefModel};
use crate::scheduler::{RespSink, Response, SubmitOpts};
use crate::util::json::Json;
use hashring::HashRing;

/// The serving surface the TCP server (and benches) drive — implemented
/// by both a single [`Coordinator`] and the multi-replica [`Router`].
pub trait Frontend: Clone + Send + 'static {
    /// Submit a request (assigning its id); returns `(id, response rx)`.
    fn submit_opts(&self, opts: SubmitOpts) -> (u64, Receiver<Response>);
    /// Submit with a caller-supplied terminal sink instead of a fresh
    /// channel (the epoll reactor path: the response lands in the
    /// request's lock-free event ring); returns the assigned id.
    fn submit_sink(&self, opts: SubmitOpts, resp: RespSink) -> u64;
    /// Submit with a caller-supplied id AND sink — the mesh path, where
    /// the router assigned the id before placing the request and a
    /// requeue onto a different replica must keep it (the client's
    /// stream is keyed by it).
    fn submit_rid(&self, id: u64, opts: SubmitOpts, resp: RespSink);
    /// Request an abort of `id` (async; unknown ids are a no-op).
    fn cancel(&self, id: u64);
    /// `{"cmd":"probe"}` — cheap liveness + load heartbeat (never
    /// touches the engine thread; safe at high frequency).
    fn probe_json(&self) -> Json;
    /// `{"cmd":"stats"}` — full counters/latency/gauges/info view.
    fn stats_json(&self) -> Json;
    /// `{"cmd":"kv"}` — paged-pool occupancy + sharing gauges.
    fn kv_json(&self) -> Json;
    /// `{"cmd":"sched"}` — queue depths + preemption/swap counters.
    fn sched_json(&self) -> Json;
    /// `{"cmd":"info"}` — static serving facts (backend, model, ...).
    fn info_json(&self) -> Json;
    /// `{"cmd":"trace"}` — drain the flight recorder as Chrome
    /// trace-event JSON ([`crate::obs::dump_json`]). The router
    /// overrides this to stitch its own spans with every live process
    /// replica's dump (one shared unix-epoch clock, so stitching is
    /// concatenation).
    fn trace_json(&self) -> Json {
        crate::obs::dump_json()
    }
    /// `{"cmd":"drain"}` (reactor transport only): stop admitting,
    /// freeze/evict every request, and reply with one
    /// `{"drained":[...]}` line on `sink`'s connection — serialized
    /// after every frame/terminal of the drained requests. Only a bare
    /// replica coordinator supports it; everything else refuses.
    #[cfg(target_os = "linux")]
    fn drain_net(&self, sink: crate::net::NetSink) -> Result<()> {
        let _ = sink;
        bail!("drain: only a replica coordinator can be drained")
    }
    /// `{"cmd":"adopt"}` (reactor transport only): resume a migrated
    /// session under its original request id. Replica-side only.
    #[cfg(target_os = "linux")]
    fn adopt_net(&self, adopt: crate::coordinator::AdoptNet) -> Result<()> {
        let _ = adopt;
        bail!("adopt: only a replica coordinator can adopt sessions")
    }
    /// Whether `--pin-cores` is on for this serving stack — the reactor
    /// thread asks its frontend (it has no config of its own) and pins
    /// itself to a dedicated core when true.
    fn pin_cores(&self) -> bool {
        false
    }
}

impl Frontend for Coordinator {
    fn submit_opts(&self, opts: SubmitOpts) -> (u64, Receiver<Response>) {
        Coordinator::submit_opts(self, opts)
    }

    fn submit_sink(&self, opts: SubmitOpts, resp: RespSink) -> u64 {
        Coordinator::submit_sink(self, opts, resp)
    }

    fn submit_rid(&self, id: u64, opts: SubmitOpts, resp: RespSink) {
        Coordinator::submit_request(self, id, opts, resp)
    }

    fn cancel(&self, id: u64) {
        Coordinator::cancel(self, id)
    }

    fn probe_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("load", Json::Num(self.load_cost())),
            ("pending", Json::Num(self.metrics.gauge("sched_pending"))),
            ("live", Json::Num(self.metrics.gauge("sched_live"))),
            ("preempted", Json::Num(self.metrics.gauge("sched_preempted"))),
        ])
    }

    fn stats_json(&self) -> Json {
        self.metrics.to_json()
    }

    fn kv_json(&self) -> Json {
        self.metrics
            .to_json()
            .opt("gauges")
            .cloned()
            .unwrap_or_else(|| Json::obj(vec![]))
    }

    fn sched_json(&self) -> Json {
        self.metrics.subset_json(&["sched_", "swap_", "kv_defer"])
    }

    fn info_json(&self) -> Json {
        self.metrics
            .to_json()
            .opt("info")
            .cloned()
            .unwrap_or_else(|| Json::obj(vec![]))
    }

    #[cfg(target_os = "linux")]
    fn drain_net(&self, sink: crate::net::NetSink) -> Result<()> {
        Coordinator::drain_net(self, sink);
        Ok(())
    }

    #[cfg(target_os = "linux")]
    fn adopt_net(&self, adopt: crate::coordinator::AdoptNet) -> Result<()> {
        Coordinator::adopt_net(self, adopt);
        Ok(())
    }

    fn pin_cores(&self) -> bool {
        self.pin_cores
    }
}

/// Base of the router-assigned request-id space. Disjoint from the
/// ids a bare [`Coordinator::submit`] hands out (which count up from
/// 1), so a broadcast cancel for a router id can never collide with a
/// request submitted directly to a replica coordinator on the side.
pub const ROUTER_ID_BASE: u64 = 1 << 32;

/// Leading full blocks the prefix-affinity digest covers (with the
/// default 16-token blocks: the first 64 tokens). Capping keeps
/// affinity robust to tails — "system prompt + question A/B" must map
/// to the SAME replica even when the questions spill into further full
/// blocks; an uncapped chain digest would scatter exactly that
/// traffic. Bounded hashing also keeps routing O(1)-ish per request.
pub const AFFINITY_PREFIX_BLOCKS: usize = 4;

/// Replica-placement policy (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
    PrefixAffinity,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Result<RoutePolicy> {
        Ok(match s {
            "rr" | "round-robin" => RoutePolicy::RoundRobin,
            "least-loaded" | "ll" => RoutePolicy::LeastLoaded,
            "prefix" | "prefix-affinity" => RoutePolicy::PrefixAffinity,
            other => bail!("unknown route policy {other:?} (rr|least-loaded|prefix)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "rr",
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::PrefixAffinity => "prefix",
        }
    }
}

/// Multi-replica front-end; cheap to clone (all state is `Arc`'d).
#[derive(Clone)]
pub struct Router {
    replicas: Arc<Vec<Arc<dyn ReplicaTransport>>>,
    policy: RoutePolicy,
    /// router-owned global id space (unique across replicas)
    next_id: Arc<AtomicU64>,
    rr: Arc<AtomicUsize>,
    /// router-level metrics only (`router_*`); replica metrics live on
    /// each replica and are rolled up on read
    pub metrics: Arc<Metrics>,
    /// block size the prefix-affinity fingerprint is computed at (must
    /// match the replicas' paged pools so the digest keys align)
    kv_block_size: usize,
    /// consistent-hash ring for prefix placement; replica index = ring
    /// id. Dead/drained replicas are removed, so only their arcs remap.
    ring: Arc<Mutex<HashRing>>,
    /// tombstone per replica: set once when it is declared dead or
    /// drained; routing, rollups and probes skip tombstoned replicas
    down: Arc<Vec<AtomicBool>>,
    /// `--pin-cores` (forwarded to the reactor via [`Frontend`])
    pin_cores: bool,
    /// `--trace-out`: where the stitched flight-recorder dump lands on
    /// shutdown and on every replica death (postmortem artifact)
    trace_out: Arc<Option<std::path::PathBuf>>,
}

/// Owns the replica fleet and its supervisor thread; dropping (or
/// `shutdown`) stops all of it.
pub struct RouterHandle {
    pub router: Router,
    supervisor: Option<thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

impl RouterHandle {
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
        // dump while the children can still answer {"cmd":"trace"}
        self.router.dump_trace_out();
        for t in self.router.replicas.iter() {
            t.shutdown();
        }
    }
}

impl Drop for RouterHandle {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

impl Router {
    /// Spawn `cfg.replicas` engine replicas over `cfg.transport`
    /// (weights shared on the local reference backend) routed by
    /// `cfg.route`, plus the supervisor thread that probes them.
    pub fn start(cfg: ServingConfig) -> Result<RouterHandle> {
        let n = cfg.replicas.max(1);
        let policy = RoutePolicy::parse(&cfg.route)?;
        // the process transport has no local coordinator to set this;
        // children get `--no-obs` forwarded by ProcessReplica::spawn
        crate::obs::set_enabled(cfg.obs);
        let metrics = Arc::new(Metrics::new());
        let mut replicas: Vec<Arc<dyn ReplicaTransport>> = Vec::with_capacity(n);
        match cfg.transport.as_str() {
            "local" => {
                // one physical copy of the model for all replicas (ref
                // backend; the XLA backend is Rc-bound to its engine
                // thread and loads per replica)
                let shared = match crate::runtime::resolve_backend(&cfg)? {
                    "ref" => Some(SharedRefModel::load_or_toy(&cfg.artifacts_dir, cfg.seed)?),
                    _ => None,
                };
                for _ in 0..n {
                    let handle = match shared.clone() {
                        Some(model) => {
                            let engine_cfg = cfg.clone();
                            Coordinator::start_with(
                                cfg.clone(),
                                Box::new(move || {
                                    Engine::with_backend(
                                        Box::new(RefBackend::from_shared(&model)),
                                        engine_cfg,
                                    )
                                }),
                            )?
                        }
                        None => Coordinator::start(cfg.clone())?,
                    };
                    replicas.push(Arc::new(LocalReplica::new(handle)));
                }
            }
            #[cfg(target_os = "linux")]
            "process" => {
                for i in 0..n {
                    replicas.push(Arc::new(ProcessReplica::spawn(i, &cfg, metrics.clone())?));
                }
            }
            #[cfg(not(target_os = "linux"))]
            "process" => bail!("--transport process requires linux (epoll reactor)"),
            other => bail!("unknown replica transport {other:?} (local|process)"),
        }
        metrics.set_info("router_policy", policy.name());
        metrics.set_info("router_transport", &cfg.transport);
        metrics.set_gauge("router_replicas", n as f64);
        metrics.set_gauge("router_replicas_alive", n as f64);
        let ring = HashRing::new(&(0..n as u64).collect::<Vec<_>>());
        let router = Router {
            replicas: Arc::new(replicas),
            policy,
            next_id: Arc::new(AtomicU64::new(ROUTER_ID_BASE)),
            rr: Arc::new(AtomicUsize::new(0)),
            metrics,
            kv_block_size: cfg.kv_block_size.max(1),
            ring: Arc::new(Mutex::new(ring)),
            down: Arc::new((0..n).map(|_| AtomicBool::new(false)).collect()),
            pin_cores: cfg.pin_cores,
            trace_out: Arc::new(cfg.trace_out.clone()),
        };
        let stop = Arc::new(AtomicBool::new(false));
        let supervisor = {
            let router = router.clone();
            let stop = stop.clone();
            let (probe_ms, suspect) = (cfg.probe_ms.max(1), cfg.probe_suspect.max(1));
            thread::Builder::new()
                .name("router-supervisor".into())
                .spawn(move || supervise(router, stop, probe_ms, suspect))
                .expect("spawn router supervisor")
        };
        Ok(RouterHandle { router, supervisor: Some(supervisor), stop })
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    fn is_down(&self, i: usize) -> bool {
        self.down[i].load(Ordering::Relaxed)
    }

    fn alive_count(&self) -> usize {
        self.down.iter().filter(|d| !d.load(Ordering::Relaxed)).count()
    }

    /// Next live replica in rotation; `None` when the whole fleet is
    /// down (the caller fails the request instead of panicking).
    fn pick_rr(&self) -> Option<usize> {
        let n = self.replicas.len();
        for _ in 0..n {
            let i = self.rr.fetch_add(1, Ordering::Relaxed) % n;
            if !self.is_down(i) {
                return Some(i);
            }
        }
        None
    }

    /// Pick the replica for a request (see [`RoutePolicy`]).
    fn route(&self, opts: &SubmitOpts) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => self.pick_rr().unwrap_or(0),
            RoutePolicy::LeastLoaded => {
                // stable argmin: earliest live replica wins ties
                let mut best = None;
                let mut best_cost = f64::INFINITY;
                for (i, t) in self.replicas.iter().enumerate() {
                    if self.is_down(i) {
                        continue;
                    }
                    let cost = t.load_cost();
                    if cost < best_cost {
                        best = Some(i);
                        best_cost = cost;
                    }
                }
                best.unwrap_or(0)
            }
            RoutePolicy::PrefixAffinity => {
                // one extra O(prompt) byte-level encode on the server
                // thread (the engine re-tokenizes on its own thread) —
                // routing must not wait on the engine
                let tokens = tokenizer::encode(&opts.prompt, true, false);
                let fp = prompt_fingerprint(
                    &opts.variant.name(),
                    &tokens,
                    self.kv_block_size,
                    AFFINITY_PREFIX_BLOCKS,
                );
                match self.ring.lock().unwrap().owner(fp) {
                    Some(r) => r as usize,
                    None => self.pick_rr().unwrap_or(0),
                }
            }
        }
    }

    /// Declare replica `i` dead: tear its ring points out and requeue
    /// every request the router had accepted on it onto survivors, each
    /// from its recorded stream offset (idempotent; first caller wins).
    fn on_replica_death(&self, i: usize) {
        if self.down[i].swap(true, Ordering::SeqCst) {
            return;
        }
        self.ring.lock().unwrap().remove(i as u64);
        self.metrics.inc("router_replica_deaths");
        self.metrics.set_gauge("router_replicas_alive", self.alive_count() as f64);
        for d in self.replicas[i].take_orphans() {
            self.metrics.inc("router_requeued");
            self.place_orphan(d);
        }
        // postmortem: snapshot what the router + survivors know right
        // now (the dead child's unqueried spans died with it)
        self.dump_trace_out();
    }

    /// Write the stitched flight-recorder dump to `--trace-out`
    /// (best-effort; called on shutdown and on replica death).
    fn dump_trace_out(&self) {
        let Some(path) = self.trace_out.as_ref() else { return };
        let dump = Frontend::trace_json(self);
        if let Err(e) = std::fs::write(path, dump.to_string()) {
            eprintln!("[router] --trace-out {}: {e}", path.display());
        }
    }

    /// Re-place a drained/orphaned request on a surviving replica, or
    /// fail it with a terminal error when none is left.
    fn place_orphan(&self, d: MeshDrained) {
        match self.pick_rr() {
            Some(r) => {
                self.metrics.inc("router_routed_total");
                self.metrics.inc(&format!("router_routed_replica_{r}"));
                self.replicas[r].adopt(d);
            }
            None => {
                let id = d.req.id;
                d.req.resp_tx.send(Response::error(id, "no replicas alive".into()));
            }
        }
    }

    /// Gracefully remove replica `i` from the mesh: stop routing to it,
    /// freeze/collect everything it holds (live sessions keep their KV
    /// in [`crate::mesh`] wire form), migrate each onto survivors, then
    /// shut the replica down. Returns how many requests moved.
    pub fn drain_replica(&self, i: usize) -> Result<usize> {
        if i >= self.replicas.len() {
            bail!("replica {i} out of range (fleet size {})", self.replicas.len());
        }
        if self.down[i].swap(true, Ordering::SeqCst) {
            bail!("replica {i} is already out of the mesh");
        }
        self.ring.lock().unwrap().remove(i as u64);
        self.metrics.set_gauge("router_replicas_alive", self.alive_count() as f64);
        let drained = match self.replicas[i].drain() {
            Ok(v) => v,
            // a replica that dies mid-drain degrades to the crash path:
            // whatever the router still holds entries for is requeued
            Err(_) => self.replicas[i].take_orphans(),
        };
        let moved = drained.len();
        for d in drained {
            self.metrics.inc("router_migrated_sessions");
            self.place_orphan(d);
        }
        self.replicas[i].shutdown();
        Ok(moved)
    }

    /// Direct access to the fleet (benches and the failover drill).
    pub fn transport(&self, i: usize) -> &Arc<dyn ReplicaTransport> {
        &self.replicas[i]
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Sum of a counter across live replicas.
    pub fn counter_sum(&self, name: &str) -> u64 {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.is_down(*i))
            .map(|(_, t)| t.counter(name))
            .sum()
    }

    /// Sum of a gauge across live replicas.
    pub fn gauge_sum(&self, name: &str) -> f64 {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.is_down(*i))
            .map(|(_, t)| t.gauge(name))
            .sum()
    }

    /// Aggregate prefix-sharing hit rate, recomputed from the summed
    /// hit/miss block counts (a mean of per-replica rates would weight
    /// idle replicas equally with busy ones).
    pub fn prefix_hit_rate(&self) -> f64 {
        let hits = self.gauge_sum("paged_prefix_hit_blocks");
        let total = hits + self.gauge_sum("paged_prefix_miss_blocks");
        if total <= 0.0 {
            0.0
        } else {
            hits / total
        }
    }

    /// The `router` section of the rolled-up views: policy, fleet and
    /// liveness counts, per-replica routed counts and live load costs,
    /// plus every router-level counter.
    fn router_json(&self) -> Json {
        let routed: Vec<Json> = (0..self.replicas.len())
            .map(|i| {
                Json::Num(self.metrics.counter(&format!("router_routed_replica_{i}")) as f64)
            })
            .collect();
        let load: Vec<Json> = self
            .replicas
            .iter()
            .enumerate()
            .map(|(i, t)| {
                if self.is_down(i) {
                    Json::Null
                } else {
                    Json::Num(t.load_cost())
                }
            })
            .collect();
        let transport = self.replicas.first().map(|t| t.kind()).unwrap_or("local");
        Json::obj(vec![
            ("policy", Json::Str(self.policy.name().into())),
            ("transport", Json::Str(transport.into())),
            ("replicas", Json::Num(self.replicas.len() as f64)),
            ("alive", Json::Num(self.alive_count() as f64)),
            (
                "routed_total",
                Json::Num(self.metrics.counter("router_routed_total") as f64),
            ),
            (
                "cancel_requests",
                Json::Num(self.metrics.counter("router_cancel_requests") as f64),
            ),
            (
                "deaths",
                Json::Num(self.metrics.counter("router_replica_deaths") as f64),
            ),
            (
                "requeued",
                Json::Num(self.metrics.counter("router_requeued") as f64),
            ),
            (
                "migrated",
                Json::Num(self.metrics.counter("router_migrated_sessions") as f64),
            ),
            ("routed", Json::Arr(routed)),
            ("load", Json::Arr(load)),
        ])
    }

    /// Per-replica view: the replica's own JSON when live, a tombstone
    /// marker when dead (so array positions keep meaning replica index).
    fn per_replica(&self, f: impl Fn(&Arc<dyn ReplicaTransport>) -> Json) -> Vec<Json> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, t)| {
                if self.is_down(i) {
                    Json::obj(vec![("dead", Json::Bool(true))])
                } else {
                    f(t)
                }
            })
            .collect()
    }

    /// Roll gauges up across replicas by declared kind (totals sum,
    /// `_rate`s average, `_hwm`s max — see [`crate::metrics::gauge_kind`])
    /// and patch the aggregate hit rate, which must be recomputed from
    /// the summed block counts rather than averaged (an idle replica's
    /// rate would weight the same as a busy one's).
    fn rolled_gauges(&self, per: &[Json]) -> Json {
        let mut gauges = merge_gauge_objects(per.iter().filter_map(|j| j.opt("gauges")));
        if let Json::Obj(m) = &mut gauges {
            if m.contains_key("paged_prefix_hit_rate") {
                m.insert(
                    "paged_prefix_hit_rate".into(),
                    Json::Num(self.prefix_hit_rate()),
                );
            }
            m.insert("router_replicas".into(), Json::Num(self.replicas.len() as f64));
        }
        gauges
    }
}

/// Supervisor loop: watch child liveness every tick (cheap `try_wait`),
/// probe on the `probe_ms` cadence, and escalate `suspect` consecutive
/// probe failures to a declared death (which requeues the replica's
/// accepted requests — see [`Router::on_replica_death`]).
fn supervise(router: Router, stop: Arc<AtomicBool>, probe_ms: u64, suspect_after: u32) {
    const TICK_MS: u64 = 10;
    let ticks_per_probe = (probe_ms / TICK_MS).max(1);
    let mut suspect = vec![0u32; router.replicas.len()];
    let mut tick: u64 = 0;
    while !stop.load(Ordering::Relaxed) {
        thread::sleep(Duration::from_millis(TICK_MS));
        tick += 1;
        for (i, t) in router.replicas.iter().enumerate() {
            if router.is_down(i) {
                continue;
            }
            if !t.alive() {
                // process exit (including kill -9) — no need to wait
                // for the probe state machine
                router.on_replica_death(i);
                continue;
            }
            if tick % ticks_per_probe == 0 {
                match t.probe() {
                    Ok(_) => suspect[i] = 0,
                    Err(_) => {
                        suspect[i] += 1;
                        if suspect[i] >= suspect_after {
                            router.on_replica_death(i);
                        }
                    }
                }
            }
        }
    }
}

impl Frontend for Router {
    fn submit_opts(&self, opts: SubmitOpts) -> (u64, Receiver<Response>) {
        let (tx, rx) = channel();
        let id = Frontend::submit_sink(self, opts, RespSink::Channel(tx));
        (id, rx)
    }

    fn submit_sink(&self, opts: SubmitOpts, resp: RespSink) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        Frontend::submit_rid(self, id, opts, resp);
        id
    }

    fn submit_rid(&self, id: u64, mut opts: SubmitOpts, resp: RespSink) {
        // mint the trace id HERE, before the wire write: the entry
        // registry must know it so crash requeues and parent-side
        // frame_write spans stay on the request's one timeline
        if opts.trace == 0 && crate::obs::enabled() {
            opts.trace = crate::obs::next_trace_id();
        }
        let r = self.route(&opts);
        self.metrics.inc("router_routed_total");
        self.metrics.inc(&format!("router_routed_replica_{r}"));
        self.replicas[r].submit(id, opts, resp);
    }

    /// Broadcast: exactly one replica holds the id, the rest no-op.
    fn cancel(&self, id: u64) {
        self.metrics.inc("router_cancel_requests");
        for (i, t) in self.replicas.iter().enumerate() {
            if !self.is_down(i) {
                t.cancel(id);
            }
        }
    }

    fn probe_json(&self) -> Json {
        let alive = self.alive_count();
        let load: f64 = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.is_down(*i))
            .map(|(_, t)| t.load_cost())
            .sum();
        Json::obj(vec![
            ("ok", Json::Bool(alive > 0)),
            ("load", Json::Num(load)),
            ("replicas", Json::Num(self.replicas.len() as f64)),
            ("alive", Json::Num(alive as f64)),
        ])
    }

    fn stats_json(&self) -> Json {
        let per = self.per_replica(|t| t.metrics_json());
        let counters = sum_json_objects(per.iter().filter_map(|j| j.opt("counters")));
        // bucket-wise histogram merge: p50/p99/mean recomputed from the
        // summed raw buckets (summing per-replica quantiles would be
        // nonsense)
        let latency = merge_latency_objects(per.iter().filter_map(|j| j.opt("latency")));
        let gauges = self.rolled_gauges(&per);
        let info = Frontend::info_json(self);
        Json::obj(vec![
            ("counters", counters),
            ("latency", latency),
            ("gauges", gauges),
            ("info", info),
            ("router", self.router_json()),
            ("replicas", Json::Arr(per)),
        ])
    }

    fn kv_json(&self) -> Json {
        let per = self.per_replica(|t| t.view_json("kv"));
        self.rolled_gauges(
            &per.iter()
                .map(|g| Json::obj(vec![("gauges", g.clone())]))
                .collect::<Vec<_>>(),
        )
    }

    fn sched_json(&self) -> Json {
        let per = self.per_replica(|t| t.view_json("sched"));
        let mut merged = sum_json_objects(per.iter());
        if let Json::Obj(m) = &mut merged {
            m.insert("router".into(), self.router_json());
            m.insert("per_replica".into(), Json::Arr(per));
        }
        merged
    }

    fn info_json(&self) -> Json {
        // the first live replica speaks for the fleet (same
        // backend/model everywhere)
        let mut info = self
            .replicas
            .iter()
            .enumerate()
            .find(|(i, _)| !self.is_down(*i))
            .map(|(_, t)| t.view_json("info"))
            .unwrap_or_else(|| Json::obj(vec![]));
        if let Json::Obj(m) = &mut info {
            m.insert("replicas".into(), Json::Num(self.replicas.len() as f64));
            m.insert("route".into(), Json::Str(self.policy.name().into()));
        }
        info
    }

    fn trace_json(&self) -> Json {
        // own rings (frame_write spans + local replicas' engine threads)
        // stitched with every live process child's dump; local replicas
        // contribute an empty view (their spans are already ours)
        let others: Vec<Json> = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(i, t)| !self.is_down(*i) && t.kind() == "process")
            .map(|(_, t)| t.view_json("trace"))
            .collect();
        crate::obs::merge_dumps(crate::obs::dump_json(), others)
    }

    fn pin_cores(&self) -> bool {
        self.pin_cores
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Variant;
    use std::path::PathBuf;

    fn toy_cfg(replicas: usize, route: &str) -> ServingConfig {
        ServingConfig {
            artifacts_dir: PathBuf::from("definitely-no-artifacts-here"),
            backend: "ref".into(),
            replicas,
            route: route.into(),
            ..Default::default()
        }
    }

    #[test]
    fn route_policy_parse_roundtrip() {
        for (s, p) in [
            ("rr", RoutePolicy::RoundRobin),
            ("round-robin", RoutePolicy::RoundRobin),
            ("least-loaded", RoutePolicy::LeastLoaded),
            ("ll", RoutePolicy::LeastLoaded),
            ("prefix", RoutePolicy::PrefixAffinity),
            ("prefix-affinity", RoutePolicy::PrefixAffinity),
        ] {
            assert_eq!(RoutePolicy::parse(s).unwrap(), p);
        }
        assert!(RoutePolicy::parse("nope").is_err());
    }

    #[test]
    fn round_robin_cycles_and_ids_are_unique() {
        let handle = Router::start(toy_cfg(3, "rr")).unwrap();
        let router = handle.router.clone();
        let mut ids = Vec::new();
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                let (id, rx) = router.submit_opts(SubmitOpts::new(
                    &format!("the color of tom number {i}"),
                    3,
                    Variant::Chai,
                ));
                ids.push(id);
                rx
            })
            .collect();
        for rx in rxs {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        ids.dedup();
        assert_eq!(ids.len(), 6, "router ids must be unique across replicas");
        // rotation touched every replica
        for i in 0..3 {
            assert_eq!(
                router.metrics.counter(&format!("router_routed_replica_{i}")),
                2,
                "round-robin must spread 6 requests 2/2/2"
            );
        }
        let stats = router.stats_json();
        assert_eq!(
            stats.get("counters").unwrap().get("completed").unwrap().usize().unwrap(),
            6,
            "rollup must sum completions across replicas"
        );
        assert_eq!(
            stats.get("router").unwrap().get("replicas").unwrap().usize().unwrap(),
            3
        );
        handle.shutdown();
    }

    #[test]
    fn prefix_affinity_pins_equal_prefixes_to_one_replica() {
        let handle = Router::start(toy_cfg(4, "prefix")).unwrap();
        let router = handle.router.clone();
        // same long system prompt, different tails → same replica
        let sys = "you are a helpful assistant; answer briefly and cite tom";
        let picks: Vec<usize> = (0..4)
            .map(|i| {
                router.route(&SubmitOpts::new(
                    &format!("{sys} || question {i}"),
                    2,
                    Variant::Chai,
                ))
            })
            .collect();
        assert!(
            picks.iter().all(|p| *p == picks[0]),
            "shared system prompt must pin to one replica: {picks:?}"
        );
        handle.shutdown();
    }

    #[test]
    fn drain_replica_migrates_and_survivors_finish() {
        let handle = Router::start(toy_cfg(2, "rr")).unwrap();
        let router = handle.router.clone();
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                router
                    .submit_opts(SubmitOpts::new(
                        &format!("tom drains the mesh number {i}"),
                        4,
                        Variant::Chai,
                    ))
                    .1
            })
            .collect();
        // rr spread 2/2; drain replica 0 immediately — whatever it holds
        // (pending, live, or already finished) must not be lost
        let moved = router.drain_replica(0).unwrap();
        for rx in rxs {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        assert!(router.drain_replica(0).is_err(), "second drain must refuse");
        assert_eq!(
            router.metrics.counter("router_migrated_sessions") as usize,
            moved
        );
        assert_eq!(router.metrics.gauge("router_replicas_alive") as usize, 1);
        handle.shutdown();
    }
}
