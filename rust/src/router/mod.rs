//! Multi-replica router front-end: the subsystem that turns one engine
//! into a service.
//!
//! The [`Router`] owns N data-parallel engine **replicas** — each a full
//! [`Coordinator`] with its own engine thread, [`crate::scheduler`], and
//! paged K,V pool — and places every incoming request by a pluggable
//! [`RoutePolicy`]:
//!
//! * **round-robin** (`--route rr`) — classic rotation, the baseline.
//! * **least-loaded** (`--route least-loaded`) — picks the replica with
//!   the smallest `pending + live + preempted` population (the same
//!   numbers the server's `{"cmd":"sched"}` view reports), so a replica
//!   stuck behind a long generation stops receiving new work.
//! * **prefix-affinity** (`--route prefix`) — hashes the prompt's
//!   shareable prefix ([`prompt_fingerprint`]: the token-hash chain of
//!   its leading full blocks, the exact keys the paged pool's prefix
//!   index uses) and pins the request to `digest % N`. Repeated system
//!   prompts therefore land on the replica that already holds those
//!   blocks, multiplying the paged cache's prefix-sharing wins — the
//!   same observation RelayAttention exploits for shared system
//!   prompts, applied at the replica-placement level.
//!
//! Replicas share model weights: on the reference backend the router
//! loads/synthesizes the model once ([`SharedRefModel`]) and each
//! replica's engine thread wraps the `Arc`'d weights in its own
//! backend, so N replicas cost one model copy plus N K,V pools. The
//! router owns the request-id space (ids stay unique across replicas);
//! cancellation broadcasts to every replica (exactly one holds the id;
//! the rest no-op), so the front-end needs no id→replica bookkeeping
//! that could leak.
//!
//! [`Frontend`] is the seam the TCP server drives — both a bare
//! [`Coordinator`] (single replica, zero router overhead) and the
//! [`Router`] implement it, so every protocol feature (streaming,
//! cancellation, stats/kv/sched/info views) works identically at both
//! scales. Router views roll up counters and gauges across replicas
//! (prefix hit rate recomputed from the summed block counts), attach a
//! `router` section (`router_*` counters, per-replica routed counts,
//! live load costs), and keep the per-replica breakdown.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::ServingConfig;
use crate::coordinator::{Coordinator, CoordinatorHandle};
use crate::engine::Engine;
use crate::kv::paged::prompt_fingerprint;
use crate::metrics::{sum_json_objects, Metrics};
use crate::model::tokenizer;
use crate::runtime::reference::{RefBackend, SharedRefModel};
use crate::scheduler::{RespSink, Response, SubmitOpts};
use crate::util::json::Json;

/// The serving surface the TCP server (and benches) drive — implemented
/// by both a single [`Coordinator`] and the multi-replica [`Router`].
pub trait Frontend: Clone + Send + 'static {
    /// Submit a request (assigning its id); returns `(id, response rx)`.
    fn submit_opts(&self, opts: SubmitOpts) -> (u64, Receiver<Response>);
    /// Submit with a caller-supplied terminal sink instead of a fresh
    /// channel (the epoll reactor path: the response lands in the
    /// request's lock-free event ring); returns the assigned id.
    fn submit_sink(&self, opts: SubmitOpts, resp: RespSink) -> u64;
    /// Request an abort of `id` (async; unknown ids are a no-op).
    fn cancel(&self, id: u64);
    /// `{"cmd":"stats"}` — full counters/latency/gauges/info view.
    fn stats_json(&self) -> Json;
    /// `{"cmd":"kv"}` — paged-pool occupancy + sharing gauges.
    fn kv_json(&self) -> Json;
    /// `{"cmd":"sched"}` — queue depths + preemption/swap counters.
    fn sched_json(&self) -> Json;
    /// `{"cmd":"info"}` — static serving facts (backend, model, ...).
    fn info_json(&self) -> Json;
}

impl Frontend for Coordinator {
    fn submit_opts(&self, opts: SubmitOpts) -> (u64, Receiver<Response>) {
        Coordinator::submit_opts(self, opts)
    }

    fn submit_sink(&self, opts: SubmitOpts, resp: RespSink) -> u64 {
        Coordinator::submit_sink(self, opts, resp)
    }

    fn cancel(&self, id: u64) {
        Coordinator::cancel(self, id)
    }

    fn stats_json(&self) -> Json {
        self.metrics.to_json()
    }

    fn kv_json(&self) -> Json {
        self.metrics
            .to_json()
            .opt("gauges")
            .cloned()
            .unwrap_or_else(|| Json::obj(vec![]))
    }

    fn sched_json(&self) -> Json {
        self.metrics.subset_json(&["sched_", "swap_", "kv_defer"])
    }

    fn info_json(&self) -> Json {
        self.metrics
            .to_json()
            .opt("info")
            .cloned()
            .unwrap_or_else(|| Json::obj(vec![]))
    }
}

/// Base of the router-assigned request-id space. Disjoint from the
/// ids a bare [`Coordinator::submit`] hands out (which count up from
/// 1), so a broadcast cancel for a router id can never collide with a
/// request submitted directly to a replica coordinator on the side.
pub const ROUTER_ID_BASE: u64 = 1 << 32;

/// Leading full blocks the prefix-affinity digest covers (with the
/// default 16-token blocks: the first 64 tokens). Capping keeps
/// affinity robust to tails — "system prompt + question A/B" must map
/// to the SAME replica even when the questions spill into further full
/// blocks; an uncapped chain digest would scatter exactly that
/// traffic. Bounded hashing also keeps routing O(1)-ish per request.
pub const AFFINITY_PREFIX_BLOCKS: usize = 4;

/// Replica-placement policy (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
    PrefixAffinity,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Result<RoutePolicy> {
        Ok(match s {
            "rr" | "round-robin" => RoutePolicy::RoundRobin,
            "least-loaded" | "ll" => RoutePolicy::LeastLoaded,
            "prefix" | "prefix-affinity" => RoutePolicy::PrefixAffinity,
            other => bail!("unknown route policy {other:?} (rr|least-loaded|prefix)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "rr",
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::PrefixAffinity => "prefix",
        }
    }
}

/// Multi-replica front-end; cheap to clone (all state is `Arc`'d).
#[derive(Clone)]
pub struct Router {
    replicas: Arc<Vec<Coordinator>>,
    policy: RoutePolicy,
    /// router-owned global id space (unique across replicas)
    next_id: Arc<AtomicU64>,
    rr: Arc<AtomicUsize>,
    /// router-level metrics only (`router_*`); replica metrics live on
    /// each coordinator and are rolled up on read
    pub metrics: Arc<Metrics>,
    /// block size the prefix-affinity fingerprint is computed at (must
    /// match the replicas' paged pools so the digest keys align)
    kv_block_size: usize,
}

/// Owns the replica engine threads; dropping (or `shutdown`) stops all.
pub struct RouterHandle {
    pub router: Router,
    replica_handles: Vec<CoordinatorHandle>,
}

impl RouterHandle {
    pub fn shutdown(self) {
        for h in self.replica_handles {
            h.shutdown();
        }
    }
}

impl Router {
    /// Spawn `cfg.replicas` engine replicas (weights shared on the
    /// reference backend) routed by `cfg.route`.
    pub fn start(cfg: ServingConfig) -> Result<RouterHandle> {
        let n = cfg.replicas.max(1);
        let policy = RoutePolicy::parse(&cfg.route)?;
        // one physical copy of the model for all replicas (ref backend;
        // the XLA backend is Rc-bound to its engine thread and loads
        // per replica)
        let shared = match crate::runtime::resolve_backend(&cfg)? {
            "ref" => Some(SharedRefModel::load_or_toy(&cfg.artifacts_dir, cfg.seed)?),
            _ => None,
        };
        let mut replicas = Vec::with_capacity(n);
        let mut replica_handles = Vec::with_capacity(n);
        for _ in 0..n {
            let handle = match shared.clone() {
                Some(model) => {
                    let engine_cfg = cfg.clone();
                    Coordinator::start_with(
                        cfg.clone(),
                        Box::new(move || {
                            Engine::with_backend(
                                Box::new(RefBackend::from_shared(&model)),
                                engine_cfg,
                            )
                        }),
                    )?
                }
                None => Coordinator::start(cfg.clone())?,
            };
            replicas.push(handle.coordinator.clone());
            replica_handles.push(handle);
        }
        let metrics = Arc::new(Metrics::new());
        metrics.set_info("router_policy", policy.name());
        metrics.set_gauge("router_replicas", n as f64);
        let router = Router {
            replicas: Arc::new(replicas),
            policy,
            next_id: Arc::new(AtomicU64::new(ROUTER_ID_BASE)),
            rr: Arc::new(AtomicUsize::new(0)),
            metrics,
            kv_block_size: cfg.kv_block_size.max(1),
        };
        Ok(RouterHandle { replica_handles, router })
    }

    pub fn replicas(&self) -> &[Coordinator] {
        &self.replicas
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Pick the replica for a request (see [`RoutePolicy`]).
    fn route(&self, opts: &SubmitOpts) -> usize {
        let n = self.replicas.len();
        match self.policy {
            RoutePolicy::RoundRobin => self.rr.fetch_add(1, Ordering::Relaxed) % n,
            RoutePolicy::LeastLoaded => {
                // stable argmin: earliest replica wins ties
                let mut best = 0usize;
                let mut best_cost = f64::INFINITY;
                for (i, c) in self.replicas.iter().enumerate() {
                    let cost = c.load_cost();
                    if cost < best_cost {
                        best = i;
                        best_cost = cost;
                    }
                }
                best
            }
            RoutePolicy::PrefixAffinity => {
                // one extra O(prompt) byte-level encode on the server
                // thread (the engine re-tokenizes on its own thread) —
                // routing must not wait on the engine
                let tokens = tokenizer::encode(&opts.prompt, true, false);
                let fp = prompt_fingerprint(
                    &opts.variant.name(),
                    &tokens,
                    self.kv_block_size,
                    AFFINITY_PREFIX_BLOCKS,
                );
                (fp % n as u64) as usize
            }
        }
    }

    /// Sum of a counter across all replicas.
    pub fn counter_sum(&self, name: &str) -> u64 {
        self.replicas.iter().map(|c| c.metrics.counter(name)).sum()
    }

    /// Sum of a gauge across all replicas.
    pub fn gauge_sum(&self, name: &str) -> f64 {
        self.replicas.iter().map(|c| c.metrics.gauge(name)).sum()
    }

    /// Aggregate prefix-sharing hit rate, recomputed from the summed
    /// hit/miss block counts (a mean of per-replica rates would weight
    /// idle replicas equally with busy ones).
    pub fn prefix_hit_rate(&self) -> f64 {
        let hits = self.gauge_sum("paged_prefix_hit_blocks");
        let total = hits + self.gauge_sum("paged_prefix_miss_blocks");
        if total <= 0.0 {
            0.0
        } else {
            hits / total
        }
    }

    /// The `router` section of the rolled-up views: policy, replica
    /// count, per-replica routed counts and live load costs, plus every
    /// router-level counter.
    fn router_json(&self) -> Json {
        let routed: Vec<Json> = (0..self.replicas.len())
            .map(|i| {
                Json::Num(self.metrics.counter(&format!("router_routed_replica_{i}")) as f64)
            })
            .collect();
        let load: Vec<Json> =
            self.replicas.iter().map(|c| Json::Num(c.load_cost())).collect();
        Json::obj(vec![
            ("policy", Json::Str(self.policy.name().into())),
            ("replicas", Json::Num(self.replicas.len() as f64)),
            (
                "routed_total",
                Json::Num(self.metrics.counter("router_routed_total") as f64),
            ),
            (
                "cancel_requests",
                Json::Num(self.metrics.counter("router_cancel_requests") as f64),
            ),
            ("routed", Json::Arr(routed)),
            ("load", Json::Arr(load)),
        ])
    }

    /// Roll gauges up across replicas and patch the aggregate hit rate
    /// (sums of rates are meaningless).
    fn rolled_gauges(&self, per: &[Json]) -> Json {
        let mut gauges = sum_json_objects(per.iter().filter_map(|j| j.opt("gauges")));
        if let Json::Obj(m) = &mut gauges {
            if m.contains_key("paged_prefix_hit_rate") {
                m.insert(
                    "paged_prefix_hit_rate".into(),
                    Json::Num(self.prefix_hit_rate()),
                );
            }
            m.insert("router_replicas".into(), Json::Num(self.replicas.len() as f64));
        }
        gauges
    }
}

impl Frontend for Router {
    fn submit_opts(&self, opts: SubmitOpts) -> (u64, Receiver<Response>) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let r = self.route(&opts);
        self.metrics.inc("router_routed_total");
        self.metrics.inc(&format!("router_routed_replica_{r}"));
        (id, self.replicas[r].submit_with_id(id, opts))
    }

    fn submit_sink(&self, opts: SubmitOpts, resp: RespSink) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let r = self.route(&opts);
        self.metrics.inc("router_routed_total");
        self.metrics.inc(&format!("router_routed_replica_{r}"));
        self.replicas[r].submit_request(id, opts, resp);
        id
    }

    /// Broadcast: exactly one replica holds the id, the rest no-op.
    fn cancel(&self, id: u64) {
        self.metrics.inc("router_cancel_requests");
        for c in self.replicas.iter() {
            c.cancel(id);
        }
    }

    fn stats_json(&self) -> Json {
        let per: Vec<Json> = self.replicas.iter().map(|c| c.metrics.to_json()).collect();
        let counters = sum_json_objects(per.iter().filter_map(|j| j.opt("counters")));
        let gauges = self.rolled_gauges(&per);
        let info = Frontend::info_json(self);
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("info", info),
            ("router", self.router_json()),
            ("replicas", Json::Arr(per)),
        ])
    }

    fn kv_json(&self) -> Json {
        let per: Vec<Json> =
            self.replicas.iter().map(|c| Frontend::kv_json(c)).collect();
        self.rolled_gauges(
            &per.iter()
                .map(|g| Json::obj(vec![("gauges", g.clone())]))
                .collect::<Vec<_>>(),
        )
    }

    fn sched_json(&self) -> Json {
        let per: Vec<Json> =
            self.replicas.iter().map(|c| Frontend::sched_json(c)).collect();
        let mut merged = sum_json_objects(per.iter());
        if let Json::Obj(m) = &mut merged {
            m.insert("router".into(), self.router_json());
            m.insert("per_replica".into(), Json::Arr(per));
        }
        merged
    }

    fn info_json(&self) -> Json {
        // replica 0 speaks for the fleet (same backend/model everywhere)
        let mut info = self
            .replicas
            .first()
            .map(|c| Frontend::info_json(c))
            .unwrap_or_else(|| Json::obj(vec![]));
        if let Json::Obj(m) = &mut info {
            m.insert("replicas".into(), Json::Num(self.replicas.len() as f64));
            m.insert("route".into(), Json::Str(self.policy.name().into()));
        }
        info
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Variant;
    use std::path::PathBuf;

    fn toy_cfg(replicas: usize, route: &str) -> ServingConfig {
        ServingConfig {
            artifacts_dir: PathBuf::from("definitely-no-artifacts-here"),
            backend: "ref".into(),
            replicas,
            route: route.into(),
            ..Default::default()
        }
    }

    #[test]
    fn route_policy_parse_roundtrip() {
        for (s, p) in [
            ("rr", RoutePolicy::RoundRobin),
            ("round-robin", RoutePolicy::RoundRobin),
            ("least-loaded", RoutePolicy::LeastLoaded),
            ("ll", RoutePolicy::LeastLoaded),
            ("prefix", RoutePolicy::PrefixAffinity),
            ("prefix-affinity", RoutePolicy::PrefixAffinity),
        ] {
            assert_eq!(RoutePolicy::parse(s).unwrap(), p);
        }
        assert!(RoutePolicy::parse("nope").is_err());
    }

    #[test]
    fn round_robin_cycles_and_ids_are_unique() {
        let handle = Router::start(toy_cfg(3, "rr")).unwrap();
        let router = handle.router.clone();
        let mut ids = Vec::new();
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                let (id, rx) = router.submit_opts(SubmitOpts::new(
                    &format!("the color of tom number {i}"),
                    3,
                    Variant::Chai,
                ));
                ids.push(id);
                rx
            })
            .collect();
        for rx in rxs {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        ids.dedup();
        assert_eq!(ids.len(), 6, "router ids must be unique across replicas");
        // rotation touched every replica
        for i in 0..3 {
            assert_eq!(
                router.metrics.counter(&format!("router_routed_replica_{i}")),
                2,
                "round-robin must spread 6 requests 2/2/2"
            );
        }
        let stats = router.stats_json();
        assert_eq!(
            stats.get("counters").unwrap().get("completed").unwrap().usize().unwrap(),
            6,
            "rollup must sum completions across replicas"
        );
        assert_eq!(
            stats.get("router").unwrap().get("replicas").unwrap().usize().unwrap(),
            3
        );
        handle.shutdown();
    }

    #[test]
    fn prefix_affinity_pins_equal_prefixes_to_one_replica() {
        let handle = Router::start(toy_cfg(4, "prefix")).unwrap();
        let router = handle.router.clone();
        // same long system prompt, different tails → same replica
        let sys = "you are a helpful assistant; answer briefly and cite tom";
        let picks: Vec<usize> = (0..4)
            .map(|i| {
                router.route(&SubmitOpts::new(
                    &format!("{sys} || question {i}"),
                    2,
                    Variant::Chai,
                ))
            })
            .collect();
        assert!(
            picks.iter().all(|p| *p == picks[0]),
            "shared system prompt must pin to one replica: {picks:?}"
        );
        handle.shutdown();
    }
}
