//! Consistent-hash ring for prefix-affinity placement across replicas.
//!
//! The router places a request by its [`prompt_fingerprint`] digest
//! (`crate::kv::paged::prompt_fingerprint`): same shared-prefix traffic
//! → same digest → same replica, so the replica that already holds the
//! prefix blocks keeps getting the requests that can adopt them. PR 5
//! mapped digests to replicas with a plain `% n` — fine while the
//! replica set is fixed for the process lifetime, catastrophic for a
//! mesh: changing `n` by one remaps ~`(n-1)/n` of all keys, evicting
//! almost every warmed prefix in the fleet at once.
//!
//! A consistent-hash ring bounds that movement. Each replica owns
//! [`VNODES`] pseudo-random points on a `u64` ring (splitmix64 of
//! `(replica, vnode)` — deterministic, no coordination); a key belongs
//! to the first replica point clockwise from its digest. Removing a
//! replica only reassigns keys in the arcs its points owned (~`1/R` of
//! the keyspace, spread across survivors); adding one only steals
//! ~`1/(R+1)`. Keys whose owning replica survives NEVER move — both
//! properties are property-tested in this module.
//!
//! Membership is a set of opaque `u64` replica ids, so the ring keeps
//! working as replicas die and rejoin (a rejoining replica reclaims
//! exactly its old arcs).

/// Virtual nodes per replica. More vnodes → smoother load split between
/// survivors when a replica dies (each survivor inherits many small
/// arcs instead of one big one); 64 keeps the max/min keyspace share
/// within ~2x for small fleets while the sorted ring stays tiny
/// (R × 64 points).
pub const VNODES: usize = 64;

/// splitmix64 — the same finalizer `util::rng` seeds from; good 64-bit
/// avalanche so ring points spread uniformly.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Sorted ring of (point, replica-id) pairs.
#[derive(Debug, Clone, Default)]
pub struct HashRing {
    points: Vec<(u64, u64)>,
}

impl HashRing {
    /// Build a ring over the given replica ids (duplicates ignored).
    pub fn new(replicas: &[u64]) -> HashRing {
        let mut ring = HashRing::default();
        for &r in replicas {
            ring.add(r);
        }
        ring
    }

    /// Add a replica's vnode points (no-op if already present).
    pub fn add(&mut self, replica: u64) {
        if self.contains(replica) {
            return;
        }
        for v in 0..VNODES as u64 {
            // mix the replica id first so consecutive ids don't produce
            // correlated point sets, then spread its vnodes
            let point = splitmix64(splitmix64(replica) ^ v.wrapping_mul(0xd6e8feb86659fd93));
            self.points.push((point, replica));
        }
        self.points.sort_unstable();
    }

    /// Remove every point a replica owns (no-op if absent).
    pub fn remove(&mut self, replica: u64) {
        self.points.retain(|&(_, r)| r != replica);
    }

    pub fn contains(&self, replica: u64) -> bool {
        self.points.iter().any(|&(_, r)| r == replica)
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Number of distinct replicas on the ring.
    pub fn len(&self) -> usize {
        let mut ids: Vec<u64> = self.points.iter().map(|&(_, r)| r).collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }

    /// Owner of `key`: the first ring point at or clockwise past the
    /// key's hash (wrapping to the smallest point). `None` on an empty
    /// ring. The key is re-mixed so callers may pass raw fingerprints
    /// without worrying about their distribution.
    pub fn owner(&self, key: u64) -> Option<u64> {
        if self.points.is_empty() {
            return None;
        }
        let h = splitmix64(key);
        let i = self.points.partition_point(|&(p, _)| p < h);
        let (_, r) = self.points[i % self.points.len()];
        Some(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn corpus(rng: &mut Rng, n: usize) -> Vec<u64> {
        (0..n).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn empty_ring_owns_nothing() {
        let ring = HashRing::default();
        assert!(ring.owner(42).is_none());
        assert!(ring.is_empty());
    }

    #[test]
    fn single_replica_owns_everything() {
        let ring = HashRing::new(&[7]);
        for k in 0..1000u64 {
            assert_eq!(ring.owner(k), Some(7));
        }
    }

    #[test]
    fn add_remove_roundtrip_is_identity() {
        let mut ring = HashRing::new(&[1, 2, 3, 4]);
        let before: Vec<_> = (0..2000u64).map(|k| ring.owner(k)).collect();
        ring.remove(3);
        ring.add(3);
        let after: Vec<_> = (0..2000u64).map(|k| ring.owner(k)).collect();
        assert_eq!(before, after, "a rejoining replica must reclaim exactly its old arcs");
    }

    /// Property (the bounded-movement contract): removing one of R
    /// replicas remaps at most ~1/R of a fingerprint corpus — and never
    /// a key whose owner survived; adding one back steals at most
    /// ~1/(R+1), only for itself.
    #[test]
    fn prop_membership_change_remaps_at_most_one_rth() {
        check("ring bounded movement", 20, |rng| {
            let r = 2 + rng.below(7) as u64; // fleets of 2..=8
            let ids: Vec<u64> = (0..r).map(|i| rng.next_u64() ^ i).collect();
            let ring = HashRing::new(&ids);
            let keys = corpus(rng, 4000);
            let owners: Vec<u64> = keys.iter().map(|&k| ring.owner(k).unwrap()).collect();
            // remove one replica
            let victim = ids[rng.below(r as usize)];
            let mut shrunk = ring.clone();
            shrunk.remove(victim);
            let mut moved = 0usize;
            for (k, &old) in keys.iter().zip(&owners) {
                let new = shrunk.owner(*k).unwrap();
                prop_assert!(new != victim, "removed replica must own nothing");
                prop_assert!(
                    old == victim || new == old,
                    "key with surviving owner remapped {old} -> {new} (R={r})"
                );
                if new != old {
                    moved += 1;
                }
            }
            // expected share is 1/R; vnode variance keeps it well under
            // 2/R for any fleet size tested here
            let bound = (2.0 / r as f64 * keys.len() as f64).ceil() as usize;
            prop_assert!(
                moved <= bound,
                "removing 1 of {r} replicas moved {moved}/{} keys (bound {bound})",
                keys.len()
            );
            // adding a fresh replica steals at most ~1/(R+1), and only
            // for itself
            let newcomer = rng.next_u64() | 1 << 63;
            let mut grown = ring.clone();
            grown.add(newcomer);
            let mut stolen = 0usize;
            for (k, &old) in keys.iter().zip(&owners) {
                let new = grown.owner(*k).unwrap();
                prop_assert!(
                    new == old || new == newcomer,
                    "growth may only move keys TO the newcomer"
                );
                if new != old {
                    stolen += 1;
                }
            }
            let bound = (2.0 / (r + 1) as f64 * keys.len() as f64).ceil() as usize;
            prop_assert!(
                stolen <= bound,
                "adding to {r} replicas stole {stolen} keys (bound {bound})"
            );
            Ok(())
        });
    }

    /// Load balance sanity: with VNODES points per replica no replica
    /// owns a grossly outsized keyspace share.
    #[test]
    fn prop_load_split_is_roughly_uniform() {
        let mut rng = Rng::new(0xfeed);
        let ids: Vec<u64> = (0..4u64).map(|i| rng.next_u64() ^ i).collect();
        let ring = HashRing::new(&ids);
        let keys = corpus(&mut rng, 8000);
        let mut counts = std::collections::HashMap::new();
        for k in &keys {
            *counts.entry(ring.owner(*k).unwrap()).or_insert(0usize) += 1;
        }
        assert_eq!(counts.len(), 4, "every replica must own some keys");
        for (id, c) in counts {
            let share = c as f64 / keys.len() as f64;
            assert!(
                (0.08..=0.55).contains(&share),
                "replica {id} owns {share:.2} of the keyspace"
            );
        }
    }
}
