//! Location-transparent replica transports behind the [`Router`].
//!
//! [`ReplicaTransport`] is the seam that makes the router indifferent
//! to where a replica runs:
//!
//! * [`LocalReplica`] — PR 5's shape: a full in-process [`Coordinator`]
//!   (engine thread + scheduler + paged pool). Zero serialization;
//!   sessions migrate as in-memory [`MigratedSession`] values.
//! * [`ProcessReplica`] (Linux) — a separate `chai replica` child
//!   process serving the line-JSON protocol over the epoll reactor.
//!   The router keeps two connections per replica: a **data**
//!   connection carrying submits, token frames, terminals, and the
//!   drain exchange (per-connection FIFO is what makes drain
//!   race-free: the `{"drained":...}` reply is ordered after the final
//!   frame/terminal of everything drained), and a **control**
//!   connection for lockstep probe/cancel/stats calls (their replies
//!   carry `"id"` without `"tok"` and would be misread as terminals on
//!   the data stream).
//!
//! The router's per-request **entry registry** is the failover
//! substrate: every accepted request is recorded (prompt, sinks,
//! frames-forwarded count) *before* its wire line is written, so when
//! a replica dies — `kill -9` included — [`ProcessReplica::take_orphans`]
//! reconstructs every in-flight request and the router requeues it on
//! survivors at the recorded stream offset. Greedy decode regenerates
//! identical tokens; the offset keeps the client's stream exactly-once.

use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::coordinator::{Coordinator, CoordinatorHandle};
use crate::engine::MigratedSession;
use crate::scheduler::{Request, RespSink, SubmitOpts};
use crate::util::json::Json;
use crate::util::now_ms;

use super::Frontend;

/// A request reclaimed from a replica (graceful drain or crash
/// requeue), carrying everything a survivor needs to finish it.
pub struct MeshDrained {
    pub req: Request,
    /// frames the CLIENT has already received (the router's count, not
    /// the dead replica's — only forwarded frames matter for
    /// exactly-once streaming)
    pub streamed: usize,
    /// frozen session state; `None` restarts decode from scratch at the
    /// stream offset (bit-identical under greedy decode)
    pub session: Option<MeshSession>,
}

/// Frozen session state in whichever form the source transport holds.
pub enum MeshSession {
    /// in-memory handoff between local replicas (no serialization)
    Local(MigratedSession),
    /// [`crate::mesh`] wire record from a remote replica's drain reply;
    /// decoded on the adopting engine's thread
    Wire(Json),
}

/// One replica as the router sees it, wherever it runs.
pub trait ReplicaTransport: Send + Sync {
    /// Transport name for views ("local" | "process").
    fn kind(&self) -> &'static str;
    /// Place a request (the router already assigned `id`).
    fn submit(&self, id: u64, opts: SubmitOpts, resp: RespSink);
    /// Forward a cancel (unknown ids are a no-op).
    fn cancel(&self, id: u64);
    /// Latest known scheduling load (least-loaded routing).
    fn load_cost(&self) -> f64;
    /// Cheap liveness check (never blocks on I/O).
    fn alive(&self) -> bool;
    /// Active health probe; `Err` feeds the suspect→dead escalation.
    fn probe(&self) -> Result<f64>;
    /// One replica counter/gauge (rollup sums).
    fn counter(&self, name: &str) -> u64;
    fn gauge(&self, name: &str) -> f64;
    /// Full metrics view (`{"counters":..., "gauges":..., ...}`).
    fn metrics_json(&self) -> Json;
    /// One named command view ("kv" | "sched" | "info").
    fn view_json(&self, kind: &str) -> Json;
    /// Graceful migration: stop admitting, freeze/collect every held
    /// request. The replica is not shut down by this call.
    fn drain(&self) -> Result<Vec<MeshDrained>>;
    /// Resume a drained/orphaned request on this replica.
    fn adopt(&self, d: MeshDrained);
    /// Requests the router has accepted onto this replica that have not
    /// reached their terminal yet (failover accounting).
    fn inflight(&self) -> usize;
    /// Reclaim every tracked in-flight request after a crash (session
    /// state is gone; survivors replay from the stream offset).
    fn take_orphans(&self) -> Vec<MeshDrained>;
    /// Stop the replica (idempotent).
    fn shutdown(&self);
    /// SIGKILL the replica, bypassing every graceful path — the
    /// failover drill's hammer. Errors on transports with nothing to
    /// kill.
    fn kill_hard(&self) -> Result<()>;
}

/// Rebuild a submittable request from a tracked entry (crash requeue or
/// a drain record): survivors resume it at the recorded stream offset.
fn entry_to_drained(rid: u64, e: Entry, session: Option<MeshSession>) -> MeshDrained {
    let streamed = e.streamed;
    MeshDrained {
        req: Request {
            id: rid,
            prompt: e.prompt,
            max_new: e.max_new,
            variant: e.variant,
            submitted_ms: now_ms(),
            resp_tx: e.resp,
            stream: e.stream,
            stream_offset: streamed,
            // the SAME trace id survives the requeue: the request's
            // second life on a survivor lands on the original timeline
            trace: e.trace,
        },
        streamed,
        session,
    }
}

/// Router-side record of one request placed on a remote replica. Held
/// from before the submit line is written until the terminal arrives —
/// the registry IS the zero-loss guarantee.
struct Entry {
    prompt: String,
    max_new: usize,
    variant: crate::engine::Variant,
    stream: Option<crate::scheduler::FrameSink>,
    resp: RespSink,
    /// frames forwarded to the client so far (authoritative for
    /// exactly-once resume; the child's own count is irrelevant once
    /// it is dead)
    streamed: usize,
    /// observability trace id (0 = untraced); outlives the child that
    /// first served the request
    trace: u64,
}

// ---------------------------------------------------------------------
// Local transport
// ---------------------------------------------------------------------

/// In-process replica: a [`Coordinator`] behind the transport seam.
pub struct LocalReplica {
    coordinator: Coordinator,
    handle: Mutex<Option<CoordinatorHandle>>,
}

impl LocalReplica {
    pub fn new(handle: CoordinatorHandle) -> LocalReplica {
        LocalReplica {
            coordinator: handle.coordinator.clone(),
            handle: Mutex::new(Some(handle)),
        }
    }
}

impl ReplicaTransport for LocalReplica {
    fn kind(&self) -> &'static str {
        "local"
    }

    fn submit(&self, id: u64, opts: SubmitOpts, resp: RespSink) {
        self.coordinator.submit_request(id, opts, resp);
    }

    fn cancel(&self, id: u64) {
        self.coordinator.cancel(id);
    }

    fn load_cost(&self) -> f64 {
        self.coordinator.load_cost()
    }

    fn alive(&self) -> bool {
        true
    }

    fn probe(&self) -> Result<f64> {
        Ok(self.coordinator.load_cost())
    }

    fn counter(&self, name: &str) -> u64 {
        self.coordinator.metrics.counter(name)
    }

    fn gauge(&self, name: &str) -> f64 {
        self.coordinator.metrics.gauge(name)
    }

    fn metrics_json(&self) -> Json {
        self.coordinator.metrics.to_json()
    }

    fn view_json(&self, kind: &str) -> Json {
        match kind {
            "kv" => Frontend::kv_json(&self.coordinator),
            "sched" => Frontend::sched_json(&self.coordinator),
            "info" => Frontend::info_json(&self.coordinator),
            // a local replica's spans live in the router process's own
            // per-thread rings — the router's dump already has them, so
            // this view contributes nothing extra
            "trace" => Json::obj(vec![("traceEvents", Json::Arr(Vec::new()))]),
            _ => Json::Null,
        }
    }

    fn drain(&self) -> Result<Vec<MeshDrained>> {
        Ok(self
            .coordinator
            .drain_collect()
            .into_iter()
            .map(|d| MeshDrained {
                req: d.req,
                streamed: d.streamed,
                session: d.session.map(MeshSession::Local),
            })
            .collect())
    }

    fn adopt(&self, d: MeshDrained) {
        let MeshDrained { req, streamed, session } = d;
        match session {
            None => {
                // no frozen state: replay from scratch at the offset
                let Request { id, prompt, max_new, variant, resp_tx, stream, trace, .. } = req;
                let opts = SubmitOpts {
                    prompt,
                    max_new,
                    variant,
                    stream,
                    stream_offset: streamed,
                    trace,
                };
                self.coordinator.submit_request(id, opts, resp_tx);
            }
            Some(MeshSession::Local(m)) => self.coordinator.adopt_local(req, m, streamed),
            Some(MeshSession::Wire(j)) => self.coordinator.adopt_wire(req, j, streamed),
        }
    }

    fn inflight(&self) -> usize {
        // the coordinator owns its requests end-to-end; the router
        // tracks nothing, so a local replica has no router-side
        // in-flight set (and cannot crash independently)
        0
    }

    fn take_orphans(&self) -> Vec<MeshDrained> {
        Vec::new()
    }

    fn shutdown(&self) {
        if let Some(h) = self.handle.lock().unwrap().take() {
            h.shutdown();
        }
    }

    fn kill_hard(&self) -> Result<()> {
        bail!("local replicas share the router process; nothing to kill")
    }
}

// ---------------------------------------------------------------------
// Process transport (Linux: the replica serves over the epoll reactor)
// ---------------------------------------------------------------------

#[cfg(target_os = "linux")]
pub use process::ProcessReplica;

#[cfg(target_os = "linux")]
mod process {
    use super::*;

    use std::collections::HashMap;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::path::PathBuf;
    use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::mpsc::{channel, Sender};
    use std::sync::Arc;
    use std::thread;
    use std::time::Duration;

    use anyhow::{anyhow, Context};

    use crate::config::ServingConfig;
    use crate::engine::Timing;
    use crate::metrics::Metrics;
    use crate::scheduler::Response;
    use crate::scheduler::StreamFrame;
    use crate::server::Client;

    /// Lockstep control calls time out after this long; a probe that
    /// blows it marks the control connection poisoned (a half-read
    /// reply would desync its framing) and counts as a failed probe.
    const CTL_TIMEOUT_MS: u64 = 1000;

    /// Upper bound on waiting for a drain reply before degrading to the
    /// crash path (requeue-from-scratch via the entry registry).
    const DRAIN_TIMEOUT_SECS: u64 = 30;

    /// How many 1ms attempts a frame forward gets when the client's
    /// event ring is momentarily full before the frame is dropped
    /// (terminals never drop; the count is surfaced as
    /// `router_dropped_frames`).
    const FRAME_RETRIES: usize = 2000;

    type Entries = Arc<Mutex<HashMap<u64, Entry>>>;
    type DrainWaiter = Arc<Mutex<Option<Sender<Vec<MeshDrained>>>>>;

    /// One `chai replica` child process.
    pub struct ProcessReplica {
        child: Mutex<Child>,
        /// held open for the child's lifetime: the child exits when its
        /// stdin reaches EOF, so dropping this pipe (shutdown, or the
        /// router process dying) is the orphan-cleanup signal
        stdin: Mutex<Option<ChildStdin>>,
        /// keeps the stdout pipe readable so a chatty child can never
        /// block on a closed pipe
        _stdout: Mutex<Option<ChildStdout>>,
        addr: String,
        data: Mutex<TcpStream>,
        ctl: Mutex<Option<Client>>,
        entries: Entries,
        dead: Arc<AtomicBool>,
        /// last probed load, as f64 bits
        load: AtomicU64,
        drain_waiter: DrainWaiter,
        reader: Mutex<Option<thread::JoinHandle<()>>>,
    }

    impl ProcessReplica {
        /// Spawn `chai replica`, wait for its one-line stdout handshake
        /// (`{"replica_listening":"<addr>"}`), and connect the data +
        /// control streams. `metrics` is the ROUTER's registry
        /// (`router_dropped_frames` lands there).
        pub fn spawn(index: usize, cfg: &ServingConfig, metrics: Arc<Metrics>) -> Result<Self> {
            let exe: PathBuf = match &cfg.replica_cmd {
                Some(p) => p.clone(),
                None => std::env::current_exe().context("resolving current executable")?,
            };
            let mut cmd = Command::new(&exe);
            cmd.arg("replica")
                .arg("--backend")
                .arg(&cfg.backend)
                .arg("--artifacts")
                .arg(&cfg.artifacts_dir)
                .arg("--variant")
                .arg(&cfg.variant)
                .arg("--max-new")
                .arg(cfg.max_new_tokens.to_string())
                .arg("--max-batch")
                .arg(cfg.max_batch.to_string())
                .arg("--temperature")
                .arg(cfg.temperature.to_string())
                .arg("--seed")
                .arg(cfg.seed.to_string())
                .arg("--kv-block-size")
                .arg(cfg.kv_block_size.to_string())
                .arg("--kv-capacity-bytes")
                .arg(cfg.kv_capacity_bytes.to_string())
                .arg("--starve-ticks")
                .arg(cfg.starve_ticks.to_string())
                .arg("--swap-blocks")
                .arg(cfg.swap_blocks.to_string())
                .arg("--recompute-max-tokens")
                .arg(cfg.recompute_max_tokens.to_string())
                .arg("--net-inbox")
                .arg(cfg.net_inbox.to_string());
            if !cfg.paged_kv {
                cmd.arg("--no-paged");
            }
            if !cfg.batched_decode {
                cmd.arg("--no-batched-decode");
            }
            if cfg.preempt {
                cmd.arg("--preempt");
            }
            if !cfg.relay {
                cmd.arg("--no-relay");
            }
            if cfg.pin_cores {
                cmd.arg("--pin-cores");
            }
            if !cfg.obs {
                cmd.arg("--no-obs");
            }
            cmd.stdin(Stdio::piped()).stdout(Stdio::piped()).stderr(Stdio::inherit());
            let mut child = cmd
                .spawn()
                .with_context(|| format!("spawning replica {index} ({})", exe.display()))?;
            let stdin = child.stdin.take();
            let stdout = child.stdout.take().context("replica stdout not piped")?;
            let mut lines = BufReader::new(stdout);
            let mut line = String::new();
            if lines.read_line(&mut line).unwrap_or(0) == 0 {
                let _ = child.kill();
                let _ = child.wait();
                bail!("replica {index} exited before its listening handshake");
            }
            let addr = (|| -> Result<String> {
                Ok(Json::parse(line.trim())?.get("replica_listening")?.str()?.to_string())
            })()
            .with_context(|| format!("replica {index} handshake line {line:?}"))?;
            let data = TcpStream::connect(&addr)
                .with_context(|| format!("replica {index} data connection to {addr}"))?;
            let _ = data.set_nodelay(true);
            let ctl_stream = TcpStream::connect(&addr)
                .with_context(|| format!("replica {index} control connection to {addr}"))?;
            let _ = ctl_stream.set_nodelay(true);
            ctl_stream.set_read_timeout(Some(Duration::from_millis(CTL_TIMEOUT_MS)))?;
            let entries: Entries = Arc::new(Mutex::new(HashMap::new()));
            let dead = Arc::new(AtomicBool::new(false));
            let drain_waiter: DrainWaiter = Arc::new(Mutex::new(None));
            let reader = {
                let stream = data.try_clone()?;
                let (entries, dead) = (entries.clone(), dead.clone());
                let (drain_waiter, metrics) = (drain_waiter.clone(), metrics);
                thread::Builder::new()
                    .name(format!("replica-{index}-reader"))
                    .spawn(move || reader_loop(stream, entries, drain_waiter, dead, metrics))?
            };
            Ok(ProcessReplica {
                child: Mutex::new(child),
                stdin: Mutex::new(stdin),
                _stdout: Mutex::new(Some(lines.into_inner())),
                addr,
                data: Mutex::new(data),
                ctl: Mutex::new(Some(Client::from_stream(ctl_stream)?)),
                entries,
                dead,
                load: AtomicU64::new(0),
                drain_waiter,
                reader: Mutex::new(Some(reader)),
            })
        }

        pub fn addr(&self) -> &str {
            &self.addr
        }

        /// Write one line on the data connection. The connection-wide
        /// writer mutex is the FIFO guarantee drain relies on: a drain
        /// command serializes after every submit written before it.
        fn write_data(&self, line: String) -> std::io::Result<()> {
            let mut s = self.data.lock().unwrap();
            s.write_all(line.as_bytes())?;
            s.write_all(b"\n")
        }

        /// Lockstep call on the control connection. Any failure poisons
        /// the connection (its lockstep framing can no longer be
        /// trusted) — subsequent probes fail and the supervisor
        /// escalates suspect→dead.
        fn ctl_call(&self, req: &Json) -> Result<Json> {
            let mut g = self.ctl.lock().unwrap();
            let client = g.as_mut().ok_or_else(|| anyhow!("control connection lost"))?;
            match client.call(req) {
                Ok(j) => Ok(j),
                Err(e) => {
                    *g = None;
                    Err(e)
                }
            }
        }

        fn ctl_cmd(&self, cmd: &str) -> Result<Json> {
            self.ctl_call(&Json::obj(vec![("cmd", Json::Str(cmd.into()))]))
        }

        fn register_and_write(&self, id: u64, entry: Entry, wire: Json) {
            // register BEFORE writing: a failed write leaves the entry
            // as an orphan and the request is requeued — a request can
            // be re-run (benign under greedy decode + stream offsets)
            // but never lost
            self.entries.lock().unwrap().insert(id, entry);
            if self.write_data(wire.to_string()).is_err() {
                self.dead.store(true, Ordering::SeqCst);
            }
        }
    }

    impl ReplicaTransport for ProcessReplica {
        fn kind(&self) -> &'static str {
            "process"
        }

        fn submit(&self, id: u64, opts: SubmitOpts, resp: RespSink) {
            if self.dead.load(Ordering::Relaxed) {
                resp.send(Response::error(id, "replica process is dead".into()));
                return;
            }
            let mut line = vec![
                ("prompt", Json::Str(opts.prompt.clone())),
                ("max_new", Json::Num(opts.max_new as f64)),
                ("variant", Json::Str(opts.variant.name())),
                ("rid", Json::Num(id as f64)),
            ];
            if opts.stream.is_some() {
                line.push(("stream", Json::Bool(true)));
            }
            if opts.stream_offset > 0 {
                line.push(("offset", Json::Num(opts.stream_offset as f64)));
            }
            if opts.trace != 0 {
                line.push(("trace", Json::Num(opts.trace as f64)));
            }
            let wire = Json::obj(line);
            let entry = Entry {
                prompt: opts.prompt,
                max_new: opts.max_new,
                variant: opts.variant,
                stream: opts.stream,
                resp,
                streamed: opts.stream_offset,
                trace: opts.trace,
            };
            self.register_and_write(id, entry, wire);
        }

        fn cancel(&self, id: u64) {
            if self.dead.load(Ordering::Relaxed) {
                return;
            }
            let _ = self.ctl_call(&Json::obj(vec![
                ("cmd", Json::Str("cancel".into())),
                ("id", Json::Num(id as f64)),
            ]));
        }

        fn load_cost(&self) -> f64 {
            f64::from_bits(self.load.load(Ordering::Relaxed))
        }

        fn alive(&self) -> bool {
            if self.dead.load(Ordering::Relaxed) {
                return false;
            }
            matches!(self.child.lock().unwrap().try_wait(), Ok(None))
        }

        fn probe(&self) -> Result<f64> {
            if self.dead.load(Ordering::Relaxed) {
                bail!("replica process is dead");
            }
            let j = self.ctl_cmd("probe")?;
            let load = j.get("load")?.num()?;
            self.load.store(load.to_bits(), Ordering::Relaxed);
            Ok(load)
        }

        fn counter(&self, name: &str) -> u64 {
            self.ctl_cmd("stats")
                .ok()
                .and_then(|j| j.opt("counters")?.opt(name)?.usize().ok())
                .unwrap_or(0) as u64
        }

        fn gauge(&self, name: &str) -> f64 {
            self.ctl_cmd("stats")
                .ok()
                .and_then(|j| j.opt("gauges")?.opt(name)?.num().ok())
                .unwrap_or(0.0)
        }

        fn metrics_json(&self) -> Json {
            self.ctl_cmd("stats")
                .unwrap_or_else(|_| Json::obj(vec![("unreachable", Json::Bool(true))]))
        }

        fn view_json(&self, kind: &str) -> Json {
            self.ctl_cmd(kind)
                .unwrap_or_else(|_| Json::obj(vec![("unreachable", Json::Bool(true))]))
        }

        fn drain(&self) -> Result<Vec<MeshDrained>> {
            if self.dead.load(Ordering::Relaxed) {
                return Ok(self.take_orphans());
            }
            let (tx, rx) = channel();
            *self.drain_waiter.lock().unwrap() = Some(tx);
            let cmd = Json::obj(vec![("cmd", Json::Str("drain".into()))]);
            if self.write_data(cmd.to_string()).is_err() {
                self.dead.store(true, Ordering::SeqCst);
                self.drain_waiter.lock().unwrap().take();
                return Ok(self.take_orphans());
            }
            match rx.recv_timeout(Duration::from_secs(DRAIN_TIMEOUT_SECS)) {
                Ok(v) => Ok(v),
                Err(_) => {
                    // degrade to the crash path: whatever the registry
                    // still holds restarts from scratch on survivors
                    self.dead.store(true, Ordering::SeqCst);
                    self.drain_waiter.lock().unwrap().take();
                    Ok(self.take_orphans())
                }
            }
        }

        fn adopt(&self, d: MeshDrained) {
            if self.dead.load(Ordering::Relaxed) {
                let id = d.req.id;
                d.req.resp_tx.send(Response::error(id, "replica process is dead".into()));
                return;
            }
            let MeshDrained { req, streamed, session } = d;
            let record = match session {
                None => {
                    // no frozen state — plain re-submit at the offset
                    let Request { id, prompt, max_new, variant, resp_tx, stream, trace, .. } =
                        req;
                    let opts = SubmitOpts {
                        prompt,
                        max_new,
                        variant,
                        stream,
                        stream_offset: streamed,
                        trace,
                    };
                    self.submit(id, opts, resp_tx);
                    return;
                }
                Some(MeshSession::Wire(j)) => j,
                Some(MeshSession::Local(m)) => crate::mesh::encode_migrated(&m),
            };
            let mut wire = vec![
                ("cmd", Json::Str("adopt".into())),
                ("rid", Json::Num(req.id as f64)),
                ("streamed", Json::Num(streamed as f64)),
                ("max_new", Json::Num(req.max_new as f64)),
                ("stream", Json::Bool(req.stream.is_some())),
            ];
            if req.trace != 0 {
                wire.push(("trace", Json::Num(req.trace as f64)));
            }
            wire.push(("session", record));
            let wire = Json::obj(wire);
            let id = req.id;
            let entry = Entry {
                prompt: req.prompt,
                max_new: req.max_new,
                variant: req.variant,
                stream: req.stream,
                resp: req.resp_tx,
                streamed,
                trace: req.trace,
            };
            self.register_and_write(id, entry, wire);
        }

        fn inflight(&self) -> usize {
            self.entries.lock().unwrap().len()
        }

        fn take_orphans(&self) -> Vec<MeshDrained> {
            let taken = std::mem::take(&mut *self.entries.lock().unwrap());
            taken.into_iter().map(|(rid, e)| entry_to_drained(rid, e, None)).collect()
        }

        fn shutdown(&self) {
            self.dead.store(true, Ordering::SeqCst);
            // graceful exit signal: the child leaves on stdin EOF
            *self.stdin.lock().unwrap() = None;
            {
                let mut child = self.child.lock().unwrap();
                let mut exited = false;
                for _ in 0..100 {
                    if matches!(child.try_wait(), Ok(Some(_))) {
                        exited = true;
                        break;
                    }
                    thread::sleep(Duration::from_millis(10));
                }
                if !exited {
                    let _ = child.kill();
                    let _ = child.wait();
                }
            }
            // child is gone → the data socket reached EOF → the reader
            // thread is exiting; joining it cannot hang
            if let Some(h) = self.reader.lock().unwrap().take() {
                let _ = h.join();
            }
        }

        fn kill_hard(&self) -> Result<()> {
            // SIGKILL, nothing else: death detection must go through
            // the same supervisor/reader paths a real crash would take
            self.child.lock().unwrap().kill().context("kill replica process")
        }
    }

    /// The data-connection reader: the single thread that processes the
    /// child's frames, terminals, and drain replies, strictly in wire
    /// order. Single-threaded processing + per-connection FIFO is the
    /// whole concurrency story — a drain reply is handled only after
    /// every frame/terminal written before it.
    fn reader_loop(
        stream: TcpStream,
        entries: Entries,
        drain_waiter: DrainWaiter,
        dead: Arc<AtomicBool>,
        metrics: Arc<Metrics>,
    ) {
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            let Ok(j) = Json::parse(line.trim()) else { continue };
            if j.opt("drained").is_some() {
                handle_drain_reply(&j, &entries, &drain_waiter);
                continue;
            }
            if j.opt("tok").is_some() {
                if let Some((id, index, token, text)) = parse_frame(&j) {
                    forward_frame(&entries, &metrics, id, index, token, text);
                }
                continue;
            }
            if let Some((id, resp)) = parse_terminal(&j) {
                // terminal: the request is over — drop the entry so a
                // later crash cannot requeue a finished request
                if let Some(e) = entries.lock().unwrap().remove(&id) {
                    e.resp.send(resp);
                }
            }
            // lines without an id (connection-level protocol errors)
            // have no request to route to
        }
        // connection gone (child exit, kill -9, network error): mark
        // dead first so no new entries are registered, then hand the
        // orphans to a waiting drain call if there is one — otherwise
        // they stay registered for take_orphans
        dead.store(true, Ordering::SeqCst);
        if let Some(tx) = drain_waiter.lock().unwrap().take() {
            let taken = std::mem::take(&mut *entries.lock().unwrap());
            let orphans: Vec<MeshDrained> =
                taken.into_iter().map(|(rid, e)| entry_to_drained(rid, e, None)).collect();
            let _ = tx.send(orphans);
        }
    }

    /// Join the child's drain records with the router's entry registry.
    /// The registry is emptied atomically: every later line on this
    /// connection (there should be none) finds no entry and no-ops.
    fn handle_drain_reply(j: &Json, entries: &Entries, drain_waiter: &DrainWaiter) {
        let Some(tx) = drain_waiter.lock().unwrap().take() else {
            return; // unsolicited — nobody is draining; ignore the line
        };
        let records = crate::mesh::parse_drain_reply(j).unwrap_or_default();
        let mut taken = std::mem::take(&mut *entries.lock().unwrap());
        let mut out = Vec::new();
        for r in records {
            // entries finished before the drain landed have already
            // been removed by their terminal — skip their records
            if let Some(e) = taken.remove(&r.rid) {
                out.push(entry_to_drained(r.rid, e, r.session.map(MeshSession::Wire)));
            }
        }
        // leftovers the child never reported (a submit racing the drain
        // write, or a lost terminal): restart from scratch. Re-running
        // an already-finished request is benign — greedy decode sends a
        // bit-identical terminal and the offset suppresses its frames.
        for (rid, e) in taken {
            out.push(entry_to_drained(rid, e, None));
        }
        let _ = tx.send(out);
    }

    fn parse_frame(j: &Json) -> Option<(u64, usize, i32, String)> {
        Some((
            j.opt("id")?.usize().ok()? as u64,
            j.opt("i")?.usize().ok()?,
            j.opt("tok")?.int().ok()? as i32,
            j.opt("text")?.str().ok()?.to_string(),
        ))
    }

    /// Forward one token frame to the client's sink, bounded-retrying
    /// while its event ring is momentarily full. The registry lock is
    /// dropped between attempts so submits/terminals are never blocked
    /// behind a slow client.
    fn forward_frame(
        entries: &Entries,
        metrics: &Metrics,
        id: u64,
        index: usize,
        token: i32,
        text: String,
    ) {
        let t0 = now_ms();
        for _ in 0..FRAME_RETRIES {
            {
                let mut g = entries.lock().unwrap();
                // entry gone: terminal or drain raced us — drop
                let Some(e) = g.get_mut(&id) else { return };
                // duplicate of an already-forwarded index (a requeued
                // replica replaying): exactly-once means drop it
                if index < e.streamed {
                    return;
                }
                let Some(stream) = &e.stream else { return };
                if stream.send(StreamFrame { id, index, token, text: text.clone() }) {
                    e.streamed = e.streamed.max(index + 1);
                    // parent-side frame_write span: pairs with the
                    // child's spans on the same trace, proving the
                    // timeline stitches across the process boundary
                    crate::obs::record(
                        e.trace,
                        crate::obs::SpanKind::FrameWrite,
                        t0,
                        now_ms(),
                    );
                    return;
                }
            }
            thread::sleep(Duration::from_millis(1));
        }
        metrics.inc("router_dropped_frames");
    }

    /// Reconstruct a terminal [`Response`] from its wire line (summary,
    /// error, or cancelled — anything with `"id"` and no `"tok"`).
    fn parse_terminal(j: &Json) -> Option<(u64, Response)> {
        let id = j.opt("id")?.usize().ok()? as u64;
        let num = |k: &str| j.opt(k).and_then(|v| v.num().ok()).unwrap_or(0.0);
        let timing = Timing { ttft_ms: num("ttft_ms"), ..Timing::default() };
        let resp = Response {
            id,
            text: j.opt("text").and_then(|v| v.str().ok()).unwrap_or("").to_string(),
            n_prompt: 0,
            n_generated: num("n_generated") as usize,
            queue_ms: num("queue_ms"),
            e2e_ms: num("e2e_ms"),
            timing,
            error: j.opt("error").and_then(|v| v.str().ok()).map(|s| s.to_string()),
            cancelled: j.opt("cancelled").and_then(|v| v.boolean().ok()).unwrap_or(false),
        };
        Some((id, resp))
    }
}
