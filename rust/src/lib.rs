//! CHAI: Clustered Head Attention for Efficient LLM Inference (ICML 2024)
//! — full-system reproduction.
//!
//! This crate is the Layer-3 serving coordinator of the three-layer stack
//! described in `DESIGN.md`:
//!
//! * [`runtime`] is the pluggable compute seam ([`runtime::Backend`]):
//!   the XLA path loads AOT-compiled HLO artifacts (produced by the
//!   python compile path in `python/compile/`) onto a PJRT CPU client
//!   and executes them with persistent device buffers — python is never
//!   on the request path; the pure-rust reference path
//!   ([`runtime::reference::RefBackend`]) interprets the same artifact
//!   contract over host tensors (seeded toy model when no artifacts
//!   exist), so the whole serving stack runs under `cargo test` on a
//!   fresh checkout.
//! * [`clustering`] implements the paper's offline elbow analysis and the
//!   online 5-token cluster-membership identification (k-means++ over
//!   per-head attention features).
//! * [`engine`] drives the probe → cluster → CHAI pipeline per request, and
//!   the MHA / DejaVu / SpAtten / CHAI-static baselines.
//! * [`kv`] is the clustered KV-cache manager (per-layer `k_l`-head K,
//!   full-head V) with exact byte accounting (paper Fig 11); its
//!   [`kv::paged`] subsystem serves K,V from a refcounted block pool
//!   with token-hash prefix sharing, copy-on-write divergence and LRU
//!   eviction — the coordinator's default admission unit.
//! * [`scheduler`] owns serving policy: the FCFS pending queue, the
//!   continuous-batching live set, and the preemption engine
//!   (preempt-and-requeue under overload with KV swap-out to a host
//!   spill tier or recompute-on-resume); [`coordinator`] is the thin
//!   cross-thread tick loop around it.
//! * [`router`] is the multi-replica front-end: N data-parallel engine
//!   replicas behind a pluggable placement policy (round-robin,
//!   least-loaded, prefix-affinity by KV hash-chain fingerprint over a
//!   consistent-hash ring), one shared copy of the model weights, a
//!   global request-id space, and broadcast cancellation. Replicas are
//!   location-transparent (`--transport local|process`): the process
//!   transport runs each as a separate `chai replica` child supervised
//!   by health probes, with graceful drain migrating live sessions in
//!   [`mesh`]'s wire form and crash requeue replaying accepted requests
//!   on survivors at their recorded stream offsets; [`server`] exposes
//!   either a single
//!   coordinator or the router over a TCP line-JSON protocol with
//!   per-token streaming and request cancellation, through either a
//!   thread-per-connection transport or [`net`]'s single-thread epoll
//!   reactor with lock-free ring buffers on the request and token-frame
//!   hot paths (`--net threads|reactor`).
//! * [`obs`] is the always-on observability layer: per-request span
//!   tracing over per-thread flight-recorder rings (trace ids minted at
//!   admission and propagated over the wire, so a cross-process request
//!   yields one stitched Chrome-trace timeline via `{"cmd":"trace"}` /
//!   `--trace-out`), plus the per-tick profiler feeding the `obs_*`
//!   histograms. `--no-obs` is the escape hatch; streams are
//!   bit-identical either way.
//! * [`util`] contains the substrates the offline build needs (JSON,
//!   PRNG, CLI args, stats, a property-testing harness) — the crates.io
//!   mirror in this environment only vendors `xla` + `anyhow`.

pub mod baselines;
pub mod bench;
pub mod clustering;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod kv;
pub mod mesh;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod router;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod tensor;
pub mod util;
