//! Host-side tensors and the `.cbt` interchange format.
//!
//! [`Tensor`] is a minimal dense row-major array (f32 or i32) — enough for
//! weight loading, KV-cache staging, clustering features and literal
//! conversion. The `.cbt` file layout mirrors `python/compile/tensorio.py`
//! and is roundtrip-tested from both languages against the same fixture.

pub mod io;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }

    pub fn from_name(s: &str) -> Result<DType> {
        match s {
            "f32" | "float32" => Ok(DType::F32),
            "i32" | "int32" => Ok(DType::I32),
            _ => bail!("unsupported dtype {s:?}"),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: Data::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: Data::I32(data) }
    }

    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::f32(shape.to_vec(), vec![0.0; shape.iter().product()])
    }

    pub fn zeros_i32(shape: &[usize]) -> Tensor {
        Tensor::i32(shape.to_vec(), vec![0; shape.iter().product()])
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::i32(vec![], vec![v])
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn nbytes(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        idx.iter().zip(self.strides()).map(|(i, s)| i * s).sum()
    }

    pub fn get_f32(&self, idx: &[usize]) -> f32 {
        self.as_f32().unwrap()[self.offset(idx)]
    }

    /// Slice out sub-tensor at leading index `i` (e.g. layer `i` of
    /// `[L, H, T, dh]` → `[H, T, dh]`). Copies.
    pub fn index0(&self, i: usize) -> Tensor {
        assert!(!self.shape.is_empty() && i < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        let shape = self.shape[1..].to_vec();
        match &self.data {
            Data::F32(v) => Tensor::f32(shape, v[i * inner..(i + 1) * inner].to_vec()),
            Data::I32(v) => Tensor::i32(shape, v[i * inner..(i + 1) * inner].to_vec()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_data_contract() {
        let t = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.nbytes(), 24);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic]
    fn mismatched_shape_panics() {
        Tensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn strides_and_indexing() {
        let t = Tensor::f32(vec![2, 3, 4], (0..24).map(|x| x as f32).collect());
        assert_eq!(t.strides(), vec![12, 4, 1]);
        assert_eq!(t.get_f32(&[1, 2, 3]), 23.0);
        assert_eq!(t.get_f32(&[0, 1, 0]), 4.0);
    }

    #[test]
    fn index0_slices_layer() {
        let t = Tensor::i32(vec![3, 2], vec![1, 2, 3, 4, 5, 6]);
        let l1 = t.index0(1);
        assert_eq!(l1.shape, vec![2]);
        assert_eq!(l1.as_i32().unwrap(), &[3, 4]);
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar_i32(7);
        assert_eq!(t.len(), 1);
        assert!(t.shape.is_empty());
    }

    #[test]
    fn dtype_names_roundtrip() {
        assert_eq!(DType::from_name("f32").unwrap(), DType::F32);
        assert_eq!(DType::from_name("int32").unwrap(), DType::I32);
        assert!(DType::from_name("f64").is_err());
    }
}
