//! `.cbt` ("CHAI binary tensors") reader/writer — mirrors
//! `python/compile/tensorio.py`:
//!
//! ```text
//! magic b"CBT1" | u32 LE header len | UTF-8 JSON header | data section
//! ```
//!
//! Header: `{"tensors": [{name, dtype, shape, offset, nbytes}]}` with
//! offsets relative to the data section start, 64-byte aligned.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Data, DType, Tensor};
use crate::util::json::Json;

const MAGIC: &[u8; 4] = b"CBT1";
const ALIGN: usize = 64;

pub fn load(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let blob = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if blob.len() < 8 || &blob[..4] != MAGIC {
        bail!("{}: bad .cbt magic", path.display());
    }
    let hlen = u32::from_le_bytes(blob[4..8].try_into().unwrap()) as usize;
    if blob.len() < 8 + hlen {
        bail!("{}: truncated header", path.display());
    }
    let header = Json::parse(std::str::from_utf8(&blob[8..8 + hlen])?)?;
    let data = &blob[8 + hlen..];
    let mut out = BTreeMap::new();
    for e in header.get("tensors")?.arr()? {
        let name = e.get("name")?.str()?.to_string();
        let dtype = DType::from_name(e.get("dtype")?.str()?)?;
        let shape = e.get("shape")?.usize_vec()?;
        let offset = e.get("offset")?.usize()?;
        let nbytes = e.get("nbytes")?.usize()?;
        if offset + nbytes > data.len() {
            bail!("{}: tensor {name} out of bounds", path.display());
        }
        let raw = &data[offset..offset + nbytes];
        let n = nbytes / 4;
        let expected: usize = shape.iter().product();
        if n != expected {
            bail!("{}: tensor {name} shape/size mismatch", path.display());
        }
        let tensor = match dtype {
            DType::F32 => Tensor::f32(
                shape,
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            DType::I32 => Tensor::i32(
                shape,
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
        };
        out.insert(name, tensor);
    }
    Ok(out)
}

pub fn save(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let mut entries = Vec::new();
    let mut bufs: Vec<(usize, Vec<u8>)> = Vec::new(); // (pad, raw)
    let mut offset = 0usize;
    for (name, t) in tensors {
        let raw: Vec<u8> = match &t.data {
            Data::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            Data::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        };
        let pad = (ALIGN - offset % ALIGN) % ALIGN;
        offset += pad;
        entries.push(Json::obj(vec![
            ("name", Json::Str(name.clone())),
            ("dtype", Json::Str(t.dtype().name().into())),
            ("shape", Json::from_usizes(&t.shape)),
            ("offset", Json::Num(offset as f64)),
            ("nbytes", Json::Num(raw.len() as f64)),
        ]));
        offset += raw.len();
        bufs.push((pad, raw));
    }
    let header = Json::obj(vec![("tensors", Json::Arr(entries))]).to_string();
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for (pad, raw) in bufs {
        f.write_all(&vec![0u8; pad])?;
        f.write_all(&raw)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("chai-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("a".into(), Tensor::f32(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.0]));
        m.insert("b".into(), Tensor::i32(vec![4], vec![-1, 2, -3, 4]));
        let p = tmp("roundtrip.cbt");
        save(&p, &m).unwrap();
        let out = load(&p).unwrap();
        assert_eq!(out, m);
    }

    #[test]
    fn alignment_honored() {
        let mut m = BTreeMap::new();
        m.insert("x".into(), Tensor::f32(vec![1], vec![1.0])); // 4 bytes
        m.insert("y".into(), Tensor::f32(vec![1], vec![2.0]));
        let p = tmp("align.cbt");
        save(&p, &m).unwrap();
        let blob = std::fs::read(&p).unwrap();
        let hlen = u32::from_le_bytes(blob[4..8].try_into().unwrap()) as usize;
        let header = Json::parse(std::str::from_utf8(&blob[8..8 + hlen]).unwrap()).unwrap();
        for e in header.get("tensors").unwrap().arr().unwrap() {
            assert_eq!(e.get("offset").unwrap().usize().unwrap() % 64, 0);
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmp("bad.cbt");
        std::fs::write(&p, b"NOPE\0\0\0\0").unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut m = BTreeMap::new();
        m.insert("a".into(), Tensor::f32(vec![8], vec![0.0; 8]));
        let p = tmp("trunc.cbt");
        save(&p, &m).unwrap();
        let blob = std::fs::read(&p).unwrap();
        std::fs::write(&p, &blob[..blob.len() - 8]).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn reads_python_written_fixture_if_present() {
        // Cross-language contract: the build's weights.cbt must parse.
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/weights.cbt");
        if p.exists() {
            let m = load(&p).unwrap();
            assert!(m.contains_key("emb"), "weights.cbt missing emb");
            assert!(m.keys().any(|k| k.ends_with(".wq")));
        }
    }
}
