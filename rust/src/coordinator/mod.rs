//! Serving coordinator: the cross-thread front door of ONE engine
//! replica.
//!
//! The `xla` PJRT client is `Rc`-based (not `Send`), so all PJRT state
//! lives on ONE engine thread (the vLLM-style engine-loop design). Front
//! ends (TCP server, the multi-replica [`crate::router`], bench drivers)
//! submit [`Request`]s into a shared queue and receive a [`Response`]
//! over a per-request channel; streaming requests additionally receive
//! one [`StreamFrame`] per decoded token, and [`Coordinator::cancel`]
//! aborts a request wherever it lives (pending, live mid-decode, or
//! preempted) — the abort is threaded through the scheduler into the
//! engine, which frees the session's sole-owner K,V blocks.
//!
//! All scheduling policy lives in [`crate::scheduler`]: the engine loop
//! here is a thin tick pump that drains the cross-thread inbox (new
//! requests + cancellations) into the [`Scheduler`] and calls
//! [`Scheduler::run_tick`] — token-level continuous batching with FCFS
//! admission, fused paged decode ticks
//! ([`crate::engine::Engine::decode_tick`]), and (with `--preempt`)
//! preempt-and-requeue of live sessions under overload.
//!
//! Shutdown never strands a client: once [`CoordinatorHandle::shutdown`]
//! (or drop) is requested, every request still pending, live, or
//! preempted receives a terminal `{"error": "shutting down"}` response,
//! and later submissions are refused with the same error instead of
//! queueing into a loop that will never serve them.

pub use crate::scheduler::{Request, Response, StreamFrame, SubmitOpts};

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::config::ServingConfig;
use crate::engine::{Engine, Variant};
use crate::metrics::Metrics;
use crate::scheduler::{SchedPolicy, Scheduler};
use crate::util::now_ms;

/// Deferred engine construction, run ON the engine thread (backends are
/// not `Send`; the closure only has to be). The router passes factories
/// that close over `Arc`'d shared weights so N replicas load the model
/// once.
pub type EngineFactory = Box<dyn FnOnce() -> Result<Engine> + Send + 'static>;

#[derive(Default)]
struct Shared {
    queue: Mutex<QueueState>,
    cv: Condvar,
}

#[derive(Default)]
struct QueueState {
    waiting: VecDeque<Request>,
    /// request ids whose abort was requested but not yet applied
    cancels: Vec<u64>,
    shutdown: bool,
}

/// Handle owned by front-ends; cheap to clone.
#[derive(Clone)]
pub struct Coordinator {
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    next_id: Arc<Mutex<u64>>,
}

pub struct CoordinatorHandle {
    pub coordinator: Coordinator,
    engine_thread: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the engine thread and return the submission handle.
    pub fn start(cfg: ServingConfig) -> Result<CoordinatorHandle> {
        let load_cfg = cfg.clone();
        Self::start_with(cfg, Box::new(move || Engine::load(load_cfg)))
    }

    /// Spawn the engine thread around a caller-supplied engine factory
    /// (executed on the engine thread, since backends are not `Send`).
    pub fn start_with(cfg: ServingConfig, make_engine: EngineFactory) -> Result<CoordinatorHandle> {
        let shared = Arc::new(Shared::default());
        let metrics = Arc::new(Metrics::new());
        let coord = Coordinator {
            shared: shared.clone(),
            metrics: metrics.clone(),
            next_id: Arc::new(Mutex::new(0)),
        };
        let thread_shared = shared;
        let thread_metrics = metrics;
        let engine_thread = std::thread::Builder::new()
            .name("chai-engine".into())
            .spawn(move || {
                match make_engine() {
                    Ok(engine) => engine_loop(&engine, &cfg, &thread_shared, &thread_metrics),
                    Err(e) => {
                        eprintln!("[engine] failed to load: {e:#}");
                        // refuse current and future requests (submit
                        // checks the shutdown flag)
                        let mut g = thread_shared.queue.lock().unwrap();
                        g.shutdown = true;
                        while let Some(r) = g.waiting.pop_front() {
                            let _ = r.resp_tx.send(Response::error(r.id, format!("{e:#}")));
                        }
                    }
                }
            })?;
        Ok(CoordinatorHandle { coordinator: coord, engine_thread: Some(engine_thread) })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, prompt: &str, max_new: usize, variant: Variant) -> Receiver<Response> {
        self.submit_opts(SubmitOpts::new(prompt, max_new, variant)).1
    }

    /// Submit with full options (streaming channel); assigns the id.
    pub fn submit_opts(&self, opts: SubmitOpts) -> (u64, Receiver<Response>) {
        let id = {
            let mut g = self.next_id.lock().unwrap();
            *g += 1;
            *g
        };
        let rx = self.submit_with_id(id, opts);
        (id, rx)
    }

    /// Submit under a caller-assigned id (the router owns the id space
    /// so ids stay unique across replicas). After shutdown the request
    /// is refused with a terminal error instead of queueing forever.
    pub fn submit_with_id(&self, id: u64, opts: SubmitOpts) -> Receiver<Response> {
        let (tx, rx) = channel();
        let req = Request {
            id,
            prompt: opts.prompt,
            max_new: opts.max_new,
            variant: opts.variant,
            submitted_ms: now_ms(),
            resp_tx: tx,
            stream: opts.stream,
        };
        let mut g = self.shared.queue.lock().unwrap();
        if g.shutdown {
            let _ = req.resp_tx.send(Response::error(id, "shutting down".into()));
            return rx;
        }
        self.metrics.inc("submitted");
        g.waiting.push_back(req);
        self.shared.cv.notify_one();
        rx
    }

    /// Request an abort of request `id` (async: the engine applies it
    /// on its next tick). Safe for unknown/finished ids — the router
    /// broadcasts cancels to every replica, so no per-replica counter
    /// is bumped here (`sched_cancelled` counts the abort that
    /// actually landed; `router_cancel_requests` counts client
    /// intents).
    pub fn cancel(&self, id: u64) {
        let mut g = self.shared.queue.lock().unwrap();
        if g.shutdown {
            return; // everything gets failed at shutdown anyway
        }
        g.cancels.push(id);
        self.shared.cv.notify_one();
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().waiting.len()
    }

    /// Scheduling load of this replica for the router's least-loaded
    /// policy: inbox depth plus the scheduler's pending + live +
    /// preempted populations (the `{"cmd":"sched"}` gauges).
    pub fn load_cost(&self) -> f64 {
        self.queue_depth() as f64
            + self.metrics.gauge("sched_pending")
            + self.metrics.gauge("sched_live")
            + self.metrics.gauge("sched_preempted")
    }

    fn request_shutdown(&self) {
        let mut g = self.shared.queue.lock().unwrap();
        g.shutdown = true;
        self.shared.cv.notify_all();
    }
}

impl CoordinatorHandle {
    pub fn shutdown(mut self) {
        self.coordinator.request_shutdown();
        if let Some(h) = self.engine_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for CoordinatorHandle {
    fn drop(&mut self) {
        self.coordinator.request_shutdown();
        if let Some(h) = self.engine_thread.take() {
            let _ = h.join();
        }
    }
}

/// The thin engine loop: drain the inbox (requests + cancels), tick the
/// scheduler, repeat. Blocks on the condvar when there is nothing
/// pending, live, or preempted. On shutdown every request still held
/// anywhere in the pipeline is answered with a terminal error — a
/// client may never be left blocked on a channel whose sender quietly
/// died.
fn engine_loop(engine: &Engine, cfg: &ServingConfig, shared: &Shared, metrics: &Metrics) {
    // surface which compute backend this engine serves with (the server's
    // `stats` command and benches read these back)
    metrics.set_info("backend", engine.backend_name());
    metrics.set_info("model", &engine.manifest().model.name);
    let mut sched = Scheduler::new(SchedPolicy::from_config(cfg));
    let mut cancels: Vec<u64> = Vec::new();
    loop {
        {
            let mut g = shared.queue.lock().unwrap();
            if sched.is_idle() && g.waiting.is_empty() && g.cancels.is_empty() {
                if g.shutdown {
                    return;
                }
                // idle: block until work arrives
                g = shared
                    .cv
                    .wait_while(g, |q| {
                        q.waiting.is_empty() && q.cancels.is_empty() && !q.shutdown
                    })
                    .unwrap();
            }
            while let Some(r) = g.waiting.pop_front() {
                sched.submit(r);
            }
            cancels.append(&mut g.cancels);
            if g.shutdown {
                break;
            }
        }
        for id in cancels.drain(..) {
            sched.cancel(id, engine, metrics);
        }
        sched.run_tick(engine, metrics);
    }
    // shutdown: answer everything still in flight, then exit
    sched.fail_all(engine, metrics, "shutting down");
}
