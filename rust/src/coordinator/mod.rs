//! Serving coordinator: request queue → continuous batcher → engine loop.
//!
//! The `xla` PJRT client is `Rc`-based (not `Send`), so all PJRT state
//! lives on ONE engine thread (the vLLM-style engine-loop design). Front
//! ends (TCP server, bench drivers) submit [`Request`]s into a shared
//! queue and receive a [`Response`] over a per-request channel.
//!
//! Scheduling policy (see [`batcher`]): token-level continuous batching —
//! every tick the loop (1) admits waiting requests up to `max_batch` live
//! sessions, subject to KV-pool admission control, (2) runs ONE fused
//! decode tick over every live session ([`Engine::decode_tick`]: all
//! paged sessions of a variant go through a single ragged
//! block-table-native backend call), (3) retires finished sessions.
//! Prefill happens at admission (prefill-prioritized, like vLLM's
//! default) and skips compute for prompt blocks adopted from the prefix
//! index.

pub mod batcher;

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::config::ServingConfig;
use crate::engine::{Admission, Engine, Session, Timing, Variant};
use crate::kv::KvPool;
use crate::metrics::Metrics;
use crate::util::now_ms;

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    pub variant: Variant,
    pub submitted_ms: f64,
    pub resp_tx: Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub n_prompt: usize,
    pub n_generated: usize,
    pub queue_ms: f64,
    pub e2e_ms: f64,
    pub timing: Timing,
    pub error: Option<String>,
}

#[derive(Default)]
struct Shared {
    queue: Mutex<QueueState>,
    cv: Condvar,
}

#[derive(Default)]
struct QueueState {
    waiting: VecDeque<Request>,
    shutdown: bool,
}

/// Handle owned by front-ends; cheap to clone.
#[derive(Clone)]
pub struct Coordinator {
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    next_id: Arc<Mutex<u64>>,
}

pub struct CoordinatorHandle {
    pub coordinator: Coordinator,
    engine_thread: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the engine thread and return the submission handle.
    pub fn start(cfg: ServingConfig) -> Result<CoordinatorHandle> {
        let shared = Arc::new(Shared::default());
        let metrics = Arc::new(Metrics::new());
        let coord = Coordinator {
            shared: shared.clone(),
            metrics: metrics.clone(),
            next_id: Arc::new(Mutex::new(0)),
        };
        let thread_shared = shared;
        let thread_metrics = metrics;
        let engine_thread = std::thread::Builder::new()
            .name("chai-engine".into())
            .spawn(move || {
                match Engine::load(cfg.clone()) {
                    Ok(engine) => engine_loop(&engine, &cfg, &thread_shared, &thread_metrics),
                    Err(e) => {
                        eprintln!("[engine] failed to load: {e:#}");
                        // drain queue with errors
                        let mut g = thread_shared.queue.lock().unwrap();
                        g.shutdown = true;
                        while let Some(r) = g.waiting.pop_front() {
                            let _ = r.resp_tx.send(Response::error(r.id, format!("{e:#}")));
                        }
                    }
                }
            })?;
        Ok(CoordinatorHandle { coordinator: coord, engine_thread: Some(engine_thread) })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, prompt: &str, max_new: usize, variant: Variant) -> Receiver<Response> {
        let (tx, rx) = channel();
        let id = {
            let mut g = self.next_id.lock().unwrap();
            *g += 1;
            *g
        };
        let req = Request {
            id,
            prompt: prompt.to_string(),
            max_new,
            variant,
            submitted_ms: now_ms(),
            resp_tx: tx,
        };
        self.metrics.inc("submitted");
        let mut g = self.shared.queue.lock().unwrap();
        g.waiting.push_back(req);
        self.shared.cv.notify_one();
        rx
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().waiting.len()
    }

    fn request_shutdown(&self) {
        let mut g = self.shared.queue.lock().unwrap();
        g.shutdown = true;
        self.shared.cv.notify_all();
    }
}

impl Response {
    fn error(id: u64, msg: String) -> Response {
        Response {
            id,
            text: String::new(),
            n_prompt: 0,
            n_generated: 0,
            queue_ms: 0.0,
            e2e_ms: 0.0,
            timing: Timing::default(),
            error: Some(msg),
        }
    }
}

impl CoordinatorHandle {
    pub fn shutdown(mut self) {
        self.coordinator.request_shutdown();
        if let Some(h) = self.engine_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for CoordinatorHandle {
    fn drop(&mut self) {
        self.coordinator.request_shutdown();
        if let Some(h) = self.engine_thread.take() {
            let _ = h.join();
        }
    }
}

struct Live {
    req: Request,
    session: Session,
    started_ms: f64,
}

/// The engine loop: continuous batching at token granularity.
///
/// KV admission control is block-granular by default: a request is
/// admitted when the engine's paged store can cover its prefill blocks
/// plus one decode block, counting evictable cached blocks (prefix
/// reuse can only shrink the real allocation). With `paged_kv = false`
/// the legacy contiguous [`KvPool`] worst-case bucket accounting is
/// used instead.
fn engine_loop(engine: &Engine, cfg: &ServingConfig, shared: &Shared, metrics: &Metrics) {
    // surface which compute backend this engine serves with (the server's
    // `stats` command and benches read these back)
    metrics.set_info("backend", engine.backend_name());
    metrics.set_info("model", &engine.manifest().model.name);
    let paged = engine.paged_enabled();
    // legacy bucket-accounting pool (only consulted when !paged)
    let mut pool = KvPool::new(cfg.kv_capacity_bytes);
    let mut live: Vec<Live> = Vec::new();
    loop {
        // --- admission (prefill) ------------------------------------------
        let admit_n = batcher::admission_quota(live.len(), cfg.max_batch);
        let mut admitted: Vec<Request> = Vec::new();
        {
            let mut g = shared.queue.lock().unwrap();
            if live.is_empty() && g.waiting.is_empty() {
                if g.shutdown {
                    return;
                }
                // idle: block until work arrives
                g = shared
                    .cv
                    .wait_while(g, |q| q.waiting.is_empty() && !q.shutdown)
                    .unwrap();
                if g.shutdown && g.waiting.is_empty() {
                    return;
                }
            }
            for _ in 0..admit_n {
                match g.waiting.pop_front() {
                    Some(r) => admitted.push(r),
                    None => break,
                }
            }
        }
        // requests that can't start this tick go back to the queue head
        // in arrival order — including the ones behind a deferral, which
        // must not be dropped
        let mut deferred: Vec<Request> = Vec::new();
        let mut pending = admitted.into_iter();
        for req in pending.by_ref() {
            let queue_ms = now_ms() - req.submitted_ms;
            metrics.observe_ms("queue", queue_ms);
            if paged {
                match engine.paged_admission(&req.variant, &req.prompt) {
                    Admission::Admit => {}
                    Admission::Defer => {
                        metrics.inc("kv_defer");
                        deferred.push(req);
                        break;
                    }
                    Admission::Reject => {
                        // larger than the whole pool: deferring would
                        // spin the scheduler forever
                        metrics.inc("errors");
                        let _ = req.resp_tx.send(Response::error(
                            req.id,
                            "prompt exceeds kv pool capacity".into(),
                        ));
                        continue;
                    }
                }
            } else {
                let total = req.prompt.len() + 1 + req.max_new;
                let bucket = crate::config::Manifest::bucket_for(
                    &engine.manifest().decode_buckets,
                    total,
                )
                .unwrap_or(*engine.manifest().decode_buckets.last().unwrap());
                let kind = req.variant.cache_kind();
                if pool.admit(req.id, kind, engine.manifest(), bucket).is_err() {
                    // pool full: push back and stop admitting this tick
                    metrics.inc("kv_defer");
                    deferred.push(req);
                    break;
                }
            }
            let t0 = now_ms();
            match engine.start_session(&req.prompt, req.max_new, &req.variant) {
                Ok(session) => {
                    metrics.inc("admitted");
                    metrics.observe_ms("ttft", session.timing.ttft_ms);
                    live.push(Live { req, session, started_ms: t0 });
                }
                Err(e) => {
                    if !paged {
                        let _ = pool.release(req.id);
                    }
                    metrics.inc("errors");
                    let _ = req.resp_tx.send(Response::error(req.id, format!("{e:#}")));
                }
            }
        }
        deferred.extend(pending); // everything behind the deferral
        if !deferred.is_empty() {
            let mut g = shared.queue.lock().unwrap();
            for r in deferred.into_iter().rev() {
                g.waiting.push_front(r);
            }
        }

        // --- decode tick: one fused token step across live sessions ------
        // `decode_tick` batches every paged session of a variant into a
        // single ragged block-table-native backend call: one dispatch
        // per tick, zero bucket copies per row (the ref backend still
        // computes rows sequentially inside the call; a device backend
        // would vectorize them)
        let mut finished: Vec<usize> = Vec::new();
        if !live.is_empty() {
            if !paged {
                for l in &live {
                    pool.touch(l.req.id);
                }
            }
            metrics.observe("decode_batch", live.len() as f64);
            let mut sessions: Vec<&mut Session> =
                live.iter_mut().map(|l| &mut l.session).collect();
            let outcomes = engine.decode_tick(&mut sessions);
            drop(sessions);
            for (i, outcome) in outcomes.into_iter().enumerate() {
                match outcome {
                    Ok(more) => {
                        metrics.inc("tokens");
                        if let Some(ms) = live[i].session.timing.decode_ms.last() {
                            metrics.observe_ms("decode_step", *ms);
                        }
                        if !more {
                            finished.push(i);
                        }
                    }
                    Err(e) => {
                        metrics.inc("errors");
                        let _ = live[i]
                            .req
                            .resp_tx
                            .send(Response::error(live[i].req.id, format!("{e:#}")));
                        finished.push(i);
                    }
                }
            }
        }
        // retire back-to-front so indices stay valid
        for &i in finished.iter().rev() {
            let mut l = live.swap_remove(i);
            if paged {
                // idempotent: finish_session would release too, but
                // errored sessions never reach it
                engine.release_session(&mut l.session);
            } else {
                let _ = pool.release(l.req.id);
            }
            if l.session.done {
                let timing = l.session.timing.clone();
                let n_prompt = l.session.prompt_len;
                let n_generated = l.session.generated();
                let gen = engine.finish_session(l.session);
                metrics.inc("completed");
                let e2e = now_ms() - l.req.submitted_ms;
                metrics.observe_ms("e2e", e2e);
                let _ = l.req.resp_tx.send(Response {
                    id: l.req.id,
                    text: gen.text,
                    n_prompt,
                    n_generated,
                    queue_ms: l.started_ms - l.req.submitted_ms,
                    e2e_ms: e2e,
                    timing,
                    error: None,
                });
            }
        }

        // --- publish paged-KV occupancy/sharing gauges --------------------
        // (served verbatim by the server's `stats`/`kv` commands)
        if let Some(snap) = engine.paged_snapshot() {
            metrics.set_gauge("kv_capacity_bytes", snap.capacity_bytes as f64);
            metrics.set_gauge("kv_used_bytes", snap.used_bytes as f64);
            metrics.set_gauge("kv_cached_bytes", snap.cached_bytes as f64);
            metrics.set_gauge("kv_live_blocks", snap.live_blocks as f64);
            metrics.set_gauge("kv_cached_blocks", snap.cached_blocks as f64);
            metrics.set_gauge("kv_live_tables", snap.live_tables as f64);
            metrics.set_gauge("paged_prefix_hit_blocks", snap.stats.prefix_hit_blocks as f64);
            metrics.set_gauge("paged_prefix_miss_blocks", snap.stats.prefix_miss_blocks as f64);
            metrics.set_gauge("paged_prefix_hit_rate", snap.stats.prefix_hit_rate());
            metrics.set_gauge("paged_cow_copies", snap.stats.cow_copies as f64);
            metrics.set_gauge("paged_evictions", snap.stats.evictions as f64);
            metrics.set_gauge("paged_alloc_failures", snap.stats.alloc_failures as f64);
            // block-native hot-path accounting: bucket-shaped copies on
            // the decode path must stay 0 while batched decode is on
            metrics.set_gauge(
                "paged_decode_gather_copies",
                snap.stats.decode_gather_copies as f64,
            );
            metrics.set_gauge(
                "paged_decode_scatter_copies",
                snap.stats.decode_scatter_copies as f64,
            );
            metrics.set_gauge(
                "paged_prefill_skipped_tokens",
                snap.stats.prefill_skipped_tokens as f64,
            );
        }
    }
}
