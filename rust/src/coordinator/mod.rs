//! Serving coordinator: the cross-thread front door of ONE engine
//! replica.
//!
//! The `xla` PJRT client is `Rc`-based (not `Send`), so all PJRT state
//! lives on ONE engine thread (the vLLM-style engine-loop design). Front
//! ends (TCP server, the multi-replica [`crate::router`], bench drivers)
//! submit [`Request`]s into a shared queue and receive a [`Response`]
//! over a per-request channel; streaming requests additionally receive
//! one [`StreamFrame`] per decoded token, and [`Coordinator::cancel`]
//! aborts a request wherever it lives (pending, live mid-decode, or
//! preempted) — the abort is threaded through the scheduler into the
//! engine, which frees the session's sole-owner K,V blocks.
//!
//! All scheduling policy lives in [`crate::scheduler`]: the engine loop
//! here is a thin tick pump that drains the cross-thread inbox (new
//! requests + cancellations) into the [`Scheduler`] and calls
//! [`Scheduler::run_tick`] — token-level continuous batching with FCFS
//! admission, fused paged decode ticks
//! ([`crate::engine::Engine::decode_tick`]), and (with `--preempt`)
//! preempt-and-requeue of live sessions under overload.
//!
//! The submission inbox is a **bounded lock-free MPSC ring**
//! ([`crate::net::ring::Mpsc`]): server/router/reactor threads push
//! without taking any lock on the hot path, and backpressure is
//! explicit — a full inbox sheds the request with a terminal
//! `{"error": "overloaded"}` response instead of queueing without
//! bound (`net_shed_overloaded` counts the sheds, `net_inbox_hwm`
//! tracks the deepest occupancy). Only the cold paths (cancel
//! requests, the idle-park condvar, shutdown) still go through a
//! mutex.
//!
//! Shutdown never strands a client: once [`CoordinatorHandle::shutdown`]
//! (or drop) is requested, every request still pending, live, or
//! preempted receives a terminal `{"error": "shutting down"}` response,
//! and later submissions are refused with the same error instead of
//! queueing into a loop that will never serve them. A `submitting`
//! quiescence gate (incremented for the duration of every push) lets
//! the engine thread wait out in-flight submissions before its final
//! inbox drain, so a request can never slip into the ring after the
//! last pop and hang its client.

pub use crate::scheduler::{
    DrainedItem, FrameSink, Request, RespSink, Response, StreamFrame, SubmitOpts,
};

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::config::ServingConfig;
use crate::engine::{Engine, MigratedSession, Variant};
use crate::metrics::Metrics;
use crate::net::ring::Mpsc;
use crate::scheduler::{SchedPolicy, Scheduler};
use crate::util::json::Json;
use crate::util::now_ms;

/// Deferred engine construction, run ON the engine thread (backends are
/// not `Send`; the closure only has to be). The router passes factories
/// that close over `Arc`'d shared weights so N replicas load the model
/// once.
pub type EngineFactory = Box<dyn FnOnce() -> Result<Engine> + Send + 'static>;

struct Shared {
    /// lock-free bounded submission inbox (the request hot path):
    /// front-end threads push, the engine thread pops
    inbox: Mpsc<Request>,
    /// submitters currently between their shutdown check and the end of
    /// their push — the engine's final drain waits for this to hit 0
    submitting: AtomicUsize,
    /// fast-path mirror of `QueueState::shutdown` (checked by `submit`
    /// without taking the mutex)
    shutdown: AtomicBool,
    /// cold-path state only: cancels + the condvar the engine parks on
    queue: Mutex<QueueState>,
    cv: Condvar,
}

impl Shared {
    fn new(inbox_capacity: usize) -> Shared {
        Shared {
            inbox: Mpsc::new(inbox_capacity.max(1)),
            submitting: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            queue: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
        }
    }
}

#[derive(Default)]
struct QueueState {
    /// request ids whose abort was requested but not yet applied
    cancels: Vec<u64>,
    /// mesh control operations (drain / adopt) awaiting the engine
    /// thread — cold path, like cancels
    ops: Vec<Op>,
    shutdown: bool,
}

/// Mesh control operations the engine thread executes between ticks.
enum Op {
    /// Evacuate every held request ([`crate::scheduler::Scheduler::drain`])
    /// and hand the items to the waiting caller.
    Drain(Sender<Vec<DrainedItem>>),
    /// Adopt a session migrated from a peer replica.
    Adopt { req: Request, payload: AdoptPayload, streamed: usize },
    /// Wire-protocol drain (a `chai replica` child being told to
    /// evacuate by its parent): the reply line goes out on the
    /// requesting connection's event ring.
    #[cfg(target_os = "linux")]
    DrainNet(crate::net::NetSink),
}

/// An adopted session's payload: already-decoded (in-process mesh) or
/// the wire-encoded [`crate::mesh`] record, decoded on the engine
/// thread against this replica's own manifest.
enum AdoptPayload {
    Local(MigratedSession),
    Wire(Json),
}

/// A wire `{"cmd": "adopt", ...}` unpacked by the transport layer:
/// everything the coordinator needs to re-home a migrated session under
/// its original request id.
#[cfg(target_os = "linux")]
pub struct AdoptNet {
    /// original (router-assigned) request id — survives migration so
    /// the client's stream and cancels keep working
    pub rid: u64,
    /// frames the client has already received (resume point)
    pub streamed: usize,
    pub max_new: usize,
    /// [`crate::mesh::encode_migrated`] record
    pub record: Json,
    pub stream: Option<FrameSink>,
    pub resp: RespSink,
    /// observability trace id carried over the wire (0 = untraced) so
    /// the adopted request keeps the timeline it started on
    pub trace: u64,
}

/// Handle owned by front-ends; cheap to clone.
#[derive(Clone)]
pub struct Coordinator {
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    next_id: Arc<Mutex<u64>>,
    /// `--pin-cores`: surfaced through [`crate::router::Frontend`] so
    /// the reactor thread (spawned by the server, which holds no
    /// config) knows whether to pin itself
    pub(crate) pin_cores: bool,
}

pub struct CoordinatorHandle {
    pub coordinator: Coordinator,
    engine_thread: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the engine thread and return the submission handle.
    pub fn start(cfg: ServingConfig) -> Result<CoordinatorHandle> {
        let load_cfg = cfg.clone();
        Self::start_with(cfg, Box::new(move || Engine::load(load_cfg)))
    }

    /// Spawn the engine thread around a caller-supplied engine factory
    /// (executed on the engine thread, since backends are not `Send`).
    pub fn start_with(cfg: ServingConfig, make_engine: EngineFactory) -> Result<CoordinatorHandle> {
        // `--no-obs`: the escape hatch is a process-global flag (spans
        // are recorded from many threads; streams are bit-identical
        // either way, obs only reads clocks)
        crate::obs::set_enabled(cfg.obs);
        let shared = Arc::new(Shared::new(cfg.net_inbox));
        let metrics = Arc::new(Metrics::new());
        let coord = Coordinator {
            shared: shared.clone(),
            metrics: metrics.clone(),
            next_id: Arc::new(Mutex::new(0)),
            pin_cores: cfg.pin_cores,
        };
        let thread_shared = shared;
        let thread_metrics = metrics;
        let engine_thread = std::thread::Builder::new()
            .name("chai-engine".into())
            .spawn(move || {
                match make_engine() {
                    Ok(engine) => engine_loop(&engine, &cfg, &thread_shared, &thread_metrics),
                    Err(e) => {
                        eprintln!("[engine] failed to load: {e:#}");
                        // refuse current and future requests (submit
                        // checks the shutdown flag), then wait out any
                        // in-flight pushes and fail what they queued
                        thread_shared.shutdown.store(true, Ordering::SeqCst);
                        thread_shared.queue.lock().unwrap().shutdown = true;
                        while thread_shared.submitting.load(Ordering::SeqCst) != 0 {
                            std::thread::yield_now();
                        }
                        while let Some(r) = thread_shared.inbox.pop() {
                            r.resp_tx.send(Response::error(r.id, format!("{e:#}")));
                        }
                        let ops =
                            std::mem::take(&mut thread_shared.queue.lock().unwrap().ops);
                        for op in ops {
                            fail_op(op, &thread_metrics);
                        }
                    }
                }
            })?;
        Ok(CoordinatorHandle { coordinator: coord, engine_thread: Some(engine_thread) })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, prompt: &str, max_new: usize, variant: Variant) -> Receiver<Response> {
        self.submit_opts(SubmitOpts::new(prompt, max_new, variant)).1
    }

    /// Submit with full options (streaming channel); assigns the id.
    pub fn submit_opts(&self, opts: SubmitOpts) -> (u64, Receiver<Response>) {
        let id = self.alloc_id();
        let rx = self.submit_with_id(id, opts);
        (id, rx)
    }

    /// Submit with a caller-supplied response sink (the reactor path:
    /// no channel allocation, the terminal lands in the request's event
    /// ring); assigns and returns the id.
    pub fn submit_sink(&self, opts: SubmitOpts, resp: RespSink) -> u64 {
        let id = self.alloc_id();
        self.submit_request(id, opts, resp);
        id
    }

    fn alloc_id(&self) -> u64 {
        let mut g = self.next_id.lock().unwrap();
        *g += 1;
        *g
    }

    /// Submit under a caller-assigned id (the router owns the id space
    /// so ids stay unique across replicas). After shutdown the request
    /// is refused with a terminal error instead of queueing forever.
    pub fn submit_with_id(&self, id: u64, opts: SubmitOpts) -> Receiver<Response> {
        let (tx, rx) = channel();
        self.submit_request(id, opts, tx.into());
        rx
    }

    /// The one true submission path: lock-free push into the bounded
    /// inbox ring. A full ring sheds the request right here with a
    /// terminal `{"error": "overloaded"}` — nothing was admitted, so
    /// there is no session state to unwind — and a stopped coordinator
    /// refuses with `"shutting down"`. The `submitting` gate brackets
    /// the shutdown check *and* the push so the engine's final drain
    /// can wait out every in-flight submission (see [`engine_loop`]).
    pub fn submit_request(&self, id: u64, opts: SubmitOpts, resp_tx: RespSink) {
        // admission to the serving stack mints the trace id (unless the
        // router or a parent process already did — wire submissions to
        // `chai replica` children arrive with one)
        let trace = if opts.trace != 0 || !crate::obs::enabled() {
            opts.trace
        } else {
            crate::obs::next_trace_id()
        };
        let req = Request {
            id,
            prompt: opts.prompt,
            max_new: opts.max_new,
            variant: opts.variant,
            submitted_ms: now_ms(),
            resp_tx,
            stream: opts.stream,
            stream_offset: opts.stream_offset,
            trace,
        };
        let sh = &*self.shared;
        sh.submitting.fetch_add(1, Ordering::SeqCst);
        if sh.shutdown.load(Ordering::SeqCst) {
            sh.submitting.fetch_sub(1, Ordering::SeqCst);
            req.resp_tx.send(Response::error(id, "shutting down".into()));
            return;
        }
        match sh.inbox.push(req) {
            Ok(()) => {
                sh.submitting.fetch_sub(1, Ordering::SeqCst);
                self.metrics.inc("submitted");
                // lock-then-notify pairs with the engine's predicate
                // check under the same mutex: the engine either sees
                // the push before parking or is parked and gets the
                // notify — a wakeup can never fall between the two
                drop(sh.queue.lock().unwrap());
                sh.cv.notify_one();
            }
            Err(req) => {
                sh.submitting.fetch_sub(1, Ordering::SeqCst);
                self.metrics.inc("net_shed_overloaded");
                req.resp_tx.send(Response::error(id, "overloaded".into()));
            }
        }
    }

    /// Request an abort of request `id` (async: the engine applies it
    /// on its next tick). Safe for unknown/finished ids — the router
    /// broadcasts cancels to every replica, so no per-replica counter
    /// is bumped here (`sched_cancelled` counts the abort that
    /// actually landed; `router_cancel_requests` counts client
    /// intents).
    pub fn cancel(&self, id: u64) {
        let mut g = self.shared.queue.lock().unwrap();
        if g.shutdown {
            return; // everything gets failed at shutdown anyway
        }
        g.cancels.push(id);
        self.shared.cv.notify_one();
    }

    /// Queue a mesh op for the engine thread. `Err` hands the op back:
    /// the coordinator is shutting down and will never run it, so the
    /// caller must answer the op's client itself.
    fn push_op(&self, op: Op) -> Result<(), Op> {
        let mut g = self.shared.queue.lock().unwrap();
        if g.shutdown {
            return Err(op);
        }
        g.ops.push(op);
        self.shared.cv.notify_one();
        Ok(())
    }

    /// Evacuate every request this replica holds (blocking until the
    /// engine thread hands them over). Empty when the replica is
    /// already shutting down — its requests get terminal errors from
    /// `fail_all` instead, so nothing is silently dropped either way.
    pub fn drain_collect(&self) -> Vec<DrainedItem> {
        let (tx, rx) = channel();
        if self.push_op(Op::Drain(tx)).is_err() {
            return Vec::new();
        }
        rx.recv().unwrap_or_default()
    }

    /// Adopt a session migrated in-process from a peer replica, keeping
    /// its original id and stream position.
    pub fn adopt_local(&self, req: Request, m: MigratedSession, streamed: usize) {
        self.adopt_op(req, AdoptPayload::Local(m), streamed);
    }

    /// Adopt a wire-encoded session record (decoded on the engine
    /// thread against this replica's manifest).
    pub fn adopt_wire(&self, req: Request, record: Json, streamed: usize) {
        self.adopt_op(req, AdoptPayload::Wire(record), streamed);
    }

    fn adopt_op(&self, req: Request, payload: AdoptPayload, streamed: usize) {
        if let Err(Op::Adopt { req, .. }) = self.push_op(Op::Adopt { req, payload, streamed }) {
            self.metrics.inc("errors");
            req.resp_tx.send(Response::error(req.id, "shutting down".into()));
        }
    }

    /// Wire-protocol drain (a `chai replica` child told to evacuate by
    /// its parent): the engine thread writes one `{"drained": [...]}`
    /// reply line on the requesting connection's event ring.
    #[cfg(target_os = "linux")]
    pub fn drain_net(&self, sink: crate::net::NetSink) {
        if let Err(Op::DrainNet(sink)) = self.push_op(Op::DrainNet(sink)) {
            let err = Json::obj(vec![("error", Json::Str("shutting down".into()))]);
            sink.send_line(err.to_string(), true);
        }
    }

    /// Unpack a wire `{"cmd": "adopt"}` into a [`Request`] and queue
    /// it. The session record itself is decoded on the engine thread
    /// (it needs this replica's manifest); only the variant — needed
    /// for the `Request` — is peeked at here, and a malformed record is
    /// answered with a terminal error immediately.
    #[cfg(target_os = "linux")]
    pub fn adopt_net(&self, a: AdoptNet) {
        let variant = a.record.get("variant").and_then(|v| Variant::parse(v.str()?));
        let variant = match variant {
            Ok(v) => v,
            Err(e) => {
                self.metrics.inc("errors");
                a.resp.send(Response::error(a.rid, format!("adopt: {e:#}")));
                return;
            }
        };
        let req = Request {
            id: a.rid,
            // the prompt's tokens travel inside the session record;
            // the original text stays with the parent's entry registry
            prompt: String::new(),
            max_new: a.max_new,
            variant,
            submitted_ms: now_ms(),
            resp_tx: a.resp,
            stream: a.stream,
            stream_offset: a.streamed,
            trace: a.trace,
        };
        self.adopt_op(req, AdoptPayload::Wire(a.record), a.streamed);
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.inbox.len()
    }

    /// Scheduling load of this replica for the router's least-loaded
    /// policy: inbox depth plus the scheduler's pending + live +
    /// preempted populations (the `{"cmd":"sched"}` gauges).
    pub fn load_cost(&self) -> f64 {
        self.queue_depth() as f64
            + self.metrics.gauge("sched_pending")
            + self.metrics.gauge("sched_live")
            + self.metrics.gauge("sched_preempted")
    }

    fn request_shutdown(&self) {
        // atomic first: any submitter that misses it and pushes anyway
        // is covered by the quiescence gate in the engine's final drain
        self.shared.shutdown.store(true, Ordering::SeqCst);
        let mut g = self.shared.queue.lock().unwrap();
        g.shutdown = true;
        self.shared.cv.notify_all();
    }
}

impl CoordinatorHandle {
    pub fn shutdown(mut self) {
        self.coordinator.request_shutdown();
        if let Some(h) = self.engine_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for CoordinatorHandle {
    fn drop(&mut self) {
        self.coordinator.request_shutdown();
        if let Some(h) = self.engine_thread.take() {
            let _ = h.join();
        }
    }
}

/// The thin engine loop: drain the inbox ring (requests) and the
/// cold-path cancel list, tick the scheduler, repeat. Blocks on the
/// condvar when there is nothing pending, live, or preempted — the
/// inbox is checked inside the wait predicate (under the mutex the
/// producers' lock-then-notify pairs with), so a push can never slip
/// between the idle check and the park. On shutdown every request
/// still held anywhere in the pipeline is answered with a terminal
/// error — a client may never be left blocked on a channel whose
/// sender quietly died; the `submitting` gate guarantees the final
/// drain sees every push that beat the shutdown flag.
fn engine_loop(engine: &Engine, cfg: &ServingConfig, shared: &Shared, metrics: &Metrics) {
    // --pin-cores: park this tick thread on its own core (best-effort;
    // the gauge reports where it landed so `{"cmd":"stats"}` can verify)
    #[cfg(target_os = "linux")]
    if cfg.pin_cores {
        if let Some(cpu) = crate::net::sys::pin_next_core() {
            metrics.set_gauge("pin_engine_cpu", cpu as f64);
        }
    }
    // surface which compute backend this engine serves with (the server's
    // `stats` command and benches read these back)
    metrics.set_info("backend", engine.backend_name());
    metrics.set_info("model", &engine.manifest().model.name);
    metrics.set_gauge("net_inbox_capacity", shared.inbox.capacity() as f64);
    let mut sched = Scheduler::new(SchedPolicy::from_config(cfg));
    let mut cancels: Vec<u64> = Vec::new();
    let mut ops: Vec<Op> = Vec::new();
    let mut stopping = false;
    while !stopping {
        {
            let mut g = shared.queue.lock().unwrap();
            if sched.is_idle() && shared.inbox.is_empty() && g.cancels.is_empty() && g.ops.is_empty()
            {
                if !g.shutdown {
                    // idle: block until work arrives
                    g = shared
                        .cv
                        .wait_while(g, |q| {
                            shared.inbox.is_empty()
                                && q.cancels.is_empty()
                                && q.ops.is_empty()
                                && !q.shutdown
                        })
                        .unwrap();
                }
            }
            cancels.append(&mut g.cancels);
            ops.append(&mut g.ops);
            stopping = g.shutdown;
        }
        while let Some(r) = shared.inbox.pop() {
            sched.submit(r);
        }
        if stopping {
            break;
        }
        for id in cancels.drain(..) {
            if !sched.cancel(id, engine, metrics) {
                // cancel raced ahead of its submit (the submitter may
                // still be mid-push into the inbox): tombstone the id so
                // the submit aborts at drain time instead of running to
                // completion. Harmless for genuinely unknown ids — the
                // router broadcasts cancels and ids are never reused.
                sched.note_cancelled_unseen(id);
            }
        }
        // mesh ops run after the inbox drain so a drain reply includes
        // every submit that was already on the wire ahead of it
        for op in ops.drain(..) {
            run_op(op, &mut sched, engine, metrics);
        }
        sched.run_tick(engine, metrics);
        metrics.set_gauge("net_inbox_depth", shared.inbox.len() as f64);
        metrics.set_gauge("net_inbox_hwm", shared.inbox.high_water() as f64);
        // kernel-pool counters: sized threads, cumulative tasks run and
        // worker busy time (the router sums gauges across replicas)
        let (pool_workers, pool_tasks, pool_busy_ns) = engine.pool_stats();
        metrics.set_gauge("pool_workers", pool_workers as f64);
        metrics.set_gauge("pool_tasks", pool_tasks as f64);
        metrics.set_gauge("pool_busy_ns", pool_busy_ns as f64);
    }
    // shutdown: wait out submitters that passed the shutdown check
    // before the flag landed (they are mid-push right now), take what
    // they queued, then answer everything still in flight — including
    // mesh ops, whose callers must never block on a dead engine
    while shared.submitting.load(Ordering::SeqCst) != 0 {
        std::thread::yield_now();
    }
    while let Some(r) = shared.inbox.pop() {
        sched.submit(r);
    }
    ops.append(&mut shared.queue.lock().unwrap().ops);
    for op in ops.drain(..) {
        fail_op(op, metrics);
    }
    sched.fail_all(engine, metrics, "shutting down");
}

/// Execute one mesh op on the engine thread.
fn run_op(op: Op, sched: &mut Scheduler, engine: &Engine, metrics: &Metrics) {
    match op {
        Op::Drain(tx) => {
            let _ = tx.send(sched.drain(engine, metrics));
        }
        Op::Adopt { req, payload, streamed } => {
            let m = match payload {
                AdoptPayload::Local(m) => Ok(m),
                AdoptPayload::Wire(j) => crate::mesh::decode_migrated(&j, engine.manifest()),
            };
            match m {
                Ok(m) => sched.adopt(req, m, streamed, engine, metrics),
                Err(e) => {
                    metrics.inc("errors");
                    req.resp_tx.send(Response::error(req.id, format!("adopt: {e:#}")));
                }
            }
        }
        #[cfg(target_os = "linux")]
        Op::DrainNet(sink) => {
            let records = sched
                .drain(engine, metrics)
                .into_iter()
                .map(|d| {
                    let session = d.session.map(|m| crate::mesh::encode_migrated(&m));
                    crate::mesh::drain_record(d.req.id, d.streamed, session)
                })
                .collect();
            sink.send_line(crate::mesh::drain_reply(records).to_string(), true);
        }
    }
}

/// Answer a mesh op that will never run (engine stopping or dead).
fn fail_op(op: Op, metrics: &Metrics) {
    match op {
        Op::Drain(tx) => {
            let _ = tx.send(Vec::new());
        }
        Op::Adopt { req, .. } => {
            metrics.inc("errors");
            req.resp_tx.send(Response::error(req.id, "shutting down".into()));
        }
        #[cfg(target_os = "linux")]
        Op::DrainNet(sink) => {
            let err = Json::obj(vec![("error", Json::Str("shutting down".into()))]);
            sink.send_line(err.to_string(), true);
        }
    }
}
