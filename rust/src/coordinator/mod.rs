//! Serving coordinator: the cross-thread front door of the engine loop.
//!
//! The `xla` PJRT client is `Rc`-based (not `Send`), so all PJRT state
//! lives on ONE engine thread (the vLLM-style engine-loop design). Front
//! ends (TCP server, bench drivers) submit [`Request`]s into a shared
//! queue and receive a [`Response`] over a per-request channel.
//!
//! All scheduling policy lives in [`crate::scheduler`]: the engine loop
//! here is a thin tick pump that drains the cross-thread inbox into the
//! [`Scheduler`]'s pending queue and calls [`Scheduler::run_tick`] —
//! token-level continuous batching with FCFS admission, fused paged
//! decode ticks ([`crate::engine::Engine::decode_tick`]), and (with
//! `--preempt`) preempt-and-requeue of live sessions under overload,
//! swapping K,V state to the host spill tier or recomputing it on
//! resume.

pub use crate::scheduler::{Request, Response};

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::config::ServingConfig;
use crate::engine::{Engine, Variant};
use crate::metrics::Metrics;
use crate::scheduler::{SchedPolicy, Scheduler};
use crate::util::now_ms;

#[derive(Default)]
struct Shared {
    queue: Mutex<QueueState>,
    cv: Condvar,
}

#[derive(Default)]
struct QueueState {
    waiting: VecDeque<Request>,
    shutdown: bool,
}

/// Handle owned by front-ends; cheap to clone.
#[derive(Clone)]
pub struct Coordinator {
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    next_id: Arc<Mutex<u64>>,
}

pub struct CoordinatorHandle {
    pub coordinator: Coordinator,
    engine_thread: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the engine thread and return the submission handle.
    pub fn start(cfg: ServingConfig) -> Result<CoordinatorHandle> {
        let shared = Arc::new(Shared::default());
        let metrics = Arc::new(Metrics::new());
        let coord = Coordinator {
            shared: shared.clone(),
            metrics: metrics.clone(),
            next_id: Arc::new(Mutex::new(0)),
        };
        let thread_shared = shared;
        let thread_metrics = metrics;
        let engine_thread = std::thread::Builder::new()
            .name("chai-engine".into())
            .spawn(move || {
                match Engine::load(cfg.clone()) {
                    Ok(engine) => engine_loop(&engine, &cfg, &thread_shared, &thread_metrics),
                    Err(e) => {
                        eprintln!("[engine] failed to load: {e:#}");
                        // drain queue with errors
                        let mut g = thread_shared.queue.lock().unwrap();
                        g.shutdown = true;
                        while let Some(r) = g.waiting.pop_front() {
                            let _ = r.resp_tx.send(Response::error(r.id, format!("{e:#}")));
                        }
                    }
                }
            })?;
        Ok(CoordinatorHandle { coordinator: coord, engine_thread: Some(engine_thread) })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, prompt: &str, max_new: usize, variant: Variant) -> Receiver<Response> {
        let (tx, rx) = channel();
        let id = {
            let mut g = self.next_id.lock().unwrap();
            *g += 1;
            *g
        };
        let req = Request {
            id,
            prompt: prompt.to_string(),
            max_new,
            variant,
            submitted_ms: now_ms(),
            resp_tx: tx,
        };
        self.metrics.inc("submitted");
        let mut g = self.shared.queue.lock().unwrap();
        g.waiting.push_back(req);
        self.shared.cv.notify_one();
        rx
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().waiting.len()
    }

    fn request_shutdown(&self) {
        let mut g = self.shared.queue.lock().unwrap();
        g.shutdown = true;
        self.shared.cv.notify_all();
    }
}

impl CoordinatorHandle {
    pub fn shutdown(mut self) {
        self.coordinator.request_shutdown();
        if let Some(h) = self.engine_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for CoordinatorHandle {
    fn drop(&mut self) {
        self.coordinator.request_shutdown();
        if let Some(h) = self.engine_thread.take() {
            let _ = h.join();
        }
    }
}

/// The thin engine loop: drain the inbox, tick the scheduler, repeat.
/// Blocks on the condvar when there is nothing pending, live, or
/// preempted; returns on shutdown once all accepted work has drained.
fn engine_loop(engine: &Engine, cfg: &ServingConfig, shared: &Shared, metrics: &Metrics) {
    // surface which compute backend this engine serves with (the server's
    // `stats` command and benches read these back)
    metrics.set_info("backend", engine.backend_name());
    metrics.set_info("model", &engine.manifest().model.name);
    let mut sched = Scheduler::new(SchedPolicy::from_config(cfg));
    loop {
        {
            let mut g = shared.queue.lock().unwrap();
            if sched.is_idle() && g.waiting.is_empty() {
                if g.shutdown {
                    return;
                }
                // idle: block until work arrives
                g = shared
                    .cv
                    .wait_while(g, |q| q.waiting.is_empty() && !q.shutdown)
                    .unwrap();
                if g.shutdown && g.waiting.is_empty() {
                    return;
                }
            }
            while let Some(r) = g.waiting.pop_front() {
                sched.submit(r);
            }
        }
        sched.run_tick(engine, metrics);
    }
}
