//! Accuracy-evaluation harness: length-normalized logprob scoring of the
//! five synthetic MCQ suites (`artifacts/eval/*.json`) — regenerates the
//! accuracy columns of Tables 1-4.

use std::path::Path;

use anyhow::{Context, Result};

use crate::engine::{Engine, Variant};
use crate::model::tokenizer;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Item {
    pub prompt: String,
    pub choices: Vec<String>,
    pub label: usize,
}

#[derive(Debug, Clone)]
pub struct Suite {
    pub name: String,
    pub items: Vec<Item>,
}

pub const SUITES: [&str; 5] =
    ["piqa-syn", "hellaswag-syn", "arc-challenge-syn", "arc-easy-syn", "boolq-syn"];

pub fn load_suite(dir: &Path, name: &str) -> Result<Suite> {
    let j = Json::parse_file(&dir.join("eval").join(format!("{name}.json")))
        .with_context(|| format!("loading eval suite {name}"))?;
    let items = j
        .get("items")?
        .arr()?
        .iter()
        .map(|it| {
            Ok(Item {
                prompt: it.get("prompt")?.str()?.to_string(),
                choices: it.get("choices")?.str_vec()?,
                label: it.get("label")?.usize()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Suite { name: name.to_string(), items })
}

/// Score one item: argmax over per-choice length-normalized logprob.
pub fn predict(engine: &Engine, item: &Item, variant: &Variant) -> Result<usize> {
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (ci, choice) in item.choices.iter().enumerate() {
        let prompt_tokens = tokenizer::encode(&item.prompt, true, false);
        let mut tokens = prompt_tokens.clone();
        tokens.extend(tokenizer::encode(choice, false, false));
        let logits = engine.logits(&tokens, variant)?;
        let score = engine.score_choice(&logits, &tokens, prompt_tokens.len());
        if score > best.0 {
            best = (score, ci);
        }
    }
    Ok(best.1)
}

/// Accuracy of a variant on one suite (optionally subsampled for speed).
pub fn accuracy(
    engine: &Engine,
    suite: &Suite,
    variant: &Variant,
    max_items: Option<usize>,
) -> Result<f64> {
    let n = max_items.map(|m| m.min(suite.items.len())).unwrap_or(suite.items.len());
    let mut correct = 0usize;
    for item in &suite.items[..n] {
        if predict(engine, item, variant)? == item.label {
            correct += 1;
        }
    }
    Ok(100.0 * correct as f64 / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_all_suites() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("eval").exists() {
            return;
        }
        for name in SUITES {
            let s = load_suite(&dir, name).unwrap();
            assert!(!s.items.is_empty(), "{name} empty");
            for it in &s.items {
                assert!(it.label < it.choices.len());
                assert!(!it.prompt.is_empty());
            }
        }
    }

    #[test]
    fn missing_suite_errors() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("eval").exists() {
            return;
        }
        assert!(load_suite(&dir, "nope").is_err());
    }
}
