//! Clustered KV-cache manager (paper §3.5 + Figure 11).
//!
//! CHAI stores K panels only for each layer's `k_l` representative heads
//! while keeping all `H` V panels (Table 4 shows pruning V costs accuracy).
//! This module owns the per-request cache handles (host tensors or device
//! buffers), the exact byte accounting that regenerates Figure 11, and a
//! capacity-managed pool with admission control for the coordinator.
//!
//! Two pools coexist:
//! * [`KvPool`] — the original contiguous accounting pool (worst-case
//!   bucket bytes per request), kept for the `--no-paged` legacy path
//!   and the Figure-11 byte formulas.
//! * [`paged`] — the block-granular subsystem (refcounted block pool,
//!   per-request block tables, prefix sharing with copy-on-write, LRU
//!   eviction) that the coordinator serves with by default.

pub mod paged;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::config::Manifest;

/// Which attention layout a cache uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheKind {
    /// dense MHA: K and V are `[L, H, T, dh]`
    Mha,
    /// CHAI: per-layer K `[k_l, T, dh]`, V `[L, H, T, dh]`
    Chai,
}

/// Exact K,V byte accounting for one request at bucket length `t`.
/// This is the quantity plotted in Figure 11.
pub fn cache_bytes(kind: CacheKind, m: &Manifest, t: usize) -> usize {
    let (l, h, dh) = (m.model.n_layers, m.model.n_heads, m.model.head_dim);
    let f32s = match kind {
        CacheKind::Mha => 2 * l * h * t * dh,
        CacheKind::Chai => {
            let k_sum: usize = m.k_list.iter().sum();
            (k_sum + l * h) * t * dh
        }
    };
    f32s * 4
}

/// Relative K,V-cache saving of CHAI vs MHA (paper: up to 21.4%).
pub fn chai_saving_fraction(m: &Manifest) -> f64 {
    let mha = cache_bytes(CacheKind::Mha, m, 1024) as f64;
    let chai = cache_bytes(CacheKind::Chai, m, 1024) as f64;
    1.0 - chai / mha
}

/// A live cache registration in the pool.
#[derive(Debug, Clone)]
pub struct CacheEntry {
    pub request_id: u64,
    pub kind: CacheKind,
    pub bucket: usize,
    pub bytes: usize,
    pub last_touch: u64,
}

/// Capacity-managed KV pool: admission control + LRU eviction candidates.
/// (On this CPU testbed "device memory" is host memory; the pool enforces
/// the budget the paper's GPU serving setup would.)
#[derive(Debug)]
pub struct KvPool {
    pub capacity_bytes: usize,
    used: usize,
    entries: BTreeMap<u64, CacheEntry>,
    clock: u64,
}

impl KvPool {
    pub fn new(capacity_bytes: usize) -> KvPool {
        KvPool { capacity_bytes, used: 0, entries: BTreeMap::new(), clock: 0 }
    }

    pub fn used_bytes(&self) -> usize {
        self.used
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Can a cache of this size be admitted right now?
    pub fn fits(&self, bytes: usize) -> bool {
        self.used + bytes <= self.capacity_bytes
    }

    /// Register a request's cache; errors if it would exceed capacity.
    pub fn admit(&mut self, request_id: u64, kind: CacheKind, m: &Manifest, bucket: usize) -> Result<usize> {
        let bytes = cache_bytes(kind, m, bucket);
        if !self.fits(bytes) {
            bail!(
                "kv pool full: need {bytes} B, used {}/{} B",
                self.used,
                self.capacity_bytes
            );
        }
        if self.entries.contains_key(&request_id) {
            bail!("request {request_id} already admitted");
        }
        self.clock += 1;
        self.entries.insert(
            request_id,
            CacheEntry { request_id, kind, bucket, bytes, last_touch: self.clock },
        );
        self.used += bytes;
        Ok(bytes)
    }

    /// Mark a request's cache as touched (decode step).
    pub fn touch(&mut self, request_id: u64) {
        self.clock += 1;
        if let Some(e) = self.entries.get_mut(&request_id) {
            e.last_touch = self.clock;
        }
    }

    /// Release a finished request's cache.
    pub fn release(&mut self, request_id: u64) -> Result<()> {
        match self.entries.remove(&request_id) {
            Some(e) => {
                self.used -= e.bytes;
                Ok(())
            }
            None => bail!("request {request_id} not in pool"),
        }
    }

    /// Least-recently-touched entry — the eviction/preemption candidate.
    pub fn lru(&self) -> Option<u64> {
        self.entries.values().min_by_key(|e| e.last_touch).map(|e| e.request_id)
    }

    /// A request needs to grow into a larger bucket (sequence outgrew its
    /// cache): re-account the delta; errors if it does not fit.
    pub fn grow(&mut self, request_id: u64, m: &Manifest, new_bucket: usize) -> Result<()> {
        let (kind, old_bytes, old_bucket) = match self.entries.get(&request_id) {
            Some(e) => (e.kind, e.bytes, e.bucket),
            None => bail!("request {request_id} not in pool"),
        };
        if new_bucket <= old_bucket {
            return Ok(());
        }
        let new_bytes = cache_bytes(kind, m, new_bucket);
        if self.used - old_bytes + new_bytes > self.capacity_bytes {
            bail!("kv pool full on grow");
        }
        self.used = self.used - old_bytes + new_bytes;
        let e = self.entries.get_mut(&request_id).unwrap();
        e.bytes = new_bytes;
        e.bucket = new_bucket;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use std::path::Path;

    fn manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn chai_cache_is_smaller() {
        let Some(m) = manifest() else { return };
        for t in [128usize, 512, 2048] {
            let mha = cache_bytes(CacheKind::Mha, &m, t);
            let chai = cache_bytes(CacheKind::Chai, &m, t);
            assert!(chai < mha, "t={t}: {chai} !< {mha}");
        }
        let s = chai_saving_fraction(&m);
        assert!(s > 0.05 && s < 0.5, "saving {s}");
    }

    #[test]
    fn mha_bytes_formula() {
        let Some(m) = manifest() else { return };
        let t = 256;
        let expect =
            2 * m.model.n_layers * m.model.n_heads * t * m.model.head_dim * 4;
        assert_eq!(cache_bytes(CacheKind::Mha, &m, t), expect);
    }

    #[test]
    fn pool_admission_and_release() {
        let Some(m) = manifest() else { return };
        let one = cache_bytes(CacheKind::Mha, &m, 128);
        let mut pool = KvPool::new(one * 2 + 1);
        pool.admit(1, CacheKind::Mha, &m, 128).unwrap();
        pool.admit(2, CacheKind::Mha, &m, 128).unwrap();
        assert!(pool.admit(3, CacheKind::Mha, &m, 128).is_err());
        assert_eq!(pool.len(), 2);
        pool.release(1).unwrap();
        pool.admit(3, CacheKind::Mha, &m, 128).unwrap();
        assert!(pool.release(99).is_err());
    }

    #[test]
    fn lru_tracks_touches() {
        let Some(m) = manifest() else { return };
        let mut pool = KvPool::new(usize::MAX);
        pool.admit(1, CacheKind::Chai, &m, 128).unwrap();
        pool.admit(2, CacheKind::Chai, &m, 128).unwrap();
        pool.admit(3, CacheKind::Chai, &m, 128).unwrap();
        assert_eq!(pool.lru(), Some(1));
        pool.touch(1);
        assert_eq!(pool.lru(), Some(2));
    }

    #[test]
    fn grow_reaccounts() {
        let Some(m) = manifest() else { return };
        let small = cache_bytes(CacheKind::Mha, &m, 128);
        let big = cache_bytes(CacheKind::Mha, &m, 512);
        let mut pool = KvPool::new(big);
        pool.admit(1, CacheKind::Mha, &m, 128).unwrap();
        assert_eq!(pool.used_bytes(), small);
        pool.grow(1, &m, 512).unwrap();
        assert_eq!(pool.used_bytes(), big);
        // shrink request is a no-op
        pool.grow(1, &m, 128).unwrap();
        assert_eq!(pool.used_bytes(), big);
    }

    #[test]
    fn property_pool_accounting_consistent() {
        let Some(m) = manifest() else { return };
        check("kv-pool-accounting", 20, |rng| {
            let mut pool = KvPool::new(100 * 1024 * 1024);
            let mut live: Vec<u64> = Vec::new();
            let mut bytes_by_id: std::collections::BTreeMap<u64, (CacheKind, usize)> =
                Default::default();
            let mut next_id = 0u64;
            for _ in 0..100 {
                match rng.below(4) {
                    0 => {
                        let kind = if rng.below(2) == 0 { CacheKind::Mha } else { CacheKind::Chai };
                        let bucket = [32, 128, 512][rng.below(3)];
                        if let Ok(bytes) = pool.admit(next_id, kind, &m, bucket) {
                            crate::prop_assert!(
                                bytes == cache_bytes(kind, &m, bucket),
                                "admit returned {bytes} B"
                            );
                            bytes_by_id.insert(next_id, (kind, bytes));
                            live.push(next_id);
                        }
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        let id = live.swap_remove(i);
                        bytes_by_id.remove(&id);
                        pool.release(id).map_err(|e| e.to_string())?;
                    }
                    2 if !live.is_empty() => {
                        // grow to a random bucket; a shrink request is a
                        // no-op so tracked bytes only ever ratchet up
                        let id = live[rng.below(live.len())];
                        let bucket = [32, 128, 512, 2048][rng.below(4)];
                        let (kind, before) = *bytes_by_id.get(&id).unwrap();
                        if pool.grow(id, &m, bucket).is_ok() {
                            let grown = cache_bytes(kind, &m, bucket);
                            bytes_by_id.insert(id, (kind, before.max(grown)));
                        }
                    }
                    _ if !live.is_empty() => {
                        let id = live[rng.below(live.len())];
                        pool.touch(id);
                    }
                    _ => {}
                }
                let expect: usize = bytes_by_id.values().map(|(_, b)| *b).sum();
                crate::prop_assert!(
                    pool.used_bytes() == expect,
                    "used {} != tracked sum {}", pool.used_bytes(), expect
                );
                crate::prop_assert!(
                    pool.len() == live.len(),
                    "entry count {} != live {}", pool.len(), live.len()
                );
                crate::prop_assert!(
                    pool.used_bytes() <= pool.capacity_bytes,
                    "over capacity"
                );
                if live.is_empty() {
                    crate::prop_assert!(pool.used_bytes() == 0, "leak: {} bytes", pool.used_bytes());
                }
            }
            // drain
            for id in live.drain(..) {
                pool.release(id).map_err(|e| e.to_string())?;
            }
            crate::prop_assert!(pool.used_bytes() == 0, "leak after drain");
            Ok(())
        });
    }
}
