//! Fixed-capacity refcounted block allocator.
//!
//! The pool owns every physical KV block slab (a `Vec<f32>` holding the
//! K and V rows for `block_size` token slots across all layers — see
//! [`super::KvLayout`] for the in-block layout). Blocks are refcounted:
//! a block referenced by more than one [`super::BlockTable`] is shared
//! and must never be written (copy-on-write happens in the manager).
//! When the last reference drops, a block that carries a prefix hash is
//! *cached* — it stays resident and adoptable until LRU eviction needs
//! the bytes back; an unhashed block is freed immediately.
//!
//! Capacity is accounted in bytes, not block counts, because CHAI and
//! MHA tables allocate different block sizes from the same pool (CHAI K
//! regions hold only each layer's `k_l` representative heads).

use anyhow::{bail, Result};

/// Index into the pool's block slab.
pub type BlockId = usize;

/// What happened to a block when a reference was dropped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReleaseOutcome {
    /// other references remain
    StillLive,
    /// refcount hit zero; block retained for prefix reuse (evictable)
    Cached,
    /// refcount hit zero; block freed immediately (no prefix hash)
    Freed,
}

#[derive(Debug)]
pub struct Block {
    pub data: Vec<f32>,
    /// accounting size (data.len() * 4)
    pub bytes: usize,
    pub refs: u32,
    /// prefix-index key this block is registered under, if any
    pub hash: Option<u64>,
    /// token slots actually written (<= block_size)
    pub filled: usize,
    pub last_touch: u64,
}

#[derive(Debug, Default)]
pub struct BlockPool {
    capacity_bytes: usize,
    /// bytes of live + cached blocks
    used_bytes: usize,
    /// bytes of cached (refs == 0, evictable) blocks
    cached_bytes: usize,
    slots: Vec<Option<Block>>,
    free_slots: Vec<BlockId>,
    clock: u64,
}

impl BlockPool {
    pub fn new(capacity_bytes: usize) -> BlockPool {
        BlockPool { capacity_bytes, ..Default::default() }
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn cached_bytes(&self) -> usize {
        self.cached_bytes
    }

    /// Bytes that an allocation could claim right now: free capacity plus
    /// everything evictable.
    pub fn reclaimable_bytes(&self) -> usize {
        self.capacity_bytes - self.used_bytes + self.cached_bytes
    }

    pub fn live_blocks(&self) -> usize {
        self.slots.iter().flatten().filter(|b| b.refs > 0).count()
    }

    pub fn cached_blocks(&self) -> usize {
        self.slots.iter().flatten().filter(|b| b.refs == 0).count()
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    pub fn block(&self, id: BlockId) -> &Block {
        self.slots[id].as_ref().expect("stale block id")
    }

    fn block_mut(&mut self, id: BlockId) -> &mut Block {
        self.slots[id].as_mut().expect("stale block id")
    }

    pub fn data(&self, id: BlockId) -> &[f32] {
        &self.block(id).data
    }

    /// Mutable access to a block's slab. Callers must hold the only
    /// reference (copy-on-write is the manager's job).
    pub fn data_mut(&mut self, id: BlockId) -> &mut [f32] {
        let b = self.block_mut(id);
        debug_assert!(b.refs <= 1, "in-place write to a shared block");
        &mut b.data
    }

    /// Allocate a zeroed block of `floats` f32 slots if it fits in the
    /// *free* capacity. Eviction of cached blocks is driven by the
    /// manager (it must also unregister prefix hashes).
    pub fn try_alloc(&mut self, floats: usize) -> Option<BlockId> {
        let bytes = floats * 4;
        if self.used_bytes + bytes > self.capacity_bytes {
            return None;
        }
        let t = self.tick();
        let block = Block {
            data: vec![0.0; floats],
            bytes,
            refs: 1,
            hash: None,
            filled: 0,
            last_touch: t,
        };
        self.used_bytes += bytes;
        let id = match self.free_slots.pop() {
            Some(id) => {
                self.slots[id] = Some(block);
                id
            }
            None => {
                self.slots.push(Some(block));
                self.slots.len() - 1
            }
        };
        Some(id)
    }

    /// Take one more reference on a block (live or cached). A cached
    /// block returns to live accounting.
    pub fn retain(&mut self, id: BlockId) {
        let t = self.tick();
        let b = self.slots[id].as_mut().expect("stale block id");
        if b.refs == 0 {
            self.cached_bytes -= b.bytes;
        }
        b.refs += 1;
        b.last_touch = t;
    }

    /// Drop one reference. A zero-ref hashed block becomes cached; an
    /// unhashed one is freed.
    pub fn release(&mut self, id: BlockId) -> ReleaseOutcome {
        let t = self.tick();
        let b = self.slots[id].as_mut().expect("stale block id");
        assert!(b.refs > 0, "release of unreferenced block {id}");
        b.refs -= 1;
        b.last_touch = t;
        if b.refs > 0 {
            return ReleaseOutcome::StillLive;
        }
        if b.hash.is_some() {
            self.cached_bytes += b.bytes;
            ReleaseOutcome::Cached
        } else {
            self.free_now(id);
            ReleaseOutcome::Freed
        }
    }

    fn free_now(&mut self, id: BlockId) {
        let b = self.slots[id].take().expect("stale block id");
        self.used_bytes -= b.bytes;
        self.free_slots.push(id);
    }

    /// Evict the least-recently-touched cached block, returning its id
    /// and the prefix hash the caller must unregister. `None` when
    /// nothing is evictable.
    pub fn evict_lru(&mut self) -> Option<(BlockId, Option<u64>)> {
        let victim = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|b| (i, b)))
            .filter(|(_, b)| b.refs == 0)
            .min_by_key(|(_, b)| b.last_touch)
            .map(|(i, _)| i)?;
        let (hash, bytes) = {
            let b = self.block(victim);
            (b.hash, b.bytes)
        };
        self.cached_bytes -= bytes;
        self.free_now(victim);
        Some((victim, hash))
    }

    /// Register the prefix hash a block is indexed under. Only set once
    /// per block lifetime (cleared by [`Self::clear_hash`] on CoW-exempt
    /// in-place mutation).
    pub fn set_hash(&mut self, id: BlockId, hash: u64) {
        let b = self.block_mut(id);
        debug_assert!(b.hash.is_none(), "re-hashing block {id}");
        b.hash = Some(hash);
    }

    /// Forget a block's prefix hash (the caller must also remove it from
    /// the index): the block is about to be mutated in place.
    pub fn clear_hash(&mut self, id: BlockId) -> Option<u64> {
        self.block_mut(id).hash.take()
    }

    pub fn set_filled(&mut self, id: BlockId, filled: usize) {
        self.block_mut(id).filled = filled;
    }

    pub fn touch(&mut self, id: BlockId) {
        let t = self.tick();
        self.block_mut(id).last_touch = t;
    }

    /// Sanity check used by tests: internal byte accounting matches a
    /// fresh scan over the slots.
    pub fn check_accounting(&self) -> Result<()> {
        let scan_used: usize = self.slots.iter().flatten().map(|b| b.bytes).sum();
        let scan_cached: usize =
            self.slots.iter().flatten().filter(|b| b.refs == 0).map(|b| b.bytes).sum();
        if scan_used != self.used_bytes {
            bail!("used_bytes {} != scanned {}", self.used_bytes, scan_used);
        }
        if scan_cached != self.cached_bytes {
            bail!("cached_bytes {} != scanned {}", self.cached_bytes, scan_cached);
        }
        if self.used_bytes > self.capacity_bytes {
            bail!("over capacity: {} > {}", self.used_bytes, self.capacity_bytes);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut p = BlockPool::new(4096);
        let a = p.try_alloc(256).unwrap(); // 1024 B
        let b = p.try_alloc(256).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.used_bytes(), 2048);
        assert_eq!(p.live_blocks(), 2);
        assert_eq!(p.release(a), ReleaseOutcome::Freed);
        assert_eq!(p.used_bytes(), 1024);
        assert_eq!(p.release(b), ReleaseOutcome::Freed);
        assert_eq!(p.used_bytes(), 0);
        p.check_accounting().unwrap();
    }

    #[test]
    fn capacity_is_enforced() {
        let mut p = BlockPool::new(1024);
        assert!(p.try_alloc(128).is_some()); // 512 B
        assert!(p.try_alloc(128).is_some());
        assert!(p.try_alloc(1).is_none());
    }

    #[test]
    fn hashed_blocks_cache_and_evict() {
        let mut p = BlockPool::new(4096);
        let a = p.try_alloc(256).unwrap();
        p.set_hash(a, 0xabc);
        assert_eq!(p.release(a), ReleaseOutcome::Cached);
        assert_eq!(p.used_bytes(), 1024);
        assert_eq!(p.cached_bytes(), 1024);
        assert_eq!(p.cached_blocks(), 1);
        // adoption brings it back to live
        p.retain(a);
        assert_eq!(p.cached_bytes(), 0);
        assert_eq!(p.release(a), ReleaseOutcome::Cached);
        let (id, hash) = p.evict_lru().unwrap();
        assert_eq!(id, a);
        assert_eq!(hash, Some(0xabc));
        assert_eq!(p.used_bytes(), 0);
        assert!(p.evict_lru().is_none());
        p.check_accounting().unwrap();
    }

    #[test]
    fn shared_blocks_stay_until_last_release() {
        let mut p = BlockPool::new(4096);
        let a = p.try_alloc(16).unwrap();
        p.retain(a);
        assert_eq!(p.block(a).refs, 2);
        assert_eq!(p.release(a), ReleaseOutcome::StillLive);
        assert_eq!(p.release(a), ReleaseOutcome::Freed);
    }

    #[test]
    fn lru_evicts_oldest_cached() {
        let mut p = BlockPool::new(8192);
        let a = p.try_alloc(16).unwrap();
        let b = p.try_alloc(16).unwrap();
        p.set_hash(a, 1);
        p.set_hash(b, 2);
        p.release(a);
        p.release(b);
        p.touch(a); // a is now more recent
        let (id, _) = p.evict_lru().unwrap();
        assert_eq!(id, b);
    }

    #[test]
    fn slot_reuse_after_free() {
        let mut p = BlockPool::new(4096);
        let a = p.try_alloc(16).unwrap();
        p.release(a);
        let b = p.try_alloc(32).unwrap();
        assert_eq!(a, b, "freed slot should be reused");
        assert_eq!(p.data(b).len(), 32);
        assert!(p.data(b).iter().all(|x| *x == 0.0), "reused slab must be zeroed");
    }
}
