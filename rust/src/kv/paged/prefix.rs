//! Prefix index: token-hash chains → cached/live block ids.
//!
//! A block's key is the FNV-1a chain hash of every token it and its
//! predecessors cover, seeded by a per-variant namespace. Because
//! attention is causal, the K,V rows of positions `[0, n)` are a
//! deterministic function of tokens `[0, n)` (for CHAI, membership is a
//! deterministic function of the probe prefix, which the first block
//! covers — the manager gates sharing on `block_size >= probe_tokens`).
//! Two requests whose chains agree may therefore share physical blocks.
//!
//! Full blocks are keyed by the chain through their last token; the
//! partial tail of a prompt is keyed separately (salted) so it can only
//! be adopted by a request whose prompt ends at exactly the same token.
//! 64-bit content hashes are the same trade vLLM's prefix caching makes:
//! collisions are possible in principle and ignored in practice.

use std::collections::HashMap;

use super::pool::BlockId;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;
/// Salt folded into partial-tail keys so they can never alias a
/// full-block chain key.
const PARTIAL_SALT: u64 = 0x9e3779b97f4a7c15;

fn fnv1a_step(mut h: u64, byte: u8) -> u64 {
    h ^= byte as u64;
    h.wrapping_mul(FNV_PRIME)
}

fn fold_u64(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h = fnv1a_step(h, b);
    }
    h
}

/// Seed of a chain: hashes the sharing namespace (attention variant) so
/// e.g. online-CHAI and static-CHAI caches never alias.
pub fn chain_seed(namespace: &str) -> u64 {
    let mut h = FNV_OFFSET;
    for b in namespace.bytes() {
        h = fnv1a_step(h, b);
    }
    h
}

/// Extend a chain hash over one block's tokens.
pub fn chain_hash(prev: u64, tokens: &[i32]) -> u64 {
    let mut h = fold_u64(prev, 0x626c6f636b); // "block"
    for t in tokens {
        h = fold_u64(h, *t as u64);
    }
    h
}

/// Key for a *partial* tail block holding exactly `tokens` after the
/// chain `prev` of full blocks.
pub fn partial_hash(prev: u64, tokens: &[i32]) -> u64 {
    chain_hash(prev ^ PARTIAL_SALT, tokens) ^ fold_u64(FNV_OFFSET, tokens.len() as u64)
}

/// Routing digest of a prompt's shareable prefix: the chain hash of its
/// leading full blocks — at most `max_blocks` of them — or the salted
/// partial hash when the prompt is shorter than one block. The keys are
/// the SAME token-hash chain the prefix index files blocks under, so a
/// router that places requests by this digest lands same-prefix traffic
/// on the replica that already holds those blocks (the prefix-affinity
/// policy). The cap is what makes affinity robust to tails: hashing
/// every full block would give "system prompt + question A" and
/// "system prompt + question B" different digests whenever the
/// questions spill into further full blocks, scattering exactly the
/// traffic that should stay together — capping at the leading blocks
/// groups by the shared head instead. Pure function of its arguments —
/// no pool access.
pub fn prompt_fingerprint(
    namespace: &str,
    tokens: &[i32],
    block_size: usize,
    max_blocks: usize,
) -> u64 {
    let b = block_size.max(1);
    let mut h = chain_seed(namespace);
    let n_full = tokens.len() / b;
    if n_full == 0 {
        return partial_hash(h, tokens);
    }
    for i in 0..n_full.min(max_blocks.max(1)) {
        h = chain_hash(h, &tokens[i * b..(i + 1) * b]);
    }
    h
}

/// hash → block id map. The manager keeps it consistent with block
/// lifetimes: entries are added when a block's content is final for its
/// key, and removed on eviction or before in-place mutation.
#[derive(Debug, Default)]
pub struct PrefixIndex {
    map: HashMap<u64, BlockId>,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex::default()
    }

    pub fn get(&self, hash: u64) -> Option<BlockId> {
        self.map.get(&hash).copied()
    }

    /// Register `id` under `hash`. An existing entry wins: the first
    /// publisher's block is the canonical copy and later duplicates are
    /// simply not indexed (their owner still holds them privately).
    pub fn insert(&mut self, hash: u64, id: BlockId) -> bool {
        use std::collections::hash_map::Entry;
        match self.map.entry(hash) {
            Entry::Occupied(_) => false,
            Entry::Vacant(v) => {
                v.insert(id);
                true
            }
        }
    }

    /// Remove `hash`, but only if it still points at `id` (a later
    /// publisher may own the entry now).
    pub fn remove(&mut self, hash: u64, id: BlockId) {
        if self.map.get(&hash) == Some(&id) {
            self.map.remove(&hash);
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_is_order_and_prefix_sensitive() {
        let s = chain_seed("chai");
        let a = chain_hash(s, &[1, 2, 3]);
        let b = chain_hash(s, &[3, 2, 1]);
        assert_ne!(a, b);
        let aa = chain_hash(a, &[4, 5]);
        let ab = chain_hash(b, &[4, 5]);
        assert_ne!(aa, ab, "chain must carry history");
        // deterministic
        assert_eq!(chain_hash(s, &[1, 2, 3]), a);
    }

    #[test]
    fn namespaces_do_not_alias() {
        let t = [7i32, 8, 9];
        assert_ne!(
            chain_hash(chain_seed("chai"), &t),
            chain_hash(chain_seed("chai-static"), &t)
        );
        assert_ne!(
            chain_hash(chain_seed("chai"), &t),
            chain_hash(chain_seed("mha"), &t)
        );
    }

    #[test]
    fn partial_never_equals_full() {
        let s = chain_seed("mha");
        let t = [1i32, 2, 3, 4];
        assert_ne!(partial_hash(s, &t), chain_hash(s, &t));
        // different lengths of partial differ
        assert_ne!(partial_hash(s, &t[..3]), partial_hash(s, &t));
    }

    #[test]
    fn fingerprint_groups_by_shareable_prefix() {
        let (b, cap) = (4, 8);
        // same leading full blocks + different tails → same fingerprint
        let sys: Vec<i32> = (0..9).collect(); // 2 full blocks + tail of 1
        let mut a = sys.clone();
        a.extend([100, 101]);
        let mut c = sys.clone();
        c.extend([200]);
        assert_eq!(
            prompt_fingerprint("chai", &a, b, cap),
            prompt_fingerprint("chai", &c, b, cap)
        );
        // diverging inside the first block → different fingerprints
        let mut d = a.clone();
        d[1] = 99;
        assert_ne!(
            prompt_fingerprint("chai", &a, b, cap),
            prompt_fingerprint("chai", &d, b, cap)
        );
        // namespaces do not alias
        assert_ne!(
            prompt_fingerprint("chai", &a, b, cap),
            prompt_fingerprint("mha", &a, b, cap)
        );
        // sub-block prompts hash by their exact content (salted partial)
        assert_ne!(
            prompt_fingerprint("mha", &[1, 2], b, cap),
            prompt_fingerprint("mha", &[1, 2, 3], b, cap)
        );
        assert_eq!(
            prompt_fingerprint("mha", &[1, 2], b, cap),
            prompt_fingerprint("mha", &[1, 2], b, cap)
        );
    }

    #[test]
    fn fingerprint_cap_groups_long_divergent_tails() {
        let b = 4;
        // shared 2-block system prompt, then long tails that spill into
        // further FULL blocks — uncapped digests diverge, capped ones
        // keep the traffic together
        let sys: Vec<i32> = (0..8).collect();
        let mut a = sys.clone();
        a.extend((500..510).collect::<Vec<i32>>()); // blocks 2,3 differ
        let mut c = sys.clone();
        c.extend((900..910).collect::<Vec<i32>>());
        assert_ne!(
            prompt_fingerprint("mha", &a, b, usize::MAX),
            prompt_fingerprint("mha", &c, b, usize::MAX),
            "uncapped: divergent full tails split the digest"
        );
        assert_eq!(
            prompt_fingerprint("mha", &a, b, 2),
            prompt_fingerprint("mha", &c, b, 2),
            "capped at the shared head: same replica"
        );
    }

    #[test]
    fn index_first_publisher_wins() {
        let mut ix = PrefixIndex::new();
        assert!(ix.insert(42, 1));
        assert!(!ix.insert(42, 2));
        assert_eq!(ix.get(42), Some(1));
        // removing under the loser id is a no-op
        ix.remove(42, 2);
        assert_eq!(ix.get(42), Some(1));
        ix.remove(42, 1);
        assert_eq!(ix.get(42), None);
        assert!(ix.is_empty());
    }
}
