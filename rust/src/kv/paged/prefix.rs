//! Prefix index: token-hash chains → cached/live block ids.
//!
//! A block's key is the FNV-1a chain hash of every token it and its
//! predecessors cover, seeded by a per-variant namespace. Because
//! attention is causal, the K,V rows of positions `[0, n)` are a
//! deterministic function of tokens `[0, n)` (for CHAI, membership is a
//! deterministic function of the probe prefix, which the first block
//! covers — the manager gates sharing on `block_size >= probe_tokens`).
//! Two requests whose chains agree may therefore share physical blocks.
//!
//! Full blocks are keyed by the chain through their last token; the
//! partial tail of a prompt is keyed separately (salted) so it can only
//! be adopted by a request whose prompt ends at exactly the same token.
//! 64-bit content hashes are the same trade vLLM's prefix caching makes:
//! collisions are possible in principle and ignored in practice.

use std::collections::HashMap;

use super::pool::BlockId;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;
/// Salt folded into partial-tail keys so they can never alias a
/// full-block chain key.
const PARTIAL_SALT: u64 = 0x9e3779b97f4a7c15;

fn fnv1a_step(mut h: u64, byte: u8) -> u64 {
    h ^= byte as u64;
    h.wrapping_mul(FNV_PRIME)
}

fn fold_u64(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h = fnv1a_step(h, b);
    }
    h
}

/// Seed of a chain: hashes the sharing namespace (attention variant) so
/// e.g. online-CHAI and static-CHAI caches never alias.
pub fn chain_seed(namespace: &str) -> u64 {
    let mut h = FNV_OFFSET;
    for b in namespace.bytes() {
        h = fnv1a_step(h, b);
    }
    h
}

/// Extend a chain hash over one block's tokens.
pub fn chain_hash(prev: u64, tokens: &[i32]) -> u64 {
    let mut h = fold_u64(prev, 0x626c6f636b); // "block"
    for t in tokens {
        h = fold_u64(h, *t as u64);
    }
    h
}

/// Key for a *partial* tail block holding exactly `tokens` after the
/// chain `prev` of full blocks.
pub fn partial_hash(prev: u64, tokens: &[i32]) -> u64 {
    chain_hash(prev ^ PARTIAL_SALT, tokens) ^ fold_u64(FNV_OFFSET, tokens.len() as u64)
}

/// Routing digest of a prompt's shareable prefix: the chain hash of its
/// leading full blocks — at most `max_blocks` of them — or the salted
/// partial hash when the prompt is shorter than one block. The keys are
/// the SAME token-hash chain the prefix index files blocks under, so a
/// router that places requests by this digest lands same-prefix traffic
/// on the replica that already holds those blocks (the prefix-affinity
/// policy). The cap is what makes affinity robust to tails: hashing
/// every full block would give "system prompt + question A" and
/// "system prompt + question B" different digests whenever the
/// questions spill into further full blocks, scattering exactly the
/// traffic that should stay together — capping at the leading blocks
/// groups by the shared head instead. Pure function of its arguments —
/// no pool access.
pub fn prompt_fingerprint(
    namespace: &str,
    tokens: &[i32],
    block_size: usize,
    max_blocks: usize,
) -> u64 {
    let b = block_size.max(1);
    let mut h = chain_seed(namespace);
    let n_full = tokens.len() / b;
    if n_full == 0 {
        return partial_hash(h, tokens);
    }
    for i in 0..n_full.min(max_blocks.max(1)) {
        h = chain_hash(h, &tokens[i * b..(i + 1) * b]);
    }
    h
}

/// One relay group from [`group_by_block_prefix`]: `members` index into
/// the input chain slice; all of them begin with the SAME
/// `prefix_blocks` physical blocks (block-aligned common prefix).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrefixGroup {
    pub members: Vec<usize>,
    pub prefix_blocks: usize,
}

/// Partition block chains by their longest common *shared* block prefix
/// — the relay-decode grouping query.
///
/// Input: each live row's leading physical block ids (its full blocks
/// only — a partial tail is never part of a shared prefix). `is_shared`
/// reports whether a block may anchor a group (the manager passes
/// `refs > 1`; equality across ≥2 live tables already implies it, the
/// predicate guards the caller's invariant).
///
/// Greedy deepest-first: rows agreeing on a longer prefix split away
/// from shallower company, because a group saves `(members−1) ·
/// prefix_blocks` block-passes per tick — two pairs at depth 2 beat one
/// quad at depth 1. Rows left without company (or with no shared first
/// block) are NOT returned; they decode on the fused path.
pub fn group_by_block_prefix(
    chains: &[&[BlockId]],
    is_shared: &dyn Fn(BlockId) -> bool,
) -> Vec<PrefixGroup> {
    fn refine(
        chains: &[&[BlockId]],
        is_shared: &dyn Fn(BlockId) -> bool,
        members: Vec<usize>,
        depth: usize,
        out: &mut Vec<PrefixGroup>,
    ) {
        // members all share blocks [0, depth); try to split deeper
        let mut buckets: HashMap<BlockId, Vec<usize>> = HashMap::new();
        let mut rest: Vec<usize> = Vec::new();
        for i in members {
            match chains[i].get(depth) {
                Some(&b) if is_shared(b) => buckets.entry(b).or_default().push(i),
                _ => rest.push(i),
            }
        }
        let mut deeper: Vec<Vec<usize>> = Vec::new();
        for (_, bucket) in buckets {
            if bucket.len() >= 2 {
                deeper.push(bucket);
            } else {
                rest.extend(bucket);
            }
        }
        // deterministic group order regardless of hash-map iteration
        deeper.sort_by_key(|b| b[0]);
        for bucket in deeper {
            refine(chains, is_shared, bucket, depth + 1, out);
        }
        if rest.len() >= 2 && depth > 0 {
            rest.sort_unstable();
            out.push(PrefixGroup { members: rest, prefix_blocks: depth });
        }
    }
    let mut out = Vec::new();
    refine(chains, is_shared, (0..chains.len()).collect(), 0, &mut out);
    out.sort_by_key(|g| g.members[0]);
    out
}

/// hash → block id map. The manager keeps it consistent with block
/// lifetimes: entries are added when a block's content is final for its
/// key, and removed on eviction or before in-place mutation.
#[derive(Debug, Default)]
pub struct PrefixIndex {
    map: HashMap<u64, BlockId>,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex::default()
    }

    pub fn get(&self, hash: u64) -> Option<BlockId> {
        self.map.get(&hash).copied()
    }

    /// Register `id` under `hash`. An existing entry wins: the first
    /// publisher's block is the canonical copy and later duplicates are
    /// simply not indexed (their owner still holds them privately).
    pub fn insert(&mut self, hash: u64, id: BlockId) -> bool {
        use std::collections::hash_map::Entry;
        match self.map.entry(hash) {
            Entry::Occupied(_) => false,
            Entry::Vacant(v) => {
                v.insert(id);
                true
            }
        }
    }

    /// Remove `hash`, but only if it still points at `id` (a later
    /// publisher may own the entry now).
    pub fn remove(&mut self, hash: u64, id: BlockId) {
        if self.map.get(&hash) == Some(&id) {
            self.map.remove(&hash);
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_is_order_and_prefix_sensitive() {
        let s = chain_seed("chai");
        let a = chain_hash(s, &[1, 2, 3]);
        let b = chain_hash(s, &[3, 2, 1]);
        assert_ne!(a, b);
        let aa = chain_hash(a, &[4, 5]);
        let ab = chain_hash(b, &[4, 5]);
        assert_ne!(aa, ab, "chain must carry history");
        // deterministic
        assert_eq!(chain_hash(s, &[1, 2, 3]), a);
    }

    #[test]
    fn namespaces_do_not_alias() {
        let t = [7i32, 8, 9];
        assert_ne!(
            chain_hash(chain_seed("chai"), &t),
            chain_hash(chain_seed("chai-static"), &t)
        );
        assert_ne!(
            chain_hash(chain_seed("chai"), &t),
            chain_hash(chain_seed("mha"), &t)
        );
    }

    #[test]
    fn partial_never_equals_full() {
        let s = chain_seed("mha");
        let t = [1i32, 2, 3, 4];
        assert_ne!(partial_hash(s, &t), chain_hash(s, &t));
        // different lengths of partial differ
        assert_ne!(partial_hash(s, &t[..3]), partial_hash(s, &t));
    }

    #[test]
    fn fingerprint_groups_by_shareable_prefix() {
        let (b, cap) = (4, 8);
        // same leading full blocks + different tails → same fingerprint
        let sys: Vec<i32> = (0..9).collect(); // 2 full blocks + tail of 1
        let mut a = sys.clone();
        a.extend([100, 101]);
        let mut c = sys.clone();
        c.extend([200]);
        assert_eq!(
            prompt_fingerprint("chai", &a, b, cap),
            prompt_fingerprint("chai", &c, b, cap)
        );
        // diverging inside the first block → different fingerprints
        let mut d = a.clone();
        d[1] = 99;
        assert_ne!(
            prompt_fingerprint("chai", &a, b, cap),
            prompt_fingerprint("chai", &d, b, cap)
        );
        // namespaces do not alias
        assert_ne!(
            prompt_fingerprint("chai", &a, b, cap),
            prompt_fingerprint("mha", &a, b, cap)
        );
        // sub-block prompts hash by their exact content (salted partial)
        assert_ne!(
            prompt_fingerprint("mha", &[1, 2], b, cap),
            prompt_fingerprint("mha", &[1, 2, 3], b, cap)
        );
        assert_eq!(
            prompt_fingerprint("mha", &[1, 2], b, cap),
            prompt_fingerprint("mha", &[1, 2], b, cap)
        );
    }

    #[test]
    fn fingerprint_cap_groups_long_divergent_tails() {
        let b = 4;
        // shared 2-block system prompt, then long tails that spill into
        // further FULL blocks — uncapped digests diverge, capped ones
        // keep the traffic together
        let sys: Vec<i32> = (0..8).collect();
        let mut a = sys.clone();
        a.extend((500..510).collect::<Vec<i32>>()); // blocks 2,3 differ
        let mut c = sys.clone();
        c.extend((900..910).collect::<Vec<i32>>());
        assert_ne!(
            prompt_fingerprint("mha", &a, b, usize::MAX),
            prompt_fingerprint("mha", &c, b, usize::MAX),
            "uncapped: divergent full tails split the digest"
        );
        assert_eq!(
            prompt_fingerprint("mha", &a, b, 2),
            prompt_fingerprint("mha", &c, b, 2),
            "capped at the shared head: same replica"
        );
    }

    #[test]
    fn grouping_prefers_deeper_prefixes() {
        let all = |_: BlockId| true;
        // a,b share 2 blocks; c shares only the first with them; d is
        // alone; e,f share 3 blocks on a different chain head
        let chains: Vec<&[BlockId]> = vec![
            &[10, 11],         // a
            &[10, 11],         // b
            &[10, 12],         // c
            &[30],             // d
            &[20, 21, 22, 23], // e
            &[20, 21, 22],     // f
        ];
        let groups = group_by_block_prefix(&chains, &all);
        assert_eq!(
            groups,
            vec![
                PrefixGroup { members: vec![0, 1], prefix_blocks: 2 },
                PrefixGroup { members: vec![4, 5], prefix_blocks: 3 },
            ],
            "deepest-first: c and d fall back to the fused path"
        );
    }

    #[test]
    fn grouping_respects_shared_predicate_and_empty_chains() {
        // block 10 is not shareable → no group can anchor on it
        let chains: Vec<&[BlockId]> = vec![&[10, 11], &[10, 11], &[]];
        assert!(group_by_block_prefix(&chains, &|b| b != 10).is_empty());
        // but the group re-forms once the anchor is shareable, capped at
        // the depth where the predicate stops holding
        let groups = group_by_block_prefix(&chains, &|b| b == 10);
        assert_eq!(groups, vec![PrefixGroup { members: vec![0, 1], prefix_blocks: 1 }]);
    }

    #[test]
    fn grouping_is_deterministic_across_runs() {
        let all = |_: BlockId| true;
        let chains: Vec<&[BlockId]> = vec![&[1, 2], &[3, 4], &[1, 2], &[3, 4], &[1, 9]];
        let a = group_by_block_prefix(&chains, &all);
        for _ in 0..8 {
            assert_eq!(a, group_by_block_prefix(&chains, &all));
        }
        assert_eq!(
            a,
            vec![
                PrefixGroup { members: vec![0, 2], prefix_blocks: 2 },
                PrefixGroup { members: vec![1, 3], prefix_blocks: 2 },
            ]
        );
    }

    #[test]
    fn index_first_publisher_wins() {
        let mut ix = PrefixIndex::new();
        assert!(ix.insert(42, 1));
        assert!(!ix.insert(42, 2));
        assert_eq!(ix.get(42), Some(1));
        // removing under the loser id is a no-op
        ix.remove(42, 2);
        assert_eq!(ix.get(42), Some(1));
        ix.remove(42, 1);
        assert_eq!(ix.get(42), None);
        assert!(ix.is_empty());
    }
}
