//! Paged, cluster-aware KV cache (vLLM-style block pool for CHAI).
//!
//! The contiguous `kv::KvPool` accounts worst-case bucket bytes per
//! request; this subsystem replaces it on the serving path with real
//! block-granular storage:
//!
//! * [`pool::BlockPool`] — fixed-capacity allocator of refcounted block
//!   slabs, with LRU eviction of unreferenced cached blocks.
//! * [`table::BlockTable`] — per-request logical→physical mapping; one
//!   block id covers `block_size` token positions across all layers and
//!   both K/V roles.
//! * [`prefix::PrefixIndex`] — token-hash-chain index that lets a new
//!   request adopt matching prompt blocks from earlier requests, with
//!   copy-on-write when a shared tail block diverges at decode time.
//! * [`PagedKv`] — the manager tying these together, plus the tensor
//!   gather/scatter data plane the engine drives.
//!
//! CHAI geometry survives paging: a block's K region holds only each
//! layer's `k_l` representative heads while its V region holds all `H`
//! heads (paper §3.5 / Figure 11), so a CHAI block is strictly smaller
//! than an MHA block of the same token span and the Fig.-11 saving
//! compounds with cross-request block sharing.
//!
//! Sharing soundness: attention is causal, so K,V rows for positions
//! `[0, n)` are a deterministic function of tokens `[0, n)` given fixed
//! artifacts. For CHAI the rows additionally depend on the cluster
//! membership, itself a deterministic function of the probe prefix
//! (first `probe_tokens` tokens) and the engine seed; the engine only
//! enables sharing when `block_size >= probe_tokens`, so any chain match
//! pins the probe prefix and therefore the membership. Different
//! attention variants hash into disjoint namespaces.

pub mod pool;
pub mod prefix;
pub mod swap;
pub mod table;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::config::Manifest;
use crate::kv::CacheKind;
use crate::tensor::Tensor;

pub use pool::{BlockId, BlockPool, ReleaseOutcome};
pub use prefix::{
    chain_hash, chain_seed, group_by_block_prefix, partial_hash, prompt_fingerprint, PrefixGroup,
    PrefixIndex,
};
pub use swap::{SwapHandle, SwapPool, SwapSnapshot, SwappedBlock, SwappedSeq};
pub use table::BlockTable;

/// Typed allocation-failure error: the pool is out of blocks and
/// nothing is evictable. The scheduler matches on this (via
/// [`is_pool_exhausted`]) to preempt a live session instead of failing
/// the request when `--preempt` is on.
#[derive(Debug, Clone, Copy)]
pub struct PoolExhausted {
    pub need_bytes: usize,
    pub used_bytes: usize,
    pub capacity_bytes: usize,
}

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "kv block pool exhausted: need {} B, used {}/{} B (nothing evictable)",
            self.need_bytes, self.used_bytes, self.capacity_bytes
        )
    }
}

impl std::error::Error for PoolExhausted {}

/// Whether an error chain bottoms out in [`PoolExhausted`].
pub fn is_pool_exhausted(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.downcast_ref::<PoolExhausted>().is_some())
}

/// Geometry of one sequence's K,V rows — everything the data plane
/// needs, decoupled from the manifest so the subsystem is testable
/// without artifacts.
///
/// In-block slab layout for `block_size` B (row-major, f32):
/// ```text
/// [ K: layer 0: k_heads[0] x B x head_dim | layer 1: ... ]
/// [ V: layer 0: n_heads    x B x head_dim | layer 1: ... ]
/// ```
/// Each `(layer, head)` panel keeps its B token rows contiguous, so
/// gather/scatter against bucket-shaped `[.., T, dh]` tensors moves
/// whole `nt x dh` chunks per panel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KvLayout {
    pub n_layers: usize,
    /// V heads per layer (always the full H; Table 4 shows pruning V
    /// costs accuracy)
    pub n_heads: usize,
    pub head_dim: usize,
    /// K heads per layer: `k_list[l]` for CHAI, `n_heads` for MHA
    pub k_heads: Vec<usize>,
}

impl KvLayout {
    pub fn from_manifest(m: &Manifest, kind: CacheKind) -> KvLayout {
        let k_heads = match kind {
            CacheKind::Mha => vec![m.model.n_heads; m.model.n_layers],
            CacheKind::Chai => m.k_list.clone(),
        };
        KvLayout {
            n_layers: m.model.n_layers,
            n_heads: m.model.n_heads,
            head_dim: m.model.head_dim,
            k_heads,
        }
    }

    pub fn k_sum(&self) -> usize {
        self.k_heads.iter().sum()
    }

    /// f32 slots one token position occupies across all layers and roles.
    pub fn floats_per_token(&self) -> usize {
        (self.k_sum() + self.n_layers * self.n_heads) * self.head_dim
    }

    pub fn block_floats(&self, block_size: usize) -> usize {
        self.floats_per_token() * block_size
    }

    pub fn block_bytes(&self, block_size: usize) -> usize {
        self.block_floats(block_size) * 4
    }

    /// Offset of layer `l`'s K panel group within a block slab.
    pub fn k_layer_offset(&self, l: usize, block_size: usize) -> usize {
        self.k_heads[..l].iter().sum::<usize>() * block_size * self.head_dim
    }

    /// Offset of the V region within a block slab.
    pub fn v_base(&self, block_size: usize) -> usize {
        self.k_sum() * block_size * self.head_dim
    }

    pub fn v_layer_offset(&self, l: usize, block_size: usize) -> usize {
        self.v_base(block_size) + l * self.n_heads * block_size * self.head_dim
    }
}

/// Exact paged K,V occupancy of one request at sequence length `t`:
/// `ceil(t / block_size)` blocks. The block-granular analogue of
/// [`crate::kv::cache_bytes`] (Figure 11 with rounding to pages).
pub fn paged_cache_bytes(kind: CacheKind, m: &Manifest, t: usize, block_size: usize) -> usize {
    let layout = KvLayout::from_manifest(m, kind);
    let blocks = (t + block_size - 1) / block_size;
    blocks * layout.block_bytes(block_size)
}

/// Monotonic counters the manager maintains (surfaced through `metrics`
/// and the server `stats` command).
#[derive(Debug, Default, Clone)]
pub struct PagedStats {
    pub admitted: u64,
    pub released: u64,
    pub allocated_blocks: u64,
    pub prefix_hit_blocks: u64,
    pub prefix_miss_blocks: u64,
    pub cow_copies: u64,
    pub evictions: u64,
    pub alloc_failures: u64,
    pub appended_tokens: u64,
    /// bucket-shaped gather copies on the decode path (`gather_mha` /
    /// `gather_chai` calls) — must stay 0 on the block-table-native path
    pub decode_gather_copies: u64,
    /// bucket-shaped scatter copies on the decode path
    /// (`write_decode_row` calls) — must stay 0 on the native path
    pub decode_scatter_copies: u64,
    /// prompt positions whose prefill *compute* was skipped because
    /// their blocks were adopted from the prefix index
    pub prefill_skipped_tokens: u64,
    /// relay groups formed across decode ticks (one shared-prefix
    /// attention pass served ≥2 rows)
    pub relay_groups: u64,
    /// key positions whose decode-tick attention was NOT recomputed
    /// because a groupmate's shared-prefix pass covered them:
    /// `Σ (members − 1) · prefix_len` per group per tick
    pub relay_prefix_tokens_saved: u64,
    /// rows that shared their first block with live company but decoded
    /// on the fused path anyway (left without a groupmate by the
    /// deepest-first split, or a cluster-assignment mismatch)
    pub relay_fallback: u64,
}

impl PagedStats {
    /// Fraction of shareable prompt blocks adopted from the index.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hit_blocks + self.prefix_miss_blocks;
        if total == 0 {
            0.0
        } else {
            self.prefix_hit_blocks as f64 / total as f64
        }
    }
}

/// Point-in-time view for gauges.
#[derive(Debug, Clone)]
pub struct PagedSnapshot {
    pub capacity_bytes: usize,
    pub used_bytes: usize,
    pub cached_bytes: usize,
    pub live_blocks: usize,
    pub cached_blocks: usize,
    pub live_tables: usize,
    pub indexed_prefixes: usize,
    pub stats: PagedStats,
}

/// What `admit` did for a request's prompt.
#[derive(Debug, Default, Clone)]
pub struct AdmitReport {
    pub total_blocks: usize,
    pub adopted_full: usize,
    pub adopted_partial: bool,
}

/// The paged KV manager: allocator + prefix index + per-request tables.
#[derive(Debug)]
pub struct PagedKv {
    pub block_size: usize,
    pool: BlockPool,
    prefix: PrefixIndex,
    tables: BTreeMap<u64, BlockTable>,
    pub stats: PagedStats,
}

impl PagedKv {
    pub fn new(block_size: usize, capacity_bytes: usize) -> PagedKv {
        assert!(block_size > 0, "block_size must be positive");
        PagedKv {
            block_size,
            pool: BlockPool::new(capacity_bytes),
            prefix: PrefixIndex::new(),
            tables: BTreeMap::new(),
            stats: PagedStats::default(),
        }
    }

    pub fn has(&self, id: u64) -> bool {
        self.tables.contains_key(&id)
    }

    pub fn table(&self, id: u64) -> Option<&BlockTable> {
        self.tables.get(&id)
    }

    pub fn snapshot(&self) -> PagedSnapshot {
        PagedSnapshot {
            capacity_bytes: self.pool.capacity_bytes(),
            used_bytes: self.pool.used_bytes(),
            cached_bytes: self.pool.cached_bytes(),
            live_blocks: self.pool.live_blocks(),
            cached_blocks: self.pool.cached_blocks(),
            live_tables: self.tables.len(),
            indexed_prefixes: self.prefix.len(),
            stats: self.stats.clone(),
        }
    }

    /// Block-level admission check: can the pool cover this prompt's
    /// prefill blocks plus one decode block, counting evictable cached
    /// bytes as available? Prefix adoption can only reduce the real
    /// need. Note the policy is optimistic about decode growth (only
    /// the first decode block is reserved, vLLM-style): a long
    /// generation can still exhaust the pool mid-stream — with
    /// `--preempt` the scheduler catches the typed [`PoolExhausted`]
    /// failure and preempts the session instead of erroring it.
    pub fn can_admit(&self, layout: &KvLayout, prompt_len: usize) -> bool {
        let need_blocks = (prompt_len + self.block_size - 1) / self.block_size + 1;
        need_blocks * layout.block_bytes(self.block_size) <= self.pool.reclaimable_bytes()
    }

    /// Could this prompt fit even in an *empty* pool? `false` means the
    /// request must be rejected, not deferred — it can never be served.
    pub fn fits_ever(&self, layout: &KvLayout, prompt_len: usize) -> bool {
        let need_blocks = (prompt_len + self.block_size - 1) / self.block_size + 1;
        need_blocks * layout.block_bytes(self.block_size) <= self.pool.capacity_bytes()
    }

    fn alloc_block(&mut self, floats: usize) -> Result<BlockId> {
        loop {
            if let Some(id) = self.pool.try_alloc(floats) {
                self.stats.allocated_blocks += 1;
                return Ok(id);
            }
            match self.pool.evict_lru() {
                Some((vid, hash)) => {
                    if let Some(h) = hash {
                        self.prefix.remove(h, vid);
                    }
                    self.stats.evictions += 1;
                }
                None => {
                    self.stats.alloc_failures += 1;
                    return Err(anyhow::Error::new(PoolExhausted {
                        need_bytes: floats * 4,
                        used_bytes: self.pool.used_bytes(),
                        capacity_bytes: self.pool.capacity_bytes(),
                    }));
                }
            }
        }
    }

    /// Create a block table for request `id` over `tokens`, adopting
    /// every prompt block whose token-hash chain is already indexed and
    /// allocating the rest. `namespace` isolates attention variants;
    /// `allow_share` disables both adoption and publication (used when
    /// sharing would be unsound, e.g. CHAI with tiny blocks).
    pub fn admit(
        &mut self,
        id: u64,
        layout: KvLayout,
        namespace: &str,
        allow_share: bool,
        tokens: &[i32],
    ) -> Result<AdmitReport> {
        if self.tables.contains_key(&id) {
            bail!("sequence {id} already admitted");
        }
        let b = self.block_size;
        let bf = layout.block_floats(b);
        let seed = chain_seed(namespace);
        let mut table = BlockTable::new(layout, b, seed, allow_share);
        let n_full = tokens.len() / b;
        let rem = tokens.len() % b;

        let mut failure: Option<anyhow::Error> = None;
        let mut h = seed;
        for i in 0..n_full {
            h = chain_hash(h, &tokens[i * b..(i + 1) * b]);
            table.hash_chain.push(h);
            if allow_share {
                if let Some(bid) = self.prefix.get(h) {
                    if self.pool.block(bid).filled == b {
                        self.pool.retain(bid);
                        table.blocks.push(bid);
                        table.adopted_full += 1;
                        self.stats.prefix_hit_blocks += 1;
                        continue;
                    }
                }
                self.stats.prefix_miss_blocks += 1;
            }
            match self.alloc_block(bf) {
                Ok(bid) => table.blocks.push(bid),
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        if failure.is_none() && rem > 0 {
            let ph = partial_hash(h, &tokens[n_full * b..]);
            let mut adopted = false;
            if allow_share {
                if let Some(bid) = self.prefix.get(ph) {
                    if self.pool.block(bid).filled == rem {
                        self.pool.retain(bid);
                        table.blocks.push(bid);
                        table.adopted_partial = true;
                        self.stats.prefix_hit_blocks += 1;
                        adopted = true;
                    }
                }
            }
            if !adopted {
                match self.alloc_block(bf) {
                    Ok(bid) => table.blocks.push(bid),
                    Err(e) => failure = Some(e),
                }
            }
        }
        if let Some(e) = failure {
            // roll back every reference this admission took
            for bid in table.blocks.drain(..) {
                self.pool.release(bid);
            }
            return Err(e);
        }
        table.tokens = tokens.to_vec();
        table.len = tokens.len();
        let report = AdmitReport {
            total_blocks: table.blocks.len(),
            adopted_full: table.adopted_full,
            adopted_partial: table.adopted_partial,
        };
        self.stats.admitted += 1;
        self.tables.insert(id, table);
        Ok(report)
    }

    /// Finalize a prompt's blocks after the prefill data has been
    /// written: mark fill levels and publish owned blocks in the prefix
    /// index (full blocks under their chain hash, the partial tail under
    /// its salted key).
    pub fn commit_prefill(&mut self, id: u64) -> Result<()> {
        let t = self.tables.get(&id).ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        let b = t.block_size;
        let n_full = t.len / b;
        let rem = t.len % b;
        let allow = t.allow_share;
        // snapshot what we need so pool/prefix mutation below doesn't
        // fight the table borrow
        let plan: Vec<(BlockId, usize, u64)> = (0..t.blocks.len())
            .map(|i| {
                let bid = t.blocks[i];
                if i < n_full {
                    (bid, b, t.hash_chain[i])
                } else {
                    let ph = partial_hash(t.chain_before(n_full), &t.tokens[n_full * b..]);
                    (bid, rem, ph)
                }
            })
            .collect();
        for (bid, filled, hash) in plan {
            if self.pool.block(bid).hash.is_some() {
                // adopted — content and registration already in place
                self.pool.touch(bid);
                continue;
            }
            self.pool.set_filled(bid, filled);
            if allow && self.prefix.insert(hash, bid) {
                self.pool.set_hash(bid, hash);
            }
        }
        Ok(())
    }

    /// Make position `table.len` writable: allocate a fresh tail block
    /// on a block boundary, or copy-on-write a shared partial tail
    /// before the sequences diverge. Must be called before
    /// [`Self::write_decode_row`] / [`Self::append_committed`].
    pub fn ensure_append_slot(&mut self, id: u64) -> Result<()> {
        let (bi, off, bf, tail) = {
            let t = self.tables.get(&id).ok_or_else(|| anyhow!("unknown sequence {id}"))?;
            let (bi, off) = t.locate(t.len);
            (bi, off, t.layout.block_floats(t.block_size), t.blocks.get(bi).copied())
        };
        match tail {
            Some(bid) => {
                debug_assert!(off > 0, "partial tail with zero offset");
                if self.pool.block(bid).refs > 1 {
                    // shared tail: diverge via copy-on-write
                    let nb = self.alloc_block(bf)?;
                    let src = self.pool.data(bid).to_vec();
                    self.pool.data_mut(nb).copy_from_slice(&src);
                    self.pool.set_filled(nb, off);
                    self.pool.release(bid);
                    self.tables.get_mut(&id).unwrap().blocks[bi] = nb;
                    self.stats.cow_copies += 1;
                } else if let Some(h) = self.pool.block(bid).hash {
                    // sole owner of an indexed partial block: unpublish
                    // before mutating so the index never serves stale
                    // content
                    self.prefix.remove(h, bid);
                    self.pool.clear_hash(bid);
                }
            }
            None => {
                debug_assert_eq!(off, 0, "missing tail block mid-span");
                let nb = self.alloc_block(bf)?;
                self.tables.get_mut(&id).unwrap().blocks.push(nb);
            }
        }
        Ok(())
    }

    /// Record the token written at position `table.len` (its K,V row
    /// goes through [`Self::write_decode_row`]); publishes the block's
    /// chain hash when it fills.
    pub fn append_committed(&mut self, id: u64, token: i32) -> Result<()> {
        let (bid, filled, full_hash) = {
            let t = self.tables.get_mut(&id).ok_or_else(|| anyhow!("unknown sequence {id}"))?;
            let (bi, off) = t.locate(t.len);
            let bid = *t
                .blocks
                .get(bi)
                .ok_or_else(|| anyhow!("append without ensure_append_slot (seq {id})"))?;
            t.tokens.push(token);
            t.len += 1;
            let filled = off + 1;
            let full_hash = if filled == t.block_size {
                let h =
                    chain_hash(t.chain_before(bi), &t.tokens[bi * t.block_size..t.len]);
                t.hash_chain.push(h);
                t.allow_share.then_some(h)
            } else {
                None
            };
            (bid, filled, full_hash)
        };
        self.pool.set_filled(bid, filled);
        self.stats.appended_tokens += 1;
        if let Some(h) = full_hash {
            if self.pool.block(bid).hash.is_none() && self.prefix.insert(h, bid) {
                self.pool.set_hash(bid, h);
            }
        }
        Ok(())
    }

    /// Drop a finished request's references. Published blocks stay
    /// cached for prefix reuse until evicted; private ones free now.
    pub fn release(&mut self, id: u64) -> Result<()> {
        let t = self.tables.remove(&id).ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        for bid in t.blocks {
            self.pool.release(bid);
        }
        self.stats.released += 1;
        Ok(())
    }

    /// Evict every cached block (tests and `drop-caches` ops hook).
    /// Returns the number of blocks freed.
    pub fn drop_cached(&mut self) -> usize {
        let mut n = 0;
        while let Some((vid, hash)) = self.pool.evict_lru() {
            if let Some(h) = hash {
                self.prefix.remove(h, vid);
            }
            n += 1;
        }
        n
    }

    /// Internal-consistency scan used by tests.
    pub fn check_consistency(&self) -> Result<()> {
        self.pool.check_accounting()?;
        for (id, t) in &self.tables {
            if t.blocks.len() != (t.len + t.block_size - 1) / t.block_size {
                bail!(
                    "seq {id}: {} blocks for len {} (block_size {})",
                    t.blocks.len(),
                    t.len,
                    t.block_size
                );
            }
            for &bid in &t.blocks {
                if self.pool.block(bid).refs == 0 {
                    bail!("seq {id}: references cached/free block {bid}");
                }
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Block-native data plane (kernel-facing)
    //
    // Block-table-native kernels (`runtime::Backend::{decode_paged,
    // prefill_paged}`) read K,V rows in place out of block slabs and
    // append new rows directly — no bucket-shaped intermediate tensors.
    // These accessors are the whole surface they need beyond `table()`.
    // ------------------------------------------------------------------

    /// Read-only view of a block's f32 slab (layout per [`KvLayout`]).
    pub fn block_data(&self, id: BlockId) -> &[f32] {
        self.pool.data(id)
    }

    /// Whether more than one reference counts on `id` — a block that
    /// can anchor a relay group (and, on the swap path, one that is
    /// pinned hot by another reader).
    pub fn block_shared(&self, id: BlockId) -> bool {
        self.pool.block(id).refs > 1
    }

    /// Mutable view of a block's slab. The caller must hold the only
    /// reference (decode tails after [`Self::ensure_append_slot`], or
    /// freshly allocated prefill blocks).
    pub fn block_data_mut(&mut self, id: BlockId) -> &mut [f32] {
        self.pool.data_mut(id)
    }

    /// Token slots written in a block.
    pub fn block_filled(&self, id: BlockId) -> usize {
        self.pool.block(id).filled
    }

    /// Prefix-index hash a block is published under (`Some` means the
    /// block was adopted or published — never write to it in place).
    pub fn block_hash(&self, id: BlockId) -> Option<u64> {
        self.pool.block(id).hash
    }

    /// Number of leading token positions of sequence `id` whose blocks
    /// were adopted from the prefix index at admission (their K,V rows
    /// are already resident, so prefill compute can skip them). Computed
    /// between `admit` and prefill: at that point adopted blocks carry a
    /// prefix hash and fresh allocations do not.
    pub fn adopted_prefix_len(&self, id: u64) -> Result<usize> {
        let t = self.table_ref(id)?;
        let mut n = 0usize;
        for &bid in &t.blocks {
            let b = self.pool.block(bid);
            if b.hash.is_none() {
                break;
            }
            n += b.filled;
            if n >= t.len {
                break;
            }
        }
        Ok(n.min(t.len))
    }

    // ------------------------------------------------------------------
    // Relay decode (shared-prefix attention)
    // ------------------------------------------------------------------

    /// Partition live sequences by their longest common block-aligned
    /// physical prefix — the relay-decode grouping query. `seqs` are one
    /// decode tick's candidate rows (one attention variant); the result
    /// indexes into that slice. Only each table's *full* blocks
    /// participate (a partial tail — the row's append slot, sole-owned
    /// after CoW — is never part of a shared prefix), and only while the
    /// pool still counts more than one reference on every shared block,
    /// so a session that forked off a shared chain regroups or falls out
    /// the very tick its table diverges. Rows left without a groupmate
    /// are omitted: they decode on the fused path.
    pub fn relay_groups(&self, seqs: &[u64]) -> Vec<PrefixGroup> {
        let chains: Vec<&[BlockId]> = seqs
            .iter()
            .map(|id| {
                self.tables
                    .get(id)
                    .map(|t| &t.blocks[..t.full_blocks()])
                    .unwrap_or(&[][..])
            })
            .collect();
        let shared = |b: BlockId| self.pool.block(b).refs > 1;
        group_by_block_prefix(&chains, &shared)
    }

    // ------------------------------------------------------------------
    // Swap tier (preemption data plane)
    // ------------------------------------------------------------------

    /// Bytes a swap-out of sequence `id` would stage into the spill
    /// tier: the compacted rows of every block this table is the *sole*
    /// reader of. Shared (prefix-pinned) blocks are exempt — another
    /// live session reads them, so they stay hot and cost nothing to
    /// "swap". Input to the scheduler's swap-vs-recompute cost model.
    pub fn swap_cost(&self, id: u64) -> Result<usize> {
        let t = self.table_ref(id)?;
        let fpt = t.layout.floats_per_token();
        let mut bytes = 0usize;
        for (bi, &bid) in t.blocks.iter().enumerate() {
            let blk = self.pool.block(bid);
            if blk.refs > 1 {
                continue;
            }
            bytes += fpt * blk.filled.min(t.len - bi * t.block_size) * 4;
        }
        Ok(bytes)
    }

    /// Stage sequence `id`'s K,V state out of the hot pool and release
    /// its table. Sole-owner blocks are serialized (compacted rows —
    /// for CHAI that is each layer's cluster-rep K panels once per
    /// block, plus the full-head V rows); blocks another live table
    /// reads are **never** serialized — they stay resident (pinned by
    /// the other refs) and are re-adopted through the prefix index at
    /// swap-in. Fails (table untouched) when the tier cannot hold the
    /// payload; the caller falls back to recompute-on-resume.
    pub fn swap_out(&mut self, id: u64, tier: &mut SwapPool) -> Result<SwapHandle> {
        // size check BEFORE any copying: a denied swap must cost O(blocks),
        // not a full serialization thrown away
        let bytes = self.swap_cost(id)?;
        if !tier.fits(bytes) {
            tier.stats.denied_full += 1;
            bail!(
                "swap tier full ({} B payload, {} B free) — recompute instead",
                bytes,
                tier.free_bytes()
            );
        }
        let t = self.table_ref(id)?;
        let layout = t.layout.clone();
        let b = t.block_size;
        let len = t.len;
        let mut blocks: Vec<Option<SwappedBlock>> = Vec::with_capacity(t.blocks.len());
        for (bi, &bid) in t.blocks.clone().iter().enumerate() {
            let blk = self.pool.block(bid);
            if blk.refs > 1 {
                // pinned: a live batchmate reads this block
                blocks.push(None);
                continue;
            }
            let filled = blk.filled.min(len - bi * b);
            blocks.push(Some(SwappedBlock::capture(&layout, b, filled, &blk.data)));
        }
        let handle = tier.insert(SwappedSeq { layout, block_size: b, len, blocks, bytes })?;
        self.release(id)?;
        Ok(handle)
    }

    /// Fill a freshly re-admitted sequence's blocks back in from the
    /// spill tier (consuming the handle) and return how many *leading*
    /// positions are now valid: adopted blocks (re-found through the
    /// prefix index — including blocks that were pinned at swap-out)
    /// count as restored, serialized blocks are copied back
    /// bit-exactly, and the first unrecoverable block (pinned at
    /// swap-out but since evicted) ends the prefix — everything past it
    /// is recomputed by the suffix prefill. Call between `admit` and
    /// `prefill_paged`, exactly like `adopted_prefix_len`.
    pub fn restore_swapped(
        &mut self,
        id: u64,
        handle: SwapHandle,
        tier: &mut SwapPool,
    ) -> Result<usize> {
        let entry = tier.take(handle)?;
        let t = self.table_ref(id)?;
        if entry.layout != t.layout || entry.block_size != t.block_size {
            bail!("swap entry geometry does not match sequence {id}");
        }
        if entry.len != t.len || entry.blocks.len() != t.blocks.len() {
            bail!(
                "swap entry covers {} positions / {} blocks, table has {} / {}",
                entry.len,
                entry.blocks.len(),
                t.len,
                t.blocks.len()
            );
        }
        let blocks = t.blocks.clone();
        let (b, len) = (t.block_size, t.len);
        let mut valid = 0usize;
        let mut leading = true;
        for (bi, (&bid, saved)) in blocks.iter().zip(&entry.blocks).enumerate() {
            let span = (len - bi * b).min(b);
            if self.pool.block(bid).hash.is_some() {
                // adopted at re-admission: resident content is already
                // canonical for this chain — never write to it
                if leading {
                    valid += self.pool.block(bid).filled.min(span);
                    leading = self.pool.block(bid).filled >= span;
                }
                continue;
            }
            match saved {
                Some(sb) => {
                    sb.restore_into(&entry.layout, b, self.pool.data_mut(bid));
                    self.pool.set_filled(bid, sb.filled);
                    if leading {
                        valid += sb.filled.min(span);
                        leading = sb.filled >= span;
                    }
                }
                None => leading = false, // pinned at swap-out, evicted since
            }
        }
        Ok(valid.min(len))
    }

    // ------------------------------------------------------------------
    // Tensor data plane (engine-facing)
    // ------------------------------------------------------------------

    fn table_ref(&self, id: u64) -> Result<&BlockTable> {
        self.tables.get(&id).ok_or_else(|| anyhow!("unknown sequence {id}"))
    }

    /// Gather a sequence's K,V into dense MHA-shaped tensors
    /// (`[L, H, bucket, dh]` each); positions past `len` stay zero.
    /// Legacy bucket data plane — the block-native path never calls it
    /// (tracked by `stats.decode_gather_copies`).
    pub fn gather_mha(&mut self, id: u64, bucket: usize) -> Result<(Tensor, Tensor)> {
        self.stats.decode_gather_copies += 1;
        let t = self.table_ref(id)?;
        let lay = &t.layout;
        let (l_n, h_n, dh, b) = (lay.n_layers, lay.n_heads, lay.head_dim, t.block_size);
        if lay.k_heads.iter().any(|&k| k != h_n) {
            bail!("gather_mha on a clustered table");
        }
        if t.len > bucket {
            bail!("sequence {} exceeds bucket {bucket}", t.len);
        }
        let mut kc = vec![0.0f32; l_n * h_n * bucket * dh];
        let mut vc = vec![0.0f32; l_n * h_n * bucket * dh];
        for (bi, &bid) in t.blocks.iter().enumerate() {
            let t0 = bi * b;
            let nt = self.pool.block(bid).filled.min(t.len - t0);
            if nt == 0 {
                continue;
            }
            let data = self.pool.data(bid);
            for l in 0..l_n {
                for h in 0..h_n {
                    let dst = ((l * h_n + h) * bucket + t0) * dh;
                    let ksrc = lay.k_layer_offset(l, b) + h * b * dh;
                    kc[dst..dst + nt * dh].copy_from_slice(&data[ksrc..ksrc + nt * dh]);
                    let vsrc = lay.v_layer_offset(l, b) + h * b * dh;
                    vc[dst..dst + nt * dh].copy_from_slice(&data[vsrc..vsrc + nt * dh]);
                }
            }
        }
        let shape = vec![l_n, h_n, bucket, dh];
        Ok((Tensor::f32(shape.clone(), kc), Tensor::f32(shape, vc)))
    }

    /// Gather a CHAI sequence: per-layer K panels `[k_l, bucket, dh]`
    /// plus the dense V `[L, H, bucket, dh]`. Legacy bucket data plane.
    pub fn gather_chai(&mut self, id: u64, bucket: usize) -> Result<(Vec<Tensor>, Tensor)> {
        self.stats.decode_gather_copies += 1;
        let t = self.table_ref(id)?;
        let lay = &t.layout;
        let (l_n, h_n, dh, b) = (lay.n_layers, lay.n_heads, lay.head_dim, t.block_size);
        if t.len > bucket {
            bail!("sequence {} exceeds bucket {bucket}", t.len);
        }
        let mut kreps: Vec<Vec<f32>> =
            lay.k_heads.iter().map(|&k| vec![0.0f32; k * bucket * dh]).collect();
        let mut vc = vec![0.0f32; l_n * h_n * bucket * dh];
        for (bi, &bid) in t.blocks.iter().enumerate() {
            let t0 = bi * b;
            let nt = self.pool.block(bid).filled.min(t.len - t0);
            if nt == 0 {
                continue;
            }
            let data = self.pool.data(bid);
            for l in 0..l_n {
                for r in 0..lay.k_heads[l] {
                    let dst = (r * bucket + t0) * dh;
                    let src = lay.k_layer_offset(l, b) + r * b * dh;
                    kreps[l][dst..dst + nt * dh].copy_from_slice(&data[src..src + nt * dh]);
                }
                for h in 0..h_n {
                    let dst = ((l * h_n + h) * bucket + t0) * dh;
                    let src = lay.v_layer_offset(l, b) + h * b * dh;
                    vc[dst..dst + nt * dh].copy_from_slice(&data[src..src + nt * dh]);
                }
            }
        }
        let kreps = lay
            .k_heads
            .iter()
            .zip(kreps)
            .map(|(&k, v)| Tensor::f32(vec![k, bucket, dh], v))
            .collect();
        Ok((kreps, Tensor::f32(vec![l_n, h_n, bucket, dh], vc)))
    }

    /// Scatter prefill rows `[0, len)` from MHA-shaped caches into the
    /// sequence's *owned* blocks; adopted (hash-bearing) blocks already
    /// hold identical content and are skipped. Call before
    /// [`Self::commit_prefill`].
    pub fn write_prefill_mha(&mut self, id: u64, kc: &Tensor, vc: &Tensor, len: usize) -> Result<()> {
        let t = self.tables.get(&id).ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        let lay = t.layout.clone();
        let (l_n, h_n, dh, b) = (lay.n_layers, lay.n_heads, lay.head_dim, t.block_size);
        let bucket = *kc
            .shape
            .get(2)
            .ok_or_else(|| anyhow!("kcache must be [L,H,T,dh], got {:?}", kc.shape))?;
        if kc.shape != vec![l_n, h_n, bucket, dh] || vc.shape != kc.shape {
            bail!("cache shape mismatch: k {:?} v {:?}", kc.shape, vc.shape);
        }
        if len > bucket || len > t.len {
            bail!("prefill len {len} out of range (bucket {bucket}, table {})", t.len);
        }
        let ks = kc.as_f32()?;
        let vs = vc.as_f32()?;
        let blocks = t.blocks.clone();
        for (bi, bid) in blocks.into_iter().enumerate() {
            let t0 = bi * b;
            if t0 >= len {
                break;
            }
            if self.pool.block(bid).hash.is_some() {
                continue; // adopted
            }
            let nt = (len - t0).min(b);
            let data = self.pool.data_mut(bid);
            for l in 0..l_n {
                for h in 0..h_n {
                    let src = ((l * h_n + h) * bucket + t0) * dh;
                    let kdst = lay.k_layer_offset(l, b) + h * b * dh;
                    data[kdst..kdst + nt * dh].copy_from_slice(&ks[src..src + nt * dh]);
                    let vdst = lay.v_layer_offset(l, b) + h * b * dh;
                    data[vdst..vdst + nt * dh].copy_from_slice(&vs[src..src + nt * dh]);
                }
            }
        }
        Ok(())
    }

    /// CHAI prefill scatter: per-layer K panels + dense V.
    pub fn write_prefill_chai(
        &mut self,
        id: u64,
        kreps: &[Tensor],
        vc: &Tensor,
        len: usize,
    ) -> Result<()> {
        let t = self.tables.get(&id).ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        let lay = t.layout.clone();
        let (l_n, h_n, dh, b) = (lay.n_layers, lay.n_heads, lay.head_dim, t.block_size);
        if kreps.len() != l_n {
            bail!("expected {l_n} K panels, got {}", kreps.len());
        }
        let bucket = *vc
            .shape
            .get(2)
            .ok_or_else(|| anyhow!("vcache must be [L,H,T,dh], got {:?}", vc.shape))?;
        if vc.shape != vec![l_n, h_n, bucket, dh] {
            bail!("vcache shape mismatch: {:?}", vc.shape);
        }
        for (l, kr) in kreps.iter().enumerate() {
            if kr.shape != vec![lay.k_heads[l], bucket, dh] {
                bail!("K panel {l} shape mismatch: {:?}", kr.shape);
            }
        }
        if len > bucket || len > t.len {
            bail!("prefill len {len} out of range (bucket {bucket}, table {})", t.len);
        }
        let vs = vc.as_f32()?;
        let blocks = t.blocks.clone();
        for (bi, bid) in blocks.into_iter().enumerate() {
            let t0 = bi * b;
            if t0 >= len {
                break;
            }
            if self.pool.block(bid).hash.is_some() {
                continue; // adopted
            }
            let nt = (len - t0).min(b);
            let data = self.pool.data_mut(bid);
            for l in 0..l_n {
                let ks = kreps[l].as_f32()?;
                for r in 0..lay.k_heads[l] {
                    let src = (r * bucket + t0) * dh;
                    let dst = lay.k_layer_offset(l, b) + r * b * dh;
                    data[dst..dst + nt * dh].copy_from_slice(&ks[src..src + nt * dh]);
                }
                for h in 0..h_n {
                    let src = ((l * h_n + h) * bucket + t0) * dh;
                    let dst = lay.v_layer_offset(l, b) + h * b * dh;
                    data[dst..dst + nt * dh].copy_from_slice(&vs[src..src + nt * dh]);
                }
            }
        }
        Ok(())
    }

    /// Scatter the single new row at `pos` (== `table.len`, after
    /// [`Self::ensure_append_slot`]) out of post-decode caches.
    /// `kreps` is `None` for MHA tables (then `kc` must be Some).
    pub fn write_decode_row(
        &mut self,
        id: u64,
        kc: Option<&Tensor>,
        kreps: Option<&[Tensor]>,
        vc: &Tensor,
        pos: usize,
    ) -> Result<()> {
        self.stats.decode_scatter_copies += 1;
        let t = self.tables.get(&id).ok_or_else(|| anyhow!("unknown sequence {id}"))?;
        let lay = t.layout.clone();
        let (l_n, h_n, dh, b) = (lay.n_layers, lay.n_heads, lay.head_dim, t.block_size);
        if pos != t.len {
            bail!("decode row {pos} != next position {}", t.len);
        }
        let (bi, off) = t.locate(pos);
        let bid = *t
            .blocks
            .get(bi)
            .ok_or_else(|| anyhow!("no tail block for pos {pos} (seq {id})"))?;
        let bucket = *vc
            .shape
            .get(2)
            .ok_or_else(|| anyhow!("vcache must be [L,H,T,dh], got {:?}", vc.shape))?;
        if pos >= bucket {
            bail!("pos {pos} outside bucket {bucket}");
        }
        let vs = vc.as_f32()?;
        // borrow-friendly: pull the slab last
        let data = self.pool.data_mut(bid);
        for l in 0..l_n {
            match (kc, kreps) {
                (Some(k), None) => {
                    let ks = k.as_f32()?;
                    for h in 0..h_n {
                        let src = (((l * h_n + h) * bucket) + pos) * dh;
                        let dst = lay.k_layer_offset(l, b) + (h * b + off) * dh;
                        data[dst..dst + dh].copy_from_slice(&ks[src..src + dh]);
                    }
                }
                (None, Some(panels)) => {
                    let ks = panels[l].as_f32()?;
                    for r in 0..lay.k_heads[l] {
                        let src = (r * bucket + pos) * dh;
                        let dst = lay.k_layer_offset(l, b) + (r * b + off) * dh;
                        data[dst..dst + dh].copy_from_slice(&ks[src..src + dh]);
                    }
                }
                _ => bail!("exactly one of kc/kreps must be provided"),
            }
            for h in 0..h_n {
                let src = (((l * h_n + h) * bucket) + pos) * dh;
                let dst = lay.v_layer_offset(l, b) + (h * b + off) * dh;
                data[dst..dst + dh].copy_from_slice(&vs[src..src + dh]);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mha_layout() -> KvLayout {
        KvLayout { n_layers: 2, n_heads: 4, head_dim: 2, k_heads: vec![4, 4] }
    }

    fn chai_layout() -> KvLayout {
        KvLayout { n_layers: 2, n_heads: 4, head_dim: 2, k_heads: vec![2, 3] }
    }

    #[test]
    fn chai_block_smaller_than_mha_block() {
        // the Fig. 11 invariant at block granularity
        let b = 16;
        assert!(chai_layout().block_bytes(b) < mha_layout().block_bytes(b));
        // V region identical; difference is exactly the pruned K heads
        let diff = mha_layout().block_bytes(b) - chai_layout().block_bytes(b);
        assert_eq!(diff, (4 - 2 + 4 - 3) * b * 2 * 4);
    }

    #[test]
    fn admit_shares_full_and_partial_blocks() {
        let mut kv = PagedKv::new(4, 1 << 20);
        let tokens: Vec<i32> = (0..10).collect(); // 2 full + rem 2
        let r1 = kv.admit(1, chai_layout(), "chai", true, &tokens).unwrap();
        assert_eq!(r1.total_blocks, 3);
        assert_eq!(r1.adopted_full, 0);
        kv.commit_prefill(1).unwrap();
        let used_one = kv.snapshot().used_bytes;

        let r2 = kv.admit(2, chai_layout(), "chai", true, &tokens).unwrap();
        assert_eq!(r2.adopted_full, 2);
        assert!(r2.adopted_partial);
        kv.commit_prefill(2).unwrap();
        // full sharing: no extra bytes for the second identical prompt
        assert_eq!(kv.snapshot().used_bytes, used_one);
        assert_eq!(kv.stats.prefix_hit_blocks, 3);
        kv.check_consistency().unwrap();
    }

    #[test]
    fn adopted_prefix_len_counts_leading_adopted_positions() {
        let mut kv = PagedKv::new(4, 1 << 20);
        let tokens: Vec<i32> = (0..10).collect(); // 2 full + rem 2
        kv.admit(1, chai_layout(), "chai", true, &tokens).unwrap();
        // fresh admission: nothing adopted, nothing skippable
        assert_eq!(kv.adopted_prefix_len(1).unwrap(), 0);
        kv.commit_prefill(1).unwrap();

        // identical prompt adopts everything including the partial tail
        kv.admit(2, chai_layout(), "chai", true, &tokens).unwrap();
        assert_eq!(kv.adopted_prefix_len(2).unwrap(), 10);

        // divergence inside block 1: only block 0 counts toward the skip
        let mut other = tokens.clone();
        other[6] = 99;
        kv.admit(3, chai_layout(), "chai", true, &other).unwrap();
        assert_eq!(kv.adopted_prefix_len(3).unwrap(), 4);
        kv.check_consistency().unwrap();
    }

    #[test]
    fn divergent_prompts_share_only_common_prefix() {
        let mut kv = PagedKv::new(4, 1 << 20);
        let a: Vec<i32> = (0..12).collect();
        let mut b = a.clone();
        b[6] = 99; // diverges inside block 1
        kv.admit(1, mha_layout(), "mha", true, &a).unwrap();
        kv.commit_prefill(1).unwrap();
        let r = kv.admit(2, mha_layout(), "mha", true, &b).unwrap();
        assert_eq!(r.adopted_full, 1, "only block 0 matches");
        assert!(!r.adopted_partial);
        kv.check_consistency().unwrap();
    }

    #[test]
    fn namespaces_are_isolated() {
        let mut kv = PagedKv::new(4, 1 << 20);
        let tokens: Vec<i32> = (0..8).collect();
        kv.admit(1, chai_layout(), "chai", true, &tokens).unwrap();
        kv.commit_prefill(1).unwrap();
        let r = kv.admit(2, chai_layout(), "chai-static", true, &tokens).unwrap();
        assert_eq!(r.adopted_full, 0, "different variant must not adopt");
    }

    #[test]
    fn cow_triggers_on_shared_tail_divergence() {
        let mut kv = PagedKv::new(4, 1 << 20);
        let tokens: Vec<i32> = (0..6).collect(); // 1 full + rem 2
        kv.admit(1, chai_layout(), "chai", true, &tokens).unwrap();
        kv.commit_prefill(1).unwrap();
        let r = kv.admit(2, chai_layout(), "chai", true, &tokens).unwrap();
        assert!(r.adopted_partial);

        // seq 2 decodes first: its append must not touch seq 1's tail
        kv.ensure_append_slot(2).unwrap();
        assert_eq!(kv.stats.cow_copies, 1);
        kv.append_committed(2, 100).unwrap();

        // seq 1 now owns its tail alone; appending unpublishes, no CoW
        kv.ensure_append_slot(1).unwrap();
        assert_eq!(kv.stats.cow_copies, 1);
        kv.append_committed(1, 200).unwrap();

        assert_eq!(kv.table(1).unwrap().len, 7);
        assert_eq!(kv.table(2).unwrap().len, 7);
        kv.check_consistency().unwrap();
    }

    #[test]
    fn decode_fills_publish_blocks_for_future_reuse() {
        let mut kv = PagedKv::new(4, 1 << 20);
        let tokens: Vec<i32> = (0..6).collect();
        kv.admit(1, mha_layout(), "mha", true, &tokens).unwrap();
        kv.commit_prefill(1).unwrap();
        // generate 2 tokens -> tail block fills (6 + 2 == 2 blocks of 4)
        for tok in [7, 8] {
            kv.ensure_append_slot(1).unwrap();
            kv.append_committed(1, tok).unwrap();
        }
        kv.release(1).unwrap();
        // a prompt equal to prompt+generated adopts both blocks
        let all: Vec<i32> = vec![0, 1, 2, 3, 4, 5, 7, 8];
        let r = kv.admit(2, mha_layout(), "mha", true, &all).unwrap();
        assert_eq!(r.adopted_full, 2);
        kv.check_consistency().unwrap();
    }

    #[test]
    fn release_and_eviction_leave_no_leak() {
        let mut kv = PagedKv::new(4, 1 << 20);
        let tokens: Vec<i32> = (0..10).collect();
        kv.admit(1, chai_layout(), "chai", true, &tokens).unwrap();
        kv.commit_prefill(1).unwrap();
        kv.admit(2, chai_layout(), "chai", true, &tokens).unwrap();
        kv.commit_prefill(2).unwrap();
        kv.ensure_append_slot(2).unwrap(); // forces one CoW block
        kv.append_committed(2, 1).unwrap();
        kv.release(1).unwrap();
        kv.release(2).unwrap();
        let snap = kv.snapshot();
        assert_eq!(snap.live_tables, 0);
        // everything left is evictable cache, nothing is leaked
        assert_eq!(snap.used_bytes, snap.cached_bytes);
        kv.drop_cached();
        let snap = kv.snapshot();
        assert_eq!(snap.used_bytes, 0);
        assert_eq!(snap.indexed_prefixes, 0);
        kv.check_consistency().unwrap();
    }

    #[test]
    fn eviction_makes_room_under_pressure() {
        let lay = mha_layout();
        // room for exactly 4 blocks
        let mut kv = PagedKv::new(4, 4 * lay.block_bytes(4));
        let a: Vec<i32> = (0..8).collect();
        kv.admit(1, lay.clone(), "mha", true, &a).unwrap();
        kv.commit_prefill(1).unwrap();
        kv.release(1).unwrap(); // 2 cached blocks
        let b: Vec<i32> = (100..112).collect(); // needs 3 fresh blocks
        assert!(kv.can_admit(&lay, b.len()));
        kv.admit(2, lay.clone(), "mha", true, &b).unwrap();
        assert!(kv.stats.evictions >= 1, "cached blocks must be evicted for new work");
        // pool truly full now: an over-size admit fails and rolls back
        let huge: Vec<i32> = (0..64).collect();
        assert!(!kv.can_admit(&lay, huge.len()));
        assert!(kv.admit(3, lay, "mha", true, &huge).is_err());
        assert!(!kv.has(3));
        kv.check_consistency().unwrap();
    }

    #[test]
    fn sharing_disabled_blocks_are_private_and_freed() {
        let mut kv = PagedKv::new(4, 1 << 20);
        let tokens: Vec<i32> = (0..8).collect();
        kv.admit(1, chai_layout(), "chai", false, &tokens).unwrap();
        kv.commit_prefill(1).unwrap();
        let r = kv.admit(2, chai_layout(), "chai", false, &tokens).unwrap();
        assert_eq!(r.adopted_full, 0);
        kv.release(1).unwrap();
        kv.release(2).unwrap();
        assert_eq!(kv.snapshot().used_bytes, 0, "unpublished blocks free immediately");
    }

    #[test]
    fn gather_scatter_roundtrip_mha() {
        let lay = mha_layout();
        let (l_n, h_n, dh) = (lay.n_layers, lay.n_heads, lay.head_dim);
        let mut kv = PagedKv::new(4, 1 << 20);
        let tokens: Vec<i32> = (0..6).collect();
        kv.admit(1, lay, "mha", true, &tokens).unwrap();
        let bucket = 8;
        let n = l_n * h_n * bucket * dh;
        let kc = Tensor::f32(
            vec![l_n, h_n, bucket, dh],
            (0..n).map(|x| x as f32).collect(),
        );
        let vc = Tensor::f32(
            vec![l_n, h_n, bucket, dh],
            (0..n).map(|x| 1000.0 + x as f32).collect(),
        );
        kv.write_prefill_mha(1, &kc, &vc, 6).unwrap();
        kv.commit_prefill(1).unwrap();
        let (gk, gv) = kv.gather_mha(1, bucket).unwrap();
        let (gkf, kf) = (gk.as_f32().unwrap(), kc.as_f32().unwrap());
        let (gvf, vf) = (gv.as_f32().unwrap(), vc.as_f32().unwrap());
        for l in 0..l_n {
            for h in 0..h_n {
                for t in 0..bucket {
                    let o = ((l * h_n + h) * bucket + t) * dh;
                    for d in 0..dh {
                        if t < 6 {
                            assert_eq!(gkf[o + d], kf[o + d], "k l{l} h{h} t{t}");
                            assert_eq!(gvf[o + d], vf[o + d], "v l{l} h{h} t{t}");
                        } else {
                            assert_eq!(gkf[o + d], 0.0, "pad k l{l} h{h} t{t}");
                            assert_eq!(gvf[o + d], 0.0, "pad v l{l} h{h} t{t}");
                        }
                    }
                }
            }
        }
        // decode row appends survive the roundtrip
        kv.ensure_append_slot(1).unwrap();
        let mut k2 = kf.to_vec();
        let mut v2 = vf.to_vec();
        for l in 0..l_n {
            for h in 0..h_n {
                let o = ((l * h_n + h) * bucket + 6) * dh;
                for d in 0..dh {
                    k2[o + d] = -1.0 - (l * h_n + h) as f32;
                    v2[o + d] = -2.0 - (l * h_n + h) as f32;
                }
            }
        }
        let kc2 = Tensor::f32(vec![l_n, h_n, bucket, dh], k2.clone());
        let vc2 = Tensor::f32(vec![l_n, h_n, bucket, dh], v2.clone());
        kv.write_decode_row(1, Some(&kc2), None, &vc2, 6).unwrap();
        kv.append_committed(1, 42).unwrap();
        let (gk2, gv2) = kv.gather_mha(1, bucket).unwrap();
        for l in 0..l_n {
            for h in 0..h_n {
                let o = ((l * h_n + h) * bucket + 6) * dh;
                assert_eq!(gk2.as_f32().unwrap()[o], -1.0 - (l * h_n + h) as f32);
                assert_eq!(gv2.as_f32().unwrap()[o], -2.0 - (l * h_n + h) as f32);
            }
        }
    }

    #[test]
    fn gather_scatter_roundtrip_chai() {
        let lay = chai_layout();
        let (l_n, h_n, dh) = (lay.n_layers, lay.n_heads, lay.head_dim);
        let k_heads = lay.k_heads.clone();
        let mut kv = PagedKv::new(4, 1 << 20);
        let tokens: Vec<i32> = (0..5).collect();
        kv.admit(7, lay, "chai", true, &tokens).unwrap();
        let bucket = 8;
        let kreps: Vec<Tensor> = k_heads
            .iter()
            .enumerate()
            .map(|(l, &k)| {
                Tensor::f32(
                    vec![k, bucket, dh],
                    (0..k * bucket * dh).map(|x| (100 * l + x) as f32).collect(),
                )
            })
            .collect();
        let vn = l_n * h_n * bucket * dh;
        let vc = Tensor::f32(
            vec![l_n, h_n, bucket, dh],
            (0..vn).map(|x| 5000.0 + x as f32).collect(),
        );
        kv.write_prefill_chai(7, &kreps, &vc, 5).unwrap();
        kv.commit_prefill(7).unwrap();
        let (gk, gv) = kv.gather_chai(7, bucket).unwrap();
        for (l, (got, want)) in gk.iter().zip(&kreps).enumerate() {
            assert_eq!(got.shape, want.shape);
            let (g, w) = (got.as_f32().unwrap(), want.as_f32().unwrap());
            for r in 0..k_heads[l] {
                for t in 0..bucket {
                    for d in 0..dh {
                        let o = (r * bucket + t) * dh + d;
                        if t < 5 {
                            assert_eq!(g[o], w[o], "l{l} r{r} t{t}");
                        } else {
                            assert_eq!(g[o], 0.0, "pad l{l} r{r} t{t}");
                        }
                    }
                }
            }
        }
        let (g, w) = (gv.as_f32().unwrap(), vc.as_f32().unwrap());
        for l in 0..l_n {
            for h in 0..h_n {
                for t in 0..5 {
                    let o = ((l * h_n + h) * bucket + t) * dh;
                    assert_eq!(g[o], w[o], "v l{l} h{h} t{t}");
                }
            }
        }
    }

    #[test]
    fn swap_roundtrip_restores_block_bytes_exactly() {
        // sharing disabled → the pure serialize/restore path, no
        // adoption shortcuts
        let lay = mha_layout();
        let (l_n, h_n, dh) = (lay.n_layers, lay.n_heads, lay.head_dim);
        let mut kv = PagedKv::new(4, 1 << 20);
        let mut tier = SwapPool::new(1 << 20);
        let tokens: Vec<i32> = (0..10).collect(); // 2 full + rem 2
        kv.admit(1, lay.clone(), "mha", false, &tokens).unwrap();
        let bucket = 16;
        let n = l_n * h_n * bucket * dh;
        let kc = Tensor::f32(vec![l_n, h_n, bucket, dh], (0..n).map(|x| x as f32).collect());
        let vc = Tensor::f32(
            vec![l_n, h_n, bucket, dh],
            (0..n).map(|x| 7000.0 + x as f32).collect(),
        );
        kv.write_prefill_mha(1, &kc, &vc, 10).unwrap();
        kv.commit_prefill(1).unwrap();
        let (k0, v0) = kv.gather_mha(1, bucket).unwrap();

        // compact accounting: exactly the filled rows round-trip
        let cost = kv.swap_cost(1).unwrap();
        assert_eq!(cost, lay.floats_per_token() * 10 * 4);
        let h = kv.swap_out(1, &mut tier).unwrap();
        assert!(!kv.has(1));
        assert_eq!(kv.snapshot().used_bytes, 0, "unpublished blocks free at swap-out");
        assert_eq!(tier.used_bytes(), cost);

        // resume: fresh table, restore, bit-exact compare
        kv.admit(2, lay, "mha", false, &tokens).unwrap();
        let restored = kv.restore_swapped(2, h, &mut tier).unwrap();
        assert_eq!(restored, 10, "every position restored from the tier");
        assert_eq!(tier.used_bytes(), 0, "swap-in drains the tier");
        kv.commit_prefill(2).unwrap();
        let (k1, v1) = kv.gather_mha(2, bucket).unwrap();
        let (a, b) = (k0.as_f32().unwrap(), k1.as_f32().unwrap());
        assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()), "K bytes differ");
        let (a, b) = (v0.as_f32().unwrap(), v1.as_f32().unwrap());
        assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()), "V bytes differ");
        kv.check_consistency().unwrap();
    }

    #[test]
    fn swap_never_serializes_blocks_other_live_sessions_read() {
        let lay = chai_layout();
        let mut kv = PagedKv::new(4, 1 << 20);
        let mut tier = SwapPool::new(1 << 20);
        let tokens: Vec<i32> = (0..10).collect(); // 2 full + rem 2
        kv.admit(1, lay.clone(), "chai", true, &tokens).unwrap();
        kv.commit_prefill(1).unwrap();
        kv.admit(2, lay.clone(), "chai", true, &tokens).unwrap(); // adopts all 3
        kv.commit_prefill(2).unwrap();
        // seq 2 diverges: CoW gives it a sole-owner tail (3 tokens)
        kv.ensure_append_slot(2).unwrap();
        kv.append_committed(2, 100).unwrap();
        let seq1_before = kv.gather_chai(1, 16).unwrap();

        // only the CoW'd tail is swappable — the two shared blocks stay
        // pinned for seq 1
        let cost = kv.swap_cost(2).unwrap();
        assert_eq!(cost, lay.floats_per_token() * 3 * 4);
        let h = kv.swap_out(2, &mut tier).unwrap();
        assert_eq!(tier.stats.pinned_blocks, 2, "shared blocks must not be staged");
        assert_eq!(tier.stats.out_blocks, 1);
        assert!(kv.has(1), "seq 1 unaffected");
        kv.check_consistency().unwrap();

        // seq 1 still reads its rows bit-exactly
        let seq1_after = kv.gather_chai(1, 16).unwrap();
        for (x, y) in seq1_before.0.iter().zip(&seq1_after.0) {
            assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
        }
        assert_eq!(
            seq1_before.1.as_f32().unwrap(),
            seq1_after.1.as_f32().unwrap()
        );

        // resume: shared prefix re-adopts through the index, the CoW'd
        // tail restores from the tier — the whole sequence is valid
        let mut resumed = tokens.clone();
        resumed.push(100);
        kv.admit(3, lay, "chai", true, &resumed).unwrap();
        let restored = kv.restore_swapped(3, h, &mut tier).unwrap();
        assert_eq!(restored, 11);
        kv.commit_prefill(3).unwrap();
        kv.check_consistency().unwrap();
    }

    #[test]
    fn swap_denied_when_tier_full_leaves_table_intact() {
        let lay = mha_layout();
        let mut kv = PagedKv::new(4, 1 << 20);
        let mut tier = SwapPool::new(16); // far too small
        let tokens: Vec<i32> = (0..6).collect();
        kv.admit(1, lay, "mha", true, &tokens).unwrap();
        kv.commit_prefill(1).unwrap();
        assert!(kv.swap_out(1, &mut tier).is_err());
        assert!(kv.has(1), "denied swap must leave the table untouched");
        assert_eq!(tier.stats.denied_full, 1);
        assert_eq!(tier.used_bytes(), 0);
        kv.check_consistency().unwrap();
        kv.release(1).unwrap();
    }

    #[test]
    fn pool_exhaustion_is_typed() {
        let lay = mha_layout();
        let mut kv = PagedKv::new(4, 2 * lay.block_bytes(4));
        let tokens: Vec<i32> = (0..8).collect();
        kv.admit(1, lay.clone(), "mha", true, &tokens).unwrap();
        kv.commit_prefill(1).unwrap();
        let err = kv.admit(2, lay, "mha", true, &(100..116).collect::<Vec<i32>>()).unwrap_err();
        assert!(is_pool_exhausted(&err), "alloc failure must downcast: {err:#}");
        assert!(!is_pool_exhausted(&anyhow::anyhow!("other")));
    }

    #[test]
    fn property_random_admission_release_consistent() {
        use crate::util::proptest::check;
        check("paged-kv-lifecycle", 15, |rng| {
            let lay = KvLayout {
                n_layers: 2,
                n_heads: 2,
                head_dim: 2,
                k_heads: vec![1, 2],
            };
            let mut kv = PagedKv::new(4, 200 * lay.block_bytes(4));
            let mut live: Vec<u64> = Vec::new();
            let mut next = 0u64;
            for _ in 0..80 {
                match rng.below(4) {
                    0 => {
                        let n = rng.range(1, 20);
                        let base = rng.below(3) as i32; // few distinct prompts → sharing
                        let tokens: Vec<i32> = (0..n as i32).map(|i| base * 1000 + i).collect();
                        if kv.admit(next, lay.clone(), "mha", true, &tokens).is_ok() {
                            kv.commit_prefill(next).map_err(|e| e.to_string())?;
                            live.push(next);
                        }
                        next += 1;
                    }
                    1 if !live.is_empty() => {
                        let id = live[rng.below(live.len())];
                        // alloc failure under pressure is a legal outcome;
                        // the append only happens once a slot exists
                        if kv.ensure_append_slot(id).is_ok() {
                            kv.append_committed(id, rng.below(1000) as i32)
                                .map_err(|e| e.to_string())?;
                        }
                    }
                    2 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        let id = live.swap_remove(i);
                        kv.release(id).map_err(|e| e.to_string())?;
                    }
                    _ => {}
                }
                kv.check_consistency().map_err(|e| e.to_string())?;
            }
            for id in live.drain(..) {
                kv.release(id).map_err(|e| e.to_string())?;
            }
            kv.drop_cached();
            let snap = kv.snapshot();
            crate::prop_assert!(snap.used_bytes == 0, "leak: {} bytes", snap.used_bytes);
            crate::prop_assert!(snap.indexed_prefixes == 0, "stale index entries");
            Ok(())
        });
    }
}
