//! Host-side swap tier for preempted sequences.
//!
//! When the scheduler preempts a live session (see `scheduler`), its
//! K,V state can be staged out of the hot block pool into this spill
//! tier instead of being recomputed on resume. Swapped state is stored
//! *compacted*: per block, only the `filled` token rows of every panel
//! round-trip, so a swapped CHAI block carries just each layer's `k_l`
//! cluster-representative K panels (serialized once per block — the
//! panels resident in the block ARE the rep panels) plus the full-head
//! V rows. Blocks another live table still references are never
//! serialized (they stay pinned in the hot tier — the manager records
//! a `None` placeholder and re-adopts them through the prefix index on
//! swap-in); see [`super::PagedKv::swap_out`].
//!
//! The tier has its own byte budget (`--swap-blocks`, accounted against
//! the MHA block size): when an entry does not fit, swap-out is denied
//! and the scheduler falls back to recompute-on-resume.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::KvLayout;

/// Ticket returned by a swap-out; redeemed (once) by swap-in.
pub type SwapHandle = u64;

/// One serialized block: the compacted rows of its K and V panels.
#[derive(Debug, Clone)]
pub struct SwappedBlock {
    /// token rows captured (<= block_size)
    pub filled: usize,
    /// compact row data: `floats_per_token * filled` f32s, K panels
    /// first (layer-major, panel-major), then V panels
    pub data: Vec<f32>,
}

impl SwappedBlock {
    /// Serialize `filled` rows of every panel out of a block slab.
    pub fn capture(
        layout: &KvLayout,
        block_size: usize,
        filled: usize,
        slab: &[f32],
    ) -> SwappedBlock {
        let dh = layout.head_dim;
        let mut data = Vec::with_capacity(layout.floats_per_token() * filled);
        for l in 0..layout.n_layers {
            let base = layout.k_layer_offset(l, block_size);
            for r in 0..layout.k_heads[l] {
                let src = base + r * block_size * dh;
                data.extend_from_slice(&slab[src..src + filled * dh]);
            }
        }
        for l in 0..layout.n_layers {
            let base = layout.v_layer_offset(l, block_size);
            for h in 0..layout.n_heads {
                let src = base + h * block_size * dh;
                data.extend_from_slice(&slab[src..src + filled * dh]);
            }
        }
        SwappedBlock { filled, data }
    }

    /// Scatter the compact rows back into a (freshly allocated) slab.
    pub fn restore_into(&self, layout: &KvLayout, block_size: usize, slab: &mut [f32]) {
        let dh = layout.head_dim;
        let mut cur = 0usize;
        for l in 0..layout.n_layers {
            let base = layout.k_layer_offset(l, block_size);
            for r in 0..layout.k_heads[l] {
                let dst = base + r * block_size * dh;
                slab[dst..dst + self.filled * dh]
                    .copy_from_slice(&self.data[cur..cur + self.filled * dh]);
                cur += self.filled * dh;
            }
        }
        for l in 0..layout.n_layers {
            let base = layout.v_layer_offset(l, block_size);
            for h in 0..layout.n_heads {
                let dst = base + h * block_size * dh;
                slab[dst..dst + self.filled * dh]
                    .copy_from_slice(&self.data[cur..cur + self.filled * dh]);
                cur += self.filled * dh;
            }
        }
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// Everything needed to rebuild one preempted sequence's K,V rows.
#[derive(Debug)]
pub struct SwappedSeq {
    pub layout: KvLayout,
    pub block_size: usize,
    /// covered positions at swap-out time (== the table's `len`)
    pub len: usize,
    /// per logical block: `Some` = serialized here, `None` = pinned in
    /// the hot tier at swap-out (another live table was reading it)
    pub blocks: Vec<Option<SwappedBlock>>,
    /// accounting size of the serialized payload
    pub bytes: usize,
}

/// Monotonic swap-tier counters (surfaced as `swap_*` gauges).
#[derive(Debug, Default, Clone)]
pub struct SwapStats {
    pub swap_outs: u64,
    pub swap_ins: u64,
    pub out_blocks: u64,
    pub in_blocks: u64,
    /// blocks exempted from serialization because another live table
    /// still read them (prefix-pinned)
    pub pinned_blocks: u64,
    /// swap-outs denied because the tier was full (caller falls back to
    /// recompute-on-resume)
    pub denied_full: u64,
    pub out_bytes: u64,
    pub in_bytes: u64,
    /// entries dropped without a swap-in (errored resumes)
    pub discarded: u64,
}

/// Point-in-time view for gauges.
#[derive(Debug, Clone)]
pub struct SwapSnapshot {
    pub capacity_bytes: usize,
    pub used_bytes: usize,
    pub entries: usize,
    pub blocks: usize,
    pub stats: SwapStats,
}

/// Fixed-budget host spill tier: swapped sequences keyed by handle.
#[derive(Debug)]
pub struct SwapPool {
    capacity_bytes: usize,
    used_bytes: usize,
    next: SwapHandle,
    entries: BTreeMap<SwapHandle, SwappedSeq>,
    pub stats: SwapStats,
}

impl SwapPool {
    pub fn new(capacity_bytes: usize) -> SwapPool {
        SwapPool {
            capacity_bytes,
            used_bytes: 0,
            next: 0,
            entries: BTreeMap::new(),
            stats: SwapStats::default(),
        }
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn free_bytes(&self) -> usize {
        self.capacity_bytes - self.used_bytes
    }

    pub fn fits(&self, bytes: usize) -> bool {
        bytes <= self.free_bytes()
    }

    /// Store a swapped sequence; the caller must have checked
    /// [`Self::fits`] (a non-fitting insert is an error, not an evict —
    /// the swap tier never drops state it has accepted).
    pub fn insert(&mut self, entry: SwappedSeq) -> Result<SwapHandle> {
        if !self.fits(entry.bytes) {
            bail!(
                "swap tier full: need {} B, used {}/{} B",
                entry.bytes,
                self.used_bytes,
                self.capacity_bytes
            );
        }
        let h = self.next;
        self.next += 1;
        self.used_bytes += entry.bytes;
        self.stats.swap_outs += 1;
        self.stats.out_bytes += entry.bytes as u64;
        self.stats.out_blocks += entry.blocks.iter().flatten().count() as u64;
        self.stats.pinned_blocks += entry.blocks.iter().filter(|b| b.is_none()).count() as u64;
        self.entries.insert(h, entry);
        Ok(h)
    }

    /// Redeem a handle: the entry leaves the tier (swap-in).
    pub fn take(&mut self, handle: SwapHandle) -> Result<SwappedSeq> {
        let e = self
            .entries
            .remove(&handle)
            .ok_or_else(|| anyhow!("unknown swap handle {handle}"))?;
        self.used_bytes -= e.bytes;
        self.stats.swap_ins += 1;
        self.stats.in_bytes += e.bytes as u64;
        self.stats.in_blocks += e.blocks.iter().flatten().count() as u64;
        Ok(e)
    }

    /// Drop an entry without restoring it (errored resume path).
    pub fn discard(&mut self, handle: SwapHandle) {
        if let Some(e) = self.entries.remove(&handle) {
            self.used_bytes -= e.bytes;
            self.stats.discarded += 1;
        }
    }

    pub fn snapshot(&self) -> SwapSnapshot {
        SwapSnapshot {
            capacity_bytes: self.capacity_bytes,
            used_bytes: self.used_bytes,
            entries: self.entries.len(),
            blocks: self.entries.values().map(|e| e.blocks.iter().flatten().count()).sum(),
            stats: self.stats.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> KvLayout {
        KvLayout { n_layers: 2, n_heads: 4, head_dim: 2, k_heads: vec![2, 3] }
    }

    #[test]
    fn block_capture_restore_roundtrip_exact() {
        let lay = layout();
        let b = 4;
        let n = lay.block_floats(b);
        // distinct value per slot so any index slip is caught
        let slab: Vec<f32> = (0..n).map(|x| x as f32).collect();
        for filled in 1..=b {
            let sb = SwappedBlock::capture(&lay, b, filled, &slab);
            assert_eq!(sb.data.len(), lay.floats_per_token() * filled);
            let mut out = vec![0.0f32; n];
            sb.restore_into(&lay, b, &mut out);
            // every captured row restored bit-exactly; untouched slots zero
            let dh = lay.head_dim;
            for l in 0..lay.n_layers {
                for r in 0..lay.k_heads[l] {
                    let base = lay.k_layer_offset(l, b) + r * b * dh;
                    for t in 0..b {
                        for d in 0..dh {
                            let idx = base + t * dh + d;
                            let want = if t < filled { slab[idx] } else { 0.0 };
                            assert_eq!(out[idx].to_bits(), want.to_bits(), "k l{l} r{r} t{t}");
                        }
                    }
                }
                for h in 0..lay.n_heads {
                    let base = lay.v_layer_offset(l, b) + h * b * dh;
                    for t in 0..b {
                        for d in 0..dh {
                            let idx = base + t * dh + d;
                            let want = if t < filled { slab[idx] } else { 0.0 };
                            assert_eq!(out[idx].to_bits(), want.to_bits(), "v l{l} h{h} t{t}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn pool_accounting_and_capacity() {
        let lay = layout();
        let mut p = SwapPool::new(1000);
        let sb = SwappedBlock::capture(&lay, 4, 2, &vec![1.0; lay.block_floats(4)]);
        let bytes = sb.bytes();
        let entry = SwappedSeq {
            layout: lay.clone(),
            block_size: 4,
            len: 2,
            blocks: vec![Some(sb.clone()), None],
            bytes,
        };
        assert!(p.fits(bytes));
        let h = p.insert(entry).unwrap();
        assert_eq!(p.used_bytes(), bytes);
        assert_eq!(p.stats.pinned_blocks, 1);
        assert_eq!(p.stats.out_blocks, 1);
        let snap = p.snapshot();
        assert_eq!(snap.entries, 1);
        assert_eq!(snap.blocks, 1);

        // a too-big entry is denied, never evicted-for
        let big = SwappedSeq {
            layout: lay.clone(),
            block_size: 4,
            len: 8,
            blocks: vec![],
            bytes: 2000,
        };
        assert!(p.insert(big).is_err());

        let back = p.take(h).unwrap();
        assert_eq!(back.bytes, bytes);
        assert_eq!(p.used_bytes(), 0);
        assert!(p.take(h).is_err(), "handles are single-use");
    }

    #[test]
    fn discard_frees_without_swap_in() {
        let lay = layout();
        let mut p = SwapPool::new(1000);
        let h = p
            .insert(SwappedSeq { layout: lay, block_size: 4, len: 1, blocks: vec![], bytes: 100 })
            .unwrap();
        p.discard(h);
        assert_eq!(p.used_bytes(), 0);
        assert_eq!(p.stats.discarded, 1);
        assert_eq!(p.stats.swap_ins, 0);
    }
}
