//! Per-request block table: the logical → physical mapping for one
//! sequence's K,V cache.
//!
//! One logical block id covers `block_size` consecutive token positions
//! across *all* layers and both roles (K and V) — the same design vLLM
//! uses, which makes prefix adoption atomic: adopting block `i` adopts
//! every layer's rows for those positions at once. The CHAI-specific
//! geometry (per-layer `k_l` K heads) lives in [`super::KvLayout`],
//! carried here so the data plane never needs the manifest.

use super::pool::BlockId;
use super::KvLayout;

#[derive(Debug)]
pub struct BlockTable {
    /// geometry of this sequence's rows (decides block byte size)
    pub layout: KvLayout,
    pub block_size: usize,
    /// sharing namespace seed (attention variant)
    pub seed: u64,
    /// whether this table may adopt/publish prefix blocks
    pub allow_share: bool,
    /// physical block per `block_size` span of positions
    pub blocks: Vec<BlockId>,
    /// token ids backing the hash chain (prompt + generated)
    pub tokens: Vec<i32>,
    /// filled token positions (== tokens.len())
    pub len: usize,
    /// chain hash after each completed full block: `hash_chain[i]` keys
    /// `blocks[i]`
    pub hash_chain: Vec<u64>,
    /// blocks adopted from the prefix index at admission (stats)
    pub adopted_full: usize,
    pub adopted_partial: bool,
}

impl BlockTable {
    pub fn new(layout: KvLayout, block_size: usize, seed: u64, allow_share: bool) -> BlockTable {
        BlockTable {
            layout,
            block_size,
            seed,
            allow_share,
            blocks: Vec::new(),
            tokens: Vec::new(),
            len: 0,
            hash_chain: Vec::new(),
            adopted_full: 0,
            adopted_partial: false,
        }
    }

    /// Number of completely filled blocks.
    pub fn full_blocks(&self) -> usize {
        self.len / self.block_size
    }

    /// Tokens in the trailing partial block (0 when block-aligned).
    pub fn tail_len(&self) -> usize {
        self.len % self.block_size
    }

    /// Chain hash preceding block `i` (the namespace seed for i == 0).
    pub fn chain_before(&self, i: usize) -> u64 {
        if i == 0 {
            self.seed
        } else {
            self.hash_chain[i - 1]
        }
    }

    /// Block index and in-block offset of a token position.
    pub fn locate(&self, pos: usize) -> (usize, usize) {
        (pos / self.block_size, pos % self.block_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> KvLayout {
        KvLayout { n_layers: 2, n_heads: 4, head_dim: 8, k_heads: vec![2, 3] }
    }

    #[test]
    fn geometry_helpers() {
        let mut t = BlockTable::new(layout(), 16, 7, true);
        t.tokens = (0..40).collect();
        t.len = 40;
        assert_eq!(t.full_blocks(), 2);
        assert_eq!(t.tail_len(), 8);
        assert_eq!(t.locate(0), (0, 0));
        assert_eq!(t.locate(16), (1, 0));
        assert_eq!(t.locate(39), (2, 7));
        assert_eq!(t.chain_before(0), 7);
    }
}
