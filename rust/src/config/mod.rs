//! Configuration: the AOT manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) plus serving-side settings. The manifest is the
//! single source of truth for shapes — the rust side never hardcodes model
//! dimensions.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Model dimensions (mirror of `python/compile/configs.py::ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_model: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub n_params: usize,
    /// RoPE base (the reference backend computes the forward itself;
    /// the AOT path has these baked into the lowered HLO)
    pub rope_theta: f64,
    pub rms_eps: f64,
}

/// One AOT-compiled executable: shapes of its runtime inputs/outputs.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: String,
    pub impl_name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl ArtifactSpec {
    pub fn bucket(&self) -> Result<usize> {
        self.meta.get("bucket")?.usize()
    }
}

/// The parsed manifest: model config + artifact index + cluster config.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelConfig,
    pub weight_order: Vec<String>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub probe_tokens: usize,
    pub probe_bucket: usize,
    pub analyze_bucket: usize,
    pub logprob_bucket: usize,
    pub prefill_buckets: Vec<usize>,
    pub decode_buckets: Vec<usize>,
    pub dejavu_sparsities: Vec<usize>,
    pub uniform_k_sweep: Vec<usize>,
    /// per-layer cluster counts from the offline elbow (clusters.json)
    pub k_list: Vec<usize>,
    pub k_max: usize,
    pub attn_impl: String,
    /// In-memory offline clusters `(membership, reps)` — set by backends
    /// whose manifest is synthesized (no clusters.json on disk); when
    /// `None`, [`Manifest::static_clusters`] reads the file.
    pub clusters: Option<(Vec<Vec<usize>>, Vec<Vec<usize>>)>,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.arr()?
        .iter()
        .map(|e| {
            Ok(TensorSpec {
                name: e.get("name")?.str()?.to_string(),
                dtype: e.get("dtype")?.str()?.to_string(),
                shape: e.get("shape")?.usize_vec()?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        let m = j.get("model")?;
        let model = ModelConfig {
            name: m.get("name")?.str()?.to_string(),
            vocab_size: m.get("vocab_size")?.usize()?,
            n_layers: m.get("n_layers")?.usize()?,
            n_heads: m.get("n_heads")?.usize()?,
            d_model: m.get("d_model")?.usize()?,
            head_dim: m.get("head_dim")?.usize()?,
            d_ff: m.get("d_ff")?.usize()?,
            max_seq: m.get("max_seq")?.usize()?,
            n_params: j.get("n_params")?.usize()?,
            // older manifests predate these keys; the python defaults apply
            rope_theta: m.opt("rope_theta").map(|v| v.num()).transpose()?.unwrap_or(10000.0),
            rms_eps: m.opt("rms_eps").map(|v| v.num()).transpose()?.unwrap_or(1e-5),
        };
        let mut artifacts = BTreeMap::new();
        for a in j.get("artifacts")?.arr()? {
            let spec = ArtifactSpec {
                name: a.get("name")?.str()?.to_string(),
                path: a.get("path")?.str()?.to_string(),
                impl_name: a.get("impl")?.str()?.to_string(),
                inputs: tensor_specs(a.get("inputs")?)?,
                outputs: tensor_specs(a.get("outputs")?)?,
                meta: a.get("meta")?.clone(),
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        let k_list = j.get("k_list")?.usize_vec()?;
        if k_list.len() != model.n_layers {
            bail!("k_list length {} != n_layers {}", k_list.len(), model.n_layers);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            weight_order: j.get("weight_order")?.str_vec()?,
            artifacts,
            probe_tokens: j.get("probe_tokens")?.usize()?,
            probe_bucket: j.get("probe_bucket")?.usize()?,
            analyze_bucket: j.get("analyze_bucket")?.usize()?,
            logprob_bucket: j.get("logprob_bucket")?.usize()?,
            prefill_buckets: j.get("prefill_buckets")?.usize_vec()?,
            decode_buckets: j.get("decode_buckets")?.usize_vec()?,
            dejavu_sparsities: j.get("dejavu_sparsities")?.usize_vec()?,
            uniform_k_sweep: j.get("uniform_k_sweep")?.usize_vec()?,
            k_max: j.get("k_max")?.usize()?,
            k_list,
            attn_impl: j.get("attn_impl")?.str()?.to_string(),
            clusters: None,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest (have: {:?})",
                                   self.artifacts.keys().collect::<Vec<_>>()))
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.path)
    }

    /// Smallest bucket that fits `len`.
    pub fn bucket_for(buckets: &[usize], len: usize) -> Option<usize> {
        buckets.iter().copied().filter(|b| *b >= len).min()
    }

    /// The CHAI-static membership/reps from the offline phase: the
    /// in-memory clusters of a synthesized manifest, or clusters.json.
    pub fn static_clusters(&self) -> Result<(Vec<Vec<usize>>, Vec<Vec<usize>>)> {
        if let Some(c) = &self.clusters {
            return Ok(c.clone());
        }
        let j = Json::parse_file(&self.dir.join("clusters.json"))?;
        let mut membership = Vec::new();
        let mut reps = Vec::new();
        for l in j.get("layers")?.arr()? {
            membership.push(l.get("membership")?.usize_vec()?);
            reps.push(l.get("reps")?.usize_vec()?);
        }
        Ok((membership, reps))
    }

    /// Per-layer elbow SSE curves (Figure 8) from clusters.json.
    pub fn elbow_errors(&self) -> Result<Vec<Vec<f64>>> {
        let j = Json::parse_file(&self.dir.join("clusters.json"))?;
        j.get("layers")?.arr()?.iter().map(|l| l.get("errors")?.f64_vec()).collect()
    }
}

/// Serving-side settings (engine + coordinator).
#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub artifacts_dir: PathBuf,
    /// compute backend: "xla" (AOT artifacts), "ref" (pure-rust
    /// interpreter, no artifacts needed), or "auto" (xla when
    /// `artifacts_dir` holds a manifest, else ref)
    pub backend: String,
    /// attention variant the engine serves with
    pub variant: String,
    /// max new tokens per request default
    pub max_new_tokens: usize,
    /// max requests admitted per scheduler tick
    pub max_batch: usize,
    /// sampling temperature (0 = greedy)
    pub temperature: f64,
    pub seed: u64,
    /// serve K,V through the paged block subsystem (`kv::paged`);
    /// `false` falls back to contiguous per-session tensors + `KvPool`
    /// bucket accounting
    pub paged_kv: bool,
    /// block-table-native serving on paged-capable backends: fuse all
    /// live paged sessions into one ragged `decode_paged` call per tick
    /// and skip prefill compute for adopted prefix blocks; `false`
    /// (`--no-batched-decode`) restores the per-session bucket
    /// gather/scatter path for comparison
    pub batched_decode: bool,
    /// token positions per KV block (paged path)
    pub kv_block_size: usize,
    /// total K,V block pool budget in bytes (paged path; the legacy
    /// path uses the same budget for its bucket accounting)
    pub kv_capacity_bytes: usize,
    /// preempt-and-requeue live sessions under overload (`--preempt`):
    /// when the queue head has starved past `starve_ticks`, the
    /// scheduler freezes the LRU live session — swapping its K,V blocks
    /// to the host spill tier or recomputing them on resume
    pub preempt: bool,
    /// consecutive deferred ticks before the queue head may trigger a
    /// preemption (`--starve-ticks`)
    pub starve_ticks: u64,
    /// host swap-tier budget in MHA-sized KV blocks (`--swap-blocks`);
    /// 0 disables the tier (every preemption recomputes on resume)
    pub swap_blocks: usize,
    /// preempted sessions with at most this many cached positions
    /// recompute on resume rather than swapping
    /// (`--recompute-max-tokens`)
    pub recompute_max_tokens: usize,
    /// data-parallel engine replicas behind the router front-end
    /// (`--replicas`); each has its own engine thread, scheduler and
    /// paged pool, sharing one copy of the model weights on the ref
    /// backend
    pub replicas: usize,
    /// router placement policy (`--route`): "rr" round-robin,
    /// "least-loaded" by pending+live+preempted population, or
    /// "prefix" affinity by the prompt's KV hash-chain fingerprint
    pub route: String,
    /// streaming front-end transport (`--net`): "threads" spawns one
    /// I/O thread per connection; "reactor" (Linux) multiplexes every
    /// connection on one epoll thread with lock-free rings on the
    /// request and token-frame hot paths
    pub net: String,
    /// capacity of each coordinator's bounded submission inbox
    /// (`--net-inbox`); a submission that finds it full is shed with a
    /// terminal `{"error":"overloaded"}` line instead of queueing
    /// without bound
    pub net_inbox: usize,
    /// replica transport (`--transport`): "local" keeps every replica
    /// in-process behind the router; "process" (Linux) spawns each as
    /// a separate `chai replica` child process speaking the line-JSON
    /// protocol over its own epoll reactor, so a replica crash cannot
    /// take the router down
    pub transport: String,
    /// health-probe cadence in milliseconds for mesh replicas
    /// (`--probe-ms`)
    pub probe_ms: u64,
    /// consecutive failed probes before a suspect replica is declared
    /// dead and its accepted requests are requeued on survivors
    /// (`--probe-suspect`)
    pub probe_suspect: u32,
    /// binary to spawn for `--transport process` replicas
    /// (`--replica-cmd`); `None` re-executes the current binary
    pub replica_cmd: Option<PathBuf>,
    /// relay decode (`--no-relay` disables): batchmates whose block
    /// tables share a block-aligned physical prefix compute that span's
    /// attention ONCE per tick (per rep panel for CHAI) and LSE-merge it
    /// with their private suffix phase — exact softmax math, logits
    /// within 1e-5 of the fused path, greedy streams identical
    pub relay: bool,
    /// pin the engine tick and reactor threads to dedicated cores via
    /// `sched_setaffinity` (`--pin-cores`; Linux, off by default)
    pub pin_cores: bool,
    /// compute threads per engine for intra-tick kernel parallelism
    /// (`--threads N`). 0 = auto: the allowed-cpu mask divided across
    /// replicas (`CHAI_THREADS` env overrides auto, for `cargo test`).
    /// 1 = the exact legacy serial path, no workers spawned. Any value
    /// produces bitwise-identical outputs — tasks partition only
    /// independent output slices, never a reduction.
    pub threads: usize,
    /// span tracing + flight recorder + per-tick profiler
    /// ([`crate::obs`]; `--no-obs` disables). Always-on by default —
    /// the `bench_serving --obs` gate holds the overhead at ≤2% decode
    /// tok/s, and token streams are bit-identical either way.
    pub obs: bool,
    /// write the merged Chrome-trace dump here on shutdown and on
    /// replica death (`--trace-out`); `{"cmd":"trace"}` serves the same
    /// dump on demand
    pub trace_out: Option<PathBuf>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            backend: "auto".into(),
            variant: "chai".into(),
            max_new_tokens: 32,
            max_batch: 8,
            temperature: 0.0,
            seed: 0,
            paged_kv: true,
            batched_decode: true,
            kv_block_size: 16,
            kv_capacity_bytes: 512 * 1024 * 1024,
            preempt: false,
            starve_ticks: 4,
            swap_blocks: 64,
            recompute_max_tokens: 16,
            replicas: 1,
            route: "rr".into(),
            net: "threads".into(),
            net_inbox: 4096,
            transport: "local".into(),
            probe_ms: 100,
            probe_suspect: 3,
            replica_cmd: None,
            relay: true,
            pin_cores: false,
            threads: 0,
            obs: true,
            trace_out: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn bucket_for_picks_smallest_fit() {
        let b = [32, 128, 512, 2048];
        assert_eq!(Manifest::bucket_for(&b, 1), Some(32));
        assert_eq!(Manifest::bucket_for(&b, 32), Some(32));
        assert_eq!(Manifest::bucket_for(&b, 33), Some(128));
        assert_eq!(Manifest::bucket_for(&b, 2048), Some(2048));
        assert_eq!(Manifest::bucket_for(&b, 2049), None);
    }

    #[test]
    fn loads_built_manifest() {
        let Some(m) = repo_manifest() else { return };
        assert_eq!(m.model.n_heads, 16);
        assert_eq!(m.k_list.len(), m.model.n_layers);
        assert!(m.artifacts.contains_key("logprob_mha"));
        assert!(m.artifacts.contains_key("decode_chai_t128"));
        let a = m.artifact("decode_mha_t128").unwrap();
        assert_eq!(a.bucket().unwrap(), 128);
        // kcache input shape [L, H, T, dh]
        let kc = a.inputs.iter().find(|i| i.name == "kcache").unwrap();
        assert_eq!(kc.shape, vec![m.model.n_layers, m.model.n_heads, 128, m.model.head_dim]);
    }

    #[test]
    fn static_clusters_consistent_with_k_list() {
        let Some(m) = repo_manifest() else { return };
        let (mem, reps) = m.static_clusters().unwrap();
        assert_eq!(mem.len(), m.model.n_layers);
        for l in 0..m.model.n_layers {
            assert_eq!(reps[l].len(), m.k_list[l]);
            assert_eq!(mem[l].len(), m.model.n_heads);
            assert!(mem[l].iter().all(|x| *x < m.k_list[l]));
            // canonical: reps sorted
            let mut sorted = reps[l].clone();
            sorted.sort();
            assert_eq!(sorted, reps[l]);
        }
    }

    #[test]
    fn elbow_errors_match_layer_count() {
        let Some(m) = repo_manifest() else { return };
        let errs = m.elbow_errors().unwrap();
        assert_eq!(errs.len(), m.model.n_layers);
        assert!(errs.iter().all(|e| e.len() == m.model.n_heads));
    }
}
