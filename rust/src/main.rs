//! `chai` CLI — leader entrypoint for the serving stack.
//!
//! Subcommands:
//!   serve     start the TCP line-JSON server (engine thread + coordinator)
//!   replica   one mesh replica (Linux): reactor server on an ephemeral
//!             port, spawned and supervised by a `chai serve
//!             --transport process` parent
//!   generate  one-shot generation from the command line
//!   eval      accuracy of a variant on the synthetic suites (Tables 1-3)
//!   analyze   offline head analysis: correlations, elbow, memberships
//!   info      print manifest/model/cluster summary
//!
//! Examples:
//!   chai serve --artifacts artifacts --bind 127.0.0.1:7777
//!   chai serve --backend ref                             # pure-rust backend (no artifacts needed)
//!   chai serve --kv-block-size 16 --kv-capacity-mb 512   # paged KV knobs
//!   chai serve --no-paged                                # legacy contiguous KV
//!   chai serve --no-batched-decode                       # per-session bucket decode (no fused block-native ticks)
//!   chai serve --preempt --swap-blocks 64 --starve-ticks 4
//!                                                        # overload scheduling: preempt-and-requeue the LRU live
//!                                                        # session (KV swap-out to a host tier / recompute on resume)
//!   chai serve --replicas 4 --route prefix               # multi-replica router front-end: 4 data-parallel engines
//!                                                        # (shared weights), prefix-affinity placement; --route
//!                                                        # rr|least-loaded|prefix. Streaming: {"stream": true};
//!                                                        # abort: {"cmd": "cancel", "id": N}
//!   chai serve --net reactor --net-inbox 4096            # epoll-reactor transport (Linux): ONE I/O thread multiplexes
//!                                                        # all streaming connections; bounded submission inbox sheds
//!                                                        # with {"error":"overloaded"} when full. --net threads (default)
//!                                                        # keeps the thread-per-connection transport
//!   chai serve --replicas 4 --transport process           # location-transparent mesh (Linux): each replica is a
//!                                                        # separate `chai replica` process behind the same router;
//!                                                        # health probes (--probe-ms 100 --probe-suspect 3) requeue
//!                                                        # a dead replica's in-flight requests on the survivors
//!   chai serve --trace-out trace.json                    # dump the observability flight recorder (Chrome trace JSON)
//!                                                        # on shutdown/replica death; {"cmd":"trace"} drains it live;
//!                                                        # --no-obs disables span recording entirely
//!   chai generate --prompt "the color of tom is" --variant chai
//!   chai eval --variant chai --suites piqa-syn,boolq-syn --max-items 20
//!   chai analyze --samples 64
//!   chai info

use std::path::PathBuf;

use anyhow::{bail, Result};

use chai::bench::Table;
use chai::clustering::correlation;
use chai::config::ServingConfig;
use chai::engine::{Engine, Variant};
use chai::router::Router;
use chai::eval;
use chai::kv;
use chai::runtime::{Backend, In};
use chai::server::Server;
use chai::tensor::Tensor;
use chai::util::args::Args;
use chai::util::json::Json;

fn serving_config(args: &Args) -> Result<ServingConfig> {
    Ok(ServingConfig {
        artifacts_dir: PathBuf::from(args.str("artifacts", "artifacts")),
        // xla | ref | auto (auto = xla when artifacts exist, else the
        // pure-rust reference backend with a seeded toy model)
        backend: args.str("backend", "auto"),
        variant: args.str("variant", "chai"),
        max_new_tokens: args.usize("max-new", 32)?,
        max_batch: args.usize("max-batch", 8)?,
        temperature: args.f64("temperature", 0.0)?,
        seed: args.usize("seed", 0)? as u64,
        // paged block-pool KV is the serving default; --no-paged falls
        // back to contiguous per-session tensors + bucket admission
        paged_kv: !args.bool("no-paged"),
        // fused block-table-native decode ticks are the default on
        // paged-capable backends; --no-batched-decode restores the
        // per-session bucket gather/scatter path
        batched_decode: !args.bool("no-batched-decode"),
        kv_block_size: args.usize("kv-block-size", 16)?,
        // --kv-capacity-bytes carries the exact pool size (the process
        // transport forwards it to replica children so parent and child
        // budgets agree to the byte); --kv-capacity-mb is the human knob
        kv_capacity_bytes: match args.opt_str("kv-capacity-bytes") {
            Some(v) => v.parse()?,
            None => args.usize("kv-capacity-mb", 512)? * 1024 * 1024,
        },
        // overload scheduling: --preempt enables preempt-and-requeue of
        // the LRU live session once the queue head has starved past
        // --starve-ticks; its K,V blocks swap out to a --swap-blocks
        // sized host tier or recompute on resume (cost-model chosen,
        // sessions under --recompute-max-tokens always recompute)
        preempt: args.bool("preempt"),
        starve_ticks: args.usize("starve-ticks", 4)? as u64,
        swap_blocks: args.usize("swap-blocks", 64)?,
        recompute_max_tokens: args.usize("recompute-max-tokens", 16)?,
        // multi-replica router front-end: --replicas N engine replicas
        // (own scheduler + paged pool each, one shared copy of the
        // model weights on the ref backend) placed by --route
        // rr|least-loaded|prefix
        replicas: args.usize("replicas", 1)?,
        route: args.str("route", "rr"),
        // streaming front-end transport: --net threads (default,
        // portable) or --net reactor (Linux, single epoll I/O thread);
        // --net-inbox bounds each coordinator's submission ring (full
        // inbox = shed with a terminal {"error":"overloaded"} line)
        net: args.str("net", "threads"),
        net_inbox: args.usize("net-inbox", 4096)?,
        // replica mesh: --transport local keeps every replica in the
        // router process; --transport process (Linux) spawns each one
        // as a `chai replica` child speaking line-JSON over the epoll
        // reactor, with health probes every --probe-ms escalating
        // suspect->dead after --probe-suspect consecutive failures
        transport: args.str("transport", "local"),
        probe_ms: args.usize("probe-ms", 100)? as u64,
        probe_suspect: args.usize("probe-suspect", 3)? as u32,
        // replica child binary override (tests point this at the
        // freshly-built `chai`); default re-executes the current binary
        replica_cmd: args.opt_str("replica-cmd").map(PathBuf::from),
        // relay decode is the default on the paged path; --no-relay
        // restores fully fused per-row attention for comparison
        relay: !args.bool("no-relay"),
        // --pin-cores pins the engine tick + reactor threads to
        // dedicated cores (sched_setaffinity; Linux, off by default)
        pin_cores: args.bool("pin-cores"),
        // --threads N sizes each engine's kernel worker pool; 0 = auto
        // (allowed-cpu mask / replicas), 1 = exact legacy serial path
        threads: args.usize("threads", 0)?,
        // always-on observability (span rings + per-tick profiler);
        // --no-obs is the escape hatch: no spans recorded, no trace ids
        // minted or propagated (token streams are identical either way)
        obs: !args.bool("no-obs"),
        // --trace-out FILE dumps the flight recorder as Chrome
        // trace-event JSON on shutdown and on replica death
        trace_out: args.opt_str("trace-out").map(PathBuf::from),
    })
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "serve" => cmd_serve(&args),
        "replica" => cmd_replica(&args),
        "generate" => cmd_generate(&args),
        "eval" => cmd_eval(&args),
        "analyze" => cmd_analyze(&args),
        "info" => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: chai <serve|generate|eval|analyze|info> [--artifacts DIR] ...\n\
                 see rust/src/main.rs header for examples"
            );
            Ok(())
        }
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = serving_config(args)?;
    let bind = args.str("bind", "127.0.0.1:7777");
    let net_mode = chai::net::NetMode::parse(&cfg.net)?;
    let (replicas, route) = (cfg.replicas.max(1), cfg.route.clone());
    // the router front-end serves any replica count; a single replica
    // still gets streaming + cancellation with no placement overhead
    let handle = Router::start(cfg)?;
    let server = Server::start_with(handle.router.clone(), &bind, net_mode)?;
    println!(
        "chai serving on {} ({replicas} replica(s), route policy {route}, net {})",
        server.addr,
        net_mode.name()
    );
    println!("protocol: one JSON per line, e.g. {{\"prompt\": \"the color of tom is\", \"variant\": \"chai\"}}");
    println!("          streaming: add \"stream\": true; abort with {{\"cmd\": \"cancel\", \"id\": N}}");
    // serve until killed
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// One mesh replica: a single coordinator behind a reactor server on an
/// ephemeral port. The parent learns the port from the one-line stdout
/// handshake and owns our lifetime through the stdin pipe — EOF there
/// (graceful shutdown OR a dead parent) is the exit signal, so a
/// replica can never outlive its router as an orphan.
#[cfg(target_os = "linux")]
fn cmd_replica(args: &Args) -> Result<()> {
    use std::io::{Read, Write};

    let mut cfg = serving_config(args)?;
    cfg.replicas = 1; // a replica is exactly one engine; fan-out is the parent's job
    let trace_out = cfg.trace_out.take(); // the child dumps its own rings
    let handle = chai::coordinator::Coordinator::start(cfg)?;
    let server = Server::start_with(
        handle.coordinator.clone(),
        "127.0.0.1:0",
        chai::net::NetMode::Reactor,
    )?;
    // the handshake line must be the FIRST stdout line and must flush:
    // the parent blocks on it before connecting
    let hello = Json::obj(vec![("replica_listening", Json::Str(server.addr.to_string()))]);
    println!("{}", hello.to_string());
    std::io::stdout().flush()?;
    // park until the parent closes our stdin
    let mut sink = Vec::new();
    let _ = std::io::stdin().read_to_end(&mut sink);
    server.stop();
    handle.shutdown();
    if let Some(path) = trace_out {
        if let Err(e) = std::fs::write(&path, chai::obs::dump_json().to_string()) {
            eprintln!("[replica] --trace-out {}: {e}", path.display());
        }
    }
    Ok(())
}

#[cfg(not(target_os = "linux"))]
fn cmd_replica(_args: &Args) -> Result<()> {
    bail!("chai replica requires Linux (epoll reactor)")
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = serving_config(args)?;
    let prompt = args.str("prompt", "the color of tom is");
    let max_new = args.usize("max-new", 24)?;
    let variant = Variant::parse(&args.str("variant", "chai"))?;
    let engine = Engine::load(cfg)?;
    let gen = engine.generate(&prompt, max_new, &variant)?;
    println!("prompt:  {prompt}");
    println!("output:  {}", gen.text);
    println!(
        "timing:  ttft {:.2} ms (probe {:.2} + cluster {:.2} + prefill {:.2}), \
         {} decode steps, mean {:.2} ms/tok",
        gen.timing.ttft_ms,
        gen.timing.probe_ms,
        gen.timing.cluster_ms,
        gen.timing.prefill_ms,
        gen.timing.decode_ms.len(),
        chai::util::stats::mean(&gen.timing.decode_ms),
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = serving_config(args)?;
    let dir = cfg.artifacts_dir.clone();
    let engine = Engine::load(cfg)?;
    let variants: Vec<Variant> = args
        .str("variant", "mha,chai")
        .split(',')
        .map(Variant::parse)
        .collect::<Result<_>>()?;
    let suites: Vec<String> = match args.opt_str("suites") {
        Some(s) => s.split(',').map(|x| x.to_string()).collect(),
        None => eval::SUITES.iter().map(|s| s.to_string()).collect(),
    };
    let max_items = args.usize("max-items", 0)?;
    let max_items = if max_items == 0 { None } else { Some(max_items) };
    let mut table = Table::new(
        "Accuracy (synthetic suites)",
        &std::iter::once("variant")
            .chain(suites.iter().map(|s| s.as_str()))
            .collect::<Vec<_>>(),
    );
    for v in &variants {
        let mut row = vec![v.name()];
        for s in &suites {
            let suite = eval::load_suite(&dir, s)?;
            let acc = eval::accuracy(&engine, &suite, v, max_items)?;
            row.push(format!("{acc:.1}"));
        }
        table.row(row);
    }
    table.print();
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let cfg = serving_config(args)?;
    let engine = Engine::load(cfg)?;
    let m = engine.manifest().clone();
    let n_samples = args.usize("samples", 32)?;
    let samples = load_analysis_samples(&m.dir, n_samples)?;
    println!("analyzing {} samples (bucket {})...", samples.len(), m.analyze_bucket);

    // per-layer features: last-query attention rows across samples
    let mut feats: Vec<Vec<Vec<f32>>> = vec![vec![Vec::new(); m.model.n_heads]; m.model.n_layers];
    for s in &samples {
        let maps = analyze_sample(&engine, s)?;
        let (l, h, t) = (m.model.n_layers, m.model.n_heads, m.analyze_bucket);
        let ln = chai::model::tokenizer::encode(s, true, false).len().min(t);
        let v = maps.as_f32()?;
        for li in 0..l {
            for hi in 0..h {
                let base = ((li * h + hi) * t + (ln - 1)) * t;
                feats[li][hi].extend_from_slice(&v[base..base + ln]);
            }
        }
    }
    let mut table = Table::new(
        "Per-layer head redundancy (Figure 6 analogue)",
        &["layer", "mean corr", "frac>0.95", "elbow k"],
    );
    for (li, layer) in feats.iter().enumerate() {
        let corr = correlation::correlation_matrix(layer);
        let res = chai::clustering::elbow::cluster_layer(layer, 0);
        table.row(vec![
            li.to_string(),
            format!("{:.3}", correlation::mean_offdiag(&corr)),
            format!("{:.2}", correlation::frac_above(&corr, 0.95)),
            res.k.to_string(),
        ]);
    }
    table.print();
    println!("offline clusters.json k_list: {:?}", m.k_list);
    println!(
        "CHAI K,V-cache saving vs MHA: {:.1}%",
        100.0 * kv::chai_saving_fraction(&m)
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = serving_config(args)?;
    // static facts only: read the manifest (or synthesize the toy one)
    // without building an engine or loading/uploading weights; backend
    // resolution/validation is shared with the engine path
    let backend = chai::runtime::resolve_backend(&cfg)?;
    let m = if cfg.artifacts_dir.join("manifest.json").exists() {
        chai::config::Manifest::load(&cfg.artifacts_dir)?
    } else {
        chai::runtime::reference::RefBackend::toy(cfg.seed).manifest().clone()
    };
    println!("backend:     {backend}");
    println!("model:       {} ({} params)", m.model.name, m.model.n_params);
    println!(
        "dims:        L={} H={} d={} dh={} ff={} vocab={}",
        m.model.n_layers, m.model.n_heads, m.model.d_model, m.model.head_dim,
        m.model.d_ff, m.model.vocab_size
    );
    println!("k_list:      {:?} (k_max {})", m.k_list, m.k_max);
    println!("buckets:     prefill {:?} decode {:?}", m.prefill_buckets, m.decode_buckets);
    println!("attn impl:   {}", m.attn_impl);
    println!("artifacts:   {}", m.artifacts.len());
    for (name, a) in &m.artifacts {
        println!("  {name:32} {} inputs, {} outputs", a.inputs.len(), a.outputs.len());
    }
    Ok(())
}

// --- helpers shared with benches (duplicated minimally) -------------------

pub fn load_analysis_samples(dir: &std::path::Path, n: usize) -> Result<Vec<String>> {
    let j = Json::parse_file(&dir.join("analysis_samples.json"))?;
    let samples = j.get("samples")?.str_vec()?;
    if samples.is_empty() {
        bail!("no analysis samples");
    }
    Ok(samples.into_iter().take(n).collect())
}

pub fn analyze_sample(engine: &Engine, text: &str) -> Result<Tensor> {
    let m = engine.manifest();
    let t = m.analyze_bucket;
    let mut ids = chai::model::tokenizer::encode(text, true, false);
    ids.truncate(t);
    let ln = ids.len();
    ids.resize(t, chai::model::tokenizer::PAD);
    let outs = engine.rt.run(
        "analyze",
        &[
            In::Host(&Tensor::i32(vec![t], ids)),
            In::Host(&Tensor::scalar_i32(ln as i32)),
        ],
    )?;
    outs[0].to_tensor()
}
