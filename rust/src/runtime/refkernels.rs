//! Pure-Rust reference kernels — the Rust mirror of
//! `python/compile/kernels/ref.py` plus the model primitives from
//! `python/compile/model.py` (rmsnorm, rope, swiglu).
//!
//! These are the numeric core of [`super::reference::RefBackend`] and the
//! correctness oracle for everything the serving stack executes without
//! artifacts. Semantics are pinned to the python side by committed golden
//! fixtures (`rust/tests/golden/*.cbt`, regenerated and diffed by
//! `python/tests/test_golden_export.py`) at 1e-5 tolerance.
//!
//! Shapes (unbatched, row-major f32 slices with explicit dims; the
//! serving path is B=1):
//!   q:   [G, Tq, dh]   queries for G heads (or G = K cluster reps)
//!   k:   [G, Tk, dh]
//!   v:   [H, Tk, dh]
//!   membership: [H] in [0, K)  — cluster id of each head
//!
//! Masking: query i sits at absolute position `q_offset + i`; key j at
//! position j. Allowed iff `j <= q_offset + i && j < length`.

use super::pool;
use super::pool::SendPtr;

/// Additive mask value (mirrors `ref.NEG_INF`).
pub const NEG_INF: f32 = -1e9;

// ---------------------------------------------------------------------------
// Parallel partitioning helpers
//
// Every kernel below splits work ONLY over independent output slices —
// matmul row tiles and column panels, head panels, (head, query) rows —
// never over the k-reduction, so each output element is produced by one
// task accumulating in the same scalar order as the serial loop and the
// results are bitwise identical at every pool size (tests/parallel.rs).
// Thresholds gate dispatch on work size so toy decode shapes skip the
// pool; they tune only WHERE work runs, never what is computed.
// ---------------------------------------------------------------------------

/// Minimum per-task work (≈ multiply-adds) worth a pool dispatch.
const PAR_MIN_FLOPS: usize = 8 * 1024;

/// Contiguous `i`-th of `parts` slices of `0..len` (balanced, in order).
#[inline]
fn split(len: usize, parts: usize, i: usize) -> (usize, usize) {
    (i * len / parts, (i + 1) * len / parts)
}

/// Task grid `(row_tiles, col_tiles)` for an `m×kk×n` matmul under the
/// current pool; `(1, 1)` means run serial. Columns only split when the
/// rows alone cannot feed every thread (short-m decode matmuls).
fn par_grid(m: usize, kk: usize, n: usize, col_unit: usize) -> (usize, usize) {
    let t = pool::threads();
    if t <= 1 {
        return (1, 1);
    }
    let max_tasks = ((m * kk * n) / PAR_MIN_FLOPS).max(1).min(t * 2);
    if max_tasks <= 1 {
        return (1, 1);
    }
    let tm = m.min(max_tasks);
    let tn = (max_tasks / tm).clamp(1, n.div_ceil(col_unit).max(1));
    (tm, tn)
}

/// Half-open `(start, end)` index range of an output partition.
type Span = (usize, usize);

/// Serial matmul over an output tile: rows `[r0, r1)` × cols `[c0, c1)`
/// of `a [m, kk] @ b [kk, n]`, accumulated in ascending-`ki` order (the
/// same per-element order as the whole-matrix loop). `out` is the full
/// `[m, n]` buffer; tiles are disjoint, so the raw pointer is sound.
fn mm_tile(a: &[f32], b: &[f32], kk: usize, n: usize, r: Span, c: Span, out: SendPtr) {
    let (r0, r1) = r;
    let (c0, c1) = c;
    for mi in r0..r1 {
        let arow = &a[mi * kk..(mi + 1) * kk];
        let orow = unsafe { out.slice(mi * n + c0, c1 - c0) };
        for (ki, &av) in arow.iter().enumerate() {
            let brow = &b[ki * n + c0..ki * n + c1];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Packed weight panels
//
// `pack_b` re-lays a `[kk, n]` weight matrix panel-major: `panel`-wide
// column groups stored contiguously per `ki`, so the blocked matmul's
// inner loop streams one cache-resident panel instead of striding `n`
// floats between rows. Packing is a pure data reorder — `matmul_packed`
// visits `ki` in the same ascending order per output element, so its
// results are bitwise identical to `matmul` (asserted below). Panels
// are packed ONCE at weight load (`reference.rs`) and shared read-only
// across replicas and pool workers.
// ---------------------------------------------------------------------------

/// Default packing width: 64 f32 = 2 cache lines per `ki` row.
pub const PANEL: usize = 64;

/// A `[kk, n]` matrix packed panel-major (see module comment). The last
/// panel is zero-padded to `panel` width; the pad is never read.
pub struct PackedB {
    pub kk: usize,
    pub n: usize,
    pub panel: usize,
    data: Vec<f32>,
}

/// Pack `b [kk, n]` into `panel`-wide column panels.
pub fn pack_b(b: &[f32], kk: usize, n: usize, panel: usize) -> PackedB {
    assert_eq!(b.len(), kk * n, "b shape");
    assert!(panel > 0, "panel width");
    let np = n.div_ceil(panel);
    let mut data = vec![0.0f32; np * kk * panel];
    for p in 0..np {
        let c0 = p * panel;
        let w = (n - c0).min(panel);
        for ki in 0..kk {
            data[(p * kk + ki) * panel..(p * kk + ki) * panel + w]
                .copy_from_slice(&b[ki * n + c0..ki * n + c0 + w]);
        }
    }
    PackedB { kk, n, panel, data }
}

/// Serial packed-matmul tile: rows `[r0, r1)` × panels `[p0, p1)`.
fn mmp_tile(a: &[f32], bp: &PackedB, r: (usize, usize), p: (usize, usize), out: SendPtr) {
    let (kk, n, panel) = (bp.kk, bp.n, bp.panel);
    for pi in p.0..p.1 {
        let c0 = pi * panel;
        let w = (n - c0).min(panel);
        for mi in r.0..r.1 {
            let arow = &a[mi * kk..(mi + 1) * kk];
            let orow = unsafe { out.slice(mi * n + c0, w) };
            for (ki, &av) in arow.iter().enumerate() {
                let brow = &bp.data[(pi * kk + ki) * panel..(pi * kk + ki) * panel + w];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// `a [m, kk] @ packed b → out [m, n]`, blocked over the packed panels
/// and parallel over (row tile × panel tile) output cells. Bitwise
/// identical to `matmul` with the unpacked matrix.
pub fn matmul_packed_into(a: &[f32], bp: &PackedB, m: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * bp.kk, "a shape");
    assert_eq!(out.len(), m * bp.n, "out shape");
    out.fill(0.0);
    let np = bp.n.div_ceil(bp.panel);
    let t = pool::threads();
    let max_tasks = ((m * bp.kk * bp.n) / PAR_MIN_FLOPS).max(1).min(t * 2);
    let tm = m.min(max_tasks);
    let tp = (max_tasks / tm.max(1)).clamp(1, np);
    let ptr = SendPtr::new(out);
    if t <= 1 || tm * tp <= 1 {
        mmp_tile(a, bp, (0, m), (0, np), ptr);
        return;
    }
    pool::run(tm * tp, |i| {
        let (ri, pi) = (i / tp, i % tp);
        mmp_tile(a, bp, split(m, tm, ri), split(np, tp, pi), ptr);
    });
}

/// Allocating wrapper over [`matmul_packed_into`].
pub fn matmul_packed(a: &[f32], bp: &PackedB, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * bp.n];
    matmul_packed_into(a, bp, m, &mut out);
    out
}

/// `softmax(q kᵀ / sqrt(dh))` with causal + length masking.
///
/// q: `[g, tq, dh]`, k: `[g, tk, dh]` → `[g, tq, tk]` row-stochastic.
/// `key_mask` (additive, `[tk]`) is the SpAtten token-pruning hook and
/// is applied after the causal/length mask, exactly like the jnp path.
pub fn attention_scores(
    q: &[f32],
    k: &[f32],
    g: usize,
    tq: usize,
    tk: usize,
    dh: usize,
    q_offset: usize,
    length: usize,
    key_mask: Option<&[f32]>,
) -> Vec<f32> {
    assert_eq!(q.len(), g * tq * dh, "q shape");
    assert_eq!(k.len(), g * tk * dh, "k shape");
    let scale = (dh as f32).sqrt();
    let mut out = vec![0.0f32; g * tq * tk];
    // parallel over (head, query) output rows — each row's score walk,
    // max, and normalize are self-contained
    let ptr = SendPtr::new(&mut out);
    let min_rows = (PAR_MIN_FLOPS / (tk * dh).max(1)).max(1);
    pool::par_ranges(g * tq, min_rows, |r0, r1| {
        for r in r0..r1 {
            let (gi, qi) = (r / tq, r % tq);
            let qrow = &q[r * dh..r * dh + dh];
            let orow = unsafe { ptr.slice(r * tk, tk) };
            let qpos = q_offset + qi;
            for (kj, slot) in orow.iter_mut().enumerate() {
                let mut s = if kj <= qpos && kj < length {
                    let krow = &k[(gi * tk + kj) * dh..(gi * tk + kj) * dh + dh];
                    let mut acc = 0.0f32;
                    for d in 0..dh {
                        acc += qrow[d] * krow[d];
                    }
                    acc / scale
                } else {
                    NEG_INF
                };
                if let Some(m) = key_mask {
                    s += m[kj];
                }
                *slot = s;
            }
            // stable softmax (subtract row max, exp, normalize)
            let mx = orow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for x in orow.iter_mut() {
                *x = (*x - mx).exp();
                sum += *x;
            }
            for x in orow.iter_mut() {
                *x /= sum;
            }
        }
    });
    out
}

/// `probs [g,tq,tk] × v [g,tk,dh] → [g,tq,dh]`.
pub fn attn_av(probs: &[f32], v: &[f32], g: usize, tq: usize, tk: usize, dh: usize) -> Vec<f32> {
    assert_eq!(probs.len(), g * tq * tk, "probs shape");
    assert_eq!(v.len(), g * tk * dh, "v shape");
    let mut out = vec![0.0f32; g * tq * dh];
    let ptr = SendPtr::new(&mut out);
    let min_rows = (PAR_MIN_FLOPS / (tk * dh).max(1)).max(1);
    pool::par_ranges(g * tq, min_rows, |r0, r1| {
        for r in r0..r1 {
            let gi = r / tq;
            let prow = &probs[r * tk..r * tk + tk];
            let orow = unsafe { ptr.slice(r * dh, dh) };
            for (kj, &p) in prow.iter().enumerate() {
                let vrow = &v[(gi * tk + kj) * dh..(gi * tk + kj) * dh + dh];
                for d in 0..dh {
                    orow[d] += p * vrow[d];
                }
            }
        }
    });
    out
}

/// Dense multi-head attention. Returns `(out [h,tq,dh], probs [h,tq,tk])`.
pub fn mha_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    h: usize,
    tq: usize,
    tk: usize,
    dh: usize,
    q_offset: usize,
    length: usize,
    key_mask: Option<&[f32]>,
) -> (Vec<f32>, Vec<f32>) {
    let probs = attention_scores(q, k, h, tq, tk, dh, q_offset, length, key_mask);
    let out = attn_av(&probs, v, h, tq, tk, dh);
    (out, probs)
}

/// CHAI clustered-head attention (paper §3.4): scores once per cluster
/// representative (`q_rep`/`k_rep`: `[kc, tq, dh]`), broadcast to every
/// member head via `membership`, applied to each head's own V (all V
/// kept, per Table 4).
///
/// Returns `(out [h,tq,dh], probs_rep [kc,tq,tk])`.
pub fn clustered_attention(
    q_rep: &[f32],
    k_rep: &[f32],
    v: &[f32],
    membership: &[usize],
    kc: usize,
    h: usize,
    tq: usize,
    tk: usize,
    dh: usize,
    q_offset: usize,
    length: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(membership.len(), h, "membership shape");
    let probs = attention_scores(q_rep, k_rep, kc, tq, tk, dh, q_offset, length, None);
    // broadcast rep probabilities to member heads, then the same AV loop
    // as the dense path — with singleton clusters this is bit-for-bit MHA
    let mut probs_full = vec![0.0f32; h * tq * tk];
    for (hh, &m) in membership.iter().enumerate() {
        assert!(m < kc, "membership {m} out of range (k={kc})");
        probs_full[hh * tq * tk..(hh + 1) * tq * tk]
            .copy_from_slice(&probs[m * tq * tk..(m + 1) * tq * tk]);
    }
    let out = attn_av(&probs_full, v, h, tq, tk, dh);
    (out, probs)
}

/// Table-4 ablation (CHAI-QKV): V is also taken from the representative
/// head, i.e. the whole head is pruned. `rep_heads [kc]` indexes into v.
/// Returns `(out [h,tq,dh], probs_rep [kc,tq,tk])`.
pub fn clustered_attention_qkv(
    q_rep: &[f32],
    k_rep: &[f32],
    v: &[f32],
    membership: &[usize],
    rep_heads: &[usize],
    kc: usize,
    h: usize,
    tq: usize,
    tk: usize,
    dh: usize,
    q_offset: usize,
    length: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(rep_heads.len(), kc, "rep_heads shape");
    let probs = attention_scores(q_rep, k_rep, kc, tq, tk, dh, q_offset, length, None);
    let mut v_rep = vec![0.0f32; kc * tk * dh];
    for (ci, &rh) in rep_heads.iter().enumerate() {
        assert!(rh < h, "rep head {rh} out of range (h={h})");
        v_rep[ci * tk * dh..(ci + 1) * tk * dh]
            .copy_from_slice(&v[rh * tk * dh..(rh + 1) * tk * dh]);
    }
    let out_rep = attn_av(&probs, &v_rep, kc, tq, tk, dh);
    let mut out = vec![0.0f32; h * tq * dh];
    for (hh, &m) in membership.iter().enumerate() {
        out[hh * tq * dh..(hh + 1) * tq * dh]
            .copy_from_slice(&out_rep[m * tq * dh..(m + 1) * tq * dh]);
    }
    (out, probs)
}

// ---------------------------------------------------------------------------
// Paged (block-table-native) attention
//
// The bucket kernels above take contiguous `[g, Tk, dh]` K/V tensors; the
// paged variants read rows in place out of the KV block slabs the pool
// owns, addressed through a block table — no gather into bucket shapes.
//
// Addressing (see `kv::paged::KvLayout`): within a slab, panel `g`'s row
// for absolute position `j` lives at `base + (g*B + j%B)*dh`, in slab
// `blocks[j/B]`, where `base` is the layer's K (or V) panel-group offset
// and `B` the block size.
//
// Numerics are pinned to the bucket kernels bit-for-bit: masked bucket
// entries softmax to exactly 0.0 (`exp(NEG_INF - mx)` underflows) and a
// `+= 0.0 * v` contributes nothing, so iterating keys over `[0, len)`
// instead of `[0, Tk)` reproduces identical accumulation — asserted by
// `paged_matches_bucket_kernels_bitwise` below and the engine-level
// paged-vs-contiguous stream property test.
// ---------------------------------------------------------------------------

/// `softmax(q kᵀ / sqrt(dh))` against block-resident keys.
///
/// q: `[g, tq, dh]` at absolute positions `q_offset + qi`; key `j` read
/// from `blocks[j / block_size]` at `k_base + (g*block_size + j%B)*dh`.
/// Causal: `j <= q_offset + qi`. Returns `[g, tq, len]`; rows are
/// stochastic over their unmasked prefix, masked tail entries are 0.
#[allow(clippy::too_many_arguments)]
pub fn paged_attention_scores(
    q: &[f32],
    blocks: &[&[f32]],
    k_base: usize,
    g: usize,
    tq: usize,
    dh: usize,
    block_size: usize,
    q_offset: usize,
    len: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; g * tq * len];
    paged_attention_scores_into(q, blocks, k_base, g, tq, dh, block_size, q_offset, len, &mut out);
    out
}

/// [`paged_attention_scores`] into a caller-owned (scratch-arena)
/// buffer; parallel over (panel, query) output rows.
#[allow(clippy::too_many_arguments)]
pub fn paged_attention_scores_into(
    q: &[f32],
    blocks: &[&[f32]],
    k_base: usize,
    g: usize,
    tq: usize,
    dh: usize,
    block_size: usize,
    q_offset: usize,
    len: usize,
    out: &mut [f32],
) {
    assert_eq!(q.len(), g * tq * dh, "q shape");
    assert_eq!(out.len(), g * tq * len, "out shape");
    assert!(blocks.len() * block_size >= len, "block table too short for len");
    let scale = (dh as f32).sqrt();
    out.fill(0.0); // masked tail entries must be exact 0.0
    let ptr = SendPtr::new(out);
    let min_rows = (PAR_MIN_FLOPS / (len * dh).max(1)).max(1);
    pool::par_ranges(g * tq, min_rows, |r0, r1| {
        for r in r0..r1 {
            let (gi, qi) = (r / tq, r % tq);
            let qrow = &q[r * dh..r * dh + dh];
            let orow = unsafe { ptr.slice(r * len, len) };
            // keys [0, kmax) are unmasked for this query; walk whole
            // blocks so the slab lookup runs once per block, not per key
            let kmax = (q_offset + qi + 1).min(len);
            let mut kj = 0usize;
            while kj < kmax {
                let slab = blocks[kj / block_size];
                let hi = (kj - kj % block_size + block_size).min(kmax);
                let base = k_base + gi * block_size * dh;
                for (slot, off) in orow[kj..hi].iter_mut().zip(kj % block_size..) {
                    let krow = &slab[base + off * dh..base + off * dh + dh];
                    let mut acc = 0.0f32;
                    for d in 0..dh {
                        acc += qrow[d] * krow[d];
                    }
                    *slot = acc / scale;
                }
                kj = hi;
            }
            let mx = orow[..kmax].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for x in orow[..kmax].iter_mut() {
                *x = (*x - mx).exp();
                sum += *x;
            }
            for x in orow[..kmax].iter_mut() {
                *x /= sum;
            }
        }
    });
}

/// `probs [g, tq, len] × block-resident V → [g, tq, dh]`; V row `j` for
/// panel `g` at `blocks[j/B][v_base + (g*B + j%B)*dh]`.
///
/// Query `qi` sits at absolute position `q_offset + qi`, so only its
/// unmasked prefix `[0, kmax)` is accumulated — the causal tail was
/// softmaxed to exact 0.0 and `+= 0.0 * v` contributes nothing, making
/// the bound bit-identical to the full `[0, len)` walk.
#[allow(clippy::too_many_arguments)]
pub fn paged_attn_av(
    probs: &[f32],
    blocks: &[&[f32]],
    v_base: usize,
    g: usize,
    tq: usize,
    dh: usize,
    block_size: usize,
    q_offset: usize,
    len: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; g * tq * dh];
    paged_attn_av_into(probs, blocks, v_base, g, tq, dh, block_size, q_offset, len, &mut out);
    out
}

/// [`paged_attn_av`] into a caller-owned (scratch-arena) buffer;
/// parallel over (panel, query) output rows.
#[allow(clippy::too_many_arguments)]
pub fn paged_attn_av_into(
    probs: &[f32],
    blocks: &[&[f32]],
    v_base: usize,
    g: usize,
    tq: usize,
    dh: usize,
    block_size: usize,
    q_offset: usize,
    len: usize,
    out: &mut [f32],
) {
    assert_eq!(probs.len(), g * tq * len, "probs shape");
    assert_eq!(out.len(), g * tq * dh, "out shape");
    out.fill(0.0);
    let ptr = SendPtr::new(out);
    let min_rows = (PAR_MIN_FLOPS / (len * dh).max(1)).max(1);
    pool::par_ranges(g * tq, min_rows, |r0, r1| {
        for r in r0..r1 {
            let (gi, qi) = (r / tq, r % tq);
            let prow = &probs[r * len..r * len + len];
            let orow = unsafe { ptr.slice(r * dh, dh) };
            let kmax = (q_offset + qi + 1).min(len);
            let mut kj = 0usize;
            while kj < kmax {
                let slab = blocks[kj / block_size];
                let hi = (kj - kj % block_size + block_size).min(kmax);
                let base = v_base + gi * block_size * dh;
                for (&p, off) in prow[kj..hi].iter().zip(kj % block_size..) {
                    let vrow = &slab[base + off * dh..base + off * dh + dh];
                    for d in 0..dh {
                        orow[d] += p * vrow[d];
                    }
                }
                kj = hi;
            }
        }
    });
}

/// Dense MHA attention against block-resident K,V. Returns `[h, tq, dh]`.
#[allow(clippy::too_many_arguments)]
pub fn paged_mha_attention(
    q: &[f32],
    blocks: &[&[f32]],
    k_base: usize,
    v_base: usize,
    h: usize,
    tq: usize,
    dh: usize,
    block_size: usize,
    q_offset: usize,
    len: usize,
) -> Vec<f32> {
    let probs = paged_attention_scores(q, blocks, k_base, h, tq, dh, block_size, q_offset, len);
    paged_attn_av(&probs, blocks, v_base, h, tq, dh, block_size, q_offset, len)
}

/// CHAI clustered attention against block-resident K-reps and V: scores
/// once per representative panel, broadcast to member heads via
/// `membership`, applied to each head's own block-resident V (§3.4).
/// Returns `[h, tq, dh]`.
#[allow(clippy::too_many_arguments)]
pub fn paged_clustered_attention(
    q_rep: &[f32],
    blocks: &[&[f32]],
    k_base: usize,
    v_base: usize,
    membership: &[usize],
    kc: usize,
    h: usize,
    tq: usize,
    dh: usize,
    block_size: usize,
    q_offset: usize,
    len: usize,
) -> Vec<f32> {
    assert_eq!(membership.len(), h, "membership shape");
    let probs =
        paged_attention_scores(q_rep, blocks, k_base, kc, tq, dh, block_size, q_offset, len);
    let mut probs_full = vec![0.0f32; h * tq * len];
    for (hh, &m) in membership.iter().enumerate() {
        assert!(m < kc, "membership {m} out of range (k={kc})");
        probs_full[hh * tq * len..(hh + 1) * tq * len]
            .copy_from_slice(&probs[m * tq * len..(m + 1) * tq * len]);
    }
    paged_attn_av(&probs_full, blocks, v_base, h, tq, dh, block_size, q_offset, len)
}

// ---------------------------------------------------------------------------
// Relay decode (shared-prefix attention, RelayAttention-style)
//
// A relay group is a set of decode rows whose block tables begin with the
// SAME physical blocks (block-aligned common prefix, refcount > 1). The
// attention of each row's single query splits into two phases:
//
//   prefix phase  — keys [0, S)        computed ONCE for the whole group
//                   from the shared slabs, with every group query stacked
//                   into one `[g, n, dh]` pass per rep panel;
//   suffix phase  — keys [S, len_r)    computed per row over its private
//                   tail blocks.
//
// Each phase returns *unnormalized* softmax partials per (panel, row):
// the running row max `m`, the sum of exponentials `s = Σ exp(score−m)`,
// and the exp-weights themselves (which weight V into a partial output
// `o = Σ exp(score−m)·v`). `relay_merge` then renormalizes:
//
//   M   = max(m_p, m_s)
//   out = (o_p·e^{m_p−M} + o_s·e^{m_s−M}) / (s_p·e^{m_p−M} + s_s·e^{m_s−M})
//
// which is algebraically the exact softmax-weighted value over the full
// key range — only the float *association* differs from the fused
// kernel, so relay logits land within 1e-5 of the fused oracle rather
// than bit-identical (the engine-level property tests pin both bounds).
// ---------------------------------------------------------------------------

/// One phase of relay attention: raw `q·kᵀ/√dh` scores of `n` stacked
/// single-token queries (`q: [g, n, dh]`) against block-resident keys
/// `[0, len)`, returned as softmax partials.
///
/// No causal mask is applied: relay phases only ever cover keys at or
/// below every stacked query's position (the shared prefix sits below
/// all group members; a private suffix ends at the row's own position).
///
/// Returns `(expw [g, n, len], m [g, n], s [g, n])` where
/// `expw[kj] = exp(score_kj − m)` and `s = Σ expw`.
#[allow(clippy::too_many_arguments)]
pub fn paged_relay_scores(
    q: &[f32],
    blocks: &[&[f32]],
    k_base: usize,
    g: usize,
    n: usize,
    dh: usize,
    block_size: usize,
    len: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(q.len(), g * n * dh, "q shape");
    assert!(blocks.len() * block_size >= len, "block table too short for len");
    assert!(len > 0, "relay phase over an empty key range");
    let scale = (dh as f32).sqrt();
    let mut expw = vec![0.0f32; g * n * len];
    let mut m = vec![0.0f32; g * n];
    let mut s = vec![0.0f32; g * n];
    let ew_ptr = SendPtr::new(&mut expw);
    let m_ptr = SendPtr::new(&mut m);
    let s_ptr = SendPtr::new(&mut s);
    let min_rows = (PAR_MIN_FLOPS / (len * dh).max(1)).max(1);
    pool::par_ranges(g * n, min_rows, |r0, r1| {
        for r in r0..r1 {
            let gi = r / n;
            let qrow = &q[r * dh..r * dh + dh];
            let orow = unsafe { ew_ptr.slice(r * len, len) };
            let mut kj = 0usize;
            while kj < len {
                let slab = blocks[kj / block_size];
                let hi = (kj - kj % block_size + block_size).min(len);
                let base = k_base + gi * block_size * dh;
                for (slot, off) in orow[kj..hi].iter_mut().zip(kj % block_size..) {
                    let krow = &slab[base + off * dh..base + off * dh + dh];
                    let mut acc = 0.0f32;
                    for d in 0..dh {
                        acc += qrow[d] * krow[d];
                    }
                    *slot = acc / scale;
                }
                kj = hi;
            }
            let mx = orow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for x in orow.iter_mut() {
                *x = (*x - mx).exp();
                sum += *x;
            }
            unsafe {
                m_ptr.slice(r, 1)[0] = mx;
                s_ptr.slice(r, 1)[0] = sum;
            }
        }
    });
    (expw, m, s)
}

/// Log-sum-exp merge of two relay phases into the exact softmax-weighted
/// output (one head panel, one row): `o_*` are the unnormalized partial
/// value accumulations `Σ exp(score−m)·v` of each phase.
pub fn relay_merge(
    o_p: &[f32],
    m_p: f32,
    s_p: f32,
    o_s: &[f32],
    m_s: f32,
    s_s: f32,
    out: &mut [f32],
) {
    assert_eq!(o_p.len(), out.len(), "prefix partial shape");
    assert_eq!(o_s.len(), out.len(), "suffix partial shape");
    let mx = m_p.max(m_s);
    let (w_p, w_s) = ((m_p - mx).exp(), (m_s - mx).exp());
    let denom = s_p * w_p + s_s * w_s;
    for ((o, &a), &b) in out.iter_mut().zip(o_p).zip(o_s) {
        *o = (a * w_p + b * w_s) / denom;
    }
}

// ---------------------------------------------------------------------------
// Model primitives (mirror of python/compile/model.py)
// ---------------------------------------------------------------------------

/// RMSNorm over the last axis: `x [t, d] * rsqrt(mean(x²) + eps) * w [d]`.
pub fn rmsnorm(x: &[f32], w: &[f32], t: usize, d: usize, eps: f32) -> Vec<f32> {
    let mut out = vec![0.0f32; t * d];
    rmsnorm_into(x, w, t, d, eps, &mut out);
    out
}

/// [`rmsnorm`] into a caller-owned (scratch-arena) buffer; parallel
/// over token rows.
pub fn rmsnorm_into(x: &[f32], w: &[f32], t: usize, d: usize, eps: f32, out: &mut [f32]) {
    assert_eq!(x.len(), t * d, "x shape");
    assert_eq!(w.len(), d, "w shape");
    assert_eq!(out.len(), t * d, "out shape");
    let ptr = SendPtr::new(out);
    let min_rows = (PAR_MIN_FLOPS / (2 * d).max(1)).max(1);
    pool::par_ranges(t, min_rows, |t0, t1| {
        for ti in t0..t1 {
            let row = &x[ti * d..(ti + 1) * d];
            let mut var = 0.0f32;
            for v in row {
                var += v * v;
            }
            var /= d as f32;
            let r = 1.0 / (var + eps).sqrt();
            let orow = unsafe { ptr.slice(ti * d, d) };
            for i in 0..d {
                orow[i] = row[i] * r * w[i];
            }
        }
    });
}

/// Rotary embedding, in place. x: `[g, t, dh]`; `positions [t]` are the
/// absolute positions of the t rows; `dh` must be even.
///
/// The per-position sin/cos table depends only on `(ti, channel)`, so it
/// is computed ONCE and reused by every head group (it used to be
/// recomputed `g`× per token — `bench_microbench` times the hoist
/// against the old body). Head-group panels are independent output
/// slices, so they fan out across the pool.
pub fn rope(x: &mut [f32], positions: &[usize], g: usize, t: usize, dh: usize, theta: f32) {
    assert_eq!(x.len(), g * t * dh, "x shape");
    assert_eq!(positions.len(), t, "positions shape");
    assert_eq!(dh % 2, 0, "head_dim must be even for rope");
    let half = dh / 2;
    // frequencies depend only on the channel — hoist out of the hot loop
    let freqs: Vec<f32> = (0..half).map(|i| theta.powf(-(i as f32) / half as f32)).collect();
    // sin/cos per (position row, channel), shared by all g head groups;
    // same `angle.sin()/.cos()` calls as before, so bitwise-pinned
    let mut sincos = vec![0.0f32; t * half * 2];
    for ti in 0..t {
        let pos = positions[ti] as f32;
        for (i, &freq) in freqs.iter().enumerate() {
            let angle = pos * freq;
            let e = &mut sincos[(ti * half + i) * 2..(ti * half + i) * 2 + 2];
            e[0] = angle.sin();
            e[1] = angle.cos();
        }
    }
    let ptr = SendPtr::new(x);
    let min_groups = (PAR_MIN_FLOPS / (t * 3 * dh).max(1)).max(1);
    pool::par_ranges(g, min_groups, |g0, g1| {
        for gi in g0..g1 {
            for ti in 0..t {
                let row = unsafe { ptr.slice((gi * t + ti) * dh, dh) };
                for i in 0..half {
                    let (sin, cos) = (sincos[(ti * half + i) * 2], sincos[(ti * half + i) * 2 + 1]);
                    let (x1, x2) = (row[i], row[half + i]);
                    row[i] = x1 * cos - x2 * sin;
                    row[half + i] = x1 * sin + x2 * cos;
                }
            }
        }
    });
}

/// `a [m, kk] @ b [kk, n] → [m, n]`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, kk: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_into(a, b, m, kk, n, &mut out);
    out
}

/// [`matmul`] into a caller-owned buffer, parallel over (row tile ×
/// column tile) output cells. Each cell accumulates its elements in the
/// same ascending-`ki` order as the serial loop — the reduction is
/// never split — so results are bitwise identical at every pool size.
pub fn matmul_into(a: &[f32], b: &[f32], m: usize, kk: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * kk, "a shape");
    assert_eq!(b.len(), kk * n, "b shape");
    assert_eq!(out.len(), m * n, "out shape");
    out.fill(0.0);
    let ptr = SendPtr::new(out);
    let (tm, tn) = par_grid(m, kk, n, 16);
    if tm * tn <= 1 {
        mm_tile(a, b, kk, n, (0, m), (0, n), ptr);
        return;
    }
    pool::run(tm * tn, |i| {
        let (ri, ci) = (i / tn, i % tn);
        mm_tile(a, b, kk, n, split(m, tm, ri), split(n, tn, ci), ptr);
    });
}

/// SwiGLU MLP: `(silu(x@wg) * (x@wu)) @ wd` with x `[t, d]`,
/// wg/wu `[d, f]`, wd `[f, d]`.
pub fn swiglu(x: &[f32], wg: &[f32], wu: &[f32], wd: &[f32], t: usize, d: usize, f: usize) -> Vec<f32> {
    let mut gate = matmul(x, wg, t, d, f);
    let up = matmul(x, wu, t, d, f);
    for (g, u) in gate.iter_mut().zip(&up) {
        // silu(g) * u; silu(x) = x * sigmoid(x)
        *g = *g / (1.0 + (-*g).exp()) * u;
    }
    matmul(&gate, wd, t, f, d)
}

/// SwiGLU over packed weight panels with caller-owned (scratch-arena)
/// gate/up/out buffers. The gate and up projections are independent
/// matmuls over the same `x`, so they dispatch CONCURRENTLY as one task
/// grid spanning both outputs; numerics are bitwise-identical to
/// [`swiglu`] (same packed-vs-plain argument as `matmul_packed_into`,
/// and the gate/up split touches disjoint buffers).
#[allow(clippy::too_many_arguments)]
pub fn swiglu_packed_into(
    x: &[f32],
    wg: &PackedB,
    wu: &PackedB,
    wd: &PackedB,
    t: usize,
    d: usize,
    f: usize,
    gate: &mut [f32],
    up: &mut [f32],
    out: &mut [f32],
) {
    assert_eq!(x.len(), t * d, "x shape");
    assert_eq!((wg.kk, wg.n), (d, f), "wg shape");
    assert_eq!((wu.kk, wu.n), (d, f), "wu shape");
    assert_eq!((wd.kk, wd.n), (f, d), "wd shape");
    assert_eq!(gate.len(), t * f, "gate shape");
    assert_eq!(up.len(), t * f, "up shape");
    gate.fill(0.0);
    up.fill(0.0);
    let np = f.div_ceil(wg.panel);
    let t_pool = pool::threads();
    let max_tasks = ((t * d * f) / PAR_MIN_FLOPS).max(1).min(t_pool.max(1));
    let tm = t.min(max_tasks);
    let tp = (max_tasks / tm.max(1)).clamp(1, np);
    let cells = tm * tp;
    let (gp, upp) = (SendPtr::new(gate), SendPtr::new(up));
    pool::run(2 * cells, |i| {
        let (which, cell) = (i / cells, i % cells);
        let (ri, pi) = (cell / tp, cell % tp);
        let (bp, outp) = if which == 0 { (wg, gp) } else { (wu, upp) };
        mmp_tile(x, bp, split(t, tm, ri), split(np, tp, pi), outp);
    });
    let gp = SendPtr::new(gate);
    pool::par_ranges(t * f, PAR_MIN_FLOPS / 8, |e0, e1| {
        let grow = unsafe { gp.slice(e0, e1 - e0) };
        for (g, &u) in grow.iter_mut().zip(&up[e0..e1]) {
            *g = *g / (1.0 + (-*g).exp()) * u;
        }
    });
    matmul_packed_into(gate, wd, t, out);
}

/// Per-head Q/K/V projection: gather head columns of `w [d, h*dh]` for
/// `heads` and project `xn [t, d]` → `[len(heads), t, dh]`. Both the
/// dense path (`heads = 0..h`) and the clustered path (representatives
/// only — the FLOP saving) use this, so CHAI with singleton clusters is
/// bitwise-identical to MHA.
pub fn project_heads(
    xn: &[f32],
    w: &[f32],
    heads: &[usize],
    t: usize,
    d: usize,
    h: usize,
    dh: usize,
) -> Vec<f32> {
    assert_eq!(xn.len(), t * d, "xn shape");
    assert_eq!(w.len(), d * h * dh, "w shape");
    let hd = h * dh;
    let mut out = vec![0.0f32; heads.len() * t * dh];
    // each head's [t, dh] output panel is contiguous and independent —
    // the CHAI-natural parallel axis (reps only on the clustered path)
    let ptr = SendPtr::new(&mut out);
    let min_heads = (PAR_MIN_FLOPS / (t * d * dh).max(1)).max(1);
    pool::par_ranges(heads.len(), min_heads, |g0, g1| {
        for (gi, &hh) in heads.iter().enumerate().take(g1).skip(g0) {
            assert!(hh < h, "head {hh} out of range (h={h})");
            for ti in 0..t {
                let xrow = &xn[ti * d..(ti + 1) * d];
                let orow = unsafe { ptr.slice((gi * t + ti) * dh, dh) };
                for (j, &xv) in xrow.iter().enumerate() {
                    let wrow = &w[j * hd + hh * dh..j * hd + hh * dh + dh];
                    for dd in 0..dh {
                        orow[dd] += xv * wrow[dd];
                    }
                }
            }
        }
    });
    out
}

/// [`project_heads`] over a head-major packed projection matrix
/// (`pack_b(w, d, h*dh, panel = dh)` — one panel per head, so head
/// `hh`'s weight column block streams contiguously instead of striding
/// `h*dh` floats per feature). Writes a caller-owned (scratch-arena)
/// buffer; bitwise identical to [`project_heads`] (same per-element
/// ascending-`j` accumulation).
#[allow(clippy::too_many_arguments)]
pub fn project_heads_packed_into(
    xn: &[f32],
    wp: &PackedB,
    heads: &[usize],
    t: usize,
    d: usize,
    h: usize,
    dh: usize,
    out: &mut [f32],
) {
    assert_eq!(xn.len(), t * d, "xn shape");
    assert_eq!((wp.kk, wp.n, wp.panel), (d, h * dh, dh), "w packing");
    assert_eq!(out.len(), heads.len() * t * dh, "out shape");
    out.fill(0.0);
    let ptr = SendPtr::new(out);
    let min_heads = (PAR_MIN_FLOPS / (t * d * dh).max(1)).max(1);
    pool::par_ranges(heads.len(), min_heads, |g0, g1| {
        for (gi, &hh) in heads.iter().enumerate().take(g1).skip(g0) {
            assert!(hh < h, "head {hh} out of range (h={h})");
            let wbase = hh * d * dh; // panel hh: (hh*d + j)*dh
            for ti in 0..t {
                let xrow = &xn[ti * d..(ti + 1) * d];
                let orow = unsafe { ptr.slice((gi * t + ti) * dh, dh) };
                for (j, &xv) in xrow.iter().enumerate() {
                    let wrow = &wp.data[wbase + j * dh..wbase + j * dh + dh];
                    for dd in 0..dh {
                        orow[dd] += xv * wrow[dd];
                    }
                }
            }
        }
    });
}

/// `[h, t, dh] → [t, h*dh]` (the `_unheads` transpose).
pub fn unheads(x: &[f32], h: usize, t: usize, dh: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; t * h * dh];
    unheads_into(x, h, t, dh, &mut out);
    out
}

/// [`unheads`] into a caller-owned (scratch-arena) buffer.
pub fn unheads_into(x: &[f32], h: usize, t: usize, dh: usize, out: &mut [f32]) {
    assert_eq!(x.len(), h * t * dh, "x shape");
    assert_eq!(out.len(), t * h * dh, "out shape");
    for hh in 0..h {
        for ti in 0..t {
            let src = &x[(hh * t + ti) * dh..(hh * t + ti) * dh + dh];
            out[ti * h * dh + hh * dh..ti * h * dh + hh * dh + dh].copy_from_slice(src);
        }
    }
}

/// Boolean mask of the `n_keep` largest entries by rank counting
/// (`rank_i = #{j : s_j > s_i}`, keep `rank < n_keep`) — the SpAtten
/// selection from `logprob_spatten_graph` (ties keep everything tied).
pub fn top_mask(scores: &[f32], n_keep: usize) -> Vec<bool> {
    scores
        .iter()
        .map(|&si| scores.iter().filter(|&&sj| sj > si).count() < n_keep)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn scores_rows_are_causal_distributions() {
        let (g, tq, tk, dh) = (2, 5, 5, 4);
        let q = fill(g * tq * dh, 1);
        let k = fill(g * tk * dh, 2);
        let probs = attention_scores(&q, &k, g, tq, tk, dh, 0, 4, None);
        for gi in 0..g {
            for qi in 0..tq {
                let row = &probs[(gi * tq + qi) * tk..(gi * tq + qi) * tk + tk];
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-5, "row sum {sum}");
                for (kj, &p) in row.iter().enumerate() {
                    if kj > qi || kj >= 4 {
                        assert_eq!(p, 0.0, "masked g{gi} q{qi} k{kj}");
                    }
                }
            }
        }
    }

    #[test]
    fn singleton_clusters_equal_mha_bitwise() {
        let (h, tq, tk, dh) = (4, 6, 6, 4);
        let q = fill(h * tq * dh, 3);
        let k = fill(h * tk * dh, 4);
        let v = fill(h * tk * dh, 5);
        let membership: Vec<usize> = (0..h).collect();
        let (mo, mp) = mha_attention(&q, &k, &v, h, tq, tk, dh, 0, tk, None);
        let (co, cp) = clustered_attention(&q, &k, &v, &membership, h, h, tq, tk, dh, 0, tk);
        assert_eq!(mo, co, "outputs must be bit-for-bit identical");
        assert_eq!(mp, cp);
    }

    #[test]
    fn clustered_broadcasts_rep_scores() {
        let (h, kc, tq, tk, dh) = (4, 2, 3, 3, 2);
        let q_rep = fill(kc * tq * dh, 6);
        let k_rep = fill(kc * tk * dh, 7);
        let v = fill(h * tk * dh, 8);
        let membership = vec![0, 0, 1, 1];
        let (out, probs) =
            clustered_attention(&q_rep, &k_rep, &v, &membership, kc, h, tq, tk, dh, 0, tk);
        assert_eq!(out.len(), h * tq * dh);
        assert_eq!(probs.len(), kc * tq * tk);
        // heads sharing a cluster and identical V rows would agree; here
        // V differs so outputs differ, but both derive from rep 0/1 rows
        let manual0 = attn_av(&probs[..tq * tk], &v[..tk * dh], 1, tq, tk, dh);
        assert_eq!(&out[..tq * dh], &manual0[..]);
    }

    #[test]
    fn qkv_ablation_reuses_rep_v() {
        let (h, kc, tq, tk, dh) = (4, 2, 3, 3, 2);
        let q_rep = fill(kc * tq * dh, 9);
        let k_rep = fill(kc * tk * dh, 10);
        let v = fill(h * tk * dh, 11);
        let membership = vec![0, 0, 1, 1];
        let rep_heads = vec![0, 2];
        let (out, _) = clustered_attention_qkv(
            &q_rep, &k_rep, &v, &membership, &rep_heads, kc, h, tq, tk, dh, 0, tk,
        );
        // member heads copy their representative's output exactly
        assert_eq!(out[..tq * dh], out[tq * dh..2 * tq * dh]);
        assert_eq!(out[2 * tq * dh..3 * tq * dh], out[3 * tq * dh..]);
    }

    /// Scatter contiguous `[g, tk, dh]` rows into block slabs with the
    /// `kv::paged` in-slab layout (panel-major, `base + (g*B + off)*dh`).
    fn blocks_from_contiguous(
        x: &[f32],
        g: usize,
        dh: usize,
        b: usize,
        base: usize,
        slab_floats: usize,
        len: usize,
        tk: usize,
    ) -> Vec<Vec<f32>> {
        let n_blocks = (len + b - 1) / b;
        let mut blocks = vec![vec![0.0f32; slab_floats]; n_blocks];
        for gi in 0..g {
            for j in 0..len {
                let src = (gi * tk + j) * dh;
                let dst = base + (gi * b + j % b) * dh;
                blocks[j / b][dst..dst + dh].copy_from_slice(&x[src..src + dh]);
            }
        }
        blocks
    }

    #[test]
    fn paged_matches_bucket_kernels_bitwise() {
        // one layer, h=2 K panels + h=2 V panels, block size 4: the paged
        // kernels over block slabs must reproduce the bucket kernels over
        // zero-padded contiguous caches bit-for-bit
        let (h, dh, b, len, tk, tq, q_offset) = (2usize, 4, 4, 6, 8, 2, 4);
        let q = fill(h * tq * dh, 20);
        let mut k = fill(h * tk * dh, 21);
        let mut v = fill(h * tk * dh, 22);
        // zero the padded rows like a real bucket cache
        for gi in 0..h {
            for j in len..tk {
                for d in 0..dh {
                    k[(gi * tk + j) * dh + d] = 0.0;
                    v[(gi * tk + j) * dh + d] = 0.0;
                }
            }
        }
        let slab_floats = 2 * h * b * dh; // K region then V region
        let (k_base, v_base) = (0usize, h * b * dh);
        let mut blocks = blocks_from_contiguous(&k, h, dh, b, k_base, slab_floats, len, tk);
        for (bi, vb) in blocks_from_contiguous(&v, h, dh, b, v_base, slab_floats, len, tk)
            .into_iter()
            .enumerate()
        {
            for (dst, src) in blocks[bi][v_base..].iter_mut().zip(&vb[v_base..]) {
                *dst = *src;
            }
        }
        let slabs: Vec<&[f32]> = blocks.iter().map(|x| x.as_slice()).collect();

        let (want, wprobs) = mha_attention(&q, &k, &v, h, tq, tk, dh, q_offset, len, None);
        let got =
            paged_mha_attention(&q, &slabs, k_base, v_base, h, tq, dh, b, q_offset, len);
        let bits = |x: &[f32]| x.iter().map(|e| e.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&want), bits(&got), "paged MHA must equal bucket MHA bitwise");

        // the AV bound to the unmasked prefix must be bit-identical to the
        // bucket AV over the full padded range (skipped terms are exact 0)
        let probs = paged_attention_scores(&q, &slabs, k_base, h, tq, dh, b, q_offset, len);
        let mut probs_padded = vec![0.0f32; h * tq * tk];
        for gi in 0..h {
            for qi in 0..tq {
                probs_padded[(gi * tq + qi) * tk..(gi * tq + qi) * tk + len]
                    .copy_from_slice(&probs[(gi * tq + qi) * len..(gi * tq + qi) * len + len]);
            }
        }
        assert_eq!(bits(&probs_padded), bits(&wprobs), "paged scores must match bucket scores");
        let av = paged_attn_av(&probs, &slabs, v_base, h, tq, dh, b, q_offset, len);
        assert_eq!(bits(&want), bits(&av), "bounded paged AV must equal bucket AV bitwise");

        // clustered: kc=1 rep panel broadcast to both heads
        let membership = vec![0usize, 0];
        let (cwant, _) = clustered_attention(
            &q[..tq * dh],
            &k[..tk * dh],
            &v,
            &membership,
            1,
            h,
            tq,
            tk,
            dh,
            q_offset,
            len,
        );
        let cgot = paged_clustered_attention(
            &q[..tq * dh],
            &slabs,
            k_base,
            v_base,
            &membership,
            1,
            h,
            tq,
            dh,
            b,
            q_offset,
            len,
        );
        assert_eq!(bits(&cwant), bits(&cgot), "paged CHAI must equal bucket CHAI bitwise");
    }

    #[test]
    fn relay_split_matches_fused_softmax() {
        // split keys [0, len) at a block boundary, run the two relay
        // phases, LSE-merge — must agree with the fused paged kernel to
        // 1e-5 (the split only reassociates the float accumulation)
        let (g, dh, b, len, split) = (3usize, 4, 4, 12, 8);
        let n = 4; // stacked decode queries, all at positions >= len-1
        let q = fill(g * n * dh, 30);
        let k = fill(g * len * dh, 31);
        let v = fill(g * len * dh, 32);
        let slab_floats = 2 * g * b * dh;
        let (k_base, v_base) = (0usize, g * b * dh);
        let mut blocks = blocks_from_contiguous(&k, g, dh, b, k_base, slab_floats, len, len);
        for (bi, vb) in blocks_from_contiguous(&v, g, dh, b, v_base, slab_floats, len, len)
            .into_iter()
            .enumerate()
        {
            blocks[bi][v_base..].copy_from_slice(&vb[v_base..]);
        }
        let slabs: Vec<&[f32]> = blocks.iter().map(|x| x.as_slice()).collect();

        // fused oracle: every query sees all len keys (q_offset high
        // enough that no causal masking applies)
        let fused = paged_mha_attention(&q, &slabs, k_base, v_base, g, n, dh, b, len - 1, len);

        // relay: prefix phase over [0, split), suffix over [split, len)
        let (ew_p, m_p, s_p) =
            paged_relay_scores(&q, &slabs[..split / b], k_base, g, n, dh, b, split);
        let o_p = paged_attn_av(&ew_p, &slabs[..split / b], v_base, g, n, dh, b, split - 1, split);
        let slen = len - split;
        let (ew_s, m_s, s_s) =
            paged_relay_scores(&q, &slabs[split / b..], k_base, g, n, dh, b, slen);
        let o_s = paged_attn_av(&ew_s, &slabs[split / b..], v_base, g, n, dh, b, slen - 1, slen);
        let mut merged = vec![0.0f32; g * n * dh];
        for gi in 0..g {
            for qi in 0..n {
                let r = gi * n + qi;
                let (lo, hi) = (r * dh, r * dh + dh);
                relay_merge(
                    &o_p[lo..hi],
                    m_p[r],
                    s_p[r],
                    &o_s[lo..hi],
                    m_s[r],
                    s_s[r],
                    &mut merged[lo..hi],
                );
            }
        }
        for (i, (a, b)) in fused.iter().zip(&merged).enumerate() {
            assert!((a - b).abs() <= 1e-5, "relay merge diverged at {i}: {a} vs {b}");
        }
    }

    #[test]
    fn relay_single_phase_is_plain_softmax_attention() {
        // degenerate merge (suffix covers everything, empty-weight prefix)
        // reduces to normalizing one phase — sanity for the partials
        let (g, dh, b, len) = (2usize, 4, 4, 8);
        let q = fill(g * dh, 33);
        let k = fill(g * len * dh, 34);
        let v = fill(g * len * dh, 35);
        let slab_floats = 2 * g * b * dh;
        let (k_base, v_base) = (0usize, g * b * dh);
        let mut blocks = blocks_from_contiguous(&k, g, dh, b, k_base, slab_floats, len, len);
        for (bi, vb) in blocks_from_contiguous(&v, g, dh, b, v_base, slab_floats, len, len)
            .into_iter()
            .enumerate()
        {
            blocks[bi][v_base..].copy_from_slice(&vb[v_base..]);
        }
        let slabs: Vec<&[f32]> = blocks.iter().map(|x| x.as_slice()).collect();
        let fused = paged_mha_attention(&q, &slabs, k_base, v_base, g, 1, dh, b, len - 1, len);
        let (ew, m, s) = paged_relay_scores(&q, &slabs, k_base, g, 1, dh, b, len);
        let o = paged_attn_av(&ew, &slabs, v_base, g, 1, dh, b, len - 1, len);
        let mut got = vec![0.0f32; g * dh];
        for gi in 0..g {
            let (lo, hi) = (gi * dh, gi * dh + dh);
            // empty prefix: m = -inf would poison exp, so fold via a
            // zero-weight partial at the same max
            let zero = vec![0.0f32; dh];
            relay_merge(&zero, m[gi], 0.0, &o[lo..hi], m[gi], s[gi], &mut got[lo..hi]);
        }
        for (a, b) in fused.iter().zip(&got) {
            assert!((a - b).abs() <= 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let d = 8;
        let x = vec![2.0f32; d];
        let w = vec![1.0f32; d];
        let out = rmsnorm(&x, &w, 1, d, 1e-5);
        for v in out {
            assert!((v - 1.0).abs() < 1e-3, "{v}");
        }
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let (g, t, dh) = (2, 1, 6);
        let x0 = fill(g * t * dh, 12);
        let mut x = x0.clone();
        rope(&mut x, &[0], g, t, dh, 10000.0);
        assert_eq!(x, x0);
    }

    #[test]
    fn rope_preserves_norm() {
        let (g, t, dh) = (1, 3, 8);
        let x0 = fill(g * t * dh, 13);
        let mut x = x0.clone();
        rope(&mut x, &[3, 4, 5], g, t, dh, 10000.0);
        for ti in 0..t {
            let n0: f32 = x0[ti * dh..(ti + 1) * dh].iter().map(|v| v * v).sum();
            let n1: f32 = x[ti * dh..(ti + 1) * dh].iter().map(|v| v * v).sum();
            assert!((n0 - n1).abs() < 1e-4, "t{ti}: {n0} vs {n1}");
        }
    }

    #[test]
    fn matmul_identity() {
        let a = fill(6, 14);
        let eye = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &eye, 2, 3, 3), a);
    }

    #[test]
    fn unheads_transposes() {
        // [h=2, t=2, dh=1]: rows h0t0,h0t1,h1t0,h1t1 -> t0:[h0,h1], t1:[h0,h1]
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(unheads(&x, 2, 2, 1), vec![1.0, 3.0, 2.0, 4.0]);
    }

    #[test]
    fn top_mask_keeps_largest() {
        let m = top_mask(&[0.5, 2.0, 1.0, -1.0], 2);
        assert_eq!(m, vec![false, true, true, false]);
        // ties: everything tied at the boundary stays
        let m = top_mask(&[1.0, 1.0, 0.0], 1);
        assert_eq!(m, vec![true, true, false]);
    }

    #[test]
    fn packed_matmul_matches_plain_bitwise() {
        let bits = |x: &[f32]| x.iter().map(|e| e.to_bits()).collect::<Vec<_>>();
        // odd shapes force a ragged trailing panel
        for &(m, kk, n) in &[(1usize, 16usize, 16usize), (7, 33, 129), (64, 48, 70)] {
            let a = fill(m * kk, 40);
            let b = fill(kk * n, 41);
            let plain = matmul(&a, &b, m, kk, n);
            let packed = matmul_packed(&a, &pack_b(&b, kk, n, PANEL), m);
            assert_eq!(bits(&plain), bits(&packed), "m={m} kk={kk} n={n}");
        }
    }

    #[test]
    fn packed_project_heads_matches_plain_bitwise() {
        let bits = |x: &[f32]| x.iter().map(|e| e.to_bits()).collect::<Vec<_>>();
        let (t, d, h, dh) = (5usize, 16usize, 4usize, 6usize);
        let xn = fill(t * d, 42);
        let w = fill(d * h * dh, 43);
        let wp = pack_b(&w, d, h * dh, dh);
        for heads in [vec![0, 1, 2, 3], vec![2, 0], vec![3]] {
            let plain = project_heads(&xn, &w, &heads, t, d, h, dh);
            let mut packed = vec![1.0f32; heads.len() * t * dh]; // non-zero: _into must overwrite
            project_heads_packed_into(&xn, &wp, &heads, t, d, h, dh, &mut packed);
            assert_eq!(bits(&plain), bits(&packed), "heads {heads:?}");
        }
    }

    #[test]
    fn packed_swiglu_matches_plain_bitwise() {
        let bits = |x: &[f32]| x.iter().map(|e| e.to_bits()).collect::<Vec<_>>();
        let (t, d, f) = (3usize, 16usize, 32usize);
        let x = fill(t * d, 44);
        let (wg, wu, wd) = (fill(d * f, 45), fill(d * f, 46), fill(f * d, 47));
        let plain = swiglu(&x, &wg, &wu, &wd, t, d, f);
        let (wgp, wup, wdp) =
            (pack_b(&wg, d, f, PANEL), pack_b(&wu, d, f, PANEL), pack_b(&wd, f, d, PANEL));
        let (mut gate, mut up, mut out) =
            (vec![1.0f32; t * f], vec![1.0f32; t * f], vec![1.0f32; t * d]);
        swiglu_packed_into(&x, &wgp, &wup, &wdp, t, d, f, &mut gate, &mut up, &mut out);
        assert_eq!(bits(&plain), bits(&out));
    }

    #[test]
    fn into_variants_overwrite_dirty_scratch() {
        // the arena hands back dirty buffers; every _into must fully
        // define its output
        let bits = |x: &[f32]| x.iter().map(|e| e.to_bits()).collect::<Vec<_>>();
        let (t, d) = (4usize, 8usize);
        let x = fill(t * d, 48);
        let w = fill(d, 49);
        let want = rmsnorm(&x, &w, t, d, 1e-5);
        let mut got = vec![7.0f32; t * d];
        rmsnorm_into(&x, &w, t, d, 1e-5, &mut got);
        assert_eq!(bits(&want), bits(&got));
        let b = fill(d * d, 50);
        let want = matmul(&x, &b, t, d, d);
        let mut got = vec![7.0f32; t * d];
        matmul_into(&x, &b, t, d, d, &mut got);
        assert_eq!(bits(&want), bits(&got));
        let want = unheads(&x, 2, 2, d);
        let mut got = vec![7.0f32; t * d];
        unheads_into(&x, 2, 2, d, &mut got);
        assert_eq!(bits(&want), bits(&got));
    }
}
