//! Persistent worker pool for intra-tick kernel parallelism.
//!
//! Hand-rolled in the repo style (no rayon/crossbeam): N-1 parked
//! worker threads plus the submitting engine thread cooperate on one
//! scoped job at a time. A job is a closure over borrowed slices and a
//! task count; [`Pool::run`] does not return until every task has
//! finished, which is what makes handing workers a lifetime-erased
//! borrow sound.
//!
//! Ownership contract: the [`Engine`](crate::engine::Engine) owns its
//! pool (`Arc<Pool>`, one per engine thread) and *installs* a `Weak`
//! alias into this thread's local slot. Kernels dispatch through the
//! module-level [`run`]/[`par_ranges`] helpers, which upgrade the alias
//! — when the engine (and its pool) is gone, or when the caller is
//! already inside a pool task (workers never install a pool; the
//! submitter sets a re-entrancy flag), the helpers degrade to the exact
//! serial loop. Dropping the pool parks nothing: `Drop` flags shutdown,
//! wakes every worker and joins them.
//!
//! Partitioning invariant (see DESIGN.md): tasks split only over
//! independent *output* slices — matmul row tiles and column panels,
//! head panels, paged (head, query) rows — never over a reduction
//! axis, so each output element is accumulated by one task in the same
//! scalar order at every pool size and the results are bitwise
//! identical to `--threads 1`.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::Instant;

/// One in-flight scoped job: a lifetime-erased borrow of the caller's
/// closure plus claim/drain cursors. The borrow is only dereferenced
/// between job post and `pending == 0`, and `Pool::run` blocks until
/// then, so the erased lifetime never outlives the real one.
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    n: usize,
    next: usize,
    pending: usize,
    panicked: bool,
}

struct Slot {
    job: Option<Job>,
    shutdown: bool,
}

struct Inner {
    slot: Mutex<Slot>,
    work: Condvar,
    done: Condvar,
    tasks: AtomicU64,
    busy_ns: AtomicU64,
}

pub struct Pool {
    inner: Arc<Inner>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl Pool {
    /// Pool with `threads` compute threads total: `threads - 1` parked
    /// workers plus the submitting thread, which participates in every
    /// job. `threads <= 1` spawns nothing and [`Pool::run`] is the
    /// plain serial loop. With `pin`, each worker pins itself to the
    /// next allowed core (Linux), round-robining the same cursor as the
    /// engine/reactor threads.
    pub fn new(threads: usize, pin: bool) -> Pool {
        let threads = threads.max(1);
        let inner = Arc::new(Inner {
            slot: Mutex::new(Slot { job: None, shutdown: false }),
            work: Condvar::new(),
            done: Condvar::new(),
            tasks: AtomicU64::new(0),
            busy_ns: AtomicU64::new(0),
        });
        let handles = (0..threads - 1)
            .map(|wi| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("chai-pool-{wi}"))
                    .spawn(move || worker(&inner, pin))
                    .expect("spawn pool worker")
            })
            .collect();
        Pool { inner, handles, threads }
    }

    /// Total compute threads (workers + the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// `(threads, tasks_completed, busy_ns)` — fed to the
    /// `pool_{workers,tasks,busy_ns}` gauges.
    pub fn stats(&self) -> (usize, u64, u64) {
        (
            self.threads,
            self.inner.tasks.load(Ordering::Relaxed),
            self.inner.busy_ns.load(Ordering::Relaxed),
        )
    }

    /// Run `f(0), f(1), …, f(n-1)` across the pool and the calling
    /// thread, returning once ALL tasks completed. Tasks must write
    /// disjoint data. Single submitter per pool (the owning engine
    /// thread); nested calls must go through the module-level [`run`],
    /// which degrades them to serial instead of deadlocking.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if self.threads <= 1 || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        // Erase the borrow's lifetime; run() blocks until pending == 0,
        // so no worker touches `f` after this frame unwinds.
        let w0 = crate::util::now_ms();
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        let mut slot = self.inner.slot.lock().unwrap();
        debug_assert!(slot.job.is_none(), "one scoped job at a time");
        slot.job = Some(Job { f: f_static, n, next: 0, pending: n, panicked: false });
        self.inner.work.notify_all();
        // participate: claim tasks alongside the workers
        loop {
            let i = match slot.job.as_mut() {
                Some(j) if j.next < j.n => {
                    let i = j.next;
                    j.next += 1;
                    i
                }
                _ => break,
            };
            drop(slot);
            let t0 = Instant::now();
            let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))).is_ok();
            self.inner.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            self.inner.tasks.fetch_add(1, Ordering::Relaxed);
            slot = self.inner.slot.lock().unwrap();
            let j = slot.job.as_mut().expect("job present while tasks pending");
            j.panicked |= !ok;
            j.pending -= 1;
            if j.pending == 0 {
                break;
            }
        }
        // drain: workers may still be running claimed tasks
        let panicked = loop {
            match &slot.job {
                Some(j) if j.pending > 0 => slot = self.inner.done.wait(slot).unwrap(),
                Some(j) => {
                    let p = j.panicked;
                    slot.job = None;
                    break p;
                }
                None => break false,
            }
        };
        drop(slot);
        // per-tick profiler: wall time of the parallel section, charged
        // to the submitting (engine) thread's phase accumulator. No ring
        // span — kernels post dozens of jobs per tick and per-job spans
        // would evict the request-level history.
        crate::obs::tick_phase_add(
            crate::obs::SpanKind::PoolTask,
            crate::util::now_ms() - w0,
        );
        if panicked {
            panic!("pool task panicked");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut slot = self.inner.slot.lock().unwrap();
            slot.shutdown = true;
        }
        self.inner.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker(inner: &Inner, pin: bool) {
    #[cfg(target_os = "linux")]
    if pin {
        let _ = crate::net::sys::pin_next_core();
    }
    #[cfg(not(target_os = "linux"))]
    let _ = pin;
    let mut slot = inner.slot.lock().unwrap();
    loop {
        if slot.shutdown {
            return;
        }
        let claim = match slot.job.as_mut() {
            Some(j) if j.next < j.n => {
                let i = j.next;
                j.next += 1;
                Some((j.f, i))
            }
            _ => None,
        };
        match claim {
            Some((f, i)) => {
                drop(slot);
                let t0 = Instant::now();
                let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))).is_ok();
                inner.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                inner.tasks.fetch_add(1, Ordering::Relaxed);
                slot = inner.slot.lock().unwrap();
                let j = slot.job.as_mut().expect("job present while tasks pending");
                j.panicked |= !ok;
                j.pending -= 1;
                if j.pending == 0 {
                    inner.done.notify_all();
                }
            }
            None => slot = inner.work.wait(slot).unwrap(),
        }
    }
}

// ---------------------------------------------------------------------------
// Thread-local dispatch (what the kernels call)
// ---------------------------------------------------------------------------

thread_local! {
    static CURRENT: RefCell<Weak<Pool>> = const { RefCell::new(Weak::new()) };
    static IN_JOB: Cell<bool> = const { Cell::new(false) };
}

/// Alias `pool` as this thread's kernel-dispatch pool (non-owning; the
/// caller keeps the `Arc` — the engine stores it so pool lifetime ==
/// engine lifetime).
pub fn install(pool: &Arc<Pool>) {
    CURRENT.with(|c| *c.borrow_mut() = Arc::downgrade(pool));
}

fn installed() -> Option<Arc<Pool>> {
    CURRENT.with(|c| c.borrow().upgrade())
}

/// Compute threads available to kernel dispatch on this thread (1 when
/// no pool is installed or when already inside a pool task).
pub fn threads() -> usize {
    if IN_JOB.with(|f| f.get()) {
        return 1;
    }
    installed().map(|p| p.threads()).unwrap_or(1)
}

/// Dispatch `n` tasks through this thread's installed pool, or run them
/// serially (no pool, pool of 1, or nested inside another task). Tasks
/// must write disjoint data; results are bitwise independent of the
/// pool size because task boundaries only partition output elements.
pub fn run(n: usize, f: impl Fn(usize) + Sync) {
    let pool = if IN_JOB.with(|g| g.get()) { None } else { installed() };
    match pool {
        Some(p) if p.threads() > 1 && n > 1 => {
            struct Reset;
            impl Drop for Reset {
                fn drop(&mut self) {
                    IN_JOB.with(|g| g.set(false));
                }
            }
            IN_JOB.with(|g| g.set(true));
            let _reset = Reset;
            p.run(n, &f);
        }
        _ => {
            for i in 0..n {
                f(i);
            }
        }
    }
}

/// Split `items` into contiguous ranges of at least `min_per_task`
/// items and run `f(start, end)` on each through the pool. The range
/// boundaries depend only on the pool size, never the data, and each
/// output element belongs to exactly one range.
pub fn par_ranges(items: usize, min_per_task: usize, f: impl Fn(usize, usize) + Sync) {
    if items == 0 {
        return;
    }
    let t = threads();
    let max_tasks = (items / min_per_task.max(1)).max(1);
    let tasks = max_tasks.min(t * 2).min(items);
    if tasks <= 1 {
        f(0, items);
        return;
    }
    let per = items.div_ceil(tasks);
    let tasks = items.div_ceil(per);
    run(tasks, |i| {
        let s = i * per;
        let e = (s + per).min(items);
        if s < e {
            f(s, e);
        }
    });
}

/// Raw mutable base pointer for scoped parallel writes into DISJOINT
/// regions of one output buffer (matmul tiles, head panels, per-row
/// attention outputs). Sound because [`Pool::run`] joins before
/// returning — the pointee outlives every task — and because callers
/// partition the buffer so no element is written by two tasks.
#[derive(Clone, Copy)]
pub struct SendPtr(*mut f32);

unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    pub fn new(s: &mut [f32]) -> SendPtr {
        SendPtr(s.as_mut_ptr())
    }

    /// # Safety
    /// `start..start + len` must be in bounds of the original slice and
    /// disjoint from every other task's range.
    pub unsafe fn slice(&self, start: usize, len: usize) -> &'static mut [f32] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }
}

// ---------------------------------------------------------------------------
// Sizing
// ---------------------------------------------------------------------------

/// CPUs this process may run on: the affinity/cgroup-aware mask on
/// Linux (see `net::sys::allowed_cpus`), `available_parallelism`
/// elsewhere. Never 0.
pub fn allowed_cpu_count() -> usize {
    #[cfg(target_os = "linux")]
    {
        crate::net::sys::allowed_cpus().len().max(1)
    }
    #[cfg(not(target_os = "linux"))]
    {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Pool size for an engine: `--threads N` wins, then the `CHAI_THREADS`
/// env override (how CI shakes races under `cargo test`, which has no
/// such flag), then the allowed-cpu mask divided across data-parallel
/// replicas so an N-replica fleet does not oversubscribe the box.
pub fn resolve_threads(requested: usize, replicas: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(s) = std::env::var("CHAI_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    (allowed_cpu_count() / replicas.max(1)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_task_exactly_once() {
        for threads in [1, 2, 3, 8] {
            let pool = Pool::new(threads, false);
            let n = 100;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}: every task exactly once"
            );
        }
    }

    #[test]
    fn scoped_tasks_write_borrowed_slices() {
        let pool = Pool::new(4, false);
        let mut out = vec![0.0f32; 64];
        let p = SendPtr::new(&mut out);
        pool.run(8, &|i| {
            let chunk = unsafe { p.slice(i * 8, 8) };
            for (j, e) in chunk.iter_mut().enumerate() {
                *e = (i * 8 + j) as f32;
            }
        });
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as f32));
    }

    #[test]
    fn back_to_back_jobs_reuse_workers() {
        let pool = Pool::new(3, false);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(7, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 350);
        let (t, tasks, _) = pool.stats();
        assert_eq!(t, 3);
        assert_eq!(tasks, 350);
    }

    #[test]
    fn panicking_task_propagates_without_hanging() {
        let pool = Pool::new(4, false);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(16, &|i| {
                if i == 11 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must propagate to the submitter");
        // the pool survives and accepts the next job
        let n = AtomicUsize::new(0);
        pool.run(4, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn thread_local_dispatch_degrades_serially() {
        // no pool installed: run() is the serial loop
        let hits = AtomicUsize::new(0);
        run(5, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
        // installed pool: parallel, and nested calls degrade to serial
        // instead of deadlocking on the single job slot
        let pool = Arc::new(Pool::new(4, false));
        install(&pool);
        let outer = AtomicUsize::new(0);
        run(8, |_| {
            run(8, |_| {
                outer.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(outer.load(Ordering::Relaxed), 64);
        drop(pool);
        // weak alias expired: back to serial
        assert_eq!(threads(), 1);
    }

    #[test]
    fn par_ranges_covers_exactly_once() {
        let pool = Arc::new(Pool::new(3, false));
        install(&pool);
        let n = 1001;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_ranges(n, 16, |s, e| {
            for h in &hits[s..e] {
                h.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn resolve_threads_precedence() {
        assert_eq!(resolve_threads(5, 1), 5);
        assert_eq!(resolve_threads(1, 8), 1);
        // auto divides the allowed mask across replicas, floor 1
        let auto = resolve_threads(0, usize::MAX);
        assert_eq!(auto, 1);
    }
}
