//! Compute backends: the [`Backend`] seam the engine drives, with two
//! implementations.
//!
//! * [`Runtime`] (alias [`XlaBackend`]) — PJRT runtime: load AOT HLO-text
//!   artifacts, compile once, execute with persistent device buffers.
//! * [`reference::RefBackend`] — pure-Rust interpreter with the exact
//!   masking/softmax/cluster-gather semantics of
//!   `python/compile/kernels/ref.py`; needs no artifacts (it can
//!   synthesize a seeded toy model), so the full serving stack is
//!   testable by `cargo test` on a fresh checkout.
//!
//! [`backend_for`] selects by [`crate::config::ServingConfig::backend`]
//! (`xla` | `ref` | `auto`); `auto` falls back to the reference backend
//! when no artifacts are present.
//!
//! PJRT design notes:
//! * HLO **text** is the interchange format (`HloModuleProto::from_text_file`
//!   reassigns instruction ids; serialized jax≥0.5 protos are rejected by
//!   xla_extension 0.5.1).
//! * Model weights are uploaded to device buffers **once** at startup and
//!   shared by every executable (the manifest fixes the argument order).
//! * The `xla` crate's client is `Rc`-based (not `Send`): the whole runtime
//!   lives on a single engine thread; the coordinator feeds it through
//!   channels (see `coordinator::EngineLoop`).
//! * jax lowers with `return_tuple=True`; depending on the PJRT build the
//!   result arrives either as one tuple buffer or already untupled —
//!   [`Executable::run`] normalizes both cases.

pub mod pool;
pub mod refkernels;
pub mod reference;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::config::{ArtifactSpec, Manifest, ServingConfig};
use crate::kv::paged::PagedKv;
use crate::tensor::{Data, Tensor};

/// Parsed per-layer cluster assignment for CHAI kernels:
/// `membership[l][h]` is head `h`'s cluster id in layer `l`,
/// `reps[l]` lists the representative head per cluster (slot order ==
/// cluster id == K panel order in the paged block layout).
#[derive(Debug, Clone)]
pub struct ClusterAssignment {
    pub membership: Vec<Vec<usize>>,
    pub reps: Vec<Vec<usize>>,
}

/// Relay-group membership of one decode row (see
/// `kv::paged::PagedKv::relay_groups`): rows of the same `group` share
/// the identical leading physical blocks covering positions
/// `[0, prefix_len)`, so the backend computes that span's attention ONCE
/// for the whole group (per rep panel for CHAI) and LSE-merges it with
/// each row's private-suffix phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RelayRef {
    /// group index within this `decode_paged` call
    pub group: usize,
    /// block-aligned shared-prefix length in token positions
    pub prefix_len: usize,
}

/// One row of a batched block-table-native decode call
/// ([`Backend::decode_paged`]): the next token of one live sequence.
/// The block table itself is resolved through the store by `seq`; rows
/// are ragged — every sequence brings its own length and, for CHAI, its
/// own cluster assignment.
pub struct PagedDecodeRow<'a> {
    /// sequence id in the paged store
    pub seq: u64,
    /// token whose K,V row this step appends (the previous sample)
    pub token: i32,
    /// absolute position of `token` (== the sequence's current length)
    pub pos: usize,
    /// CHAI membership/reps; `None` selects the dense MHA kernel
    pub clusters: Option<&'a ClusterAssignment>,
    /// shared-prefix relay descriptor; `None` decodes fully fused
    pub relay: Option<RelayRef>,
}

/// The compute seam between the engine and whatever executes the model
/// graphs. Implementations take the artifact-call contract of the AOT
/// manifest (`run("decode_mha_t32", inputs)` → outputs) so sessions,
/// paged gather/scatter, CHAI membership probing and admission behave
/// identically on every backend.
///
/// Backends with block-table-native kernels additionally implement the
/// `*_paged` entry points: they read K,V in place from the paged block
/// pool and append new rows directly, so the decode hot path performs
/// zero bucket-shaped gather/scatter copies. The reference backend
/// implements them; the XLA backend keeps the bucket artifacts until
/// paged artifacts are re-lowered (`python/compile/aot.py
/// --paged-artifacts` holds the lowering stubs).
pub trait Backend {
    /// Shape/bucket/cluster source of truth for this backend.
    fn manifest(&self) -> &Manifest;

    /// Execute one artifact by manifest name.
    fn run(&self, name: &str, extras: &[In]) -> Result<Vec<Out>>;

    /// Precompile/prepare artifacts (no-op where compilation is free).
    fn warmup(&self, _names: &[&str]) -> Result<()> {
        Ok(())
    }

    /// Whether this backend implements the block-table-native
    /// [`Self::decode_paged`] / [`Self::prefill_paged`] entry points.
    fn supports_paged(&self) -> bool {
        false
    }

    /// Batched block-table-native decode: advance every row by one
    /// token in a single call. For each row the backend computes the
    /// token's K,V, appends it into the sequence's tail block (made
    /// writable by the engine via `ensure_append_slot`), and attends
    /// over the block-resident cache in place. Rows are independent and
    /// ragged; the result is per-row (logits `[V]` or that row's error)
    /// in row order, so one bad session cannot fail its batchmates.
    fn decode_paged(&self, rows: &[PagedDecodeRow], _store: &mut PagedKv) -> Vec<Result<Tensor>> {
        rows.iter()
            .map(|_| {
                Err(anyhow::anyhow!(
                    "backend {:?} has no block-table decode kernels (re-lower paged \
                     artifacts or serve with --backend ref)",
                    self.name()
                ))
            })
            .collect()
    }

    /// Prefix-skipping block-native prefill: run the forward only for
    /// positions `[start, len)` of sequence `seq`'s prompt (tokens are
    /// read from its block table), writing suffix K,V rows into owned
    /// blocks and reading `[0, start)` from block-resident (adopted)
    /// rows. `start == len` computes logits-only for the last position
    /// without touching storage. Returns last-position logits `[V]`.
    fn prefill_paged(
        &self,
        _seq: u64,
        _start: usize,
        _clusters: Option<&ClusterAssignment>,
        _store: &mut PagedKv,
    ) -> Result<Tensor> {
        bail!(
            "backend {:?} has no block-table prefill kernels (re-lower paged artifacts \
             or serve with --backend ref)",
            self.name()
        )
    }

    /// Short identifier for logs/metrics ("xla" | "ref").
    fn name(&self) -> &'static str;
}

/// The AOT/PJRT implementation of [`Backend`].
pub type XlaBackend = Runtime;

impl Backend for Runtime {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&self, name: &str, extras: &[In]) -> Result<Vec<Out>> {
        Runtime::run(self, name, extras)
    }

    fn warmup(&self, names: &[&str]) -> Result<()> {
        Runtime::warmup(self, names)
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Resolve (and validate) which backend a serving config selects,
/// without constructing it: `auto` resolves by artifact presence, an
/// explicit `xla` without artifacts is an error. The single source of
/// truth for backend names — `backend_for` and `chai info` both use it.
pub fn resolve_backend(cfg: &ServingConfig) -> Result<&'static str> {
    let have_artifacts = cfg.artifacts_dir.join("manifest.json").exists();
    match cfg.backend.as_str() {
        "xla" if have_artifacts => Ok("xla"),
        "xla" => bail!(
            "backend xla needs artifacts at {} (run `make artifacts`, or use --backend ref)",
            cfg.artifacts_dir.display()
        ),
        "ref" => Ok("ref"),
        "auto" | "" => Ok(if have_artifacts { "xla" } else { "ref" }),
        other => bail!("unknown backend {other:?} (expected ref|xla|auto)"),
    }
}

/// Build the backend a serving config asks for. `auto` (the default)
/// uses the AOT/XLA path when `artifacts_dir` holds a manifest and
/// falls back to the pure-Rust reference backend (seeded toy model)
/// otherwise, so the stack always comes up.
pub fn backend_for(cfg: &ServingConfig) -> Result<Box<dyn Backend>> {
    match resolve_backend(cfg)? {
        "xla" => Ok(Box::new(Runtime::load(&cfg.artifacts_dir)?)),
        _ => {
            if !cfg.artifacts_dir.join("manifest.json").exists() {
                eprintln!(
                    "[runtime] no artifacts at {}; serving with the pure-rust \
                     reference backend (seeded toy model)",
                    cfg.artifacts_dir.display()
                );
            }
            Ok(Box::new(reference::RefBackend::load_or_toy(&cfg.artifacts_dir, cfg.seed)?))
        }
    }
}

/// Output of an execution: either still on device or already on host.
pub enum Out {
    Buf(xla::PjRtBuffer),
    Host(Tensor),
}

impl Out {
    pub fn to_tensor(&self) -> Result<Tensor> {
        match self {
            Out::Host(t) => Ok(t.clone()),
            Out::Buf(b) => literal_to_tensor(&b.to_literal_sync()?),
        }
    }
}

/// Input to an execution.
pub enum In<'a> {
    Host(&'a Tensor),
    Buf(&'a xla::PjRtBuffer),
}

pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

pub struct Runtime {
    pub manifest: Manifest,
    pub client: xla::PjRtClient,
    weights: Vec<xla::PjRtBuffer>,
    exes: RefCell<BTreeMap<String, Rc<Executable>>>,
    /// cumulative executions per artifact (metrics)
    pub exec_counts: RefCell<BTreeMap<String, u64>>,
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
    let lit = match &t.data {
        Data::F32(v) => xla::Literal::vec1(v),
        Data::I32(v) => xla::Literal::vec1(v),
    };
    Ok(lit.reshape(&dims)?)
}

pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(Tensor::f32(dims, lit.to_vec::<f32>()?)),
        xla::ElementType::S32 => Ok(Tensor::i32(dims, lit.to_vec::<i32>()?)),
        ty => bail!("unsupported element type {ty:?}"),
    }
}

impl Runtime {
    /// Load manifest + weights from an artifacts dir; upload weights.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let weights_file = crate::tensor::io::load(&dir.join("weights.cbt"))
            .context("loading weights.cbt")?;
        let mut weights = Vec::with_capacity(manifest.weight_order.len());
        for name in &manifest.weight_order {
            let t = weights_file
                .get(name)
                .with_context(|| format!("weight {name} missing from weights.cbt"))?;
            weights.push(upload(&client, t)?);
        }
        Ok(Runtime {
            manifest,
            client,
            weights,
            exes: RefCell::new(BTreeMap::new()),
            exec_counts: RefCell::new(BTreeMap::new()),
        })
    }

    /// Get (lazily compiling + caching) an executable by artifact name.
    pub fn executable(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        eprintln!(
            "[runtime] compiled {name} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        let e = Rc::new(Executable {
            spec,
            exe,
            client: self.client.clone(),
        });
        self.exes.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Execute an artifact: uploads host inputs, prepends the persistent
    /// weight buffers, returns per-output results.
    pub fn run(&self, name: &str, extras: &[In]) -> Result<Vec<Out>> {
        let exe = self.executable(name)?;
        *self
            .exec_counts
            .borrow_mut()
            .entry(name.to_string())
            .or_insert(0) += 1;
        exe.run_with_weights(&self.weights, extras)
    }

    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        upload(&self.client, t)
    }

    /// Precompile a set of artifacts (so first-request latency excludes
    /// XLA compilation).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }
}

pub fn upload(client: &xla::PjRtClient, t: &Tensor) -> Result<xla::PjRtBuffer> {
    let buf = match &t.data {
        Data::F32(v) => client.buffer_from_host_buffer(v, &t.shape, None)?,
        Data::I32(v) => client.buffer_from_host_buffer(v, &t.shape, None)?,
    };
    Ok(buf)
}

impl Executable {
    fn run_with_weights(&self, weights: &[xla::PjRtBuffer], extras: &[In]) -> Result<Vec<Out>> {
        if extras.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} runtime inputs ({:?}), got {}",
                self.spec.name,
                self.spec.inputs.len(),
                self.spec.inputs.iter().map(|i| i.name.as_str()).collect::<Vec<_>>(),
                extras.len()
            );
        }
        // Host inputs must be uploaded; keep them alive for the call.
        let uploaded: Vec<xla::PjRtBuffer> = extras
            .iter()
            .filter_map(|e| match e {
                In::Host(t) => Some(upload(&self.client, t)),
                In::Buf(_) => None,
            })
            .collect::<Result<_>>()?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(weights.len() + extras.len());
        args.extend(weights.iter());
        let mut up_iter = uploaded.iter();
        for e in extras {
            match e {
                In::Host(_) => args.push(up_iter.next().unwrap()),
                In::Buf(b) => args.push(b),
            }
        }
        let mut outputs = self.exe.execute_b(&args)?;
        let replica = outputs.swap_remove(0);
        let expected = self.spec.outputs.len();
        if replica.len() == 1 {
            // jax lowers with return_tuple=True: the result is one
            // tuple-typed buffer; decompose on the host.
            let is_tuple = matches!(replica[0].on_device_shape(), Ok(xla::Shape::Tuple(_)));
            if is_tuple {
                let mut lit = replica[0].to_literal_sync()?;
                let parts = lit.decompose_tuple()?;
                if parts.len() != expected {
                    bail!(
                        "{}: tuple arity {} != manifest outputs {}",
                        self.spec.name,
                        parts.len(),
                        expected
                    );
                }
                return parts
                    .iter()
                    .map(|l| Ok(Out::Host(literal_to_tensor(l)?)))
                    .collect();
            }
        }
        if replica.len() == expected {
            return Ok(replica.into_iter().map(Out::Buf).collect());
        }
        bail!(
            "{}: unexpected output count {} (manifest says {})",
            self.spec.name,
            replica.len(),
            expected
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let d = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn literal_tensor_roundtrip() {
        let t = Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
        let ti = Tensor::i32(vec![4], vec![1, -2, 3, -4]);
        let back = literal_to_tensor(&tensor_to_literal(&ti).unwrap()).unwrap();
        assert_eq!(back, ti);
    }

    #[test]
    fn loads_and_runs_probe() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load(&dir).unwrap();
        let m = &rt.manifest;
        let p = m.probe_bucket;
        let tokens = Tensor::i32(vec![p], (0..p as i32).map(|i| i % 250).collect());
        let length = Tensor::scalar_i32(p as i32);
        let outs = rt
            .run("probe_mha", &[In::Host(&tokens), In::Host(&length)])
            .unwrap();
        assert_eq!(outs.len(), 1);
        let maps = outs[0].to_tensor().unwrap();
        assert_eq!(
            maps.shape,
            vec![m.model.n_layers, m.model.n_heads, p, p]
        );
        // rows are causal probability distributions
        let v = maps.as_f32().unwrap();
        let row0: f32 = v[..p].iter().sum();
        assert!((row0 - 1.0).abs() < 1e-4, "row sum {row0}");
        assert_eq!(*rt.exec_counts.borrow().get("probe_mha").unwrap(), 1);
    }

    #[test]
    fn run_rejects_wrong_arity() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load(&dir).unwrap();
        let tokens = Tensor::i32(vec![8], vec![0; 8]);
        assert!(rt.run("probe_mha", &[In::Host(&tokens)]).is_err());
    }

    #[test]
    fn decode_roundtrip_through_buffers() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load(&dir).unwrap();
        let m = rt.manifest.clone();
        let t = 32usize;
        let (l, h, dh) = (m.model.n_layers, m.model.n_heads, m.model.head_dim);
        let kc = Tensor::zeros_f32(&[l, h, t, dh]);
        let vc = Tensor::zeros_f32(&[l, h, t, dh]);
        let tok = Tensor::scalar_i32(5);
        let pos = Tensor::scalar_i32(0);
        let outs = rt
            .run(
                "decode_mha_t32",
                &[In::Host(&tok), In::Host(&pos), In::Host(&kc), In::Host(&vc)],
            )
            .unwrap();
        assert_eq!(outs.len(), 3);
        let logits = outs[0].to_tensor().unwrap();
        assert_eq!(logits.shape, vec![m.model.vocab_size]);
        assert!(logits.as_f32().unwrap().iter().all(|x| x.is_finite()));
        // feed caches back as buffers for a second step if they are bufs
        if let (Out::Buf(kb), Out::Buf(vb)) = (&outs[1], &outs[2]) {
            let tok2 = Tensor::scalar_i32(7);
            let pos2 = Tensor::scalar_i32(1);
            let outs2 = rt
                .run(
                    "decode_mha_t32",
                    &[In::Host(&tok2), In::Host(&pos2), In::Buf(kb), In::Buf(vb)],
                )
                .unwrap();
            assert_eq!(outs2.len(), 3);
        }
    }
}
