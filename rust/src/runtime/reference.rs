//! `RefBackend` — the pure-Rust reference implementation of [`Backend`].
//!
//! Interprets every artifact family the engine calls (`probe_mha`,
//! `analyze`, `logprob_*`, `prefill_{mha,chai}_t*`, `decode_{mha,chai}_t*`)
//! over host [`Tensor`]s using the [`super::refkernels`] oracles, with the
//! exact masking/softmax/cluster-gather semantics of
//! `python/compile/kernels/ref.py` / `model.py`.
//!
//! Two ways to get one:
//!
//! * [`RefBackend::load`] — real trained weights: reads `manifest.json`,
//!   `weights.cbt` and `clusters.json` from an artifacts dir. No HLO
//!   files or XLA toolchain needed, so this doubles as the correctness
//!   oracle for the AOT path once artifacts exist.
//! * [`RefBackend::toy`] — no artifacts at all: synthesizes a small
//!   deterministic toy model (seeded xoshiro weights, head-group
//!   redundancy induced like `model.init_params`) plus a matching
//!   in-memory manifest. This is what un-gates the engine, coordinator
//!   and server integration tests on a fresh checkout.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::pool;
use super::refkernels as rk;
use super::{Backend, ClusterAssignment, In, Out, PagedDecodeRow};
use crate::config::{ArtifactSpec, Manifest, ModelConfig, TensorSpec};
use crate::kv::paged::{BlockId, PagedKv};
use crate::tensor::{io, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;

pub struct RefBackend {
    manifest: Manifest,
    /// `Arc`'d so data-parallel replicas (the router's N engines) share
    /// one physical copy of the model weights
    weights: std::sync::Arc<BTreeMap<String, Tensor>>,
    /// panel-major repacks of every projection matrix (see
    /// [`rk::pack_b`]), built once at load so the per-token matmuls
    /// stream their B operand contiguously; shared across replicas
    /// like `weights`
    packed: std::sync::Arc<BTreeMap<String, rk::PackedB>>,
    /// tick-lifetime scratch buffers for the forward walk (engine
    /// thread only — pool workers never touch the arena)
    scratch: RefCell<Scratch>,
    /// cumulative executions per artifact (parity with `Runtime`)
    pub exec_counts: RefCell<BTreeMap<String, u64>>,
}

/// A free-list of `Vec<f32>` scratch buffers. The forward walks
/// allocate the same handful of per-layer intermediates (`xn`, `q`,
/// `k_new`, `v_new`, attention output, MLP gate/up) every layer of
/// every tick; recycling them turns that steady-state allocator
/// traffic into two `Vec` pops. `take` zero-fills, so a recycled
/// buffer is indistinguishable from a fresh one (the `_into` kernels
/// additionally overwrite every element they produce).
#[derive(Default)]
struct Scratch {
    free: Vec<Vec<f32>>,
}

impl Scratch {
    fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    fn put(&mut self, v: Vec<f32>) {
        // a forward walk holds well under this many buffers at once;
        // the cap only guards against unbounded growth on odd paths
        if self.free.len() < 32 {
            self.free.push(v);
        }
    }
}

/// The shareable half of a [`RefBackend`]: manifest + `Arc`'d weights.
/// The router builds one of these and hands a clone to every replica's
/// engine thread, so N data-parallel replicas hold ONE copy of the
/// model while keeping their own execution state
/// ([`RefBackend::from_shared`] — the backend itself is not `Sync`, the
/// weights are).
#[derive(Clone)]
pub struct SharedRefModel {
    manifest: Manifest,
    weights: std::sync::Arc<BTreeMap<String, Tensor>>,
    packed: std::sync::Arc<BTreeMap<String, rk::PackedB>>,
}

impl SharedRefModel {
    /// Validate once (real weights when the dir holds a manifest, the
    /// seeded toy model otherwise) and wrap for sharing.
    pub fn load_or_toy(dir: &Path, seed: u64) -> Result<SharedRefModel> {
        let be = RefBackend::load_or_toy(dir, seed)?;
        Ok(SharedRefModel { manifest: be.manifest, weights: be.weights, packed: be.packed })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

// ---------------------------------------------------------------------------
// Construction
// ---------------------------------------------------------------------------

impl RefBackend {
    /// Real weights when the dir holds a manifest, toy model otherwise.
    pub fn load_or_toy(dir: &Path, seed: u64) -> Result<RefBackend> {
        if dir.join("manifest.json").exists() {
            Self::load(dir)
        } else {
            Ok(Self::toy(seed))
        }
    }

    /// Interpret the real artifact set: manifest + weights.cbt (the HLO
    /// files are not needed — that is the point).
    pub fn load(dir: &Path) -> Result<RefBackend> {
        let manifest = Manifest::load(dir)?;
        let weights = io::load(&dir.join("weights.cbt"))
            .context("ref backend needs weights.cbt next to manifest.json")?;
        Self::new(manifest, weights)
    }

    /// Deterministic toy model (no filesystem access).
    pub fn toy(seed: u64) -> RefBackend {
        Self::toy_custom(seed, vec![2, 3])
    }

    /// Toy model with an explicit per-layer cluster count. Tests use
    /// `k_list = [H; L]` for the singleton-cluster == MHA identity on
    /// the prefill/decode artifacts (which bake `k_list` statically).
    pub fn toy_custom(seed: u64, k_list: Vec<usize>) -> RefBackend {
        let model = toy_model_config(k_list.len());
        let weights = toy_weights(&model, &k_list, seed);
        let manifest = toy_manifest(model, k_list, weights.keys().cloned().collect());
        Self::new(manifest, weights).expect("toy backend is self-consistent")
    }

    fn new(manifest: Manifest, weights: BTreeMap<String, Tensor>) -> Result<RefBackend> {
        let m = &manifest.model;
        let hd = m.n_heads * m.head_dim;
        let mut expect = vec![
            ("emb".to_string(), vec![m.vocab_size, m.d_model]),
            ("final_norm".to_string(), vec![m.d_model]),
            ("lm_head".to_string(), vec![m.d_model, m.vocab_size]),
        ];
        for i in 0..m.n_layers {
            expect.push((format!("l{i}.attn_norm"), vec![m.d_model]));
            expect.push((format!("l{i}.wq"), vec![m.d_model, hd]));
            expect.push((format!("l{i}.wk"), vec![m.d_model, hd]));
            expect.push((format!("l{i}.wv"), vec![m.d_model, hd]));
            expect.push((format!("l{i}.wo"), vec![hd, m.d_model]));
            expect.push((format!("l{i}.mlp_norm"), vec![m.d_model]));
            expect.push((format!("l{i}.wg"), vec![m.d_model, m.d_ff]));
            expect.push((format!("l{i}.wu"), vec![m.d_model, m.d_ff]));
            expect.push((format!("l{i}.wd"), vec![m.d_ff, m.d_model]));
        }
        for (name, shape) in expect {
            let t = weights
                .get(&name)
                .ok_or_else(|| anyhow!("weight {name} missing"))?;
            if t.shape != shape {
                bail!("weight {name}: shape {:?}, expected {:?}", t.shape, shape);
            }
            t.as_f32().with_context(|| format!("weight {name} must be f32"))?;
        }
        if manifest.k_list.iter().any(|&k| k == 0 || k > m.n_heads) {
            bail!("manifest k_list {:?} invalid for H={}", manifest.k_list, m.n_heads);
        }
        let packed = pack_projection_weights(m, &weights)?;
        Ok(RefBackend {
            manifest,
            weights: std::sync::Arc::new(weights),
            packed: std::sync::Arc::new(packed),
            scratch: RefCell::new(Scratch::default()),
            exec_counts: RefCell::new(BTreeMap::new()),
        })
    }

    /// A replica backend over an already-validated shared model: clones
    /// the manifest, shares the weight storage, gets fresh exec counts.
    pub fn from_shared(model: &SharedRefModel) -> RefBackend {
        RefBackend {
            manifest: model.manifest.clone(),
            weights: model.weights.clone(),
            packed: model.packed.clone(),
            scratch: RefCell::new(Scratch::default()),
            exec_counts: RefCell::new(BTreeMap::new()),
        }
    }

    fn w(&self, name: &str) -> Result<&[f32]> {
        self.weights
            .get(name)
            .ok_or_else(|| anyhow!("weight {name} missing"))?
            .as_f32()
    }

    fn wp(&self, name: &str) -> Result<&rk::PackedB> {
        self.packed
            .get(name)
            .ok_or_else(|| anyhow!("packed panels for weight {name} missing"))
    }

    fn take(&self, len: usize) -> Vec<f32> {
        self.scratch.borrow_mut().take(len)
    }

    fn put(&self, buf: Vec<f32>) {
        self.scratch.borrow_mut().put(buf)
    }
}

/// Repack every matmul right-hand side once at weight load. Q/K/V pack
/// with one panel per head (`panel = head_dim`) so the per-head
/// projections stream each head's column block contiguously; the wide
/// matmuls (`wo`, MLP, `lm_head`) use the cache-blocked [`rk::PANEL`].
fn pack_projection_weights(
    m: &ModelConfig,
    weights: &BTreeMap<String, Tensor>,
) -> Result<BTreeMap<String, rk::PackedB>> {
    let w = |name: &str| -> Result<&[f32]> {
        weights.get(name).ok_or_else(|| anyhow!("weight {name} missing"))?.as_f32()
    };
    let (d, f, hd) = (m.d_model, m.d_ff, m.n_heads * m.head_dim);
    let mut packed = BTreeMap::new();
    packed.insert("lm_head".to_string(), rk::pack_b(w("lm_head")?, d, m.vocab_size, rk::PANEL));
    for i in 0..m.n_layers {
        for name in [format!("l{i}.wq"), format!("l{i}.wk"), format!("l{i}.wv")] {
            let p = rk::pack_b(w(&name)?, d, hd, m.head_dim);
            packed.insert(name, p);
        }
        for (name, kk, n) in [
            (format!("l{i}.wo"), hd, d),
            (format!("l{i}.wg"), d, f),
            (format!("l{i}.wu"), d, f),
            (format!("l{i}.wd"), f, d),
        ] {
            let p = rk::pack_b(w(&name)?, kk, n, rk::PANEL);
            packed.insert(name, p);
        }
    }
    Ok(packed)
}

impl Backend for RefBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&self, name: &str, extras: &[In]) -> Result<Vec<Out>> {
        *self.exec_counts.borrow_mut().entry(name.to_string()).or_insert(0) += 1;
        self.dispatch(name, extras)
            .with_context(|| format!("ref backend executing {name}"))
    }

    fn supports_paged(&self) -> bool {
        true
    }

    /// Batched ragged decode against block-resident K,V: every row
    /// appends its token's rows into its own (pre-CoW'd) tail block and
    /// attends in place — zero bucket-shaped copies. Rows without a
    /// relay descriptor are independent, so batching is a dispatch
    /// fusion, not a numeric change: their logits are bit-for-bit the
    /// single-row result, and one row's failure never poisons its
    /// batchmates. Rows the engine relay-grouped run the shared-prefix
    /// two-phase path instead ([`Self::relay_forward`]): exact softmax
    /// math, float association differs, logits within 1e-5 of fused.
    fn decode_paged(&self, rows: &[PagedDecodeRow], store: &mut PagedKv) -> Vec<Result<Tensor>> {
        *self
            .exec_counts
            .borrow_mut()
            .entry("decode_paged".to_string())
            .or_insert(0) += rows.len() as u64;
        let v = self.manifest.model.vocab_size;
        let mut out: Vec<Option<Result<Tensor>>> = (0..rows.len()).map(|_| None).collect();
        // validate every row up front; relay groups span valid rows only
        for (ri, r) in rows.iter().enumerate() {
            let len_now = match store.table(r.seq) {
                Some(t) => t.len,
                None => {
                    out[ri] = Some(Err(anyhow!("unknown paged sequence {}", r.seq)));
                    continue;
                }
            };
            if r.pos != len_now {
                out[ri] = Some(Err(anyhow!(
                    "decode row at position {} but sequence {} has length {len_now}",
                    r.pos,
                    r.seq
                )));
            }
        }
        let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (ri, r) in rows.iter().enumerate() {
            if out[ri].is_none() {
                if let Some(rl) = r.relay {
                    groups.entry(rl.group).or_default().push(ri);
                }
            }
        }
        for members in groups.into_values() {
            // degenerate or heterogeneous groups fall back to fused —
            // never a wrong answer, only a missed saving
            let lead = &rows[members[0]];
            let coherent = members.len() >= 2
                && members.iter().all(|&ri| {
                    rows[ri].relay.map(|rl| rl.prefix_len) == lead.relay.map(|rl| rl.prefix_len)
                        && match (lead.clusters, rows[ri].clusters) {
                            (None, None) => true,
                            (Some(a), Some(b)) => {
                                a.membership == b.membership && a.reps == b.reps
                            }
                            _ => false,
                        }
                });
            if !coherent {
                continue;
            }
            *self
                .exec_counts
                .borrow_mut()
                .entry("decode_relay_groups".to_string())
                .or_insert(0) += 1;
            let specs: Vec<(u64, i32, usize)> = members
                .iter()
                .map(|&ri| (rows[ri].seq, rows[ri].token, rows[ri].pos))
                .collect();
            let prefix_len = lead.relay.expect("grouped row has a descriptor").prefix_len;
            match self.relay_forward(store, &specs, prefix_len, lead.clusters) {
                Ok(per_row) => {
                    for (&ri, logits) in members.iter().zip(per_row) {
                        out[ri] = Some(Ok(Tensor::f32(vec![v], logits)));
                    }
                }
                Err(e) => {
                    let msg = format!("relay decode group failed: {e:#}");
                    for &ri in &members {
                        out[ri] = Some(Err(anyhow!("{msg}")));
                    }
                }
            }
        }
        // stack the remaining independent rows: cluster-coherent rows
        // fuse into one multi-row forward (bit-identical per row, the
        // attention fanned across the worker pool); a batch that fails
        // validation falls through to the per-row path below, which
        // also isolates whichever row was at fault
        let mut remaining: Vec<usize> = (0..rows.len()).filter(|&ri| out[ri].is_none()).collect();
        while !remaining.is_empty() {
            let lead = rows[remaining[0]].clusters;
            let (batch, rest): (Vec<usize>, Vec<usize>) =
                remaining.into_iter().partition(|&ri| match (lead, rows[ri].clusters) {
                    (None, None) => true,
                    (Some(a), Some(b)) => a.membership == b.membership && a.reps == b.reps,
                    _ => false,
                });
            remaining = rest;
            if batch.len() < 2 {
                continue;
            }
            let specs: Vec<(u64, i32, usize)> =
                batch.iter().map(|&ri| (rows[ri].seq, rows[ri].token, rows[ri].pos)).collect();
            if let Ok(per_row) = self.fused_forward(store, &specs, lead) {
                *self
                    .exec_counts
                    .borrow_mut()
                    .entry("decode_fused_groups".to_string())
                    .or_insert(0) += 1;
                for (&ri, logits) in batch.iter().zip(per_row) {
                    out[ri] = Some(Ok(Tensor::f32(vec![v], logits)));
                }
            }
        }
        for (ri, r) in rows.iter().enumerate() {
            if out[ri].is_some() {
                continue;
            }
            let res = self
                .paged_forward(store, r.seq, &[r.token], r.pos, r.pos + 1, r.clusters, true)
                .with_context(|| format!("paged decode of sequence {}", r.seq))
                .map(|logits| Tensor::f32(vec![v], logits));
            out[ri] = Some(res);
        }
        out.into_iter().map(|o| o.expect("every decode row resolved")).collect()
    }

    /// Prefix-skipping prefill: forward only positions `[start, len)`,
    /// reading the adopted prefix from block-resident rows. `start ==
    /// len` (whole prompt adopted) recomputes the last position's
    /// hidden state read-only, just for its logits.
    fn prefill_paged(
        &self,
        seq: u64,
        start: usize,
        clusters: Option<&ClusterAssignment>,
        store: &mut PagedKv,
    ) -> Result<Tensor> {
        *self
            .exec_counts
            .borrow_mut()
            .entry("prefill_paged".to_string())
            .or_insert(0) += 1;
        let (tokens, len) = {
            let t = store
                .table(seq)
                .ok_or_else(|| anyhow!("unknown paged sequence {seq}"))?;
            (t.tokens.clone(), t.len)
        };
        if len == 0 {
            bail!("paged prefill of an empty sequence {seq}");
        }
        if start > len {
            bail!("prefill start {start} beyond prompt length {len}");
        }
        let logits = if start == len {
            self.paged_forward(store, seq, &tokens[len - 1..], len - 1, len, clusters, false)?
        } else {
            self.paged_forward(store, seq, &tokens[start..], start, len, clusters, true)?
        };
        Ok(Tensor::f32(vec![self.manifest.model.vocab_size], logits))
    }

    fn name(&self) -> &'static str {
        "ref"
    }
}

// ---------------------------------------------------------------------------
// Input plumbing
// ---------------------------------------------------------------------------

fn hosts<'a>(extras: &'a [In]) -> Result<Vec<&'a Tensor>> {
    extras
        .iter()
        .map(|e| match e {
            In::Host(t) => Ok(*t),
            In::Buf(_) => bail!("reference backend accepts host tensors only"),
        })
        .collect()
}

fn arity(ins: &[&Tensor], n: usize, name: &str) -> Result<()> {
    if ins.len() != n {
        bail!("{name}: expected {n} runtime inputs, got {}", ins.len());
    }
    Ok(())
}

fn i32s<'a>(t: &'a Tensor, shape: &[usize], what: &str) -> Result<&'a [i32]> {
    if t.shape != shape {
        bail!("{what}: shape {:?}, expected {:?}", t.shape, shape);
    }
    t.as_i32().with_context(|| format!("{what} must be i32"))
}

fn f32s<'a>(t: &'a Tensor, shape: &[usize], what: &str) -> Result<&'a [f32]> {
    if t.shape != shape {
        bail!("{what}: shape {:?}, expected {:?}", t.shape, shape);
    }
    t.as_f32().with_context(|| format!("{what} must be f32"))
}

fn scalar(t: &Tensor, what: &str) -> Result<i32> {
    if t.len() != 1 {
        bail!("{what}: expected a scalar, got shape {:?}", t.shape);
    }
    Ok(t.as_i32().with_context(|| format!("{what} must be i32"))?[0])
}

/// membership [L, H] → per-layer head→cluster vectors, bounds-checked.
fn parse_membership(t: &Tensor, l: usize, h: usize, k_list: &[usize]) -> Result<Vec<Vec<usize>>> {
    let v = i32s(t, &[l, h], "membership")?;
    let mut out = Vec::with_capacity(l);
    for (li, &kl) in k_list.iter().enumerate() {
        let row: Vec<usize> = v[li * h..(li + 1) * h]
            .iter()
            .map(|&x| {
                let x = x.max(0) as usize;
                if x >= kl {
                    bail!("membership {x} >= k {kl} in layer {li}");
                }
                Ok(x)
            })
            .collect::<Result<_>>()?;
        out.push(row);
    }
    Ok(out)
}

/// Broadcast per-panel relay exp-weights `[kc, n, len]` to member heads
/// `[h, n, len]` — the relay analogue of the `probs_full` broadcast
/// inside `rk::paged_clustered_attention`.
fn broadcast_expw(ew: &[f32], membership: &[usize], h: usize, n: usize, len: usize) -> Vec<f32> {
    let mut full = vec![0.0f32; h * n * len];
    for (hh, &m) in membership.iter().enumerate() {
        full[hh * n * len..(hh + 1) * n * len]
            .copy_from_slice(&ew[m * n * len..(m + 1) * n * len]);
    }
    full
}

/// reps [L, k_cols] → per-layer representative-head lists of length
/// `k_list[l]` (extra columns are lowering padding, ignored).
fn parse_reps(t: &Tensor, l: usize, h: usize, k_list: &[usize]) -> Result<Vec<Vec<usize>>> {
    if t.shape.len() != 2 || t.shape[0] != l {
        bail!("reps: shape {:?}, expected [{l}, k_max]", t.shape);
    }
    let cols = t.shape[1];
    let v = t.as_i32().context("reps must be i32")?;
    let mut out = Vec::with_capacity(l);
    for (li, &kl) in k_list.iter().enumerate() {
        if kl > cols {
            bail!("reps: layer {li} needs {kl} entries, tensor has {cols}");
        }
        let row: Vec<usize> = v[li * cols..li * cols + kl]
            .iter()
            .map(|&x| {
                let x = x.max(0) as usize;
                if x >= h {
                    bail!("rep head {x} >= H {h} in layer {li}");
                }
                Ok(x)
            })
            .collect::<Result<_>>()?;
        out.push(row);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Graph interpreters
// ---------------------------------------------------------------------------

/// Per-layer weight handles + dims, resolved once per run.
struct Ctx<'a> {
    be: &'a RefBackend,
    l: usize,
    h: usize,
    dh: usize,
    d: usize,
    f: usize,
    v: usize,
    theta: f32,
    eps: f32,
}

/// What a dense layer pass should also return.
struct MhaCapture {
    k: Vec<f32>,
    v: Vec<f32>,
    probs: Vec<f32>,
}

impl<'a> Ctx<'a> {
    fn new(be: &'a RefBackend) -> Ctx<'a> {
        let m = &be.manifest.model;
        Ctx {
            be,
            l: m.n_layers,
            h: m.n_heads,
            dh: m.head_dim,
            d: m.d_model,
            f: m.d_ff,
            v: m.vocab_size,
            theta: m.rope_theta as f32,
            eps: m.rms_eps as f32,
        }
    }

    fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let emb = self.be.w("emb")?;
        let mut x = vec![0.0f32; tokens.len() * self.d];
        for (ti, &tok) in tokens.iter().enumerate() {
            // out-of-range ids clamp (jnp.take clips)
            let row = (tok.max(0) as usize).min(self.v - 1);
            x[ti * self.d..(ti + 1) * self.d]
                .copy_from_slice(&emb[row * self.d..(row + 1) * self.d]);
        }
        Ok(x)
    }

    fn unembed(&self, x: &[f32], t: usize) -> Result<Vec<f32>> {
        let mut xn = self.be.take(t * self.d);
        rk::rmsnorm_into(x, self.be.w("final_norm")?, t, self.d, self.eps, &mut xn);
        let logits = rk::matmul_packed(&xn, self.be.wp("lm_head")?, t);
        self.be.put(xn);
        Ok(logits)
    }

    fn residual_mlp(&self, x: &mut [f32], i: usize, t: usize) -> Result<()> {
        let be = self.be;
        let mut xn2 = be.take(t * self.d);
        rk::rmsnorm_into(x, be.w(&format!("l{i}.mlp_norm"))?, t, self.d, self.eps, &mut xn2);
        let mut gate = be.take(t * self.f);
        let mut up = be.take(t * self.f);
        let mut mlp = be.take(t * self.d);
        rk::swiglu_packed_into(
            &xn2,
            be.wp(&format!("l{i}.wg"))?,
            be.wp(&format!("l{i}.wu"))?,
            be.wp(&format!("l{i}.wd"))?,
            t,
            self.d,
            self.f,
            &mut gate,
            &mut up,
            &mut mlp,
        );
        for (xe, me) in x.iter_mut().zip(&mlp) {
            *xe += me;
        }
        be.put(xn2);
        be.put(gate);
        be.put(up);
        be.put(mlp);
        Ok(())
    }

    fn add_attn_out(&self, x: &mut [f32], i: usize, out: &[f32], g: usize, t: usize) -> Result<()> {
        debug_assert_eq!(g, self.h);
        let be = self.be;
        let mut heads = be.take(t * g * self.dh);
        rk::unheads_into(out, g, t, self.dh, &mut heads);
        let mut proj = be.take(t * self.d);
        rk::matmul_packed_into(&heads, be.wp(&format!("l{i}.wo"))?, t, &mut proj);
        for (xe, pe) in x.iter_mut().zip(&proj) {
            *xe += pe;
        }
        be.put(heads);
        be.put(proj);
        Ok(())
    }

    /// One dense-MHA decoder layer over the sequence itself (prefill /
    /// scoring / probe). `head_scale`/`key_mask` are the SpAtten hooks.
    #[allow(clippy::too_many_arguments)]
    fn mha_block(
        &self,
        x: &mut [f32],
        i: usize,
        t: usize,
        positions: &[usize],
        length: usize,
        key_mask: Option<&[f32]>,
        head_scale: Option<&[f32]>,
    ) -> Result<MhaCapture> {
        let (h, dh, d) = (self.h, self.dh, self.d);
        let xn = rk::rmsnorm(x, self.be.w(&format!("l{i}.attn_norm"))?, t, d, self.eps);
        let all: Vec<usize> = (0..h).collect();
        let mut q = rk::project_heads(&xn, self.be.w(&format!("l{i}.wq"))?, &all, t, d, h, dh);
        rk::rope(&mut q, positions, h, t, dh, self.theta);
        let mut k = rk::project_heads(&xn, self.be.w(&format!("l{i}.wk"))?, &all, t, d, h, dh);
        rk::rope(&mut k, positions, h, t, dh, self.theta);
        let v = rk::project_heads(&xn, self.be.w(&format!("l{i}.wv"))?, &all, t, d, h, dh);
        let (mut out, probs) = rk::mha_attention(&q, &k, &v, h, t, t, dh, 0, length, key_mask);
        if let Some(hs) = head_scale {
            for hh in 0..h {
                for e in &mut out[hh * t * dh..(hh + 1) * t * dh] {
                    *e *= hs[hh];
                }
            }
        }
        self.add_attn_out(x, i, &out, h, t)?;
        self.residual_mlp(x, i, t)?;
        Ok(MhaCapture { k, v, probs })
    }

    /// One clustered-head decoder layer (CHAI). Returns (k_rep, v).
    #[allow(clippy::too_many_arguments)]
    fn chai_block(
        &self,
        x: &mut [f32],
        i: usize,
        t: usize,
        positions: &[usize],
        length: usize,
        membership: &[usize],
        reps: &[usize],
        qkv: bool,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (h, dh, d) = (self.h, self.dh, self.d);
        let kc = reps.len();
        let xn = rk::rmsnorm(x, self.be.w(&format!("l{i}.attn_norm"))?, t, d, self.eps);
        let mut q_rep = rk::project_heads(&xn, self.be.w(&format!("l{i}.wq"))?, reps, t, d, h, dh);
        rk::rope(&mut q_rep, positions, kc, t, dh, self.theta);
        let mut k_rep = rk::project_heads(&xn, self.be.w(&format!("l{i}.wk"))?, reps, t, d, h, dh);
        rk::rope(&mut k_rep, positions, kc, t, dh, self.theta);
        let all: Vec<usize> = (0..h).collect();
        let v = rk::project_heads(&xn, self.be.w(&format!("l{i}.wv"))?, &all, t, d, h, dh);
        let (out, _) = if qkv {
            rk::clustered_attention_qkv(
                &q_rep, &k_rep, &v, membership, reps, kc, h, t, t, dh, 0, length,
            )
        } else {
            rk::clustered_attention(&q_rep, &k_rep, &v, membership, kc, h, t, t, dh, 0, length)
        };
        self.add_attn_out(x, i, &out, h, t)?;
        self.residual_mlp(x, i, t)?;
        Ok((k_rep, v))
    }
}

impl RefBackend {
    fn dispatch(&self, name: &str, extras: &[In]) -> Result<Vec<Out>> {
        // the manifest is the artifact namespace on every backend
        let spec = self.manifest.artifact(name)?;
        let ins = hosts(extras)?;
        let m = &self.manifest;
        match name {
            "probe_mha" => return self.run_probe(&ins, m.probe_bucket),
            "analyze" => return self.run_probe(&ins, m.analyze_bucket),
            "logprob_mha" => return self.run_logprob_mha(&ins),
            "logprob_spatten" => return self.run_logprob_spatten(&ins, spec),
            "logprob_chai" => return self.run_logprob_chai(&ins, &m.k_list, false),
            "logprob_chai_qkv" => return self.run_logprob_chai(&ins, &m.k_list, true),
            _ => {}
        }
        if let Some(kstr) = name.strip_prefix("logprob_chai_k") {
            let k: usize = kstr.parse().with_context(|| format!("bad artifact name {name}"))?;
            return self.run_logprob_chai(&ins, &vec![k; m.model.n_layers], false);
        }
        if name.starts_with("logprob_dejavu_s") {
            let n_keep = spec.meta.get("n_keep")?.usize()?;
            return self.run_logprob_dejavu(&ins, n_keep);
        }
        if let Some(tstr) = name.strip_prefix("prefill_mha_t") {
            return self.run_prefill_mha(&ins, tstr.parse()?);
        }
        if let Some(tstr) = name.strip_prefix("prefill_chai_t") {
            return self.run_prefill_chai(&ins, tstr.parse()?);
        }
        if let Some(tstr) = name.strip_prefix("decode_mha_t") {
            return self.run_decode_mha(&ins, tstr.parse()?);
        }
        if let Some(tstr) = name.strip_prefix("decode_chai_t") {
            return self.run_decode_chai(&ins, tstr.parse()?);
        }
        bail!("artifact {name:?} not implemented by the reference backend")
    }

    /// Dense probe: per-layer attention maps `[L, H, T, T]`.
    fn run_probe(&self, ins: &[&Tensor], t: usize) -> Result<Vec<Out>> {
        arity(ins, 2, "probe")?;
        let c = Ctx::new(self);
        let tokens = i32s(ins[0], &[t], "tokens")?;
        let length = (scalar(ins[1], "length")?.max(0) as usize).min(t);
        let positions: Vec<usize> = (0..t).collect();
        let mut x = c.embed(tokens)?;
        let mut maps = Vec::with_capacity(c.l * c.h * t * t);
        for i in 0..c.l {
            let cap = c.mha_block(&mut x, i, t, &positions, length, None, None)?;
            maps.extend_from_slice(&cap.probs);
        }
        Ok(vec![Out::Host(Tensor::f32(vec![c.l, c.h, t, t], maps))])
    }

    /// Full-sequence logits `[T, V]` (MHA scoring path).
    fn run_logprob_mha(&self, ins: &[&Tensor]) -> Result<Vec<Out>> {
        arity(ins, 2, "logprob_mha")?;
        let c = Ctx::new(self);
        let t = self.manifest.logprob_bucket;
        let tokens = i32s(ins[0], &[t], "tokens")?;
        let length = (scalar(ins[1], "length")?.max(0) as usize).min(t);
        let positions: Vec<usize> = (0..t).collect();
        let mut x = c.embed(tokens)?;
        for i in 0..c.l {
            c.mha_block(&mut x, i, t, &positions, length, None, None)?;
        }
        Ok(vec![Out::Host(Tensor::f32(vec![t, c.v], c.unembed(&x, t)?))])
    }

    /// CHAI scoring path (`qkv` = Table-4 ablation).
    fn run_logprob_chai(&self, ins: &[&Tensor], k_list: &[usize], qkv: bool) -> Result<Vec<Out>> {
        arity(ins, 4, "logprob_chai")?;
        let c = Ctx::new(self);
        let t = self.manifest.logprob_bucket;
        let tokens = i32s(ins[0], &[t], "tokens")?;
        let length = (scalar(ins[1], "length")?.max(0) as usize).min(t);
        let mem = parse_membership(ins[2], c.l, c.h, k_list)?;
        let reps = parse_reps(ins[3], c.l, c.h, k_list)?;
        let positions: Vec<usize> = (0..t).collect();
        let mut x = c.embed(tokens)?;
        for i in 0..c.l {
            c.chai_block(&mut x, i, t, &positions, length, &mem[i], &reps[i], qkv)?;
        }
        Ok(vec![Out::Host(Tensor::f32(vec![t, c.v], c.unembed(&x, t)?))])
    }

    /// DejaVu head sparsity: only `kept [L, n_keep]` heads are computed;
    /// pruned heads contribute zero to the output projection.
    fn run_logprob_dejavu(&self, ins: &[&Tensor], n_keep: usize) -> Result<Vec<Out>> {
        arity(ins, 3, "logprob_dejavu")?;
        let c = Ctx::new(self);
        let t = self.manifest.logprob_bucket;
        let tokens = i32s(ins[0], &[t], "tokens")?;
        let length = (scalar(ins[1], "length")?.max(0) as usize).min(t);
        let kept_t = i32s(ins[2], &[c.l, n_keep], "kept")?;
        let positions: Vec<usize> = (0..t).collect();
        let mut x = c.embed(tokens)?;
        for i in 0..c.l {
            let kept: Vec<usize> = kept_t[i * n_keep..(i + 1) * n_keep]
                .iter()
                .map(|&hh| {
                    let hh = hh.max(0) as usize;
                    if hh >= c.h {
                        bail!("kept head {hh} >= H {} in layer {i}", c.h);
                    }
                    Ok(hh)
                })
                .collect::<Result<_>>()?;
            let (h, dh, d) = (c.h, c.dh, c.d);
            let xn = rk::rmsnorm(&x, self.w(&format!("l{i}.attn_norm"))?, t, d, c.eps);
            let mut q = rk::project_heads(&xn, self.w(&format!("l{i}.wq"))?, &kept, t, d, h, dh);
            rk::rope(&mut q, &positions, kept.len(), t, dh, c.theta);
            let mut k = rk::project_heads(&xn, self.w(&format!("l{i}.wk"))?, &kept, t, d, h, dh);
            rk::rope(&mut k, &positions, kept.len(), t, dh, c.theta);
            let v = rk::project_heads(&xn, self.w(&format!("l{i}.wv"))?, &kept, t, d, h, dh);
            let (out, _) =
                rk::mha_attention(&q, &k, &v, kept.len(), t, t, dh, 0, length, None);
            // scatter kept-head outputs into the full head layout
            let mut full = vec![0.0f32; h * t * dh];
            for (gi, &hh) in kept.iter().enumerate() {
                full[hh * t * dh..(hh + 1) * t * dh]
                    .copy_from_slice(&out[gi * t * dh..(gi + 1) * t * dh]);
            }
            c.add_attn_out(&mut x, i, &full, h, t)?;
            c.residual_mlp(&mut x, i, t)?;
        }
        Ok(vec![Out::Host(Tensor::f32(vec![t, c.v], c.unembed(&x, t)?))])
    }

    /// SpAtten cascade token + head pruning (accuracy-only baseline);
    /// mirror of `logprob_spatten_graph` with the schedule read from the
    /// artifact's meta.
    fn run_logprob_spatten(&self, ins: &[&Tensor], spec: &ArtifactSpec) -> Result<Vec<Out>> {
        arity(ins, 2, "logprob_spatten")?;
        let c = Ctx::new(self);
        let t = self.manifest.logprob_bucket;
        let tokens = i32s(ins[0], &[t], "tokens")?;
        let length = (scalar(ins[1], "length")?.max(0) as usize).min(t);
        let token_keep = spec.meta.get("token_keep")?.f64_vec()?;
        let head_keep = spec.meta.get("head_keep")?.num()?;
        if token_keep.len() != c.l {
            bail!("token_keep length {} != n_layers {}", token_keep.len(), c.l);
        }
        let positions: Vec<usize> = (0..t).collect();
        let mut x = c.embed(tokens)?;
        let mut token_imp = vec![0.0f32; t];
        let mut head_imp = vec![0.0f32; c.h];
        let mut key_mask = vec![0.0f32; t];
        let mut head_scale = vec![1.0f32; c.h];
        for i in 0..c.l {
            let n_keep_tok = ((token_keep[i] * t as f64) as usize).max(1);
            if n_keep_tok < t {
                let keep = rk::top_mask(&token_imp, n_keep_tok);
                for (mk, kp) in key_mask.iter_mut().zip(&keep) {
                    *mk = if *kp { 0.0 } else { rk::NEG_INF };
                }
            }
            if i >= 2 && head_keep < 1.0 {
                let n_keep_h = ((head_keep * c.h as f64) as usize).max(1);
                let keep = rk::top_mask(&head_imp, n_keep_h);
                for (hs, kp) in head_scale.iter_mut().zip(&keep) {
                    *hs = if *kp { 1.0 } else { 0.0 };
                }
            }
            let cap = c.mha_block(
                &mut x,
                i,
                t,
                &positions,
                length,
                Some(&key_mask),
                Some(&head_scale),
            )?;
            // cumulative token importance: attention mass received
            for hh in 0..c.h {
                for qi in 0..t {
                    let row = &cap.probs[(hh * t + qi) * t..(hh * t + qi) * t + t];
                    for (ki, &p) in row.iter().enumerate() {
                        token_imp[ki] += p;
                    }
                }
            }
            // cumulative head importance: ‖A·V‖ per head (pre-gating)
            let av = rk::attn_av(&cap.probs, &cap.v, c.h, t, t, c.dh);
            for hh in 0..c.h {
                let s: f32 = av[hh * t * c.dh..(hh + 1) * t * c.dh]
                    .iter()
                    .map(|e| e * e)
                    .sum();
                head_imp[hh] += s.sqrt();
            }
        }
        Ok(vec![Out::Host(Tensor::f32(vec![t, c.v], c.unembed(&x, t)?))])
    }

    /// MHA prefill: (last-position logits [V], K cache [L,H,T,dh], V
    /// cache [L,H,T,dh]).
    fn run_prefill_mha(&self, ins: &[&Tensor], t: usize) -> Result<Vec<Out>> {
        arity(ins, 2, "prefill_mha")?;
        let c = Ctx::new(self);
        let tokens = i32s(ins[0], &[t], "tokens")?;
        let length = scalar(ins[1], "length")?.max(1) as usize;
        if length > t {
            bail!("length {length} exceeds bucket {t}");
        }
        let positions: Vec<usize> = (0..t).collect();
        let mut x = c.embed(tokens)?;
        let mut ks = Vec::with_capacity(c.l * c.h * t * c.dh);
        let mut vs = Vec::with_capacity(c.l * c.h * t * c.dh);
        for i in 0..c.l {
            let cap = c.mha_block(&mut x, i, t, &positions, length, None, None)?;
            ks.extend_from_slice(&cap.k);
            vs.extend_from_slice(&cap.v);
        }
        let last = &x[(length - 1) * c.d..length * c.d];
        let logits = c.unembed(last, 1)?;
        let shape = vec![c.l, c.h, t, c.dh];
        Ok(vec![
            Out::Host(Tensor::f32(vec![c.v], logits)),
            Out::Host(Tensor::f32(shape.clone(), ks)),
            Out::Host(Tensor::f32(shape, vs)),
        ])
    }

    /// CHAI prefill: (last logits [V], per-layer clustered K caches
    /// [k_l,T,dh], V cache [L,H,T,dh]).
    fn run_prefill_chai(&self, ins: &[&Tensor], t: usize) -> Result<Vec<Out>> {
        arity(ins, 4, "prefill_chai")?;
        let c = Ctx::new(self);
        let k_list = self.manifest.k_list.clone();
        let tokens = i32s(ins[0], &[t], "tokens")?;
        let length = scalar(ins[1], "length")?.max(1) as usize;
        if length > t {
            bail!("length {length} exceeds bucket {t}");
        }
        let mem = parse_membership(ins[2], c.l, c.h, &k_list)?;
        let reps = parse_reps(ins[3], c.l, c.h, &k_list)?;
        let positions: Vec<usize> = (0..t).collect();
        let mut x = c.embed(tokens)?;
        let mut kreps = Vec::with_capacity(c.l);
        let mut vs = Vec::with_capacity(c.l * c.h * t * c.dh);
        for i in 0..c.l {
            let (k_rep, v) =
                c.chai_block(&mut x, i, t, &positions, length, &mem[i], &reps[i], false)?;
            kreps.push(Tensor::f32(vec![k_list[i], t, c.dh], k_rep));
            vs.extend_from_slice(&v);
        }
        let last = &x[(length - 1) * c.d..length * c.d];
        let logits = c.unembed(last, 1)?;
        let mut outs = vec![Out::Host(Tensor::f32(vec![c.v], logits))];
        outs.extend(kreps.into_iter().map(Out::Host));
        outs.push(Out::Host(Tensor::f32(vec![c.l, c.h, t, c.dh], vs)));
        Ok(outs)
    }

    /// Single-token MHA decode over bucket-shaped caches (functional
    /// update at `pos`, exactly like the lowered graph).
    fn run_decode_mha(&self, ins: &[&Tensor], t: usize) -> Result<Vec<Out>> {
        arity(ins, 4, "decode_mha")?;
        let c = Ctx::new(self);
        let token = scalar(ins[0], "token")?;
        let pos = scalar(ins[1], "pos")?.max(0) as usize;
        if pos >= t {
            bail!("pos {pos} outside bucket {t}");
        }
        let shape = [c.l, c.h, t, c.dh];
        let mut kc = f32s(ins[2], &shape, "kcache")?.to_vec();
        let mut vc = f32s(ins[3], &shape, "vcache")?.to_vec();
        let positions = [pos];
        let length = pos + 1;
        let mut x = c.embed(&[token])?;
        let layer = c.h * t * c.dh;
        for i in 0..c.l {
            let (h, dh, d) = (c.h, c.dh, c.d);
            let xn = rk::rmsnorm(&x, self.w(&format!("l{i}.attn_norm"))?, 1, d, c.eps);
            let all: Vec<usize> = (0..h).collect();
            let mut q = rk::project_heads(&xn, self.w(&format!("l{i}.wq"))?, &all, 1, d, h, dh);
            rk::rope(&mut q, &positions, h, 1, dh, c.theta);
            let mut k_new = rk::project_heads(&xn, self.w(&format!("l{i}.wk"))?, &all, 1, d, h, dh);
            rk::rope(&mut k_new, &positions, h, 1, dh, c.theta);
            let v_new = rk::project_heads(&xn, self.w(&format!("l{i}.wv"))?, &all, 1, d, h, dh);
            for hh in 0..h {
                let dst = i * layer + (hh * t + pos) * dh;
                kc[dst..dst + dh].copy_from_slice(&k_new[hh * dh..(hh + 1) * dh]);
                vc[dst..dst + dh].copy_from_slice(&v_new[hh * dh..(hh + 1) * dh]);
            }
            let (out, _) = rk::mha_attention(
                &q,
                &kc[i * layer..(i + 1) * layer],
                &vc[i * layer..(i + 1) * layer],
                h,
                1,
                t,
                dh,
                pos,
                length,
                None,
            );
            c.add_attn_out(&mut x, i, &out, h, 1)?;
            c.residual_mlp(&mut x, i, 1)?;
        }
        let logits = c.unembed(&x, 1)?;
        Ok(vec![
            Out::Host(Tensor::f32(vec![c.v], logits)),
            Out::Host(Tensor::f32(shape.to_vec(), kc)),
            Out::Host(Tensor::f32(shape.to_vec(), vc)),
        ])
    }

    /// Single-token CHAI decode: clustered K caches per layer + dense V.
    fn run_decode_chai(&self, ins: &[&Tensor], t: usize) -> Result<Vec<Out>> {
        let c = Ctx::new(self);
        let k_list = self.manifest.k_list.clone();
        arity(ins, 2 + c.l + 3, "decode_chai")?;
        let token = scalar(ins[0], "token")?;
        let pos = scalar(ins[1], "pos")?.max(0) as usize;
        if pos >= t {
            bail!("pos {pos} outside bucket {t}");
        }
        let mut kreps: Vec<Vec<f32>> = Vec::with_capacity(c.l);
        for i in 0..c.l {
            kreps.push(
                f32s(ins[2 + i], &[k_list[i], t, c.dh], &format!("krep{i}"))?.to_vec(),
            );
        }
        let vshape = [c.l, c.h, t, c.dh];
        let mut vc = f32s(ins[2 + c.l], &vshape, "vcache")?.to_vec();
        let mem = parse_membership(ins[3 + c.l], c.l, c.h, &k_list)?;
        let reps = parse_reps(ins[4 + c.l], c.l, c.h, &k_list)?;
        let positions = [pos];
        let length = pos + 1;
        let mut x = c.embed(&[token])?;
        let layer = c.h * t * c.dh;
        for i in 0..c.l {
            let (h, dh, d) = (c.h, c.dh, c.d);
            let kl = k_list[i];
            let xn = rk::rmsnorm(&x, self.w(&format!("l{i}.attn_norm"))?, 1, d, c.eps);
            let mut q_rep =
                rk::project_heads(&xn, self.w(&format!("l{i}.wq"))?, &reps[i], 1, d, h, dh);
            rk::rope(&mut q_rep, &positions, kl, 1, dh, c.theta);
            let mut k_new =
                rk::project_heads(&xn, self.w(&format!("l{i}.wk"))?, &reps[i], 1, d, h, dh);
            rk::rope(&mut k_new, &positions, kl, 1, dh, c.theta);
            let all: Vec<usize> = (0..h).collect();
            let v_new = rk::project_heads(&xn, self.w(&format!("l{i}.wv"))?, &all, 1, d, h, dh);
            for r in 0..kl {
                let dst = (r * t + pos) * dh;
                kreps[i][dst..dst + dh].copy_from_slice(&k_new[r * dh..(r + 1) * dh]);
            }
            for hh in 0..h {
                let dst = i * layer + (hh * t + pos) * dh;
                vc[dst..dst + dh].copy_from_slice(&v_new[hh * dh..(hh + 1) * dh]);
            }
            let (out, _) = rk::clustered_attention(
                &q_rep,
                &kreps[i],
                &vc[i * layer..(i + 1) * layer],
                &mem[i],
                kl,
                h,
                1,
                t,
                dh,
                pos,
                length,
            );
            c.add_attn_out(&mut x, i, &out, h, 1)?;
            c.residual_mlp(&mut x, i, 1)?;
        }
        let logits = c.unembed(&x, 1)?;
        let mut outs = vec![Out::Host(Tensor::f32(vec![c.v], logits))];
        for (i, kr) in kreps.into_iter().enumerate() {
            outs.push(Out::Host(Tensor::f32(vec![k_list[i], t, c.dh], kr)));
        }
        outs.push(Out::Host(Tensor::f32(vshape.to_vec(), vc)));
        Ok(outs)
    }

    /// Block-table-native forward for positions `[p0, p0+tokens.len())`
    /// of paged sequence `seq`, with `len == p0 + tokens.len()` the
    /// total covered sequence length. Shared by `decode_paged` (tq = 1)
    /// and `prefill_paged` (the non-adopted prompt suffix).
    ///
    /// Per layer: project Q (and the new K,V rows) for the computed
    /// positions only, scatter the new rows straight into their blocks
    /// (skipping hash-bearing blocks — adopted/published content is
    /// identical by construction and must not be touched), then attend
    /// against the block-resident cache in place via the paged kernels.
    /// With `write_rows = false` nothing is written (logits-only pass
    /// over an already fully-resident sequence).
    ///
    /// Numerically bit-for-bit with the bucket artifacts: every op is
    /// row-independent except attention, and the paged kernels preserve
    /// the bucket kernels' accumulation order (see `refkernels`).
    fn paged_forward(
        &self,
        store: &mut PagedKv,
        seq: u64,
        tokens: &[i32],
        p0: usize,
        len: usize,
        clusters: Option<&ClusterAssignment>,
        write_rows: bool,
    ) -> Result<Vec<f32>> {
        let c = Ctx::new(self);
        let (layout, b, blocks) = {
            let t = store
                .table(seq)
                .ok_or_else(|| anyhow!("unknown paged sequence {seq}"))?;
            (t.layout.clone(), t.block_size, t.blocks.clone())
        };
        let tq = tokens.len();
        if tq == 0 || p0 + tq != len {
            bail!("paged forward spans [{p0}, {}) but len is {len}", p0 + tq);
        }
        if blocks.len() * b < len {
            bail!("block table covers {} positions, need {len}", blocks.len() * b);
        }
        if layout.n_layers != c.l || layout.n_heads != c.h || layout.head_dim != c.dh {
            bail!("table layout does not match the model: {layout:?}");
        }
        match clusters {
            Some(cl) => {
                for (i, r) in cl.reps.iter().enumerate() {
                    if r.len() != layout.k_heads[i] {
                        bail!(
                            "layer {i}: {} representatives for a {}-panel table",
                            r.len(),
                            layout.k_heads[i]
                        );
                    }
                }
            }
            None => {
                if layout.k_heads.iter().any(|&k| k != c.h) {
                    bail!("dense paged kernel on a clustered table");
                }
            }
        }
        let positions: Vec<usize> = (p0..len).collect();
        let all: Vec<usize> = (0..c.h).collect();
        let mut x = c.embed(tokens)?;
        for i in 0..c.l {
            let (h, dh, d) = (c.h, c.dh, c.d);
            let mut xn = self.take(tq * d);
            rk::rmsnorm_into(&x, self.w(&format!("l{i}.attn_norm"))?, tq, d, c.eps, &mut xn);
            let k_heads: &[usize] = match clusters {
                Some(cl) => &cl.reps[i],
                None => &all,
            };
            let gk = k_heads.len();
            let mut q = self.take(gk * tq * dh);
            rk::project_heads_packed_into(
                &xn,
                self.wp(&format!("l{i}.wq"))?,
                k_heads,
                tq,
                d,
                h,
                dh,
                &mut q,
            );
            rk::rope(&mut q, &positions, gk, tq, dh, c.theta);
            let mut k_new = self.take(gk * tq * dh);
            rk::project_heads_packed_into(
                &xn,
                self.wp(&format!("l{i}.wk"))?,
                k_heads,
                tq,
                d,
                h,
                dh,
                &mut k_new,
            );
            rk::rope(&mut k_new, &positions, gk, tq, dh, c.theta);
            let mut v_new = self.take(h * tq * dh);
            rk::project_heads_packed_into(
                &xn,
                self.wp(&format!("l{i}.wv"))?,
                &all,
                tq,
                d,
                h,
                dh,
                &mut v_new,
            );
            let k_base = layout.k_layer_offset(i, b);
            let v_base = layout.v_layer_offset(i, b);
            if write_rows {
                for qi in 0..tq {
                    let p = p0 + qi;
                    let bid = blocks[p / b];
                    if store.block_hash(bid).is_some() {
                        continue;
                    }
                    let off = p % b;
                    let slab = store.block_data_mut(bid);
                    for gi in 0..gk {
                        let dst = k_base + (gi * b + off) * dh;
                        slab[dst..dst + dh].copy_from_slice(
                            &k_new[(gi * tq + qi) * dh..(gi * tq + qi) * dh + dh],
                        );
                    }
                    for hh in 0..h {
                        let dst = v_base + (hh * b + off) * dh;
                        slab[dst..dst + dh].copy_from_slice(
                            &v_new[(hh * tq + qi) * dh..(hh * tq + qi) * dh + dh],
                        );
                    }
                }
            }
            let slabs: Vec<&[f32]> = blocks.iter().map(|&bid| store.block_data(bid)).collect();
            let out = match clusters {
                None => {
                    rk::paged_mha_attention(&q, &slabs, k_base, v_base, h, tq, dh, b, p0, len)
                }
                Some(cl) => rk::paged_clustered_attention(
                    &q,
                    &slabs,
                    k_base,
                    v_base,
                    &cl.membership[i],
                    gk,
                    h,
                    tq,
                    dh,
                    b,
                    p0,
                    len,
                ),
            };
            drop(slabs);
            c.add_attn_out(&mut x, i, &out, h, tq)?;
            c.residual_mlp(&mut x, i, tq)?;
            self.put(xn);
            self.put(q);
            self.put(k_new);
            self.put(v_new);
        }
        c.unembed(&x[(tq - 1) * c.d..], 1)
    }

    /// One relay group's decode step: the group's single-token rows run
    /// the forward stacked (`t = n`; every non-attention op is
    /// row-independent, so stacking is bit-neutral), and each layer's
    /// attention splits into two phases — the shared prefix `[0, S)`
    /// computed ONCE from the group's common blocks with all n queries
    /// in one pass per rep panel (the CHAI compounding: once per batch
    /// AND once per cluster), then each row's private suffix
    /// `[S, pos+1)` over its own tail blocks — merged by
    /// [`rk::relay_merge`] into the exact softmax-weighted output.
    ///
    /// Every row's new K,V rows are appended BEFORE any attention
    /// reads; tails are sole-owned post-CoW, so groupmates never
    /// observe each other's writes and cross-row write order is
    /// immaterial. Returns per-row logits in input order.
    fn relay_forward(
        &self,
        store: &mut PagedKv,
        rows: &[(u64, i32, usize)],
        prefix_len: usize,
        clusters: Option<&ClusterAssignment>,
    ) -> Result<Vec<Vec<f32>>> {
        let c = Ctx::new(self);
        let n = rows.len();
        let b = store.block_size;
        if n < 2 || prefix_len == 0 || prefix_len % b != 0 {
            bail!("malformed relay group: {n} rows, shared prefix {prefix_len} (block {b})");
        }
        let pb = prefix_len / b;
        let mut layout = None;
        let mut tables: Vec<Vec<BlockId>> = Vec::with_capacity(n);
        for &(seq, _tok, pos) in rows {
            let t = store
                .table(seq)
                .ok_or_else(|| anyhow!("unknown paged sequence {seq}"))?;
            if pos != t.len {
                bail!("relay row at position {pos} but sequence {seq} has length {}", t.len);
            }
            if prefix_len > t.len || t.blocks.len() * b < t.len + 1 {
                bail!("relay prefix {prefix_len} outside sequence {seq} (len {})", t.len);
            }
            match &layout {
                None => layout = Some(t.layout.clone()),
                Some(l) => {
                    if l.k_heads != t.layout.k_heads {
                        bail!("relay group mixes table layouts");
                    }
                }
            }
            tables.push(t.blocks.clone());
        }
        let layout = layout.expect("n >= 2");
        if layout.n_layers != c.l || layout.n_heads != c.h || layout.head_dim != c.dh {
            bail!("table layout does not match the model: {layout:?}");
        }
        match clusters {
            Some(cl) => {
                for (i, r) in cl.reps.iter().enumerate() {
                    if r.len() != layout.k_heads[i] {
                        bail!(
                            "layer {i}: {} representatives for a {}-panel table",
                            r.len(),
                            layout.k_heads[i]
                        );
                    }
                }
            }
            None => {
                if layout.k_heads.iter().any(|&k| k != c.h) {
                    bail!("dense paged kernel on a clustered table");
                }
            }
        }
        // the shared prefix must be the SAME physical blocks everywhere —
        // a member that CoW-forked off the chain would fail this, but the
        // engine regroups from live refcounts every tick, so a stale
        // grouping is an invariant violation, not an expected state
        let shared: Vec<BlockId> = tables[0][..pb].to_vec();
        for (ti, t) in tables.iter().enumerate() {
            if t[..pb] != shared[..] {
                bail!("relay group member {ti} does not hold the shared prefix blocks");
            }
        }
        let positions: Vec<usize> = rows.iter().map(|r| r.2).collect();
        let tokens: Vec<i32> = rows.iter().map(|r| r.1).collect();
        let all: Vec<usize> = (0..c.h).collect();
        let mut x = c.embed(&tokens)?;
        for i in 0..c.l {
            let (h, dh, d) = (c.h, c.dh, c.d);
            let mut xn = self.take(n * d);
            rk::rmsnorm_into(&x, self.w(&format!("l{i}.attn_norm"))?, n, d, c.eps, &mut xn);
            let k_heads: &[usize] = match clusters {
                Some(cl) => &cl.reps[i],
                None => &all,
            };
            let gk = k_heads.len();
            let mut q = self.take(gk * n * dh);
            rk::project_heads_packed_into(
                &xn,
                self.wp(&format!("l{i}.wq"))?,
                k_heads,
                n,
                d,
                h,
                dh,
                &mut q,
            );
            rk::rope(&mut q, &positions, gk, n, dh, c.theta);
            let mut k_new = self.take(gk * n * dh);
            rk::project_heads_packed_into(
                &xn,
                self.wp(&format!("l{i}.wk"))?,
                k_heads,
                n,
                d,
                h,
                dh,
                &mut k_new,
            );
            rk::rope(&mut k_new, &positions, gk, n, dh, c.theta);
            let mut v_new = self.take(h * n * dh);
            rk::project_heads_packed_into(
                &xn,
                self.wp(&format!("l{i}.wv"))?,
                &all,
                n,
                d,
                h,
                dh,
                &mut v_new,
            );
            let k_base = layout.k_layer_offset(i, b);
            let v_base = layout.v_layer_offset(i, b);
            for ri in 0..n {
                let p = positions[ri];
                let bid = tables[ri][p / b];
                if store.block_hash(bid).is_some() {
                    continue;
                }
                let off = p % b;
                let slab = store.block_data_mut(bid);
                for gi in 0..gk {
                    let dst = k_base + (gi * b + off) * dh;
                    slab[dst..dst + dh]
                        .copy_from_slice(&k_new[(gi * n + ri) * dh..(gi * n + ri) * dh + dh]);
                }
                for hh in 0..h {
                    let dst = v_base + (hh * b + off) * dh;
                    slab[dst..dst + dh]
                        .copy_from_slice(&v_new[(hh * n + ri) * dh..(hh * n + ri) * dh + dh]);
                }
            }
            // phase 1: shared prefix, one stacked-Q pass per rep panel
            let p0 = crate::util::now_ms();
            let pslabs: Vec<&[f32]> = shared.iter().map(|&bid| store.block_data(bid)).collect();
            let (ew_p, m_p, s_p) =
                rk::paged_relay_scores(&q, &pslabs, k_base, gk, n, dh, b, prefix_len);
            let ew_p_owned;
            let ew_p_h: &[f32] = match clusters {
                None => &ew_p,
                Some(cl) => {
                    ew_p_owned = broadcast_expw(&ew_p, &cl.membership[i], h, n, prefix_len);
                    &ew_p_owned
                }
            };
            let o_p = rk::paged_attn_av(
                ew_p_h,
                &pslabs,
                v_base,
                h,
                n,
                dh,
                b,
                prefix_len - 1,
                prefix_len,
            );
            drop(pslabs);
            let p1 = crate::util::now_ms();
            crate::obs::record(0, crate::obs::SpanKind::RelayP, p0, p1);
            crate::obs::tick_phase_add(crate::obs::SpanKind::RelayP, p1 - p0);
            // phase 2: per-row private suffix, then the LSE merge.
            // Rows are independent — each reads only its own tail
            // blocks and writes only its own `merged` rows — so they
            // fan out across the pool; every per-row computation is
            // the serial loop body verbatim, so the result is bitwise
            // invariant under the pool size.
            let mut merged = self.take(h * n * dh);
            {
                let mptr = pool::SendPtr::new(&mut merged);
                let store_ro: &PagedKv = store;
                let (q_ref, tables_ref, positions_ref) = (&q, &tables, &positions);
                let (o_p_ref, m_p_ref, s_p_ref) = (&o_p, &m_p, &s_p);
                let membership: Option<&[usize]> =
                    clusters.map(|cl| cl.membership[i].as_slice());
                pool::run(n, |ri| {
                    let slen = positions_ref[ri] + 1 - prefix_len;
                    let sslabs: Vec<&[f32]> = tables_ref[ri][pb..]
                        .iter()
                        .map(|&bid| store_ro.block_data(bid))
                        .collect();
                    let mut qr = vec![0.0f32; gk * dh];
                    for gi in 0..gk {
                        qr[gi * dh..(gi + 1) * dh]
                            .copy_from_slice(&q_ref[(gi * n + ri) * dh..(gi * n + ri) * dh + dh]);
                    }
                    let (ew_s, m_s, s_s) =
                        rk::paged_relay_scores(&qr, &sslabs, k_base, gk, 1, dh, b, slen);
                    let ew_s_owned;
                    let ew_s_h: &[f32] = match membership {
                        None => &ew_s,
                        Some(mem) => {
                            ew_s_owned = broadcast_expw(&ew_s, mem, h, 1, slen);
                            &ew_s_owned
                        }
                    };
                    let o_s =
                        rk::paged_attn_av(ew_s_h, &sslabs, v_base, h, 1, dh, b, slen - 1, slen);
                    for hh in 0..h {
                        let g = match membership {
                            Some(mem) => mem[hh],
                            None => hh,
                        };
                        let dst = (hh * n + ri) * dh;
                        let mrow = unsafe { mptr.slice(dst, dh) };
                        rk::relay_merge(
                            &o_p_ref[dst..dst + dh],
                            m_p_ref[g * n + ri],
                            s_p_ref[g * n + ri],
                            &o_s[hh * dh..(hh + 1) * dh],
                            m_s[g],
                            s_s[g],
                            mrow,
                        );
                    }
                });
            }
            let p2 = crate::util::now_ms();
            crate::obs::record(0, crate::obs::SpanKind::RelayS, p1, p2);
            crate::obs::tick_phase_add(crate::obs::SpanKind::RelayS, p2 - p1);
            c.add_attn_out(&mut x, i, &merged, h, n)?;
            c.residual_mlp(&mut x, i, n)?;
            self.put(xn);
            self.put(q);
            self.put(k_new);
            self.put(v_new);
            self.put(merged);
        }
        let logits = c.unembed(&x, n)?;
        Ok((0..n).map(|ri| logits[ri * c.v..(ri + 1) * c.v].to_vec()).collect())
    }

    /// Fused decode for independent (non-relay) rows that share a
    /// cluster assignment: the whole tick's single-token rows run the
    /// forward stacked (`t = n`) so the projection / MLP / unembed
    /// matmuls see one tall multiplicand instead of `n` degenerate
    /// one-row ones, and each layer's per-row attention — the only op
    /// that is NOT row-independent in shape — fans out across the
    /// worker pool, one task per row over that row's own block table.
    ///
    /// Every non-attention op is row-independent and each row's
    /// attention call is the single-row [`Self::paged_forward`] call
    /// verbatim (same slabs, same `tq = 1` kernel arguments), so the
    /// per-row logits are bit-for-bit the sequential result at every
    /// pool size, including `--threads 1`. Like the relay path, all
    /// K/V appends land in sole-owned post-CoW tail blocks before any
    /// attention reads, so cross-row write order is immaterial.
    fn fused_forward(
        &self,
        store: &mut PagedKv,
        rows: &[(u64, i32, usize)],
        clusters: Option<&ClusterAssignment>,
    ) -> Result<Vec<Vec<f32>>> {
        let c = Ctx::new(self);
        let n = rows.len();
        let b = store.block_size;
        if n < 2 {
            bail!("fused decode needs at least 2 rows, got {n}");
        }
        let mut layout = None;
        let mut tables: Vec<Vec<BlockId>> = Vec::with_capacity(n);
        for &(seq, _tok, pos) in rows {
            let t = store
                .table(seq)
                .ok_or_else(|| anyhow!("unknown paged sequence {seq}"))?;
            if pos != t.len {
                bail!("fused row at position {pos} but sequence {seq} has length {}", t.len);
            }
            if t.blocks.len() * b < t.len + 1 {
                bail!("block table of sequence {seq} has no room for position {pos}");
            }
            match &layout {
                None => layout = Some(t.layout.clone()),
                Some(l) => {
                    if l.k_heads != t.layout.k_heads {
                        bail!("fused decode batch mixes table layouts");
                    }
                }
            }
            tables.push(t.blocks.clone());
        }
        let layout = layout.expect("n >= 2");
        if layout.n_layers != c.l || layout.n_heads != c.h || layout.head_dim != c.dh {
            bail!("table layout does not match the model: {layout:?}");
        }
        match clusters {
            Some(cl) => {
                for (i, r) in cl.reps.iter().enumerate() {
                    if r.len() != layout.k_heads[i] {
                        bail!(
                            "layer {i}: {} representatives for a {}-panel table",
                            r.len(),
                            layout.k_heads[i]
                        );
                    }
                }
            }
            None => {
                if layout.k_heads.iter().any(|&k| k != c.h) {
                    bail!("dense paged kernel on a clustered table");
                }
            }
        }
        let positions: Vec<usize> = rows.iter().map(|r| r.2).collect();
        let tokens: Vec<i32> = rows.iter().map(|r| r.1).collect();
        let all: Vec<usize> = (0..c.h).collect();
        let mut x = c.embed(&tokens)?;
        for i in 0..c.l {
            let (h, dh, d) = (c.h, c.dh, c.d);
            let mut xn = self.take(n * d);
            rk::rmsnorm_into(&x, self.w(&format!("l{i}.attn_norm"))?, n, d, c.eps, &mut xn);
            let k_heads: &[usize] = match clusters {
                Some(cl) => &cl.reps[i],
                None => &all,
            };
            let gk = k_heads.len();
            let mut q = self.take(gk * n * dh);
            rk::project_heads_packed_into(
                &xn,
                self.wp(&format!("l{i}.wq"))?,
                k_heads,
                n,
                d,
                h,
                dh,
                &mut q,
            );
            rk::rope(&mut q, &positions, gk, n, dh, c.theta);
            let mut k_new = self.take(gk * n * dh);
            rk::project_heads_packed_into(
                &xn,
                self.wp(&format!("l{i}.wk"))?,
                k_heads,
                n,
                d,
                h,
                dh,
                &mut k_new,
            );
            rk::rope(&mut k_new, &positions, gk, n, dh, c.theta);
            let mut v_new = self.take(h * n * dh);
            rk::project_heads_packed_into(
                &xn,
                self.wp(&format!("l{i}.wv"))?,
                &all,
                n,
                d,
                h,
                dh,
                &mut v_new,
            );
            let k_base = layout.k_layer_offset(i, b);
            let v_base = layout.v_layer_offset(i, b);
            // append every row's new K,V before any attention reads
            for ri in 0..n {
                let p = positions[ri];
                let bid = tables[ri][p / b];
                if store.block_hash(bid).is_some() {
                    continue;
                }
                let off = p % b;
                let slab = store.block_data_mut(bid);
                for gi in 0..gk {
                    let dst = k_base + (gi * b + off) * dh;
                    slab[dst..dst + dh]
                        .copy_from_slice(&k_new[(gi * n + ri) * dh..(gi * n + ri) * dh + dh]);
                }
                for hh in 0..h {
                    let dst = v_base + (hh * b + off) * dh;
                    slab[dst..dst + dh]
                        .copy_from_slice(&v_new[(hh * n + ri) * dh..(hh * n + ri) * dh + dh]);
                }
            }
            // per-row attention, one pool task per row: each reads only
            // its own table's blocks and writes only its own rows of
            // `attn`, in exactly the single-row kernel call shape
            let mut attn = self.take(h * n * dh);
            {
                let aptr = pool::SendPtr::new(&mut attn);
                let store_ro: &PagedKv = store;
                let (q_ref, tables_ref, positions_ref) = (&q, &tables, &positions);
                let membership: Option<&[usize]> =
                    clusters.map(|cl| cl.membership[i].as_slice());
                pool::run(n, |ri| {
                    let pos = positions_ref[ri];
                    let len_r = pos + 1;
                    let slabs: Vec<&[f32]> = tables_ref[ri]
                        .iter()
                        .map(|&bid| store_ro.block_data(bid))
                        .collect();
                    let mut qr = vec![0.0f32; gk * dh];
                    for gi in 0..gk {
                        qr[gi * dh..(gi + 1) * dh]
                            .copy_from_slice(&q_ref[(gi * n + ri) * dh..(gi * n + ri) * dh + dh]);
                    }
                    let out_r = match membership {
                        None => rk::paged_mha_attention(
                            &qr, &slabs, k_base, v_base, h, 1, dh, b, pos, len_r,
                        ),
                        Some(mem) => rk::paged_clustered_attention(
                            &qr, &slabs, k_base, v_base, mem, gk, h, 1, dh, b, pos, len_r,
                        ),
                    };
                    for hh in 0..h {
                        let dst = unsafe { aptr.slice((hh * n + ri) * dh, dh) };
                        dst.copy_from_slice(&out_r[hh * dh..(hh + 1) * dh]);
                    }
                });
            }
            c.add_attn_out(&mut x, i, &attn, h, n)?;
            c.residual_mlp(&mut x, i, n)?;
            self.put(xn);
            self.put(q);
            self.put(k_new);
            self.put(v_new);
            self.put(attn);
        }
        let logits = c.unembed(&x, n)?;
        Ok((0..n).map(|ri| logits[ri * c.v..(ri + 1) * c.v].to_vec()).collect())
    }
}

// ---------------------------------------------------------------------------
// Toy model synthesis
// ---------------------------------------------------------------------------

/// Contiguous-block head→group assignment (mirror of
/// `model.head_group_of`).
fn head_group_of(h_idx: usize, n_heads: usize, n_groups: usize) -> usize {
    (h_idx * n_groups / n_heads).min(n_groups - 1)
}

fn toy_model_config(n_layers: usize) -> ModelConfig {
    let (v, d, h, dh, f) = (260usize, 16usize, 4usize, 4usize, 32usize);
    let hd = h * dh;
    let per_layer = 3 * d * hd + hd * d + 3 * d * f + 2 * d;
    ModelConfig {
        name: "toy-ref".into(),
        vocab_size: v,
        n_layers,
        n_heads: h,
        d_model: d,
        head_dim: dh,
        d_ff: f,
        max_seq: 64,
        n_params: v * d + n_layers * per_layer + d + d * v,
        rope_theta: 10000.0,
        rms_eps: 1e-5,
    }
}

/// `n` seeded normals at `scale` (He-style when `scale = 1/sqrt(fan_in)`).
fn normals(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

/// `[d, h*dh]` Q/K projection where same-group heads share a base matrix
/// plus small noise — the redundancy structure CHAI's clustering
/// exploits (mirror of `model.init_params`'s `grouped_qk`).
fn grouped_qk(rng: &mut Rng, d: usize, h: usize, dh: usize, groups: usize) -> Vec<f32> {
    let hd = h * dh;
    let scale = 1.0 / (d as f32).sqrt();
    let bases: Vec<Vec<f32>> = (0..groups).map(|_| normals(rng, d * dh, scale)).collect();
    let mut out = vec![0.0f32; d * hd];
    for hh in 0..h {
        let base = &bases[head_group_of(hh, h, groups)];
        for j in 0..d {
            for dd in 0..dh {
                let noise = rng.normal() as f32 * scale * 0.05;
                out[j * hd + hh * dh + dd] = base[j * dh + dd] + noise;
            }
        }
    }
    out
}

/// Seeded He-style init with the same head-group redundancy induction as
/// `model.init_params`, so the online k-means finds real structure.
fn toy_weights(m: &ModelConfig, k_list: &[usize], seed: u64) -> BTreeMap<String, Tensor> {
    let (v, d, h, dh, f) = (m.vocab_size, m.d_model, m.n_heads, m.head_dim, m.d_ff);
    let hd = h * dh;
    let mut rng = Rng::new(seed.wrapping_add(0x70f0_5eed));
    let mut w = BTreeMap::new();
    w.insert("emb".to_string(), Tensor::f32(vec![v, d], normals(&mut rng, v * d, 0.02)));
    w.insert("final_norm".to_string(), Tensor::f32(vec![d], vec![1.0; d]));
    w.insert(
        "lm_head".to_string(),
        Tensor::f32(vec![d, v], normals(&mut rng, d * v, 1.0 / (d as f32).sqrt())),
    );
    for (i, &kl) in k_list.iter().enumerate() {
        let groups = kl.clamp(1, h);
        let wq = grouped_qk(&mut rng, d, h, dh, groups);
        let wk = grouped_qk(&mut rng, d, h, dh, groups);
        w.insert(format!("l{i}.attn_norm"), Tensor::f32(vec![d], vec![1.0; d]));
        w.insert(format!("l{i}.wq"), Tensor::f32(vec![d, hd], wq));
        w.insert(format!("l{i}.wk"), Tensor::f32(vec![d, hd], wk));
        w.insert(
            format!("l{i}.wv"),
            Tensor::f32(vec![d, hd], normals(&mut rng, d * hd, 1.0 / (d as f32).sqrt())),
        );
        w.insert(
            format!("l{i}.wo"),
            Tensor::f32(vec![hd, d], normals(&mut rng, hd * d, 1.0 / (hd as f32).sqrt())),
        );
        w.insert(format!("l{i}.mlp_norm"), Tensor::f32(vec![d], vec![1.0; d]));
        w.insert(
            format!("l{i}.wg"),
            Tensor::f32(vec![d, f], normals(&mut rng, d * f, 1.0 / (d as f32).sqrt())),
        );
        w.insert(
            format!("l{i}.wu"),
            Tensor::f32(vec![d, f], normals(&mut rng, d * f, 1.0 / (d as f32).sqrt())),
        );
        w.insert(
            format!("l{i}.wd"),
            Tensor::f32(vec![f, d], normals(&mut rng, f * d, 1.0 / (f as f32).sqrt())),
        );
    }
    w
}

fn ts(name: &str, dtype: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.to_string(), dtype: dtype.to_string(), shape: shape.to_vec() }
}

fn spec(
    name: &str,
    inputs: Vec<TensorSpec>,
    outputs: Vec<TensorSpec>,
    meta: Vec<(&str, Json)>,
) -> ArtifactSpec {
    ArtifactSpec {
        name: name.to_string(),
        path: format!("<builtin:{name}>"),
        impl_name: "ref".to_string(),
        inputs,
        outputs,
        meta: Json::obj(meta),
    }
}

/// In-memory manifest for the toy model: same schema the AOT pipeline
/// writes, including per-artifact meta, so every manifest consumer
/// (engine, admission, benches, `info`) works unchanged.
fn toy_manifest(model: ModelConfig, k_list: Vec<usize>, weight_order: Vec<String>) -> Manifest {
    let (l, h, dh, v) = (model.n_layers, model.n_heads, model.head_dim, model.vocab_size);
    let k_max = k_list.iter().copied().max().unwrap_or(1);
    let probe_bucket = 8;
    let analyze_bucket = 32;
    let logprob_bucket = 64;
    let buckets = vec![32usize, 64];
    let dejavu_sparsities = vec![50usize];
    let uniform_k_sweep = vec![2usize, h];
    // token-keep schedule stretched over this depth (cascade: monotone
    // non-increasing), head_keep as in configs.SPATTEN_HEAD_KEEP
    let sched = [1.0f64, 0.625];
    let token_keep: Vec<f64> = (0..l).map(|i| sched[i.min(sched.len() - 1)]).collect();
    let head_keep = 0.75f64;
    let k_list_json = Json::from_usizes(&k_list);

    let mut artifacts: BTreeMap<String, ArtifactSpec> = BTreeMap::new();
    let mut add = |s: ArtifactSpec| {
        artifacts.insert(s.name.clone(), s);
    };
    add(spec(
        "probe_mha",
        vec![ts("tokens", "int32", &[probe_bucket]), ts("length", "int32", &[])],
        vec![ts("probe_maps", "float32", &[l, h, probe_bucket, probe_bucket])],
        vec![("bucket", Json::Num(probe_bucket as f64))],
    ));
    add(spec(
        "analyze",
        vec![ts("tokens", "int32", &[analyze_bucket]), ts("length", "int32", &[])],
        vec![ts("attn_maps", "float32", &[l, h, analyze_bucket, analyze_bucket])],
        vec![("bucket", Json::Num(analyze_bucket as f64))],
    ));
    let t = logprob_bucket;
    add(spec(
        "logprob_mha",
        vec![ts("tokens", "int32", &[t]), ts("length", "int32", &[])],
        vec![ts("logits", "float32", &[t, v])],
        vec![("bucket", Json::Num(t as f64))],
    ));
    for (name, qkv) in [("logprob_chai", false), ("logprob_chai_qkv", true)] {
        add(spec(
            name,
            vec![
                ts("tokens", "int32", &[t]),
                ts("length", "int32", &[]),
                ts("membership", "int32", &[l, h]),
                ts("reps", "int32", &[l, k_max]),
            ],
            vec![ts("logits", "float32", &[t, v])],
            vec![
                ("bucket", Json::Num(t as f64)),
                ("k_list", k_list_json.clone()),
                ("qkv", Json::Bool(qkv)),
            ],
        ));
    }
    for &k in &uniform_k_sweep {
        add(spec(
            &format!("logprob_chai_k{k}"),
            vec![
                ts("tokens", "int32", &[t]),
                ts("length", "int32", &[]),
                ts("membership", "int32", &[l, h]),
                ts("reps", "int32", &[l, k]),
            ],
            vec![ts("logits", "float32", &[t, v])],
            vec![
                ("bucket", Json::Num(t as f64)),
                ("k_list", Json::from_usizes(&vec![k; l])),
                ("uniform_k", Json::Num(k as f64)),
            ],
        ));
    }
    for &sp in &dejavu_sparsities {
        let n_keep = ((h * (100 - sp)) as f64 / 100.0).round().max(1.0) as usize;
        add(spec(
            &format!("logprob_dejavu_s{sp}"),
            vec![
                ts("tokens", "int32", &[t]),
                ts("length", "int32", &[]),
                ts("kept", "int32", &[l, n_keep]),
            ],
            vec![ts("logits", "float32", &[t, v])],
            vec![
                ("bucket", Json::Num(t as f64)),
                ("sparsity", Json::Num(sp as f64)),
                ("n_keep", Json::Num(n_keep as f64)),
            ],
        ));
    }
    add(spec(
        "logprob_spatten",
        vec![ts("tokens", "int32", &[t]), ts("length", "int32", &[])],
        vec![ts("logits", "float32", &[t, v])],
        vec![
            ("bucket", Json::Num(t as f64)),
            ("token_keep", Json::from_f64s(&token_keep)),
            ("head_keep", Json::Num(head_keep)),
        ],
    ));
    for &t in &buckets {
        let cache = [l, h, t, dh];
        add(spec(
            &format!("prefill_mha_t{t}"),
            vec![ts("tokens", "int32", &[t]), ts("length", "int32", &[])],
            vec![
                ts("logits", "float32", &[v]),
                ts("kcache", "float32", &cache),
                ts("vcache", "float32", &cache),
            ],
            vec![("bucket", Json::Num(t as f64))],
        ));
        let mut chai_outs = vec![ts("logits", "float32", &[v])];
        for (i, &kl) in k_list.iter().enumerate() {
            chai_outs.push(ts(&format!("krep{i}"), "float32", &[kl, t, dh]));
        }
        chai_outs.push(ts("vcache", "float32", &cache));
        add(spec(
            &format!("prefill_chai_t{t}"),
            vec![
                ts("tokens", "int32", &[t]),
                ts("length", "int32", &[]),
                ts("membership", "int32", &[l, h]),
                ts("reps", "int32", &[l, k_max]),
            ],
            chai_outs.clone(),
            vec![("bucket", Json::Num(t as f64)), ("k_list", k_list_json.clone())],
        ));
        add(spec(
            &format!("decode_mha_t{t}"),
            vec![
                ts("token", "int32", &[]),
                ts("pos", "int32", &[]),
                ts("kcache", "float32", &cache),
                ts("vcache", "float32", &cache),
            ],
            vec![
                ts("logits", "float32", &[v]),
                ts("kcache", "float32", &cache),
                ts("vcache", "float32", &cache),
            ],
            vec![("bucket", Json::Num(t as f64))],
        ));
        let mut chai_ins = vec![ts("token", "int32", &[]), ts("pos", "int32", &[])];
        for (i, &kl) in k_list.iter().enumerate() {
            chai_ins.push(ts(&format!("krep{i}"), "float32", &[kl, t, dh]));
        }
        chai_ins.push(ts("vcache", "float32", &cache));
        chai_ins.push(ts("membership", "int32", &[l, h]));
        chai_ins.push(ts("reps", "int32", &[l, k_max]));
        add(spec(
            &format!("decode_chai_t{t}"),
            chai_ins,
            chai_outs,
            vec![("bucket", Json::Num(t as f64)), ("k_list", k_list_json.clone())],
        ));
    }

    // offline clusters: contiguous head blocks per layer, reps = first
    // head of each block (canonical: sorted)
    let mut membership = Vec::with_capacity(l);
    let mut reps = Vec::with_capacity(l);
    for &kl in &k_list {
        let mem: Vec<usize> = (0..h).map(|hh| head_group_of(hh, h, kl)).collect();
        let rep: Vec<usize> = (0..kl).map(|g| mem.iter().position(|&x| x == g).unwrap()).collect();
        membership.push(mem);
        reps.push(rep);
    }

    Manifest {
        dir: PathBuf::from("<toy>"),
        model,
        weight_order,
        artifacts,
        probe_tokens: 5,
        probe_bucket,
        analyze_bucket,
        logprob_bucket,
        prefill_buckets: buckets.clone(),
        decode_buckets: buckets,
        dejavu_sparsities,
        uniform_k_sweep,
        k_max,
        k_list,
        attn_impl: "ref".to_string(),
        clusters: Some((membership, reps)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toy_backend_is_deterministic() {
        let a = RefBackend::toy(3);
        let b = RefBackend::toy(3);
        let m = a.manifest.clone();
        let tokens = Tensor::i32(vec![m.probe_bucket], vec![65, 66, 67, 68, 69, 258, 258, 258]);
        let len = Tensor::scalar_i32(5);
        let oa = a.run("probe_mha", &[In::Host(&tokens), In::Host(&len)]).unwrap();
        let ob = b.run("probe_mha", &[In::Host(&tokens), In::Host(&len)]).unwrap();
        assert_eq!(oa[0].to_tensor().unwrap(), ob[0].to_tensor().unwrap());
        // different seeds give different weights
        let c = RefBackend::toy(4);
        let oc = c.run("probe_mha", &[In::Host(&tokens), In::Host(&len)]).unwrap();
        assert_ne!(oa[0].to_tensor().unwrap(), oc[0].to_tensor().unwrap());
    }

    #[test]
    fn probe_rows_are_causal_distributions() {
        let be = RefBackend::toy(0);
        let m = be.manifest.clone();
        let p = m.probe_bucket;
        let tokens = Tensor::i32(vec![p], (0..p as i32).map(|i| i % 250).collect());
        let len = Tensor::scalar_i32(p as i32);
        let outs = be.run("probe_mha", &[In::Host(&tokens), In::Host(&len)]).unwrap();
        let maps = outs[0].to_tensor().unwrap();
        assert_eq!(maps.shape, vec![m.model.n_layers, m.model.n_heads, p, p]);
        let v = maps.as_f32().unwrap();
        for row in v.chunks(p) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row sum {s}");
        }
        // causal: first row attends only to position 0
        assert!((v[0] - 1.0).abs() < 1e-5);
        assert_eq!(*be.exec_counts.borrow().get("probe_mha").unwrap(), 1);
    }

    #[test]
    fn run_rejects_wrong_arity_and_unknown_artifacts() {
        let be = RefBackend::toy(0);
        let tokens = Tensor::i32(vec![8], vec![0; 8]);
        assert!(be.run("probe_mha", &[In::Host(&tokens)]).is_err());
        assert!(be.run("decode_mha_t9999", &[]).is_err());
    }

    #[test]
    fn logprob_logits_are_finite() {
        let be = RefBackend::toy(1);
        let m = be.manifest.clone();
        let t = m.logprob_bucket;
        let mut toks = vec![258i32; t];
        for (i, b) in "the color of tom is".bytes().enumerate() {
            toks[i] = b as i32;
        }
        let tokens = Tensor::i32(vec![t], toks);
        let len = Tensor::scalar_i32(19);
        let outs = be.run("logprob_mha", &[In::Host(&tokens), In::Host(&len)]).unwrap();
        let lg = outs[0].to_tensor().unwrap();
        assert_eq!(lg.shape, vec![t, m.model.vocab_size]);
        assert!(lg.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_updates_cache_functionally() {
        let be = RefBackend::toy(2);
        let m = be.manifest.clone();
        let (l, h, dh) = (m.model.n_layers, m.model.n_heads, m.model.head_dim);
        let t = m.decode_buckets[0];
        let kc = Tensor::zeros_f32(&[l, h, t, dh]);
        let vc = Tensor::zeros_f32(&[l, h, t, dh]);
        let outs = be
            .run(
                &format!("decode_mha_t{t}"),
                &[
                    In::Host(&Tensor::scalar_i32(5)),
                    In::Host(&Tensor::scalar_i32(0)),
                    In::Host(&kc),
                    In::Host(&vc),
                ],
            )
            .unwrap();
        assert_eq!(outs.len(), 3);
        let logits = outs[0].to_tensor().unwrap();
        assert_eq!(logits.shape, vec![m.model.vocab_size]);
        assert!(logits.as_f32().unwrap().iter().all(|x| x.is_finite()));
        let kc2 = outs[1].to_tensor().unwrap();
        // row 0 written, inputs untouched
        assert!(kc2.as_f32().unwrap().iter().any(|&x| x != 0.0));
        assert!(kc.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    fn bits(t: &Tensor) -> Vec<u32> {
        t.as_f32().unwrap().iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn paged_prefill_and_decode_match_bucket_artifacts_bitwise() {
        use crate::kv::paged::KvLayout;
        use crate::kv::CacheKind;
        let be = RefBackend::toy(5);
        let m = be.manifest().clone();
        let t = m.decode_buckets[0];
        let (l_n, h_n, dh) = (m.model.n_layers, m.model.n_heads, m.model.head_dim);
        let layout = KvLayout::from_manifest(&m, CacheKind::Mha);
        let mut kv = PagedKv::new(4, 1 << 24);
        let tokens: Vec<i32> = vec![65, 101, 109, 32, 99, 111];
        let n = tokens.len();
        kv.admit(1, layout, "mha", true, &tokens).unwrap();

        // bucket path: padded prefill artifact
        let mut padded = vec![258i32; t];
        padded[..n].copy_from_slice(&tokens);
        let toks = Tensor::i32(vec![t], padded);
        let ln = Tensor::scalar_i32(n as i32);
        let outs = be
            .run(&format!("prefill_mha_t{t}"), &[In::Host(&toks), In::Host(&ln)])
            .unwrap();
        let want = outs[0].to_tensor().unwrap();

        // block-native path writes straight into the blocks
        let got = be.prefill_paged(1, 0, None, &mut kv).unwrap();
        assert_eq!(bits(&want), bits(&got), "paged prefill logits");
        kv.commit_prefill(1).unwrap();

        // block-resident K,V rows equal the bucket caches for real rows
        let kc = outs[1].to_tensor().unwrap();
        let vc = outs[2].to_tensor().unwrap();
        let (gk, gv) = kv.gather_mha(1, t).unwrap();
        let (kf, vf) = (kc.as_f32().unwrap(), vc.as_f32().unwrap());
        let (gkf, gvf) = (gk.as_f32().unwrap(), gv.as_f32().unwrap());
        for li in 0..l_n {
            for hh in 0..h_n {
                for p in 0..n {
                    for d in 0..dh {
                        let o = ((li * h_n + hh) * t + p) * dh + d;
                        assert_eq!(kf[o].to_bits(), gkf[o].to_bits(), "K l{li} h{hh} p{p}");
                        assert_eq!(vf[o].to_bits(), gvf[o].to_bits(), "V l{li} h{hh} p{p}");
                    }
                }
            }
        }

        // one decode step: bucket artifact vs block-native row
        let tok = 107i32;
        let douts = be
            .run(
                &format!("decode_mha_t{t}"),
                &[
                    In::Host(&Tensor::scalar_i32(tok)),
                    In::Host(&Tensor::scalar_i32(n as i32)),
                    In::Host(&kc),
                    In::Host(&vc),
                ],
            )
            .unwrap();
        kv.ensure_append_slot(1).unwrap();
        let rows = [PagedDecodeRow { seq: 1, token: tok, pos: n, clusters: None, relay: None }];
        let dgot = be.decode_paged(&rows, &mut kv).unwrap();
        assert_eq!(
            bits(&douts[0].to_tensor().unwrap()),
            bits(&dgot[0]),
            "paged decode logits"
        );
        kv.append_committed(1, tok).unwrap();
    }

    #[test]
    fn paged_chai_matches_bucket_artifacts_bitwise() {
        use crate::kv::paged::KvLayout;
        use crate::kv::CacheKind;
        let be = RefBackend::toy(6);
        let m = be.manifest().clone();
        let t = m.decode_buckets[0];
        let (l, h, k_max) = (m.model.n_layers, m.model.n_heads, m.k_max);
        let (mem, reps) = m.static_clusters().unwrap();
        let cl = ClusterAssignment { membership: mem.clone(), reps: reps.clone() };
        let layout = KvLayout::from_manifest(&m, CacheKind::Chai);
        let mut kv = PagedKv::new(8, 1 << 24);
        let tokens: Vec<i32> = vec![66, 67, 68, 69, 70, 71, 72];
        let n = tokens.len();
        kv.admit(9, layout, "chai-static", true, &tokens).unwrap();

        let mut mv = Vec::new();
        for row in &mem {
            mv.extend(row.iter().map(|x| *x as i32));
        }
        let mut rv = vec![0i32; l * k_max];
        for (li, row) in reps.iter().enumerate() {
            for (j, r) in row.iter().enumerate() {
                rv[li * k_max + j] = *r as i32;
            }
        }
        let mt = Tensor::i32(vec![l, h], mv);
        let rt_ = Tensor::i32(vec![l, k_max], rv);
        let mut padded = vec![258i32; t];
        padded[..n].copy_from_slice(&tokens);
        let toks = Tensor::i32(vec![t], padded);
        let ln = Tensor::scalar_i32(n as i32);
        let outs = be
            .run(
                &format!("prefill_chai_t{t}"),
                &[In::Host(&toks), In::Host(&ln), In::Host(&mt), In::Host(&rt_)],
            )
            .unwrap();
        let want = outs[0].to_tensor().unwrap();
        let got = be.prefill_paged(9, 0, Some(&cl), &mut kv).unwrap();
        assert_eq!(bits(&want), bits(&got), "paged CHAI prefill logits");
        kv.commit_prefill(9).unwrap();

        // one CHAI decode step
        let kreps: Vec<Tensor> = (1..=l).map(|i| outs[i].to_tensor().unwrap()).collect();
        let vc = outs[l + 1].to_tensor().unwrap();
        let tok_t = Tensor::scalar_i32(80);
        let pos_t = Tensor::scalar_i32(n as i32);
        let mut ins: Vec<In> = vec![In::Host(&tok_t), In::Host(&pos_t)];
        for kr in &kreps {
            ins.push(In::Host(kr));
        }
        ins.push(In::Host(&vc));
        ins.push(In::Host(&mt));
        ins.push(In::Host(&rt_));
        let douts = be.run(&format!("decode_chai_t{t}"), &ins).unwrap();
        kv.ensure_append_slot(9).unwrap();
        let rows =
            [PagedDecodeRow { seq: 9, token: 80, pos: n, clusters: Some(&cl), relay: None }];
        let dgot = be.decode_paged(&rows, &mut kv).unwrap();
        assert_eq!(
            bits(&douts[0].to_tensor().unwrap()),
            bits(&dgot[0]),
            "paged CHAI decode logits"
        );
    }

    #[test]
    fn prefill_paged_skips_adopted_prefix() {
        use crate::kv::paged::KvLayout;
        use crate::kv::CacheKind;
        let be = RefBackend::toy(7);
        let m = be.manifest().clone();
        let layout = KvLayout::from_manifest(&m, CacheKind::Mha);
        let mut kv = PagedKv::new(4, 1 << 24);
        let tokens: Vec<i32> = (40..50).collect(); // 2 full blocks + tail 2
        kv.admit(1, layout.clone(), "mha", true, &tokens).unwrap();
        let full = be.prefill_paged(1, 0, None, &mut kv).unwrap();
        kv.commit_prefill(1).unwrap();

        // identical prompt adopts everything: logits-only pass (start == len)
        kv.admit(2, layout.clone(), "mha", true, &tokens).unwrap();
        let start = kv.adopted_prefix_len(2).unwrap();
        assert_eq!(start, tokens.len());
        let skipped = be.prefill_paged(2, start, None, &mut kv).unwrap();
        assert_eq!(bits(&full), bits(&skipped), "fully-adopted prefill logits");
        kv.commit_prefill(2).unwrap();

        // divergent suffix: only the shared leading block is skipped
        let mut other = tokens.clone();
        other[5] = 99; // diverges inside block 1
        kv.admit(3, layout, "mha", true, &other).unwrap();
        let start = kv.adopted_prefix_len(3).unwrap();
        assert_eq!(start, 4, "one leading block adopted");
        let suffix = be.prefill_paged(3, start, None, &mut kv).unwrap();
        kv.commit_prefill(3).unwrap();
        // oracle: the same divergent prompt prefilled from scratch
        let mut kv2 = PagedKv::new(4, 1 << 24);
        kv2.admit(7, KvLayout::from_manifest(&m, CacheKind::Mha), "mha", true, &other)
            .unwrap();
        let scratch = be.prefill_paged(7, 0, None, &mut kv2).unwrap();
        assert_eq!(bits(&scratch), bits(&suffix), "prefix-suffix == full prefill");
    }

    #[test]
    fn toy_manifest_is_self_consistent() {
        let be = RefBackend::toy(0);
        let m = be.manifest.clone();
        assert_eq!(m.k_list.len(), m.model.n_layers);
        assert!(m.artifacts.contains_key("logprob_mha"));
        assert!(m.artifacts.contains_key("decode_chai_t32"));
        let (mem, reps) = m.static_clusters().unwrap();
        assert_eq!(mem.len(), m.model.n_layers);
        for li in 0..m.model.n_layers {
            assert_eq!(reps[li].len(), m.k_list[li]);
            assert!(mem[li].iter().all(|&x| x < m.k_list[li]));
            for (j, &r) in reps[li].iter().enumerate() {
                assert_eq!(mem[li][r], j, "rep must sit in its own cluster");
            }
        }
        let a = m.artifact("logprob_dejavu_s50").unwrap();
        assert_eq!(a.meta.get("n_keep").unwrap().usize().unwrap(), 2);
    }
}
