//! Shared bench plumbing: artifacts discovery, JSON result emission
//! (criterion is not vendored offline; each bench is a `harness = false`
//! binary printing paper-style tables and writing
//! `bench_results/<name>.json` for EXPERIMENTS.md).

use std::path::PathBuf;

use chai::config::ServingConfig;
use chai::util::args::Args;
use chai::util::json::Json;

pub fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str("artifacts", "artifacts"))
}

pub fn opt_artifacts_dir(args: &Args) -> Option<PathBuf> {
    let d = PathBuf::from(args.str("artifacts-opt", "artifacts-opt"));
    d.join("manifest.json").exists().then_some(d)
}

pub fn bench_args() -> Args {
    // cargo bench passes a trailing "--bench" flag; Args tolerates it.
    Args::from_env()
}

pub fn write_results(name: &str, value: Json) {
    let dir = PathBuf::from("bench_results");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("{name}.json"));
    if let Err(e) = std::fs::write(&path, value.to_string()) {
        eprintln!("[bench] could not write {}: {e}", path.display());
    } else {
        eprintln!("[bench] wrote {}", path.display());
    }
}

/// Skip gracefully when artifacts are missing (fresh checkout).
pub fn require_artifacts(args: &Args) -> Option<PathBuf> {
    let d = artifacts_dir(args);
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("[bench] artifacts missing — run `make artifacts` first; skipping");
        None
    }
}

/// Backend-aware serving config: honors `--backend ref|xla|auto`. The
/// reference backend runs without artifacts (seeded toy model), so
/// `--backend ref` un-gates a bench on a fresh checkout; otherwise the
/// artifacts requirement applies as before.
#[allow(dead_code)] // each bench binary compiles its own copy of this module
pub fn serving_config(args: &Args) -> Option<ServingConfig> {
    let d = artifacts_dir(args);
    let backend = args.str("backend", "auto");
    if backend != "ref" && !d.join("manifest.json").exists() {
        eprintln!(
            "[bench] artifacts missing — run `make artifacts` or pass --backend ref; skipping"
        );
        return None;
    }
    Some(ServingConfig {
        artifacts_dir: d,
        backend,
        batched_decode: !args.bool("no-batched-decode"),
        ..Default::default()
    })
}
